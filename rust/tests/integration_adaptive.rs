//! End-to-end tests for the adaptive scheduling subsystem over the
//! scaling-aware simulated runner (no PJRT artifacts needed — always
//! runs). Pins the PR's acceptance criteria:
//!
//! - a running part exceeding `--deadline-running-ms` is **cancelled by
//!   the dispatcher** and its cores reclaimed (proactive enforcement —
//!   no caller involvement);
//! - the dispatcher's effective aging bound **recalibrates** from
//!   observed part latency when an adaptive policy is attached;
//! - with adaptive (profiled) core sizing, the fig-8 long/short
//!   misleading-size workload sees **>= 10% better p95** than the
//!   static size-proportional split.

use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

use dnc_serve::bar::{by_name, run_cell, Mode, Scenario};
use dnc_serve::bench::gate::{sim_model, SimRunner};
use dnc_serve::engine::{
    AdaptiveConfig, AdaptivePolicy, CoreMap, PartTask, ProfileStore, SchedConfig,
    SchedError, Scheduler,
};

fn sim_sched(cfg: SchedConfig) -> Arc<Scheduler> {
    Scheduler::start(cfg, Arc::new(SimRunner { workers: 2 }))
}

#[test]
fn running_part_past_deadline_is_cancelled_and_cores_reclaimed() {
    let sched = sim_sched(SchedConfig {
        cores: CoreMap::homogeneous(4),
        deadline_running: Some(Duration::from_millis(50)),
        ..Default::default()
    });
    // A part that would run ~500ms single-thread: the dispatcher must
    // cancel it near the 50ms budget without anyone calling cancel().
    let t0 = Instant::now();
    let doomed = sched.submit(PartTask::new(sim_model(500.0), Vec::new(), 4));
    let err = doomed.wait().unwrap_err();
    assert_eq!(
        err.downcast_ref::<SchedError>(),
        Some(&SchedError::Cancelled),
        "running-deadline enforcement surfaces as Cancelled: {err:#}"
    );
    assert!(
        t0.elapsed() < Duration::from_millis(300),
        "enforcement must interrupt execution: {:?}",
        t0.elapsed()
    );
    // The reclaimed cores immediately serve new work.
    let quick = sched.submit(PartTask::new(sim_model(2.0), Vec::new(), 4));
    quick.wait().expect("reclaimed cores must serve the next task");
    assert!(sched.drain(Duration::from_secs(5)));
    let st = sched.stats();
    assert_eq!(st.running_deadline_cancelled, 1, "{st:?}");
    assert_eq!(st.cancelled, 1, "{st:?}");
    assert_eq!(st.completed, 1, "{st:?}");
    assert_eq!(st.cores_busy, 0, "cores must be reclaimed: {st:?}");
    assert_eq!(st.inflight, 0, "{st:?}");
    assert_eq!(
        st.submitted,
        st.completed + st.failed + st.deadline_rejected + st.cancelled,
        "accounting must balance: {st:?}"
    );
}

#[test]
fn adaptive_aging_recalibrates_from_observed_latency() {
    // Profiles observed at ~30ms; aging_factor 2 -> the dispatcher must
    // derive an effective aging bound of ~60ms, replacing the 50ms
    // static default (visible in stats as aging_effective_ms).
    let profiles = Arc::new(ProfileStore::new());
    for _ in 0..10 {
        profiles.observe("m", Duration::from_millis(30));
    }
    let policy = Arc::new(AdaptivePolicy::new(
        Arc::clone(&profiles),
        AdaptiveConfig {
            recalibrate_every: Duration::from_millis(1),
            aging_factor: 2.0,
            min_aging: Duration::from_millis(5),
            max_aging: Duration::from_millis(1000),
        },
    ));
    let sched = Scheduler::start(
        SchedConfig { adaptive: Some(policy), ..SchedConfig::default() },
        Arc::new(SimRunner { workers: 2 }),
    );
    assert!(
        (sched.stats().aging_effective_ms - 50.0).abs() < 1.0,
        "before any event the static bound holds: {:?}",
        sched.stats().aging_effective_ms
    );
    // Any dispatcher activity past recalibrate_every re-derives it.
    std::thread::sleep(Duration::from_millis(5));
    sched
        .submit(PartTask::new(sim_model(2.0), Vec::new(), 1))
        .wait()
        .unwrap();
    assert!(sched.drain(Duration::from_secs(5)));
    let eff = sched.stats().aging_effective_ms;
    assert!(
        (eff - 60.0).abs() < 5.0,
        "aging bound must track 2 * observed p95 (~60ms), got {eff}"
    );
}

#[test]
fn adaptive_beats_static_p95_on_misleading_sizes() {
    // Small-scale pin of the bench acceptance bar over the checked-in
    // barometer scenario (the full-size run lives in
    // benches/adaptive_vs_static.rs; CI enforces it via bench-bar).
    let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("bench/scenarios/longshort.toml");
    let text = std::fs::read_to_string(&path).expect("longshort scenario file");
    let mut sc = Scenario::parse(&text).expect("longshort scenario parses");
    sc.arrival.submitters = 1;
    sc.arrival.quick_jobs = 8;
    let stat = run_cell(&sc, by_name("static").unwrap(), Mode::Quick).expect("static cell");
    let adap = run_cell(&sc, by_name("adaptive").unwrap(), Mode::Quick).expect("adaptive cell");
    assert!(
        adap.p95_ms <= 0.9 * stat.p95_ms,
        "adaptive p95 {:.2} ms must be >=10% better than static {:.2} ms",
        adap.p95_ms,
        stat.p95_ms
    );
}

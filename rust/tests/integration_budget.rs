//! End-to-end request-budget and ctx propagation over a mock scheduler
//! — no PJRT artifacts needed, so these always run. They pin:
//!
//! 1. a request whose budget expires *while queued in the batcher* is
//!    reaped at flush time with the typed `BudgetExpired` reply and
//!    **never reaches the scheduler** (`submitted` stays 0);
//! 2. a request with total budget `T` that spends `w` ms accumulating
//!    in the batcher gets a part running window of at most `T - w`: the
//!    dispatcher kills the part at the budget's absolute deadline
//!    (`T` from mint), not `w + deadline_running` — asserted against a
//!    stall runner whose nominal execution is far longer than any
//!    budget, with the kill attributed to the budget source;
//! 3. **ctx propagation**: every layer (batcher flush-time admission,
//!    scheduler task, executor worker) observes the *same*
//!    `CancelToken` identity and `Budget` value minted at the ingress
//!    — not lookalikes;
//! 4. **cancel-at-any-layer frees cores exactly once**: whichever layer
//!    the cancel lands in (before flush, while queued, while running),
//!    the request reaches exactly one terminal counter and the ledger
//!    returns to empty.
//!
//! The stack mirrors `ServerState::new` exactly: a pipelined batcher
//! with the router's admission shape, a submitter stamping one
//! scheduler task per request from the request's `RequestCtx`.

mod common;

use std::sync::Arc;
use std::time::{Duration, Instant};

use dnc_serve::coordinator::{Batcher, EmbedRequest};
use dnc_serve::engine::{Scheduler, SubmitError};
use dnc_serve::util::prop::check;

/// The router's embed pipeline with budgets over the shared stalling
/// mock stack (`tests/common`): flush-time admission plus a submitter
/// that stamps each request's ctx onto its scheduler task (what
/// `ServerState::new` builds over `InferenceService::submit`).
fn budgeted_embed_stack(
    max_wait: Duration,
) -> (Arc<Scheduler>, Batcher<EmbedRequest, Result<Vec<f32>, SubmitError>>) {
    common::embed_stack(4, 2, 16, max_wait, true)
}

#[test]
fn budget_dead_in_batcher_never_reaches_the_scheduler() {
    // The batcher accumulates for 80ms; the request only has 10ms of
    // budget. At flush time the admission closure must settle it with
    // the typed error — nothing is ever submitted to the scheduler.
    let (sched, batcher) = budgeted_embed_stack(Duration::from_millis(80));
    let (req, _ctx) = common::embed_request(vec![1, 2], Duration::from_millis(10));
    let rx = batcher.submit(req);
    let reply = rx.recv_timeout(Duration::from_secs(5)).expect("admission must reply");
    let e = reply.expect_err("expired request must be rejected");
    assert_eq!(e, SubmitError::BudgetExpired, "want the typed rejection, got: {e}");
    // the Display form keeps the wire vocabulary the clients key on
    assert!(e.to_string().contains("deadline_rejected"), "{e}");
    // give any (buggy) submission a moment to land, then check
    std::thread::sleep(Duration::from_millis(20));
    let st = sched.stats();
    assert_eq!(st.submitted, 0, "expired request reached the scheduler: {st:?}");
    assert_eq!(st.cores_busy, 0, "{st:?}");
}

#[test]
fn fresh_requests_still_flow_through() {
    // Sanity for the same stack: a request with plenty of budget is
    // submitted (and, on this stall runner, killed at its own deadline
    // rather than running the nominal 10s).
    let (sched, batcher) = budgeted_embed_stack(Duration::from_millis(5));
    let (req, _ctx) = common::embed_request(vec![1, 2], Duration::from_millis(150));
    let rx = batcher.submit(req);
    let reply = rx.recv_timeout(Duration::from_secs(5)).expect("reply must arrive");
    assert!(reply.is_err(), "stall runner can only end by budget kill");
    let st = sched.stats();
    assert_eq!(st.submitted, 1, "fresh request must be submitted: {st:?}");
}

#[test]
fn part_running_window_is_the_remaining_budget() {
    // Total budget T = 400ms, of which w ≈ 150ms is burned accumulating
    // in the batcher. The part launches with ~250ms left and the
    // dispatcher must kill it at T from mint — NOT at launch + 400ms,
    // and certainly not never (the stall runner nominally runs 10s).
    let total = Duration::from_millis(400);
    let w = Duration::from_millis(150);
    let (sched, batcher) = budgeted_embed_stack(w);
    let t0 = Instant::now();
    let (req, _ctx) = common::embed_request(vec![1, 2, 3], total);
    let rx = batcher.submit(req);
    let reply = rx.recv_timeout(Duration::from_secs(5)).expect("kill must reply");
    let waited = t0.elapsed();
    let e = reply.expect_err("budget kill must surface as an error");
    assert_eq!(e, SubmitError::Cancelled, "want the typed kill, got: {e}");
    // launched only after the batcher wait...
    assert!(
        waited >= w,
        "reply before the batch even flushed: {waited:?} < {w:?}"
    );
    // ...and killed at the *request's* deadline: T from mint plus sweep
    // and scheduling slack — which implies the part's running window
    // was at most T - w (+ slack), i.e. the budget charged the batcher
    // wait instead of granting a fresh allowance at launch.
    assert!(
        waited < total + Duration::from_millis(250),
        "kill came later than the request's own deadline: {waited:?}"
    );
    // attribution: an enforcement kill, from the budget source
    let t1 = Instant::now();
    while sched.stats().running_deadline_cancelled_budget != 1
        && t1.elapsed() < Duration::from_secs(5)
    {
        std::thread::sleep(Duration::from_millis(1));
    }
    assert!(sched.drain(Duration::from_secs(5)), "{:?}", sched.stats());
    let st = sched.stats();
    assert_eq!(st.running_deadline_cancelled_budget, 1, "{st:?}");
    assert_eq!(st.running_deadline_cancelled, 1, "{st:?}");
    assert_eq!(st.cancelled, 1, "{st:?}");
    assert_eq!(st.budget_expired, 0, "the part launched in time: {st:?}");
    assert_eq!(st.cores_busy, 0, "cores must return after the kill: {st:?}");
    assert_eq!(
        st.submitted,
        st.completed
            + st.failed
            + st.deadline_rejected
            + st.budget_expired
            + st.budget_infeasible
            + st.cancelled,
        "accounting invariant: {st:?}"
    );
}

#[test]
fn every_layer_observes_the_ingress_ctx_identity() {
    // Satellite criterion (ctx propagation): the token the batcher's
    // admission sees, the token stamped onto the scheduler task, and
    // the token handed to the executor worker must all share the flag
    // minted at the ingress — and the Budget value must be the same
    // account (same issued_at, same total), not one re-minted downstream.
    let (sched, batcher, probe, seen_tokens) =
        common::embed_stack_probed(4, 2, 16, Duration::from_millis(5), true);
    let (req, ctx) = common::embed_request(vec![1, 2], Duration::from_millis(200));
    let minted_token = ctx.token();
    let minted_budget = ctx.budget();
    let rx = batcher.submit(req);
    let reply = rx.recv_timeout(Duration::from_secs(5)).expect("reply must arrive");
    assert!(reply.is_err(), "stall runner ends by budget kill");

    let admitted = probe.admission.lock().unwrap();
    assert_eq!(admitted.len(), 1, "one flush-time admission check");
    assert!(
        admitted[0].0.same_flag(&minted_token),
        "batcher admission must see the ingress token, not a copy"
    );
    assert_eq!(admitted[0].1, minted_budget, "batcher must see the ingress budget");

    let submitted = probe.submitted.lock().unwrap();
    assert_eq!(submitted.len(), 1, "one scheduler task");
    assert!(
        submitted[0].0.same_flag(&minted_token),
        "the PartTask must carry the ingress token"
    );
    assert_eq!(submitted[0].1, minted_budget, "the PartTask must carry the ingress budget");

    let seen = seen_tokens.lock().unwrap();
    assert_eq!(seen.len(), 1, "one executor dispatch");
    assert!(
        seen[0].same_flag(&minted_token),
        "the executor must poll the ingress token"
    );
    drop(seen);
    assert!(sched.drain(Duration::from_secs(5)), "{:?}", sched.stats());
}

#[test]
fn cancel_at_any_layer_frees_cores_exactly_once() {
    // Satellite criterion: wherever the cancel lands — before the
    // batcher flush, while the task queues behind a hog, or mid-run on
    // the executor — the request must settle exactly one terminal
    // counter, its handle must resolve, and the ledger must return to
    // empty. No double-count, no leak.
    check(3, |g| {
        #[derive(Clone, Copy, PartialEq, Debug)]
        enum Layer {
            BeforeFlush,
            WhileQueued,
            WhileRunning,
        }
        let layer = *g.choice(&[Layer::BeforeFlush, Layer::WhileQueued, Layer::WhileRunning]);
        // capacity 2, 2 threads/task: a hog saturates the ledger, so a
        // second task queues behind it
        let (sched, batcher) = common::embed_stack(2, 2, 16, Duration::from_millis(1), true);

        // For WhileQueued: first occupy the cores with a long-budget hog.
        let hog = if layer == Layer::WhileQueued {
            let (req, hog_ctx) = common::embed_request(vec![9], Duration::from_secs(600));
            let rx = batcher.submit(req);
            let t0 = Instant::now();
            while sched.stats().cores_busy != 2 && t0.elapsed() < Duration::from_secs(5) {
                std::thread::sleep(Duration::from_millis(1));
            }
            assert_eq!(sched.stats().cores_busy, 2, "hog never started");
            Some((rx, hog_ctx))
        } else {
            None
        };

        let (req, ctx) = common::embed_request(vec![1, 2], Duration::from_secs(600));
        match layer {
            // cancelled before the batcher even flushes (but the flush
            // interval is 1ms, so this races flush-vs-cancel — both
            // outcomes are valid, which is exactly the point: exactly
            // one terminal accounting either way)
            Layer::BeforeFlush => ctx.cancel(),
            _ => {}
        }
        let rx = batcher.submit(req);
        match layer {
            Layer::BeforeFlush => {}
            Layer::WhileQueued => {
                // flushed + submitted, but stuck behind the hog: give
                // the flusher a moment, then cancel the queued task
                let t0 = Instant::now();
                while sched.stats().queue_depth != 1 && t0.elapsed() < Duration::from_secs(5)
                {
                    std::thread::sleep(Duration::from_millis(1));
                }
                ctx.cancel();
            }
            Layer::WhileRunning => {
                let t0 = Instant::now();
                while sched.stats().inflight != 1 && t0.elapsed() < Duration::from_secs(5) {
                    std::thread::sleep(Duration::from_millis(1));
                }
                assert_eq!(sched.stats().inflight, 1, "task never launched");
                ctx.cancel();
            }
        }
        let reply = rx.recv_timeout(Duration::from_secs(5)).expect("handle must settle");
        let e = reply.expect_err("cancelled request must error");
        assert_eq!(e, SubmitError::Cancelled, "layer {layer:?}: {e}");

        // release the hog (if any) and require full quiescence
        if let Some((hog_rx, hog_ctx)) = hog {
            hog_ctx.cancel();
            let _ = hog_rx.recv_timeout(Duration::from_secs(5));
        }
        assert!(sched.drain(Duration::from_secs(5)), "{:?}", sched.stats());
        let st = sched.stats();
        assert_eq!(st.cores_busy, 0, "layer {layer:?} leaked cores: {st:?}");
        assert_eq!(st.inflight, 0, "{st:?}");
        assert_eq!(st.queue_depth, 0, "{st:?}");
        // exactly-once: every submitted task reaches exactly one
        // terminal counter (cancel before flush may mean 0 submitted)
        assert_eq!(
            st.submitted,
            st.completed
                + st.failed
                + st.deadline_rejected
                + st.budget_expired
                + st.budget_infeasible
                + st.cancelled,
            "layer {layer:?} broke the accounting invariant: {st:?}"
        );
        match layer {
            Layer::BeforeFlush => {
                // reaped at flush (0 submitted) or cancelled in the
                // scheduler (1 submitted, 1 cancelled) — never both
                assert!(st.submitted <= 1, "{st:?}");
                assert_eq!(st.cancelled, st.submitted, "{st:?}");
            }
            Layer::WhileQueued | Layer::WhileRunning => {
                assert_eq!(st.cancelled, 2 - u64::from(layer == Layer::WhileRunning), "{st:?}");
            }
        }
    });
}

//! End-to-end request-budget propagation over a mock scheduler — no
//! PJRT artifacts needed, so these always run. They pin the PR's
//! acceptance criteria:
//!
//! 1. a request whose budget expires *while queued in the batcher* is
//!    reaped at flush time with a structured `deadline_rejected` reply
//!    and **never reaches the scheduler** (`submitted` stays 0);
//! 2. a request with total budget `T` that spends `w` ms accumulating
//!    in the batcher gets a part running window of at most `T - w`: the
//!    dispatcher kills the part at the budget's absolute deadline
//!    (`T` from mint), not `w + deadline_running` — asserted against a
//!    stall runner whose nominal execution is far longer than any
//!    budget, with the kill attributed to the budget source.
//!
//! The stack mirrors `ServerState::new` exactly: a pipelined batcher
//! with the router's reaper shape, a submitter tagging one scheduler
//! task per request with the request's token *and* budget.

mod common;

use std::sync::Arc;
use std::time::{Duration, Instant};

use dnc_serve::coordinator::{Batcher, EmbedRequest};
use dnc_serve::engine::{Budget, Scheduler};
use dnc_serve::runtime::CancelToken;

/// The router's embed pipeline with budgets over the shared stalling
/// mock stack (`tests/common`): flush-time reaper plus a submitter that
/// stamps each request's budget onto its scheduler task (what
/// `ServerState::new` builds over `serve_submit_budgeted`).
fn budgeted_embed_stack(
    max_wait: Duration,
) -> (Arc<Scheduler>, Batcher<EmbedRequest, Result<Vec<f32>, String>>) {
    common::embed_stack(4, 2, 16, max_wait, true)
}

#[test]
fn budget_dead_in_batcher_never_reaches_the_scheduler() {
    // The batcher accumulates for 80ms; the request only has 10ms of
    // budget. At flush time the reaper must settle it structurally —
    // nothing is ever submitted to the scheduler.
    let (sched, batcher) = budgeted_embed_stack(Duration::from_millis(80));
    let rx = batcher.submit(EmbedRequest {
        ids: vec![1, 2],
        cancel: CancelToken::new(),
        budget: Budget::new(Duration::from_millis(10)),
    });
    let reply = rx.recv_timeout(Duration::from_secs(5)).expect("reaper must reply");
    let e = reply.expect_err("expired request must be rejected");
    assert!(
        e.contains("deadline_rejected"),
        "want the structured deadline_rejected reply, got: {e}"
    );
    // give any (buggy) submission a moment to land, then check
    std::thread::sleep(Duration::from_millis(20));
    let st = sched.stats();
    assert_eq!(st.submitted, 0, "expired request reached the scheduler: {st:?}");
    assert_eq!(st.cores_busy, 0, "{st:?}");
}

#[test]
fn fresh_requests_still_flow_through() {
    // Sanity for the same stack: a request with plenty of budget is
    // submitted (and, on this stall runner, killed at its own deadline
    // rather than running the nominal 10s).
    let (sched, batcher) = budgeted_embed_stack(Duration::from_millis(5));
    let rx = batcher.submit(EmbedRequest {
        ids: vec![1, 2],
        cancel: CancelToken::new(),
        budget: Budget::new(Duration::from_millis(150)),
    });
    let reply = rx.recv_timeout(Duration::from_secs(5)).expect("reply must arrive");
    assert!(reply.is_err(), "stall runner can only end by budget kill");
    let st = sched.stats();
    assert_eq!(st.submitted, 1, "fresh request must be submitted: {st:?}");
}

#[test]
fn part_running_window_is_the_remaining_budget() {
    // Total budget T = 400ms, of which w ≈ 150ms is burned accumulating
    // in the batcher. The part launches with ~250ms left and the
    // dispatcher must kill it at T from mint — NOT at launch + 400ms,
    // and certainly not never (the stall runner nominally runs 10s).
    let total = Duration::from_millis(400);
    let w = Duration::from_millis(150);
    let (sched, batcher) = budgeted_embed_stack(w);
    let t0 = Instant::now();
    let rx = batcher.submit(EmbedRequest {
        ids: vec![1, 2, 3],
        cancel: CancelToken::new(),
        budget: Budget::new(total),
    });
    let reply = rx.recv_timeout(Duration::from_secs(5)).expect("kill must reply");
    let waited = t0.elapsed();
    let e = reply.expect_err("budget kill must surface as an error");
    assert!(e.contains("cancelled"), "want the typed kill, got: {e}");
    // launched only after the batcher wait...
    assert!(
        waited >= w,
        "reply before the batch even flushed: {waited:?} < {w:?}"
    );
    // ...and killed at the *request's* deadline: T from mint plus sweep
    // and scheduling slack — which implies the part's running window
    // was at most T - w (+ slack), i.e. the budget charged the batcher
    // wait instead of granting a fresh allowance at launch.
    assert!(
        waited < total + Duration::from_millis(250),
        "kill came later than the request's own deadline: {waited:?}"
    );
    // attribution: an enforcement kill, from the budget source
    let t1 = Instant::now();
    while sched.stats().running_deadline_cancelled_budget != 1
        && t1.elapsed() < Duration::from_secs(5)
    {
        std::thread::sleep(Duration::from_millis(1));
    }
    assert!(sched.drain(Duration::from_secs(5)), "{:?}", sched.stats());
    let st = sched.stats();
    assert_eq!(st.running_deadline_cancelled_budget, 1, "{st:?}");
    assert_eq!(st.running_deadline_cancelled, 1, "{st:?}");
    assert_eq!(st.cancelled, 1, "{st:?}");
    assert_eq!(st.budget_expired, 0, "the part launched in time: {st:?}");
    assert_eq!(st.cores_busy, 0, "cores must return after the kill: {st:?}");
    assert_eq!(
        st.submitted,
        st.completed + st.failed + st.deadline_rejected + st.budget_expired + st.cancelled,
        "accounting invariant: {st:?}"
    );
}

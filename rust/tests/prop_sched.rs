//! Scheduler property tests over a mock runner — no PJRT artifacts
//! needed, so these always run. They pin the acceptance criteria for
//! `engine::sched`:
//!
//! - the core ledger **never oversubscribes** the budget, under random
//!   part sizes/priorities and concurrent submitters;
//! - **every** submitted task completes (or is deadline-rejected,
//!   budget-expired or cancelled) and the accounting invariant
//!   `submitted == completed + failed + deadline_rejected +
//!   budget_expired + cancelled` holds at quiescence;
//! - a large part is **never starved** past the aging bound by a stream
//!   of backfilled small parts;
//! - a **cancelled-while-queued task never reaches an executor worker**
//!   and a cancelled-while-running task releases its cores at the next
//!   cooperative poll — cancellation never leaks ledger cores;
//! - **adaptive core sizing never exceeds the Listing-1 budget** `C`,
//!   for any profiled latency distribution;
//! - the accounting invariant still balances when the dispatcher's
//!   **running-deadline enforcer** cancels in-flight tasks;
//! - the adaptive **aging bound monotonically tracks** injected latency
//!   shifts (within its clamp);
//! - the invariant still balances with **request-budget expiry** in the
//!   mix: born-expired budgets are rejected without ever reaching a
//!   worker, queued-past-budget tasks land in `budget_expired`, and
//!   mid-run budget kills land in `cancelled` (+ the
//!   `running_deadline_cancelled_budget` split);
//! - **budget-aware admission** (`RequestCtx` cost hints) lands
//!   infeasible tasks in `budget_infeasible` — never a queue slot,
//!   never a worker — and the invariant, now `submitted == completed +
//!   failed + deadline_rejected + budget_expired + budget_infeasible +
//!   cancelled`, still balances;
//! - on a heterogeneous [`CoreMap`], **no shard's per-class ledger
//!   slice is ever oversubscribed** — even with work stealing active
//!   under mixed-affinity load;
//! - when the Fast class is exhausted, `Prefer(Fast)` work **degrades
//!   to Slow** (counted in `class_degraded`) instead of deadlocking or
//!   being rejected.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use dnc_serve::engine::{
    allocate, AdaptiveConfig, AdaptivePolicy, AllocPolicy, Budget, ClassAffinity,
    CoreClass, CoreGrant, CoreMap, PartTask, PartWeights, Priority, ProfileStore,
    SchedConfig, SchedError, Scheduler, TaskRunner,
};
use dnc_serve::runtime::{CancelToken, ExecResult, ReplyFn, TaskCancelled, Tensor};
use dnc_serve::util::prop::check;

/// Executes tasks on short sleeper threads while tracking virtual-core
/// occupancy via the ledger-granted `threads` argument. The model name
/// encodes the sleep as `"t<threads>-s<sleep_ms>"` (the `t` segment is
/// kept for log readability). Cooperative: a task whose token is
/// cancelled before it starts is skipped (never counted as a run), and
/// the token is polled once per simulated millisecond while "executing".
struct TrackingRunner {
    workers: usize,
    probe: Probe,
}

/// Shared observation points into the mock runner.
#[derive(Clone)]
struct Probe {
    /// virtual cores currently occupied by running tasks
    active: Arc<AtomicUsize>,
    /// peak concurrent occupancy ever observed
    peak: Arc<AtomicUsize>,
    /// tasks that actually began executing on a worker
    runs: Arc<AtomicUsize>,
}

impl Probe {
    fn new() -> Probe {
        Probe {
            active: Arc::new(AtomicUsize::new(0)),
            peak: Arc::new(AtomicUsize::new(0)),
            runs: Arc::new(AtomicUsize::new(0)),
        }
    }
}

fn model_name(threads: usize, sleep_ms: u64) -> String {
    format!("t{threads}-s{sleep_ms}")
}

fn parse_sleep(model: &str) -> u64 {
    let (_, s) = model.split_once("-s").expect("mock model name");
    s.parse().unwrap()
}

impl TaskRunner for TrackingRunner {
    fn workers(&self) -> usize {
        self.workers
    }

    fn run_on(
        &self,
        worker: usize,
        model: &str,
        _inputs: Vec<Tensor>,
        grant: CoreGrant,
        cancel: CancelToken,
        reply: ReplyFn,
    ) {
        let sleep_ms = parse_sleep(model);
        let threads = grant.threads;
        let probe = self.probe.clone();
        std::thread::spawn(move || {
            if cancel.is_cancelled() {
                // skipped before execution: not a run, no occupancy
                reply(Err(anyhow::Error::new(TaskCancelled)));
                return;
            }
            probe.runs.fetch_add(1, Ordering::SeqCst);
            let now = probe.active.fetch_add(threads, Ordering::SeqCst) + threads;
            probe.peak.fetch_max(now, Ordering::SeqCst);
            let mut aborted = false;
            for _ in 0..sleep_ms {
                std::thread::sleep(Duration::from_millis(1));
                if cancel.is_cancelled() {
                    aborted = true;
                    break;
                }
            }
            probe.active.fetch_sub(threads, Ordering::SeqCst);
            if aborted {
                reply(Err(anyhow::Error::new(TaskCancelled)));
            } else {
                reply(Ok(ExecResult {
                    outputs: Vec::new(),
                    exec_time: Duration::from_millis(sleep_ms),
                    worker,
                }));
            }
        });
    }
}

fn tracking_sched(cfg: SchedConfig) -> (Arc<Scheduler>, Probe) {
    let probe = Probe::new();
    let runner = TrackingRunner { workers: 4, probe: probe.clone() };
    (Scheduler::start(cfg, Arc::new(runner)), probe)
}

/// The accounting invariant every quiescent scheduler must satisfy.
fn assert_accounting_balanced(sched: &Scheduler) {
    assert!(sched.drain(Duration::from_secs(5)), "drain timed out");
    let st = sched.stats();
    assert_eq!(st.queue_depth, 0);
    assert_eq!(st.inflight, 0);
    assert_eq!(st.cores_busy, 0, "ledger must return to empty: {st:?}");
    assert_eq!(
        st.submitted,
        st.completed
            + st.failed
            + st.deadline_rejected
            + st.budget_expired
            + st.budget_infeasible
            + st.cancelled,
        "accounting invariant violated: {st:?}"
    );
}

#[test]
fn never_oversubscribes_and_everything_completes() {
    check(3, |g| {
        let capacity = *g.choice(&[4usize, 8, 16]);
        let (sched, probe) = tracking_sched(SchedConfig {
            cores: CoreMap::homogeneous(capacity),
            aging: Duration::from_millis(10),
            backfill: true,
            ..Default::default()
        });
        let k = g.usize_in(20, 40);
        // random thread asks, deliberately sometimes over capacity
        // (the scheduler must clamp), random priorities, short sleeps
        let tasks: Vec<(usize, usize, u64, Priority)> = (0..k)
            .map(|_| {
                let raw = g.usize_in(1, capacity * 2);
                let clamped = raw.clamp(1, capacity);
                let ms = g.usize_in(1, 4) as u64;
                let prio =
                    *g.choice(&[Priority::Low, Priority::Normal, Priority::High]);
                (raw, clamped, ms, prio)
            })
            .collect();

        // 3 concurrent submitters, each waiting on its own handles
        let mut joins = Vec::new();
        for chunk in tasks.chunks(tasks.len().div_ceil(3)) {
            let chunk = chunk.to_vec();
            let sched = Arc::clone(&sched);
            joins.push(std::thread::spawn(move || {
                let handles: Vec<_> = chunk
                    .iter()
                    .map(|&(raw, clamped, ms, prio)| {
                        let task =
                            PartTask::new(model_name(clamped, ms), Vec::new(), raw)
                                .with_priority(prio);
                        (clamped, sched.submit(task))
                    })
                    .collect();
                for (clamped, h) in handles {
                    let done = h.wait().expect("task must complete");
                    assert_eq!(done.threads, clamped, "scheduler clamp mismatch");
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }

        assert!(
            probe.peak.load(Ordering::SeqCst) <= capacity,
            "oversubscribed: peak {} > capacity {capacity}",
            probe.peak.load(Ordering::SeqCst)
        );
        assert_accounting_balanced(&sched);
        assert_eq!(probe.active.load(Ordering::SeqCst), 0);
        let st = sched.stats();
        assert_eq!(st.completed, k as u64, "every task completes: {st:?}");
        assert_eq!(st.failed, 0);
        assert_eq!(st.deadline_rejected, 0);
        assert_eq!(st.cancelled, 0);
    });
}

#[test]
fn large_part_never_starved_past_aging_bound() {
    // Paper §3.1 semantics under load: a full-budget part queued behind
    // a long occupier must keep running *small* parts via backfill, yet
    // still be admitted once the aging bound passes.
    let capacity = 4;
    let aging = Duration::from_millis(25);
    let (sched, probe) = tracking_sched(SchedConfig {
        cores: CoreMap::homogeneous(capacity),
        aging,
        backfill: true,
        ..Default::default()
    });

    // Occupy one core for 60ms: the 4-core part cannot fit behind it.
    let occupier = sched.submit(PartTask::new(model_name(1, 60), Vec::new(), 1));
    std::thread::sleep(Duration::from_millis(5));
    let t_large = Instant::now();
    let large = sched.submit(PartTask::new(model_name(capacity, 5), Vec::new(), capacity));
    // A stream of small parts arriving behind the large one: strict FIFO
    // would idle 3 cores; backfill must run them — but only until the
    // large part's aging bound expires.
    let smalls: Vec<_> = (0..20)
        .map(|_| sched.submit(PartTask::new(model_name(1, 3), Vec::new(), 1)))
        .collect();

    let done = large.wait().expect("large part must complete");
    let waited = t_large.elapsed();
    assert!(done.threads == capacity);
    assert!(
        waited < Duration::from_millis(500),
        "large part starved: waited {waited:?}"
    );
    for s in smalls {
        s.wait().expect("small part must complete");
    }
    occupier.wait().unwrap();

    assert!(probe.peak.load(Ordering::SeqCst) <= capacity);
    let st = sched.stats();
    assert!(
        st.backfills >= 1,
        "small parts should have backfilled the idle cores: {st:?}"
    );
    assert_eq!(st.completed, 22);
}

#[test]
fn deadline_rejection_is_typed_and_counted() {
    let capacity = 2;
    let (sched, _probe) = tracking_sched(SchedConfig {
        cores: CoreMap::homogeneous(capacity),
        aging: Duration::from_millis(25),
        backfill: true,
        ..Default::default()
    });
    let blocker = sched.submit(PartTask::new(model_name(2, 40), Vec::new(), 2));
    std::thread::sleep(Duration::from_millis(5));
    let doomed = sched.submit(
        PartTask::new(model_name(2, 1), Vec::new(), 2)
            .with_deadline(Instant::now() + Duration::from_millis(5)),
    );
    let err = doomed.wait().unwrap_err();
    assert_eq!(
        err.downcast_ref::<SchedError>(),
        Some(&SchedError::DeadlineExceeded),
        "want typed deadline rejection, got: {err:#}"
    );
    blocker.wait().unwrap();
    let st = sched.stats();
    assert_eq!(st.deadline_rejected, 1);
    assert_eq!(st.completed, 1);
    assert_accounting_balanced(&sched);
}

#[test]
fn backfill_disabled_preserves_strict_fifo() {
    // With backfill off the scheduler degrades to the seed's FIFO lease
    // semantics: a small part queued behind a non-fitting large part
    // waits even though it would fit.
    let capacity = 4;
    let (sched, _probe) = tracking_sched(SchedConfig {
        cores: CoreMap::homogeneous(capacity),
        aging: Duration::from_millis(25),
        backfill: false,
        ..Default::default()
    });
    let occupier = sched.submit(PartTask::new(model_name(1, 30), Vec::new(), 1));
    std::thread::sleep(Duration::from_millis(5));
    let large = sched.submit(PartTask::new(model_name(4, 1), Vec::new(), 4));
    let small = sched.submit(PartTask::new(model_name(1, 1), Vec::new(), 1));
    let large_done = large.wait().unwrap();
    let small_done = small.wait().unwrap();
    occupier.wait().unwrap();
    assert!(
        small_done.queue >= large_done.queue,
        "strict FIFO: small ({:?}) must not bypass large ({:?})",
        small_done.queue,
        large_done.queue
    );
    assert_eq!(sched.stats().backfills, 0);
}

#[test]
fn cancelled_while_queued_never_reaches_a_worker() {
    // Saturate the budget with one long blocker, queue tasks behind it,
    // cancel them: none may ever start on a worker, all must settle
    // with the typed Cancelled error, and the ledger must come back
    // clean — the acceptance criterion for admission-side cancellation.
    let capacity = 2;
    let (sched, probe) = tracking_sched(SchedConfig {
        cores: CoreMap::homogeneous(capacity),
        aging: Duration::from_millis(10),
        backfill: true,
        ..Default::default()
    });
    let blocker = sched.submit(PartTask::new(model_name(2, 40), Vec::new(), 2));
    std::thread::sleep(Duration::from_millis(5)); // blocker admitted
    let queued: Vec<_> = (0..3)
        .map(|_| sched.submit(PartTask::new(model_name(1, 5), Vec::new(), 1)))
        .collect();
    for h in &queued {
        h.cancel();
    }
    for h in queued {
        let err = h.wait().unwrap_err();
        assert_eq!(
            err.downcast_ref::<SchedError>(),
            Some(&SchedError::Cancelled),
            "want typed cancellation, got: {err:#}"
        );
    }
    blocker.wait().unwrap();
    assert_accounting_balanced(&sched);
    assert_eq!(
        probe.runs.load(Ordering::SeqCst),
        1,
        "cancelled queued tasks must never reach a worker"
    );
    let st = sched.stats();
    assert_eq!(st.cancelled, 3);
    assert_eq!(st.completed, 1);
}

#[test]
fn cancelled_while_running_releases_its_cores() {
    // A running task's cancel is cooperative: the mock runner polls the
    // token every simulated millisecond, so the cores must come back
    // long before the task's nominal 300ms duration.
    let capacity = 4;
    let (sched, probe) = tracking_sched(SchedConfig {
        cores: CoreMap::homogeneous(capacity),
        aging: Duration::from_millis(10),
        backfill: true,
        ..Default::default()
    });
    let h = sched.submit(PartTask::new(model_name(4, 300), Vec::new(), 4));
    std::thread::sleep(Duration::from_millis(10)); // admitted + running
    assert_eq!(probe.runs.load(Ordering::SeqCst), 1);
    let t0 = Instant::now();
    h.cancel();
    let err = h.wait().unwrap_err();
    assert_eq!(err.downcast_ref::<SchedError>(), Some(&SchedError::Cancelled));
    assert!(
        t0.elapsed() < Duration::from_millis(200),
        "cancel did not stop the running task promptly: {:?}",
        t0.elapsed()
    );
    assert_accounting_balanced(&sched);
    assert_eq!(probe.active.load(Ordering::SeqCst), 0, "occupancy must drop");
    assert_eq!(sched.stats().cancelled, 1);
}

#[test]
fn accounting_invariant_under_random_cancellation() {
    // Random mix of completing and cancelled tasks, cancelled at random
    // points (some while queued, some mid-execution): at quiescence
    // submitted == completed + failed + deadline_rejected + cancelled,
    // every handle settles, and no virtual core stays occupied.
    check(3, |g| {
        let capacity = *g.choice(&[2usize, 4, 8]);
        let (sched, probe) = tracking_sched(SchedConfig {
            cores: CoreMap::homogeneous(capacity),
            aging: Duration::from_millis(10),
            backfill: true,
            ..Default::default()
        });
        let k = g.usize_in(15, 30);
        let mut handles = Vec::with_capacity(k);
        let mut want_cancel = Vec::with_capacity(k);
        for _ in 0..k {
            let threads = g.usize_in(1, capacity);
            let ms = g.usize_in(1, 6) as u64;
            let h = sched.submit(PartTask::new(
                model_name(threads, ms),
                Vec::new(),
                threads,
            ));
            want_cancel.push(g.bool());
            handles.push(h);
        }
        let mut cancelled_req = 0u64;
        for (h, &c) in handles.iter().zip(&want_cancel) {
            if c {
                h.cancel();
                cancelled_req += 1;
            }
        }
        let (mut ok, mut cancelled_seen) = (0u64, 0u64);
        for h in handles {
            match h.wait() {
                Ok(_) => ok += 1,
                Err(e) => {
                    assert_eq!(
                        e.downcast_ref::<SchedError>(),
                        Some(&SchedError::Cancelled),
                        "only cancellation errors expected: {e:#}"
                    );
                    cancelled_seen += 1;
                }
            }
        }
        assert_accounting_balanced(&sched);
        assert_eq!(probe.active.load(Ordering::SeqCst), 0);
        let st = sched.stats();
        assert_eq!(st.submitted, k as u64);
        assert_eq!(st.completed, ok, "handle view and counters agree: {st:?}");
        assert_eq!(st.cancelled, cancelled_seen);
        assert_eq!(st.failed, 0);
        // a cancel request may lose the race with completion, but never
        // the other way around
        assert!(
            cancelled_seen <= cancelled_req,
            "cancelled {cancelled_seen} > requested {cancelled_req}"
        );
        assert_eq!(ok + cancelled_seen, k as u64, "every handle settles");
    });
}

#[test]
fn adaptive_sizing_never_exceeds_budget() {
    // Property (adaptive core sizing): for ANY profiled latency
    // distribution, the measured-cost weights fed through Listing 1
    // produce an allocation where every part gets >= 1 core, no part
    // exceeds the budget C, and (k <= C) the total is exactly C — so
    // profile feedback can never oversubscribe the ledger. Verified
    // both arithmetically and by running the allocation through the
    // occupancy-tracking scheduler.
    check(3, |g| {
        let capacity = *g.choice(&[4usize, 8, 16]);
        let profiles = Arc::new(ProfileStore::new());
        let policy = AdaptivePolicy::new(Arc::clone(&profiles), AdaptiveConfig::default());
        let n_models = g.usize_in(2, 5);
        let models: Vec<String> = (0..n_models).map(|i| format!("m{i}")).collect();
        for m in &models {
            // wildly varying measured cost, some models sampled often
            // enough for p95 weighting, some not, some never observed
            let obs = g.usize_in(0, 12);
            let ms = g.usize_in(1, 200) as u64;
            for _ in 0..obs {
                profiles.observe(m, Duration::from_millis(ms));
            }
        }
        let k = g.usize_in(1, capacity + 4);
        let parts: Vec<(String, usize)> = (0..k)
            .map(|i| (models[i % n_models].clone(), g.usize_in(1, 4096)))
            .collect();
        let keyed: Vec<(&str, usize)> =
            parts.iter().map(|(m, s)| (m.as_str(), *s)).collect();
        let w = policy.part_weights(&keyed);
        assert_eq!(w.len(), k);
        assert!((w.iter().sum::<f64>() - 1.0).abs() < 1e-9, "{w:?}");
        let alloc = allocate(
            PartWeights::Measured(&w),
            &CoreMap::homogeneous(capacity),
            AllocPolicy::PrunDef,
        )
        .into_threads();
        assert!(alloc.iter().all(|&c| c >= 1), "every part >= 1 core: {alloc:?}");
        assert!(
            alloc.iter().all(|&c| c <= capacity),
            "no part may exceed the budget: {alloc:?}"
        );
        if k <= capacity {
            assert_eq!(
                alloc.iter().sum::<usize>(),
                capacity,
                "k <= C must allocate exactly C: {alloc:?}"
            );
        }
        // and the ledger agrees: peak occupancy never exceeds C
        let (sched, probe) = tracking_sched(SchedConfig {
            cores: CoreMap::homogeneous(capacity),
            aging: Duration::from_millis(10),
            backfill: true,
            ..Default::default()
        });
        let handles: Vec<_> = alloc
            .iter()
            .map(|&threads| {
                sched.submit(PartTask::new(model_name(threads, 2), Vec::new(), threads))
            })
            .collect();
        for h in handles {
            h.wait().expect("task must complete");
        }
        assert!(
            probe.peak.load(Ordering::SeqCst) <= capacity,
            "adaptive allocation oversubscribed: peak {} > {capacity}",
            probe.peak.load(Ordering::SeqCst)
        );
        assert_accounting_balanced(&sched);
    });
}

#[test]
fn accounting_holds_with_running_deadline_cancellations() {
    // Property (running-deadline enforcer): with a scheduler-wide
    // running deadline, long tasks are cancelled mid-flight by the
    // dispatcher itself — and the accounting invariant still balances,
    // with every enforcement visible in `running_deadline_cancelled`
    // and no ledger core leaked.
    check(3, |g| {
        let capacity = *g.choice(&[2usize, 4]);
        let (sched, probe) = tracking_sched(SchedConfig {
            cores: CoreMap::homogeneous(capacity),
            aging: Duration::from_millis(10),
            backfill: true,
            deadline_running: Some(Duration::from_millis(25)),
            ..Default::default()
        });
        let k = g.usize_in(6, 12);
        let mut expected_killed = 0u64;
        let handles: Vec<_> = (0..k)
            .map(|_| {
                // short tasks finish inside the budget; long ones must
                // be killed by the enforcer (25ms budget, 1ms polls)
                let long = g.bool();
                let ms = if long {
                    expected_killed += 1;
                    80
                } else {
                    2
                };
                let threads = g.usize_in(1, capacity);
                sched.submit(PartTask::new(model_name(threads, ms), Vec::new(), threads))
            })
            .collect();
        let (mut ok, mut cancelled) = (0u64, 0u64);
        for h in handles {
            match h.wait() {
                Ok(_) => ok += 1,
                Err(e) => {
                    assert_eq!(
                        e.downcast_ref::<SchedError>(),
                        Some(&SchedError::Cancelled),
                        "running-deadline kill must surface as Cancelled: {e:#}"
                    );
                    cancelled += 1;
                }
            }
        }
        assert_accounting_balanced(&sched);
        assert_eq!(probe.active.load(Ordering::SeqCst), 0, "occupancy must drop");
        let st = sched.stats();
        assert_eq!(st.submitted, k as u64);
        assert_eq!(st.completed, ok);
        assert_eq!(st.cancelled, cancelled);
        assert_eq!(
            st.running_deadline_cancelled, expected_killed,
            "every long task (and only those) is enforced: {st:?}"
        );
        assert_eq!(cancelled, expected_killed, "handle view agrees: {st:?}");
    });
}

#[test]
fn aging_bound_monotonically_tracks_latency_shifts() {
    // Property (adaptive aging): as the injected part latency shifts
    // upward, the derived aging bound never decreases; after the window
    // refills at a lower latency it comes back down (staleness is the
    // window cap here — samples are fresh, the *cap* evicts old ones).
    let profiles = Arc::new(ProfileStore::new());
    let policy = AdaptivePolicy::new(
        Arc::clone(&profiles),
        AdaptiveConfig {
            aging_factor: 2.0,
            min_aging: Duration::from_millis(1),
            max_aging: Duration::from_millis(2000),
            ..AdaptiveConfig::default()
        },
    );
    let fallback = Duration::from_millis(50);
    assert_eq!(policy.aging_bound(fallback), fallback, "unprofiled -> static");
    let mut bounds = Vec::new();
    for shift_ms in [5u64, 10, 20, 40, 80] {
        // enough samples to dominate the 128-entry window's p95
        for _ in 0..128 {
            profiles.observe("m", Duration::from_millis(shift_ms));
        }
        bounds.push(policy.aging_bound(fallback));
    }
    for w in bounds.windows(2) {
        assert!(
            w[1] >= w[0],
            "aging bound must not decrease under rising latency: {bounds:?}"
        );
    }
    assert!(
        bounds[4] >= 8 * bounds[0],
        "16x latency shift must move the bound: {bounds:?}"
    );
    // and back down once the window is fully refreshed at low latency
    for _ in 0..128 {
        profiles.observe("m", Duration::from_millis(5));
    }
    let recovered = policy.aging_bound(fallback);
    assert!(
        recovered <= bounds[0] + Duration::from_millis(1),
        "bound must recover after the shift clears: {recovered:?} vs {bounds:?}"
    );
}

#[test]
fn accounting_holds_with_budget_expiry() {
    // Property (request budgets + budget-aware admission): with a
    // random mix of budget-less tasks, born-expired budgets, tight
    // budgets over long runs, and infeasible cost hints, at quiescence
    // the extended invariant `submitted == completed + failed +
    // deadline_rejected + budget_expired + budget_infeasible +
    // cancelled` balances, the counters agree with the per-handle error
    // types, rejected tasks never reach a worker, and no ledger core
    // stays occupied.
    check(3, |g| {
        let capacity = *g.choice(&[2usize, 4]);
        let (sched, probe) = tracking_sched(SchedConfig {
            cores: CoreMap::homogeneous(capacity),
            aging: Duration::from_millis(10),
            backfill: true,
            ..Default::default()
        });
        let k = g.usize_in(10, 20);
        #[derive(Clone, Copy, PartialEq)]
        enum Kind {
            Plain,
            BornExpired,
            TightBudget,
            Infeasible,
        }
        let mut born_expired = 0usize;
        let mut infeasible = 0usize;
        let handles: Vec<_> = (0..k)
            .map(|_| {
                let kind = *g.choice(&[
                    Kind::Plain,
                    Kind::BornExpired,
                    Kind::TightBudget,
                    Kind::Infeasible,
                ]);
                let threads = g.usize_in(1, capacity);
                let task = match kind {
                    // short task, no budget: completes
                    Kind::Plain => PartTask::new(model_name(threads, 2), Vec::new(), threads),
                    // zero budget: must be rejected before any worker
                    Kind::BornExpired => {
                        born_expired += 1;
                        PartTask::new(model_name(threads, 2), Vec::new(), threads)
                            .with_budget(Budget::new(Duration::ZERO))
                    }
                    // long run, tight budget: expires queued (budget_
                    // expired) or mid-run (cancelled), depending on
                    // where the random queueing put it
                    Kind::TightBudget => {
                        PartTask::new(model_name(threads, 60), Vec::new(), threads)
                            .with_budget(Budget::new(Duration::from_millis(15)))
                    }
                    // ample budget, but a profiled cost the budget can
                    // never cover: budget-aware admission must reject
                    // it at submit, before any queueing
                    Kind::Infeasible => {
                        infeasible += 1;
                        PartTask::new(model_name(threads, 2), Vec::new(), threads)
                            .with_budget(Budget::new(Duration::from_millis(200)))
                            .with_cost_hint(Duration::from_secs(30))
                    }
                };
                sched.submit(task)
            })
            .collect();
        let (mut ok, mut cancelled_seen, mut budget_seen, mut infeasible_seen) =
            (0u64, 0u64, 0u64, 0u64);
        for h in handles {
            match h.wait() {
                Ok(_) => ok += 1,
                Err(e) => match e.downcast_ref::<SchedError>() {
                    Some(SchedError::Cancelled) => cancelled_seen += 1,
                    Some(SchedError::BudgetExpired) => budget_seen += 1,
                    Some(SchedError::BudgetInfeasible) => infeasible_seen += 1,
                    other => panic!("unexpected error kind {other:?}: {e:#}"),
                },
            }
        }
        assert_accounting_balanced(&sched);
        assert_eq!(probe.active.load(Ordering::SeqCst), 0);
        let st = sched.stats();
        assert_eq!(st.submitted, k as u64);
        assert_eq!(st.completed, ok, "handle view and counters agree: {st:?}");
        assert_eq!(st.cancelled, cancelled_seen, "{st:?}");
        assert_eq!(st.budget_expired, budget_seen, "{st:?}");
        assert_eq!(st.budget_infeasible, infeasible_seen, "{st:?}");
        assert_eq!(st.failed, 0, "{st:?}");
        assert!(
            budget_seen >= born_expired as u64,
            "every born-expired budget must be rejected: {budget_seen} < {born_expired}"
        );
        assert_eq!(
            infeasible_seen, infeasible as u64,
            "every infeasible hint (and only those) must be rejected at submit: {st:?}"
        );
        // mid-run budget kills are enforcement kills, attributed to the
        // budget source — never to the (unset) global running deadline
        assert_eq!(st.running_deadline_cancelled, cancelled_seen, "{st:?}");
        assert_eq!(st.running_deadline_cancelled_budget, cancelled_seen, "{st:?}");
        // rejected tasks must never have reached a worker: runs are at
        // most the tasks that were not rejected at admission
        assert!(
            probe.runs.load(Ordering::SeqCst) as u64 <= k as u64 - budget_seen - infeasible_seen,
            "admission-rejected tasks reached a worker: runs {} vs k {} - budget {} - infeasible {}",
            probe.runs.load(Ordering::SeqCst),
            k,
            budget_seen,
            infeasible_seen
        );
    });
}

#[test]
fn ingress_ctx_token_reaches_the_executor() {
    // Ctx propagation at the scheduler layer: a PartTask stamped via
    // with_ctx must hand the *ingress* token (same flag, not a copy) to
    // the executor worker, and a cancel through the ctx must be the
    // cancel the worker observes.
    use dnc_serve::engine::RequestCtx;
    let capacity = 2;
    let (sched, probe) = tracking_sched(SchedConfig {
        cores: CoreMap::homogeneous(capacity),
        aging: Duration::from_millis(10),
        backfill: true,
        ..Default::default()
    });
    let ctx = RequestCtx::new();
    let h = sched
        .submit(PartTask::new(model_name(1, 300), Vec::new(), 1).with_ctx(&ctx));
    // wait (bounded) until the task is actually executing on a worker
    let t0 = Instant::now();
    while probe.runs.load(Ordering::SeqCst) != 1 && t0.elapsed() < Duration::from_secs(5) {
        std::thread::sleep(Duration::from_millis(1));
    }
    assert_eq!(probe.runs.load(Ordering::SeqCst), 1, "task never launched");
    ctx.cancel(); // cancel at the ingress, not through the handle
    let err = h.wait().unwrap_err();
    assert_eq!(err.downcast_ref::<SchedError>(), Some(&SchedError::Cancelled));
    assert_accounting_balanced(&sched);
    assert_eq!(probe.active.load(Ordering::SeqCst), 0, "cores must return");
}

// ---- sharded dispatcher properties ---------------------------------
//
// The sharded scheduler splits the ledger into disjoint per-shard
// slices with work stealing between them. Three properties pin it:
// the accounting invariant balances per shard AND globally under mixed
// cancel/budget-expiry load; no shard's slice ever oversubscribes (the
// global ledger bound follows from the per-slice bounds); and a steal
// never oversubscribes the thief — stolen work still fits the global
// budget.

/// Per-shard accounting: every shard's books must close on their own
/// (steals transfer the `submitted` count with the task).
fn assert_shard_accounting_balanced(sched: &Scheduler) {
    for (i, sh) in sched.shard_stats().iter().enumerate() {
        assert_eq!(sh.queue_depth, 0, "shard {i} queue: {sh:?}");
        assert_eq!(sh.inflight, 0, "shard {i} inflight: {sh:?}");
        assert_eq!(sh.cores_busy, 0, "shard {i} slice must empty: {sh:?}");
        // the per-class books must close too: class occupancy returns
        // to zero and the class columns partition the shard's slice
        assert_eq!(
            sh.busy_fast + sh.busy_slow,
            0,
            "shard {i} class occupancy must empty: {sh:?}"
        );
        assert_eq!(
            sh.capacity_fast + sh.capacity_slow,
            sh.capacity,
            "shard {i} class split must partition the slice: {sh:?}"
        );
        assert_eq!(
            sh.submitted,
            sh.completed
                + sh.failed
                + sh.deadline_rejected
                + sh.budget_expired
                + sh.budget_infeasible
                + sh.cancelled,
            "shard {i} accounting invariant violated: {sh:?}"
        );
    }
}

#[test]
fn sharded_accounting_invariant_under_mixed_load() {
    // Property: N shards, random request-id routing, a random mix of
    // completing, cancelled and budget-expired tasks — at quiescence
    // the invariant balances globally AND per shard, and the slices sum
    // to the configured ledger.
    check(3, |g| {
        let shards = *g.choice(&[2usize, 3, 4]);
        let capacity = shards * *g.choice(&[2usize, 4]);
        let (sched, probe) = tracking_sched(SchedConfig {
            cores: CoreMap::homogeneous(capacity),
            shards,
            aging: Duration::from_millis(10),
            backfill: true,
            ..Default::default()
        });
        assert_eq!(sched.shards(), shards);
        assert_eq!(
            sched.shard_stats().iter().map(|s| s.capacity).sum::<usize>(),
            capacity,
            "slices must partition the ledger"
        );
        let slice_max = capacity / shards; // smallest slice (even split here)
        let k = g.usize_in(15, 30);
        let mut handles = Vec::with_capacity(k);
        for _ in 0..k {
            // threads within the smallest slice so routing never clamps
            // a task differently per shard; random request ids spread
            // (and sometimes collide on) shards
            let threads = g.usize_in(1, slice_max);
            let ms = g.usize_in(1, 5) as u64;
            let mut task = PartTask::new(model_name(threads, ms), Vec::new(), threads)
                .with_request_id(g.usize_in(0, 1000) as u64);
            match *g.choice(&[0u8, 0, 0, 1, 2]) {
                1 => task = task.with_budget(Budget::new(Duration::ZERO)),
                2 => {
                    task = task.with_budget(Budget::new(Duration::from_millis(15)));
                }
                _ => {}
            }
            let h = sched.submit(task);
            if g.usize_in(0, 9) == 0 {
                h.cancel();
            }
            handles.push(h);
        }
        for h in handles {
            let _ = h.wait(); // settle; error kinds covered elsewhere
        }
        assert!(sched.drain(Duration::from_secs(5)), "drain timed out");
        assert_shard_accounting_balanced(&sched);
        assert_accounting_balanced(&sched);
        assert_eq!(probe.active.load(Ordering::SeqCst), 0);
        assert_eq!(sched.stats().submitted, k as u64);
    });
}

#[test]
fn shard_slices_never_oversubscribe() {
    // Property: while a sharded scheduler is saturated, every polled
    // snapshot shows each shard within its own slice — and the global
    // probe confirms total occupancy never exceeded the ledger.
    let shards = 2;
    let capacity = 8; // two 4-core slices
    let (sched, probe) = tracking_sched(SchedConfig {
        cores: CoreMap::homogeneous(capacity),
        shards,
        aging: Duration::from_millis(10),
        backfill: true,
        ..Default::default()
    });
    let handles: Vec<_> = (0..24)
        .map(|i| {
            let threads = 1 + (i % 4);
            sched.submit(
                PartTask::new(model_name(threads, 8), Vec::new(), threads)
                    .with_request_id(i as u64),
            )
        })
        .collect();
    // poll per-shard gauges while the load runs
    let t0 = Instant::now();
    while t0.elapsed() < Duration::from_millis(60) {
        for (i, sh) in sched.shard_stats().iter().enumerate() {
            assert!(
                sh.cores_busy <= sh.capacity,
                "shard {i} slice oversubscribed: {sh:?}"
            );
        }
        std::thread::sleep(Duration::from_millis(2));
    }
    for h in handles {
        h.wait().expect("task must complete");
    }
    assert!(
        probe.peak.load(Ordering::SeqCst) <= capacity,
        "global ledger oversubscribed: peak {} > {capacity}",
        probe.peak.load(Ordering::SeqCst)
    );
    assert!(sched.drain(Duration::from_secs(5)));
    assert_shard_accounting_balanced(&sched);
    assert_accounting_balanced(&sched);
}

#[test]
fn steal_never_oversubscribes() {
    // Property: all load pinned to one shard (one request id) forces
    // the other shard to steal — and even with stealing active, global
    // occupancy stays within the ledger, the stolen tasks fit the
    // thief's slice by construction, and both shards' books close.
    let shards = 2;
    let capacity = 8; // two 4-core slices
    let (sched, probe) = tracking_sched(SchedConfig {
        cores: CoreMap::homogeneous(capacity),
        shards,
        aging: Duration::from_millis(10),
        backfill: true,
        ..Default::default()
    });
    // 4-thread tasks fill a whole slice each; pinned to shard 0, the
    // backlog is only drainable in reasonable time via stealing
    let handles: Vec<_> = (0..8)
        .map(|_| {
            sched.submit(
                PartTask::new(model_name(4, 15), Vec::new(), 4).with_request_id(0),
            )
        })
        .collect();
    for h in handles {
        h.wait().expect("task must complete");
    }
    assert!(sched.drain(Duration::from_secs(5)));
    let st = sched.stats();
    assert!(st.steals >= 1, "pinned backlog never rebalanced: {st:?}");
    assert_eq!(st.completed, 8, "{st:?}");
    assert!(
        probe.peak.load(Ordering::SeqCst) <= capacity,
        "stealing oversubscribed the ledger: peak {} > {capacity}",
        probe.peak.load(Ordering::SeqCst)
    );
    assert_eq!(probe.active.load(Ordering::SeqCst), 0);
    assert_shard_accounting_balanced(&sched);
    assert_accounting_balanced(&sched);
}

// ---- heterogeneous core classes ------------------------------------

#[test]
fn per_class_slices_never_oversubscribed_with_stealing() {
    // Property: on a heterogeneous map split across shards, every
    // polled snapshot keeps each shard's per-class occupancy within its
    // slice's per-class capacity — even with all load pinned to one
    // shard (one request id), so the other shard must steal, and with
    // every affinity kind in the mix.
    let map = CoreMap::parse("fast=4,slow=4@0.5").expect("valid spec");
    let capacity = map.total();
    let (sched, probe) = tracking_sched(SchedConfig {
        cores: map,
        shards: 2,
        aging: Duration::from_millis(10),
        backfill: true,
        ..Default::default()
    });
    let handles: Vec<_> = (0..12)
        .map(|i| {
            let affinity = match i % 3 {
                0 => ClassAffinity::Prefer(CoreClass::Fast),
                1 => ClassAffinity::Prefer(CoreClass::Slow),
                _ => ClassAffinity::Any,
            };
            sched.submit(
                PartTask::new(model_name(2, 10), Vec::new(), 2)
                    .with_request_id(0)
                    .with_affinity(affinity),
            )
        })
        .collect();
    // poll the per-class gauges while the load runs
    let t0 = Instant::now();
    while t0.elapsed() < Duration::from_millis(50) {
        for (i, sh) in sched.shard_stats().iter().enumerate() {
            assert!(
                sh.busy_fast <= sh.capacity_fast,
                "shard {i} Fast slice oversubscribed: {sh:?}"
            );
            assert!(
                sh.busy_slow <= sh.capacity_slow,
                "shard {i} Slow slice oversubscribed: {sh:?}"
            );
        }
        std::thread::sleep(Duration::from_millis(2));
    }
    for h in handles {
        h.wait().expect("task must complete");
    }
    assert!(sched.drain(Duration::from_secs(5)));
    let st = sched.stats();
    assert!(st.steals >= 1, "pinned backlog never rebalanced: {st:?}");
    assert!(
        probe.peak.load(Ordering::SeqCst) <= capacity,
        "global ledger oversubscribed: peak {} > {capacity}",
        probe.peak.load(Ordering::SeqCst)
    );
    assert_shard_accounting_balanced(&sched);
    assert_accounting_balanced(&sched);
}

#[test]
fn fast_exhaustion_degrades_to_slow_without_rejection() {
    // Property: Prefer(Fast) work arriving while the Fast class is
    // fully held falls back to Slow — it completes promptly on the
    // other class (no deadlock, no rejection, no waiting out the hog)
    // and every such placement is counted in `class_degraded`.
    let map = CoreMap::parse("fast=2,slow=4@0.5").expect("valid spec");
    let (sched, probe) = tracking_sched(SchedConfig {
        cores: map,
        shards: 1,
        aging: Duration::from_millis(10),
        backfill: true,
        ..Default::default()
    });
    // hold the whole Fast class
    let hog = sched.submit(
        PartTask::new(model_name(2, 80), Vec::new(), 2)
            .with_affinity(ClassAffinity::Prefer(CoreClass::Fast)),
    );
    std::thread::sleep(Duration::from_millis(5)); // hog admitted
    let t0 = Instant::now();
    let degraded: Vec<_> = (0..2)
        .map(|_| {
            sched.submit(
                PartTask::new(model_name(2, 5), Vec::new(), 2)
                    .with_affinity(ClassAffinity::Prefer(CoreClass::Fast)),
            )
        })
        .collect();
    for h in degraded {
        let done = h.wait().expect("degraded task must complete, not deadlock");
        assert_eq!(done.class, CoreClass::Slow, "must fall back to the Slow class");
    }
    assert!(
        t0.elapsed() < Duration::from_millis(60),
        "degraded work waited for Fast instead of falling back: {:?}",
        t0.elapsed()
    );
    hog.wait().expect("hog must complete");
    let st = sched.stats();
    assert_eq!(st.class_degraded, 2, "{st:?}");
    assert_eq!(probe.active.load(Ordering::SeqCst), 0);
    assert_shard_accounting_balanced(&sched);
    assert_accounting_balanced(&sched);
}

//! End-to-end runtime integration: artifacts -> PJRT compile -> execute,
//! with numerics checked against goldens produced by the Python reference.
//!
//! Requires `make artifacts` (tests are skipped politely otherwise).

use std::sync::Arc;

use dnc_serve::runtime::{artifacts_dir, ExecutorPool, LocalEngine, Manifest, Tensor};
use dnc_serve::util::json::Json;

fn manifest() -> Option<Arc<Manifest>> {
    let dir = artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(Arc::new(Manifest::load(&dir).expect("manifest parses")))
}

#[test]
fn bert_b1_s16_matches_python_golden() {
    let Some(m) = manifest() else { return };
    let golden = Json::parse_file(&m.dir.join("golden/bert_b1_s16.json")).unwrap();
    let input: Vec<i32> = golden
        .req("input")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_i64().unwrap() as i32)
        .collect();
    let want = golden.req("output").unwrap().f32_arr().unwrap();

    let mut engine = LocalEngine::new(m).unwrap();
    let out = engine
        .execute("bert_b1_s16", &[Tensor::i32(vec![1, 16], input)])
        .unwrap();
    assert_eq!(out.len(), 1);
    assert_eq!(out[0].shape, vec![1, 128]);
    let got = out[0].as_f32().unwrap();
    assert_eq!(got.len(), want.len());
    for (i, (g, w)) in got.iter().zip(want.iter()).enumerate() {
        assert!(
            (g - w).abs() <= 1e-4 + 1e-4 * w.abs(),
            "element {i}: got {g}, want {w}"
        );
    }
}

#[test]
fn ocr_recognizer_matches_python_golden() {
    let Some(m) = manifest() else { return };
    let golden = Json::parse_file(&m.dir.join("golden/ocr_rec_w192.json")).unwrap();
    let crop = golden.req("crop").unwrap().f32_arr().unwrap();
    let want_ids = golden.req("rec_argmax").unwrap().usize_arr().unwrap();

    let mut engine = LocalEngine::new(m).unwrap();
    let out = engine
        .execute("ocr_rec_w192", &[Tensor::f32(vec![1, 3, 32, 192], crop.clone())])
        .unwrap();
    let logp = out[0].as_f32().unwrap();
    let n_classes = out[0].shape[1];
    let got_ids: Vec<usize> = logp
        .chunks(n_classes)
        .map(|row| {
            row.iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0
        })
        .collect();
    assert_eq!(got_ids, want_ids);

    // classifier agrees too
    let cls = engine
        .execute("ocr_cls_w192", &[Tensor::f32(vec![1, 3, 32, 192], crop)])
        .unwrap();
    let logits = cls[0].as_f32().unwrap();
    let want_cls = golden.req("cls_logits").unwrap().f32_arr().unwrap();
    assert!((logits[0] - want_cls[0]).abs() < 1e-4);
    assert!(logits[0] > logits[1], "golden crop is upright");
}

#[test]
fn detector_runs_and_shapes() {
    let Some(m) = manifest() else { return };
    let mut engine = LocalEngine::new(m).unwrap();
    let img = Tensor::zeros_f32(vec![1, 3, 192, 256]);
    let out = engine.execute("ocr_det", &[img]).unwrap();
    assert_eq!(out[0].shape, vec![1, 48, 64]);
    // blank page -> all scores low
    let max = out[0].as_f32().unwrap().iter().cloned().fold(0.0f32, f32::max);
    assert!(max < 0.1, "blank page max score {max}");
}

#[test]
fn input_validation_errors_are_friendly() {
    let Some(m) = manifest() else { return };
    let mut engine = LocalEngine::new(m).unwrap();
    // wrong arity
    let err = engine.execute("ocr_det", &[]).unwrap_err().to_string();
    assert!(err.contains("expects"), "{err}");
    // wrong shape
    let err = engine
        .execute("ocr_det", &[Tensor::zeros_f32(vec![1, 3, 64, 64])])
        .unwrap_err()
        .to_string();
    assert!(err.contains("expected"), "{err}");
    // unknown model
    assert!(engine.execute("nope", &[]).is_err());
}

#[test]
fn executor_pool_parallel_submissions() {
    let Some(m) = manifest() else { return };
    let pool = ExecutorPool::new(m, 2).unwrap();
    pool.warmup(&["bert_b1_s16"]).unwrap();

    let mut rxs = Vec::new();
    for i in 0..6i32 {
        let ids: Vec<i32> = (0..16).map(|j| (i * 31 + j) % 8192).collect();
        rxs.push(pool.submit("bert_b1_s16", vec![Tensor::i32(vec![1, 16], ids)]));
    }
    for rx in rxs {
        let res = rx.recv().unwrap().unwrap();
        assert_eq!(res.outputs[0].shape, vec![1, 128]);
        assert!(res.outputs[0].as_f32().unwrap().iter().all(|x| x.is_finite()));
    }
    assert_eq!(pool.jobs_submitted(), 6);
}

#[test]
fn pool_same_input_deterministic_across_workers() {
    let Some(m) = manifest() else { return };
    let pool = ExecutorPool::new(m, 2).unwrap();
    let ids: Vec<i32> = (0..16).collect();
    let a = pool.run("bert_b1_s16", vec![Tensor::i32(vec![1, 16], ids.clone())]).unwrap();
    let b = pool.run("bert_b1_s16", vec![Tensor::i32(vec![1, 16], ids)]).unwrap();
    assert_eq!(a.outputs[0], b.outputs[0]);
}

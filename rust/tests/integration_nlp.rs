//! End-to-end NLP integration: tokenizer -> bucketed artifacts -> the
//! three serving strategies, checking result equivalence (padding and
//! splitting must not change the numbers beyond bucket effects).

use std::sync::Arc;

use dnc_serve::engine::{AllocPolicy, RequestCtx, Session};
use dnc_serve::nlp::{BertServer, Strategy, Tokenizer};
use dnc_serve::runtime::{artifacts_dir, Manifest};
use dnc_serve::workload::seqlen;
use dnc_serve::util::prng::Rng;

fn server() -> Option<BertServer> {
    let dir = artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    let manifest = Arc::new(Manifest::load(&dir).unwrap());
    let session = Arc::new(Session::new(manifest, 16, 2).unwrap());
    Some(BertServer::new(session))
}

fn requests(lens: &[usize], seed: u64) -> Vec<Vec<i32>> {
    let tok = Tokenizer::new(8192);
    lens.iter()
        .enumerate()
        .map(|(i, &l)| tok.synthetic(l, seed + i as u64))
        .collect()
}

#[test]
fn no_batch_and_prun_agree_exactly() {
    // both run each sequence in its own bucket: identical numerics
    let Some(srv) = server() else { return };
    let reqs = requests(&[16, 30, 64], 1);
    let solo = srv.serve(&reqs, Strategy::NoBatch, &RequestCtx::new()).unwrap();
    for policy in [AllocPolicy::PrunDef, AllocPolicy::PrunOne, AllocPolicy::PrunEq] {
        let prun = srv.serve(&reqs, Strategy::Prun(policy), &RequestCtx::new()).unwrap();
        assert_eq!(prun.outputs, solo.outputs, "{policy:?}");
        assert_eq!(prun.invocations, 3);
    }
}

#[test]
fn pad_batch_returns_per_request_outputs() {
    let Some(srv) = server() else { return };
    let reqs = requests(&[16, 16], 2);
    let res = srv.serve(&reqs, Strategy::PadBatch, &RequestCtx::new()).unwrap();
    assert_eq!(res.outputs.len(), 2);
    assert_eq!(res.invocations, 1);
    let hidden = srv.session().manifest().bert.hidden;
    assert!(res.outputs.iter().all(|o| o.len() == hidden));
    // different inputs -> different embeddings
    assert_ne!(res.outputs[0], res.outputs[1]);
}

#[test]
fn identical_requests_same_output_across_strategies() {
    // With equal lengths there is no padding difference, so pad-batch
    // row i must equal the no-batch output for request i.
    let Some(srv) = server() else { return };
    let reqs = requests(&[32, 32], 3);
    let nb = srv.serve(&reqs, Strategy::NoBatch, &RequestCtx::new()).unwrap();
    let pb = srv.serve(&reqs, Strategy::PadBatch, &RequestCtx::new()).unwrap();
    for (i, (a, b)) in nb.outputs.iter().zip(pb.outputs.iter()).enumerate() {
        for (x, y) in a.iter().zip(b.iter()) {
            assert!((x - y).abs() < 1e-4, "request {i}: {x} vs {y}");
        }
    }
}

#[test]
fn random_length_batches_all_strategies_complete() {
    let Some(srv) = server() else { return };
    let mut rng = Rng::new(4);
    for x in [2usize, 5, 8] {
        let lens = seqlen::random_batch(&mut rng, x);
        let reqs = requests(&lens, 10 + x as u64);
        for strat in [
            Strategy::PadBatch,
            Strategy::NoBatch,
            Strategy::Prun(AllocPolicy::PrunDef),
        ] {
            let res = srv.serve(&reqs, strat, &RequestCtx::new()).unwrap();
            assert_eq!(res.outputs.len(), x, "{strat:?} x={x}");
            assert!(res.outputs.iter().flatten().all(|v| v.is_finite()));
        }
    }
}

#[test]
fn batch_too_large_is_an_error() {
    let Some(srv) = server() else { return };
    let reqs = requests(&vec![16; 9], 5); // largest batch bucket is 8
    assert!(srv.serve(&reqs, Strategy::PadBatch, &RequestCtx::new()).is_err());
    // but prun handles any k (one part per request)
    assert!(srv.serve(&reqs, Strategy::Prun(AllocPolicy::PrunDef), &RequestCtx::new()).is_ok());
}

#[test]
fn sequence_too_long_is_an_error() {
    let Some(srv) = server() else { return };
    let tok = Tokenizer::new(8192);
    let reqs = vec![tok.synthetic(600, 6)];
    assert!(srv.serve(&reqs, Strategy::NoBatch, &RequestCtx::new()).is_err());
}

#[test]
fn empty_batch_rejected() {
    let Some(srv) = server() else { return };
    assert!(srv.serve(&[], Strategy::PadBatch, &RequestCtx::new()).is_err());
}

#[test]
fn tokenizer_end_to_end_text_path() {
    let Some(srv) = server() else { return };
    let tok = srv.tokenizer();
    let reqs = vec![
        tok.encode("the quick brown fox jumps over the lazy dog", 64),
        tok.encode("hello", 64),
    ];
    let res = srv.serve(&reqs, Strategy::Prun(AllocPolicy::PrunDef), &RequestCtx::new()).unwrap();
    assert_eq!(res.outputs.len(), 2);
    assert_ne!(res.outputs[0], res.outputs[1]);
}

#[test]
fn profiled_weights_prun_after_warm_observations() {
    // paper §6 future work: weight by measured latency instead of size.
    // After observing each bucket, Profiled weights must produce valid
    // allocations and identical outputs.
    use dnc_serve::engine::{JobPart, PrunRequest, WeightSource};
    use dnc_serve::runtime::Tensor;
    let Some(srv) = server() else { return };
    let sess = srv.session();
    // warm the profile store with real observations
    for len in [16usize, 64] {
        let ids = Tokenizer::new(8192).synthetic(len, 9);
        let data = Tokenizer::pad(&ids, len);
        sess.run(&format!("bert_b1_s{len}"), vec![Tensor::i32(vec![1, len], data)]).unwrap();
    }
    assert!(sess.profiles().len() >= 2);
    let parts: Vec<JobPart> = [16usize, 64]
        .iter()
        .map(|&len| {
            let ids = Tokenizer::new(8192).synthetic(len, 9);
            JobPart::new(
                format!("bert_b1_s{len}"),
                vec![Tensor::i32(vec![1, len], Tokenizer::pad(&ids, len))],
            )
        })
        .collect();
    let solo: Vec<_> = parts
        .iter()
        .map(|p| sess.run(&p.model, p.inputs.clone()).unwrap())
        .collect();
    let req = PrunRequest::new(parts)
        .with_policy(AllocPolicy::PrunDef)
        .with_weights(WeightSource::Profiled);
    let outcome = sess.prun(req, &RequestCtx::new()).unwrap();
    assert_eq!(outcome.outputs, solo);
    // allocation sums to the core budget and respects ordering (the
    // longer sequence measured slower, so it gets more threads)
    assert_eq!(outcome.allocation.total_threads(), 16);
    let threads = outcome.allocation.threads();
    assert!(threads[1] >= threads[0], "{:?}", outcome.allocation);
}

//! Router timeout path end to end over a mock scheduler — no PJRT
//! artifacts needed, so this always runs. A stalled embed batch must:
//!
//! 1. return the structured `"request timed out"` error to the client,
//! 2. bump the `request_timeouts` counter, and
//! 3. cancel the batch's scheduler tasks, so `sched.cores_busy` returns
//!    to 0 instead of the abandoned work occupying ledger cores for the
//!    full (stalled) execution.
//!
//! This mirrors `ServerState::new`'s pipelined embed batcher exactly:
//! the submitter stamps one scheduler task per request from the
//! request's [`RequestCtx`], and `embed_with_timeout` (the function
//! `embed` / `embed_tokens` route through) mints that ctx and cancels
//! it on expiry.

mod common;

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

use dnc_serve::coordinator::{embed_with_timeout, Batcher, EmbedRequest};
use dnc_serve::engine::{Budget, RequestCtx, Scheduler, SubmitError};
use dnc_serve::metrics::Metrics;

/// The router's embed pipeline over the shared stalling mock stack
/// (`tests/common`): one scheduler task per request, stamped from the
/// request's ctx (what `ServerState::new` builds over `BertServer`'s
/// `InferenceService::submit`), no flush-time budget reaping.
fn stalling_embed_stack(
    cores: usize,
    threads_per_task: usize,
) -> (Arc<Scheduler>, Batcher<EmbedRequest, Result<Vec<f32>, SubmitError>>) {
    common::embed_stack(cores, threads_per_task, 4, Duration::from_millis(1), false)
}

#[test]
fn timed_out_embed_returns_structured_error_and_cancels_its_task() {
    let (sched, batcher) = stalling_embed_stack(2, 2);
    let metrics = Metrics::new();

    let t0 = Instant::now();
    let resp =
        embed_with_timeout(&batcher, &metrics, vec![1, 2, 3], Duration::from_millis(50));
    // 1. structured error, promptly. Two correct mechanisms race at the
    // 50ms mark: the router's recv timeout ("request timed out"), or
    // the dispatcher's own enforcement of the request budget minted
    // from the same 50ms — whose typed "cancelled" reply can land just
    // as the router wakes. Either is the request being refused in time.
    let msg = resp.get("error").expect("timeout must error").as_str().unwrap();
    assert!(
        msg.contains("timed out") || msg.contains("cancelled"),
        "unexpected error: {msg}"
    );
    assert!(
        t0.elapsed() < Duration::from_secs(5),
        "timeout path took {:?}",
        t0.elapsed()
    );
    // 2. counted — exactly once when the router's timeout fired; not at
    // all when the budget enforcement replied first
    let timeouts = metrics.counter("request_timeouts").load(Ordering::Relaxed);
    if msg.contains("timed out") {
        assert_eq!(timeouts, 1);
    } else {
        assert_eq!(timeouts, 0);
    }
    // 3. the stalled task was cancelled: the scheduler must go fully
    // idle (10s nominal execution, 5s drain budget — only cancellation
    // makes this pass) and release every ledger core
    let t0 = Instant::now();
    while sched.stats().cancelled != 1 && t0.elapsed() < Duration::from_secs(5) {
        std::thread::sleep(Duration::from_millis(1));
    }
    assert!(
        sched.drain(Duration::from_secs(5)),
        "cancelled task did not release the scheduler: {:?}",
        sched.stats()
    );
    let st = sched.stats();
    assert_eq!(st.cores_busy, 0, "timed-out task still holds cores: {st:?}");
    assert_eq!(st.inflight, 0);
    assert_eq!(st.cancelled, 1, "{st:?}");
    assert_eq!(st.completed, 0);
    assert_eq!(
        st.submitted,
        st.completed
            + st.failed
            + st.deadline_rejected
            + st.budget_expired
            + st.budget_infeasible
            + st.cancelled,
        "accounting invariant: {st:?}"
    );
}

#[test]
fn timed_out_embed_cancelled_while_queued_takes_no_cores() {
    // One stalled request saturates the 2-core budget; the second times
    // out while its task is still *queued* — it must be rejected from
    // the queue without ever occupying cores or reaching a worker.
    let (sched, batcher) = stalling_embed_stack(2, 2);
    let metrics = Metrics::new();

    // occupy the core budget with a request nobody times out (yet): a
    // generous request budget that never fires during the test
    let hog_ctx = RequestCtx::new().with_budget(Budget::new(Duration::from_secs(600)));
    let hog_rx = batcher.submit(EmbedRequest { ids: vec![9, 9], ctx: hog_ctx.clone() });
    // wait until the hog's task actually holds the cores
    let t0 = Instant::now();
    while sched.stats().cores_busy != 2 && t0.elapsed() < Duration::from_secs(5) {
        std::thread::sleep(Duration::from_millis(1));
    }
    assert_eq!(sched.stats().cores_busy, 2, "hog task never started");

    let resp =
        embed_with_timeout(&batcher, &metrics, vec![1, 2, 3], Duration::from_millis(50));
    assert!(resp.get("error").is_some(), "queued request must time out: {resp:?}");

    // The queued task must be swept without touching the ledger. Two
    // correct mechanisms race at the 50ms mark: the router's timeout
    // cancels the ctx (request_timeouts + sched.cancelled), or the
    // dispatcher's own sweep sees the request budget — minted from the
    // same 50ms — die first (sched.budget_expired, the reply arriving
    // before the router even times out). Either way: no cores, no queue.
    let t0 = Instant::now();
    while sched.stats().cancelled + sched.stats().budget_expired != 1
        && t0.elapsed() < Duration::from_secs(5)
    {
        std::thread::sleep(Duration::from_millis(1));
    }
    let st = sched.stats();
    assert_eq!(
        st.cancelled + st.budget_expired,
        1,
        "doomed task never swept: {st:?}"
    );
    assert_eq!(st.queue_depth, 0, "doomed task stuck in queue: {st:?}");
    assert_eq!(st.cores_busy, 2, "only the hog may hold cores: {st:?}");

    // release the hog too; everything must drain
    hog_ctx.cancel();
    assert!(sched.drain(Duration::from_secs(5)), "{:?}", sched.stats());
    assert_eq!(sched.stats().cores_busy, 0);
    drop(hog_rx);
}

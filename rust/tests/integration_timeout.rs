//! Router timeout path end to end over a mock scheduler — no PJRT
//! artifacts needed, so this always runs. A stalled embed batch must:
//!
//! 1. return the structured `"request timed out"` error to the client,
//! 2. bump the `request_timeouts` counter, and
//! 3. cancel the batch's scheduler tasks, so `sched.cores_busy` returns
//!    to 0 instead of the abandoned work occupying ledger cores for the
//!    full (stalled) execution.
//!
//! This mirrors `ServerState::new`'s pipelined embed batcher exactly:
//! the submitter tags one scheduler task per request with the request's
//! [`CancelToken`], and `embed_with_timeout` (the function `embed` /
//! `embed_tokens` route through) cancels that token on expiry.

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

use dnc_serve::coordinator::{embed_with_timeout, Batcher, EmbedRequest};
use dnc_serve::engine::{PartTask, SchedConfig, Scheduler, TaskRunner};
use dnc_serve::metrics::Metrics;
use dnc_serve::runtime::{CancelToken, ExecResult, ReplyFn, TaskCancelled, Tensor};

/// "Executes" every task for 10 simulated seconds — far past any test
/// timeout — unless its cancel token fires first (polled every 1ms).
struct StallRunner;

impl TaskRunner for StallRunner {
    fn workers(&self) -> usize {
        2
    }

    fn run_on(
        &self,
        worker: usize,
        _model: &str,
        _inputs: Vec<Tensor>,
        _threads: usize,
        cancel: CancelToken,
        reply: ReplyFn,
    ) {
        std::thread::spawn(move || {
            if cancel.is_cancelled() {
                reply(Err(anyhow::Error::new(TaskCancelled)));
                return;
            }
            for _ in 0..10_000 {
                std::thread::sleep(Duration::from_millis(1));
                if cancel.is_cancelled() {
                    reply(Err(anyhow::Error::new(TaskCancelled)));
                    return;
                }
            }
            reply(Ok(ExecResult {
                outputs: Vec::new(),
                exec_time: Duration::from_secs(10),
                worker,
            }));
        });
    }
}

/// The router's embed pipeline over a mock scheduler: a pipelined
/// batcher whose submitter enqueues one task per request, carrying the
/// request's cancel token (what `ServerState::new` builds over
/// `BertServer::serve_submit_cancellable`).
fn stalling_embed_stack(
    cores: usize,
    threads_per_task: usize,
) -> (Arc<Scheduler>, Batcher<EmbedRequest, Result<Vec<f32>, String>>) {
    let sched = Scheduler::start(
        SchedConfig {
            cores,
            aging: Duration::from_millis(10),
            backfill: true,
            ..Default::default()
        },
        Arc::new(StallRunner),
    );
    let s2 = Arc::clone(&sched);
    let batcher = Batcher::start_pipelined(
        4,
        Duration::from_millis(1),
        move |requests: Vec<EmbedRequest>| {
            let handles: Vec<_> = requests
                .into_iter()
                .map(|r| {
                    s2.submit(
                        PartTask::new("stall", Vec::new(), threads_per_task)
                            .with_cancel(r.cancel),
                    )
                })
                .collect();
            Box::new(move || {
                handles
                    .into_iter()
                    .map(|h| match h.wait() {
                        Ok(_) => Ok(Vec::new()),
                        Err(e) => Err(format!("{e:#}")),
                    })
                    .collect()
            })
        },
    );
    (sched, batcher)
}

#[test]
fn timed_out_embed_returns_structured_error_and_cancels_its_task() {
    let (sched, batcher) = stalling_embed_stack(2, 2);
    let metrics = Metrics::new();

    let t0 = Instant::now();
    let resp =
        embed_with_timeout(&batcher, &metrics, vec![1, 2, 3], Duration::from_millis(50));
    // 1. structured timeout error, promptly
    let msg = resp.get("error").expect("timeout must error").as_str().unwrap();
    assert!(msg.contains("timed out"), "unexpected error: {msg}");
    assert!(
        t0.elapsed() < Duration::from_secs(5),
        "timeout path took {:?}",
        t0.elapsed()
    );
    // 2. counted
    assert_eq!(metrics.counter("request_timeouts").load(Ordering::Relaxed), 1);
    // 3. the stalled task was cancelled: the scheduler must go fully
    // idle (10s nominal execution, 5s drain budget — only cancellation
    // makes this pass) and release every ledger core
    let t0 = Instant::now();
    while sched.stats().cancelled != 1 && t0.elapsed() < Duration::from_secs(5) {
        std::thread::sleep(Duration::from_millis(1));
    }
    assert!(
        sched.drain(Duration::from_secs(5)),
        "cancelled task did not release the scheduler: {:?}",
        sched.stats()
    );
    let st = sched.stats();
    assert_eq!(st.cores_busy, 0, "timed-out task still holds cores: {st:?}");
    assert_eq!(st.inflight, 0);
    assert_eq!(st.cancelled, 1, "{st:?}");
    assert_eq!(st.completed, 0);
    assert_eq!(
        st.submitted,
        st.completed + st.failed + st.deadline_rejected + st.cancelled,
        "accounting invariant: {st:?}"
    );
}

#[test]
fn timed_out_embed_cancelled_while_queued_takes_no_cores() {
    // One stalled request saturates the 2-core budget; the second times
    // out while its task is still *queued* — it must be rejected from
    // the queue without ever occupying cores or reaching a worker.
    let (sched, batcher) = stalling_embed_stack(2, 2);
    let metrics = Metrics::new();

    // occupy the budget with a request nobody times out (yet)
    let hog_cancel = CancelToken::new();
    let hog_rx = batcher
        .submit(EmbedRequest { ids: vec![9, 9], cancel: hog_cancel.clone() });
    // wait until the hog's task actually holds the cores
    let t0 = Instant::now();
    while sched.stats().cores_busy != 2 && t0.elapsed() < Duration::from_secs(5) {
        std::thread::sleep(Duration::from_millis(1));
    }
    assert_eq!(sched.stats().cores_busy, 2, "hog task never started");

    let resp =
        embed_with_timeout(&batcher, &metrics, vec![1, 2, 3], Duration::from_millis(50));
    assert!(resp.get("error").is_some(), "queued request must time out: {resp:?}");
    assert_eq!(metrics.counter("request_timeouts").load(Ordering::Relaxed), 1);

    // the queued task must be swept without touching the ledger
    let t0 = Instant::now();
    while sched.stats().cancelled != 1 && t0.elapsed() < Duration::from_secs(5) {
        std::thread::sleep(Duration::from_millis(1));
    }
    let st = sched.stats();
    assert_eq!(st.cancelled, 1, "cancelled task never swept: {st:?}");
    assert_eq!(st.queue_depth, 0, "cancelled task stuck in queue: {st:?}");
    assert_eq!(st.cores_busy, 2, "only the hog may hold cores: {st:?}");

    // release the hog too; everything must drain
    hog_cancel.cancel();
    assert!(sched.drain(Duration::from_secs(5)), "{:?}", sched.stats());
    assert_eq!(sched.stats().cores_busy, 0);
    drop(hog_rx);
}

//! Property tests over the *real* prun engine (PJRT-backed): output
//! ordering, allocation consistency, scheduler ledger discipline.
//! Requires built artifacts (skips otherwise). Thread counts are virtual
//! here (1-core box) but the policy/scheduling code is the production
//! path. Scheduler-only invariants live in `prop_sched.rs` (mock
//! runner, no artifacts needed).

use std::sync::Arc;

use dnc_serve::engine::{AllocPolicy, JobPart, PrunRequest, RequestCtx, Session};
use dnc_serve::runtime::{artifacts_dir, Manifest, Tensor};
use dnc_serve::util::prop::check;

fn session(cores: usize) -> Option<Session> {
    let dir = artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    let m = Arc::new(Manifest::load(&dir).unwrap());
    Some(Session::new(m, cores, 2).unwrap())
}

fn bert_part(seq_bucket: usize, seed: i32) -> JobPart {
    let ids: Vec<i32> = (0..seq_bucket as i32).map(|j| (seed * 131 + j * 7) % 8192).collect();
    JobPart::new(
        format!("bert_b1_s{seq_bucket}"),
        vec![Tensor::i32(vec![1, seq_bucket], ids)],
    )
}

#[test]
fn prun_outputs_in_input_order_and_match_run() {
    let Some(sess) = session(16) else { return };
    sess.warmup(&["bert_b1_s16", "bert_b1_s32"]).unwrap();
    // run() each part alone, then prun() them together: same outputs,
    // same order — independence is what makes divide-and-conquer sound.
    check(8, |g| {
        let k = g.usize_in(2, 5);
        let parts: Vec<JobPart> = (0..k)
            .map(|i| bert_part(*g.choice(&[16usize, 32]), i as i32))
            .collect();
        let solo: Vec<Vec<Tensor>> = parts
            .iter()
            .map(|p| sess.run(&p.model, p.inputs.clone()).unwrap())
            .collect();
        let policy = *g.choice(&[AllocPolicy::PrunDef, AllocPolicy::PrunOne, AllocPolicy::PrunEq]);
        let outcome = sess
            .prun(PrunRequest::new(parts).with_policy(policy), &RequestCtx::new())
            .unwrap();
        assert_eq!(outcome.outputs.len(), k);
        for (i, (got, want)) in outcome.outputs.iter().zip(solo.iter()).enumerate() {
            assert_eq!(got, want, "part {i} differs from solo run");
        }
    });
}

#[test]
fn prun_allocation_matches_allocator() {
    let Some(sess) = session(16) else { return };
    sess.warmup(&["bert_b1_s16", "bert_b1_s64"]).unwrap();
    check(6, |g| {
        let k = g.usize_in(1, 4);
        let parts: Vec<JobPart> = (0..k)
            .map(|i| bert_part(*g.choice(&[16usize, 64]), i as i32))
            .collect();
        let sizes: Vec<usize> = parts.iter().map(|p| p.size()).collect();
        let expect = dnc_serve::engine::allocate(
            dnc_serve::engine::PartWeights::Sizes(&sizes),
            &dnc_serve::engine::CoreMap::homogeneous(16),
            AllocPolicy::PrunDef,
        );
        let outcome = sess.prun(PrunRequest::new(parts), &RequestCtx::new()).unwrap();
        assert_eq!(outcome.allocation, expect);
        // every report carries its allocation
        for (r, &e) in outcome.reports.iter().zip(expect.threads().iter()) {
            assert_eq!(r.threads, e);
        }
    });
}

#[test]
fn prun_empty_is_noop() {
    let Some(sess) = session(16) else { return };
    let outcome = sess.prun(PrunRequest::default(), &RequestCtx::new()).unwrap();
    assert!(outcome.outputs.is_empty());
    assert!(outcome.reports.is_empty());
}

#[test]
fn prun_single_part_equals_run() {
    // paper: prun on one chunk adds negligible overhead and identical
    // results (Fig. 8 X=0).
    let Some(sess) = session(16) else { return };
    sess.warmup(&["bert_b1_s16"]).unwrap();
    let part = bert_part(16, 7);
    let solo = sess.run(&part.model, part.inputs.clone()).unwrap();
    let outcome = sess.prun(PrunRequest::single(part), &RequestCtx::new()).unwrap();
    assert_eq!(outcome.outputs[0], solo);
    assert_eq!(outcome.allocation.threads(), &[16]);
}

#[test]
fn prun_bad_model_reports_error() {
    let Some(sess) = session(16) else { return };
    let parts = vec![JobPart::new("no_such_model", vec![Tensor::zeros_f32(vec![1, 4])])];
    assert!(sess.prun(PrunRequest::new(parts), &RequestCtx::new()).is_err());
}

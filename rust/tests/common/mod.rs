//! Shared mock-scheduler fixtures for the integration-test crates
//! (each test crate compiles its own copy via `mod common;` — the
//! standard pattern for sharing across Cargo's per-file test crates).
#![allow(dead_code)] // each test crate uses a subset of the fixtures

use std::sync::Arc;
use std::time::Duration;

use dnc_serve::coordinator::{Batcher, EmbedRequest};
use dnc_serve::engine::{PartTask, SchedConfig, Scheduler, TaskRunner};
use dnc_serve::runtime::{CancelToken, ExecResult, ReplyFn, TaskCancelled, Tensor};

/// "Executes" every task for 10 simulated seconds — far past any test
/// timeout or budget — unless its cancel token fires first (polled
/// every 1ms).
pub struct StallRunner {
    pub workers: usize,
}

impl TaskRunner for StallRunner {
    fn workers(&self) -> usize {
        self.workers
    }

    fn run_on(
        &self,
        worker: usize,
        _model: &str,
        _inputs: Vec<Tensor>,
        _threads: usize,
        cancel: CancelToken,
        reply: ReplyFn,
    ) {
        std::thread::spawn(move || {
            for _ in 0..10_000 {
                if cancel.is_cancelled() {
                    reply(Err(anyhow::Error::new(TaskCancelled)));
                    return;
                }
                std::thread::sleep(Duration::from_millis(1));
            }
            reply(Ok(ExecResult {
                outputs: Vec::new(),
                exec_time: Duration::from_secs(10),
                worker,
            }));
        });
    }
}

/// The router's embed pipeline over a mock scheduler: a pipelined
/// batcher whose submitter tags one stalling scheduler task per request
/// with the request's cancel token *and* budget — what
/// `ServerState::new` builds over `BertServer::serve_submit_budgeted`.
/// With `reap_expired`, the flusher also runs the router's flush-time
/// admission control: budget-dead requests get the structured
/// `deadline_rejected` reply and are never submitted.
pub fn embed_stack(
    cores: usize,
    threads_per_task: usize,
    max_batch: usize,
    max_wait: Duration,
    reap_expired: bool,
) -> (Arc<Scheduler>, Batcher<EmbedRequest, Result<Vec<f32>, String>>) {
    let sched = Scheduler::start(
        SchedConfig { cores, aging: Duration::from_millis(10), ..Default::default() },
        Arc::new(StallRunner { workers: 2 }),
    );
    let s2 = Arc::clone(&sched);
    let batcher = Batcher::start_pipelined_with_reaper(
        max_batch,
        max_wait,
        move |r: &EmbedRequest| {
            (reap_expired && r.budget.expired()).then(|| {
                Err("deadline_rejected: request budget exhausted before execution"
                    .to_string())
            })
        },
        move |requests: Vec<EmbedRequest>| {
            let handles: Vec<_> = requests
                .into_iter()
                .map(|r| {
                    s2.submit(
                        PartTask::new("stall", Vec::new(), threads_per_task)
                            .with_cancel(r.cancel)
                            .with_budget(r.budget),
                    )
                })
                .collect();
            Box::new(move || {
                handles
                    .into_iter()
                    .map(|h| match h.wait() {
                        Ok(_) => Ok(Vec::new()),
                        Err(e) => Err(format!("{e:#}")),
                    })
                    .collect()
            })
        },
    );
    (sched, batcher)
}

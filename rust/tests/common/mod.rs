//! Shared mock-scheduler fixtures for the integration-test crates
//! (each test crate compiles its own copy via `mod common;` — the
//! standard pattern for sharing across Cargo's per-file test crates).
#![allow(dead_code)] // each test crate uses a subset of the fixtures

use std::sync::{Arc, Mutex};
use std::time::Duration;

use dnc_serve::coordinator::{Batcher, EmbedRequest};
use dnc_serve::engine::{
    Budget, CoreGrant, CoreMap, PartTask, RequestCtx, SchedConfig, Scheduler,
    SubmitError, TaskRunner,
};
use dnc_serve::runtime::{CancelToken, ExecResult, ReplyFn, TaskCancelled, Tensor};

/// "Executes" every task for 10 simulated seconds — far past any test
/// timeout or budget — unless its cancel token fires first (polled
/// every 1ms). Records every token it is handed, so the ctx-propagation
/// tests can prove the executor saw the *ingress* token, not a copy
/// with a different flag.
pub struct StallRunner {
    pub workers: usize,
    /// tokens observed by run_on, submission order
    pub seen_tokens: Arc<Mutex<Vec<CancelToken>>>,
}

impl StallRunner {
    pub fn new(workers: usize) -> StallRunner {
        StallRunner { workers, seen_tokens: Arc::new(Mutex::new(Vec::new())) }
    }
}

impl TaskRunner for StallRunner {
    fn workers(&self) -> usize {
        self.workers
    }

    fn run_on(
        &self,
        worker: usize,
        _model: &str,
        _inputs: Vec<Tensor>,
        _grant: CoreGrant,
        cancel: CancelToken,
        reply: ReplyFn,
    ) {
        self.seen_tokens.lock().unwrap().push(cancel.clone());
        std::thread::spawn(move || {
            for _ in 0..10_000 {
                if cancel.is_cancelled() {
                    reply(Err(anyhow::Error::new(TaskCancelled)));
                    return;
                }
                std::thread::sleep(Duration::from_millis(1));
            }
            reply(Ok(ExecResult {
                outputs: Vec::new(),
                exec_time: Duration::from_secs(10),
                worker,
            }));
        });
    }
}

/// Per-layer observations of one request's context as it travels the
/// embed pipeline: what the batcher's flush-time admission saw, and
/// what the submitter stamped onto the scheduler task. Together with
/// `StallRunner::seen_tokens` (the executor layer) these let a test
/// assert that every layer observed the *same* token identity and
/// budget minted at the ingress.
#[derive(Clone, Default)]
pub struct LayerProbe {
    /// (token, budget) seen by the flush-time admission closure
    pub admission: Arc<Mutex<Vec<(CancelToken, Option<Budget>)>>>,
    /// (token, budget) stamped onto each submitted scheduler task
    pub submitted: Arc<Mutex<Vec<(CancelToken, Option<Budget>)>>>,
}

/// The router's embed pipeline over a mock scheduler: a pipelined
/// batcher whose submitter tags one stalling scheduler task per request
/// with the request's [`RequestCtx`] — what `ServerState::new` builds
/// over `BertServer`'s `InferenceService::submit`. With `reap_expired`,
/// the flusher also runs the router's flush-time admission control:
/// budget-dead requests get the typed `BudgetExpired` reply and are
/// never submitted.
pub fn embed_stack(
    cores: usize,
    threads_per_task: usize,
    max_batch: usize,
    max_wait: Duration,
    reap_expired: bool,
) -> (Arc<Scheduler>, Batcher<EmbedRequest, Result<Vec<f32>, SubmitError>>) {
    let (sched, batcher, _, _) =
        embed_stack_probed(cores, threads_per_task, max_batch, max_wait, reap_expired);
    (sched, batcher)
}

/// [`embed_stack`] plus the per-layer probes (admission, submit,
/// executor) used by the ctx-propagation tests.
#[allow(clippy::type_complexity)]
pub fn embed_stack_probed(
    cores: usize,
    threads_per_task: usize,
    max_batch: usize,
    max_wait: Duration,
    reap_expired: bool,
) -> (
    Arc<Scheduler>,
    Batcher<EmbedRequest, Result<Vec<f32>, SubmitError>>,
    LayerProbe,
    Arc<Mutex<Vec<CancelToken>>>,
) {
    let runner = StallRunner::new(2);
    let seen_tokens = Arc::clone(&runner.seen_tokens);
    let sched = Scheduler::start(
        SchedConfig {
            cores: CoreMap::homogeneous(cores),
            aging: Duration::from_millis(10),
            ..Default::default()
        },
        Arc::new(runner),
    );
    let probe = LayerProbe::default();
    let p_admit = probe.clone();
    let p_submit = probe.clone();
    let s2 = Arc::clone(&sched);
    let batcher = Batcher::start_service(
        max_batch,
        max_wait,
        move |r: &EmbedRequest| {
            p_admit
                .admission
                .lock()
                .unwrap()
                .push((r.ctx.token(), r.ctx.budget()));
            if r.ctx.is_cancelled() {
                Some(Err(SubmitError::Cancelled))
            } else if reap_expired && r.ctx.expired() {
                Some(Err(SubmitError::BudgetExpired))
            } else {
                None
            }
        },
        move |requests: Vec<EmbedRequest>| {
            let handles: Vec<_> = requests
                .into_iter()
                .map(|r| {
                    let task =
                        PartTask::new("stall", Vec::new(), threads_per_task).with_ctx(&r.ctx);
                    p_submit
                        .submitted
                        .lock()
                        .unwrap()
                        .push((task.cancel.clone(), task.budget));
                    s2.submit(task)
                })
                .collect();
            Box::new(move || {
                handles
                    .into_iter()
                    .map(|h| match h.wait() {
                        Ok(_) => Ok(Vec::new()),
                        Err(e) => Err(SubmitError::classify(&e)),
                    })
                    .collect()
            })
        },
    );
    (sched, batcher, probe, seen_tokens)
}

/// Convenience: an [`EmbedRequest`] with a ctx minted from a budget.
pub fn embed_request(ids: Vec<i32>, total: Duration) -> (EmbedRequest, RequestCtx) {
    let ctx = RequestCtx::new().with_budget(Budget::new(total));
    (EmbedRequest { ids, ctx: ctx.clone() }, ctx)
}

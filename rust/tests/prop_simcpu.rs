//! Property tests for the discrete-event simulator (DESIGN.md §8).

use dnc_serve::engine::allocator::{allocate, AllocPolicy, PartWeights};
use dnc_serve::engine::ledger::CoreMap;
use dnc_serve::simcpu::{simulate, simulate_sequential, ScalProfile, SimPart};
use dnc_serve::util::prop::{check, Gen};

const CASES: u64 = 300;

fn gen_profile(g: &mut Gen) -> ScalProfile {
    ScalProfile::new(g.f64_in(0.0, 0.95), g.f64_in(0.0, 5.0))
}

fn gen_parts(g: &mut Gen) -> Vec<SimPart> {
    let k = g.size(32);
    let prof = gen_profile(g);
    g.vec(k, |g| SimPart::new(g.f64_in(0.1, 500.0), prof))
}

#[test]
fn cores_never_over_leased() {
    // Replay the admission schedule and verify occupancy <= C always.
    check(CASES, |g| {
        let parts = gen_parts(g);
        let cores = g.usize_in(1, 32);
        let alloc: Vec<usize> = g.vec(parts.len(), |g| g.usize_in(1, 48));
        let r = simulate(&parts, &alloc, cores);
        // occupancy at every start event
        for i in 0..parts.len() {
            let t = r.start_ms[i];
            let occupied: usize = (0..parts.len())
                .filter(|&j| r.start_ms[j] <= t && r.end_ms[j] > t)
                .map(|j| r.threads[j])
                .sum();
            assert!(occupied <= cores, "t={t} occupied={occupied} cores={cores}");
        }
    });
}

#[test]
fn makespan_is_max_end_and_bounds_hold() {
    check(CASES, |g| {
        let parts = gen_parts(g);
        let cores = g.usize_in(1, 32);
        let alloc = allocate(
            PartWeights::Sizes(&parts.iter().map(|p| p.t1_ms as usize + 1).collect::<Vec<_>>()),
            &CoreMap::homogeneous(cores),
            AllocPolicy::PrunDef,
        )
        .into_threads();
        let r = simulate(&parts, &alloc, cores);
        let max_end = r.end_ms.iter().cloned().fold(0.0, f64::max);
        assert!((r.makespan_ms - max_end).abs() < 1e-9);
        // lower bound: the longest single part at its own thread count
        let lb = parts
            .iter()
            .zip(r.threads.iter())
            .map(|(p, &c)| p.profile.time_ms(p.t1_ms, c))
            .fold(0.0, f64::max);
        assert!(r.makespan_ms >= lb - 1e-9);
        // upper bound: fully sequential execution
        let ub: f64 = parts
            .iter()
            .zip(r.threads.iter())
            .map(|(p, &c)| p.profile.time_ms(p.t1_ms, c))
            .sum();
        assert!(r.makespan_ms <= ub + 1e-9);
    });
}

#[test]
fn starts_monotone_in_input_order() {
    // Strict FIFO admission: start times are non-decreasing in input
    // order (matches engine::sched's no-backfill FIFO admission).
    check(CASES, |g| {
        let parts = gen_parts(g);
        let cores = g.usize_in(1, 32);
        let alloc: Vec<usize> = g.vec(parts.len(), |g| g.usize_in(1, cores));
        let r = simulate(&parts, &alloc, cores);
        for w in r.start_ms.windows(2) {
            assert!(w[0] <= w[1] + 1e-9, "FIFO violated: {:?}", r.start_ms);
        }
    });
}

#[test]
fn virtual_time_non_negative_and_finite() {
    check(CASES, |g| {
        let parts = gen_parts(g);
        let cores = g.usize_in(1, 32);
        let alloc: Vec<usize> = g.vec(parts.len(), |g| g.usize_in(1, 64));
        let r = simulate(&parts, &alloc, cores);
        for i in 0..parts.len() {
            assert!(r.start_ms[i] >= 0.0);
            assert!(r.end_ms[i] >= r.start_ms[i]);
            assert!(r.end_ms[i].is_finite());
        }
    });
}

#[test]
fn sequential_equals_sum_of_each() {
    check(CASES, |g| {
        let parts = gen_parts(g);
        let cores = g.usize_in(1, 32);
        let r = simulate_sequential(&parts, cores);
        let sum: f64 = parts.iter().map(|p| p.profile.time_ms(p.t1_ms, cores)).sum();
        assert!((r.makespan_ms - sum).abs() < 1e-6, "{} vs {sum}", r.makespan_ms);
    });
}

#[test]
fn adding_cores_never_hurts_fully_parallel_parts() {
    // With a zero-overhead profile, a bigger machine can't be slower for
    // the same per-part thread allocation.
    check(CASES, |g| {
        let prof = ScalProfile::new(0.0, 0.0);
        let k = g.size(16);
        let parts: Vec<SimPart> = g.vec(k, |g| SimPart::new(g.f64_in(1.0, 100.0), prof));
        let alloc: Vec<usize> = g.vec(k, |g| g.usize_in(1, 8));
        let small = g.usize_in(1, 16);
        let big = small + g.usize_in(1, 16);
        let r_small = simulate(&parts, &alloc, small);
        let r_big = simulate(&parts, &alloc, big);
        assert!(
            r_big.makespan_ms <= r_small.makespan_ms + 1e-9,
            "big {} > small {}",
            r_big.makespan_ms,
            r_small.makespan_ms
        );
    });
}

#[test]
fn single_part_time_matches_profile_exactly() {
    check(CASES, |g| {
        let prof = gen_profile(g);
        let t1 = g.f64_in(0.1, 1000.0);
        let cores = g.usize_in(1, 32);
        let c = g.usize_in(1, cores);
        let r = simulate(&[SimPart::new(t1, prof)], &[c], cores);
        assert!((r.makespan_ms - prof.time_ms(t1, c)).abs() < 1e-9);
    });
}

//! End-to-end OCR integration: synthetic page -> real PJRT detection ->
//! classification -> rectification -> recognition -> exact-match decode,
//! under every pipeline variant. This is the repo's proof that all three
//! layers compose on the paper's §4.1 workload.

use std::sync::Arc;

use dnc_serve::engine::{AllocPolicy, RequestCtx, Session};
use dnc_serve::ocr::{exact_match, generate, GenOptions, OcrMeta, OcrPipeline};
use dnc_serve::runtime::{artifacts_dir, Manifest};
use dnc_serve::simcpu::ocr::OcrVariant;
use dnc_serve::util::prng::Rng;

fn pipeline() -> Option<OcrPipeline> {
    let dir = artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    let manifest = Arc::new(Manifest::load(&dir).unwrap());
    let session = Arc::new(Session::new(manifest, 16, 2).unwrap());
    let meta = OcrMeta::load(&dir).unwrap();
    Some(OcrPipeline::new(session, meta))
}

#[test]
fn base_pipeline_exact_match_on_clean_images() {
    let Some(p) = pipeline() else { return };
    let mut rng = Rng::new(100);
    let opts = GenOptions { noise: 0.0, ..Default::default() };
    let mut total = (0usize, 0usize);
    for _ in 0..3 {
        let img = generate(p.meta(), &mut rng, 3, &opts);
        let result = p.process(&img, OcrVariant::Base, &RequestCtx::new()).unwrap();
        assert_eq!(result.boxes.len(), img.boxes.len(), "all boxes detected");
        let (hits, n) = exact_match(&result, &img);
        total.0 += hits;
        total.1 += n;
    }
    assert_eq!(total.0, total.1, "exact match on clean pages: {total:?}");
}

#[test]
fn prun_def_pipeline_matches_base_outputs() {
    let Some(p) = pipeline() else { return };
    let mut rng = Rng::new(200);
    let img = generate(p.meta(), &mut rng, 4, &GenOptions::default());
    let base = p.process(&img, OcrVariant::Base, &RequestCtx::new()).unwrap();
    let prun = p.process(&img, OcrVariant::Prun(AllocPolicy::PrunDef), &RequestCtx::new()).unwrap();
    assert_eq!(base.boxes, prun.boxes);
    assert_eq!(base.texts, prun.texts);
    assert_eq!(base.flipped, prun.flipped);
}

#[test]
fn all_prun_variants_exact_match_with_noise_and_flips() {
    let Some(p) = pipeline() else { return };
    let opts = GenOptions { noise: 0.04, flip_prob: 0.5, ..Default::default() };
    for (i, policy) in [AllocPolicy::PrunDef, AllocPolicy::PrunOne, AllocPolicy::PrunEq]
        .into_iter()
        .enumerate()
    {
        let mut rng = Rng::new(300 + i as u64);
        let img = generate(p.meta(), &mut rng, 4, &opts);
        let result = p.process(&img, OcrVariant::Prun(policy), &RequestCtx::new()).unwrap();
        let (hits, n) = exact_match(&result, &img);
        assert_eq!(hits, n, "{policy:?}: {hits}/{n}");
        // flips detected correctly
        for gt in &img.boxes {
            let i = result
                .boxes
                .iter()
                .position(|b| b.x == gt.x && b.y == gt.y)
                .expect("box found");
            assert_eq!(result.flipped[i], gt.flipped, "flip for '{}'", gt.text);
        }
    }
}

#[test]
fn empty_page_detects_nothing() {
    let Some(p) = pipeline() else { return };
    let mut rng = Rng::new(400);
    let img = generate(p.meta(), &mut rng, 0, &GenOptions::default());
    let result = p.process(&img, OcrVariant::Base, &RequestCtx::new()).unwrap();
    assert!(result.boxes.is_empty());
    assert!(result.texts.is_empty());
}

#[test]
fn single_box_page_prun_no_failure() {
    // the paper's <2-box case: prun must behave like run
    let Some(p) = pipeline() else { return };
    let mut rng = Rng::new(500);
    let opts = GenOptions { noise: 0.0, flip_prob: 0.0, ..Default::default() };
    let img = generate(p.meta(), &mut rng, 1, &opts);
    let result = p.process(&img, OcrVariant::Prun(AllocPolicy::PrunDef), &RequestCtx::new()).unwrap();
    let (hits, n) = exact_match(&result, &img);
    assert_eq!((hits, n), (1, 1));
}

#[test]
fn many_boxes_page_all_recognized() {
    let Some(p) = pipeline() else { return };
    let mut rng = Rng::new(600);
    let opts = GenOptions { noise: 0.02, flip_prob: 0.3, min_len: 3, max_len: 8 };
    let img = generate(p.meta(), &mut rng, 10, &opts);
    assert!(img.boxes.len() >= 8, "placed {} boxes", img.boxes.len());
    let result = p.process(&img, OcrVariant::Prun(AllocPolicy::PrunDef), &RequestCtx::new()).unwrap();
    let (hits, n) = exact_match(&result, &img);
    assert_eq!(hits, n, "{hits}/{n}");
}

#[test]
fn timing_breakdown_populated() {
    let Some(p) = pipeline() else { return };
    let mut rng = Rng::new(700);
    let img = generate(p.meta(), &mut rng, 3, &GenOptions::default());
    let r = p.process(&img, OcrVariant::Base, &RequestCtx::new()).unwrap();
    assert!(r.timing.det.as_nanos() > 0);
    assert!(r.timing.cls.as_nanos() > 0);
    assert!(r.timing.rec.as_nanos() > 0);
    assert_eq!(r.timing.total(), r.timing.det + r.timing.cls + r.timing.rec);
}

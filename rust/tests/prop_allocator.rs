//! Property tests for the paper's Listing-1 allocator (DESIGN.md §8),
//! against the 0.5 typed entry point: `allocate(PartWeights, &CoreMap,
//! policy) -> Allocation`.

use dnc_serve::engine::allocator::{allocate, AllocPolicy, Allocation, PartWeights};
use dnc_serve::engine::ledger::{CoreClass, CoreMap};
use dnc_serve::util::prop::check;

const CASES: u64 = 500;

fn gen_sizes(g: &mut dnc_serve::util::prop::Gen) -> Vec<usize> {
    let k = g.size(64);
    g.vec(k, |g| g.usize_in(1, 10_000))
}

/// A random machine: homogeneous, or a fast/slow split of the same
/// total — the allocator's thread counts must only depend on the total.
fn gen_map(g: &mut dnc_serve::util::prop::Gen, cores: usize) -> CoreMap {
    if cores >= 2 && g.bool() {
        let fast = g.usize_in(1, cores - 1);
        CoreMap::heterogeneous(fast, cores - fast)
    } else {
        CoreMap::homogeneous(cores)
    }
}

/// The size-proportional weights prun-def derives (`w_i = s_i / Σs`),
/// recomputed here so properties can reason about clamping pressure
/// without reaching into the crate-private helper.
fn size_weights(sizes: &[usize]) -> Vec<f64> {
    let total: usize = sizes.iter().sum();
    sizes.iter().map(|&s| s as f64 / total as f64).collect()
}

#[test]
fn every_part_gets_at_least_one_thread() {
    check(CASES, |g| {
        let sizes = gen_sizes(g);
        let cores = g.usize_in(1, 64);
        let map = gen_map(g, cores);
        for policy in [AllocPolicy::PrunDef, AllocPolicy::PrunOne, AllocPolicy::PrunEq] {
            let alloc = allocate(PartWeights::Sizes(&sizes), &map, policy);
            assert_eq!(alloc.len(), sizes.len());
            assert!(alloc.threads().iter().all(|&c| c >= 1), "{policy:?} {alloc:?}");
        }
    });
}

#[test]
fn prun_def_exactly_fills_cores_when_parts_fit() {
    // Listing 1's remainder distribution: when k <= C and no part was
    // clamped below its floor, the total allocation is exactly C.
    check(CASES, |g| {
        let cores = g.usize_in(1, 64);
        let k = g.usize_in(1, cores);
        let sizes: Vec<usize> = g.vec(k, |g| g.usize_in(1, 10_000));
        let map = gen_map(g, cores);
        let alloc = allocate(PartWeights::Sizes(&sizes), &map, AllocPolicy::PrunDef);
        let total = alloc.total_threads();
        // clamping to >=1 can push the total above C, never below
        assert!(total >= cores, "sizes={sizes:?} cores={cores} alloc={alloc:?}");
        // without clamping pressure (every floor >= 1), total == C
        let w = size_weights(&sizes);
        if w.iter().all(|&wi| wi * cores as f64 >= 1.0) {
            assert_eq!(total, cores, "sizes={sizes:?} alloc={alloc:?}");
        }
    });
}

#[test]
fn more_parts_than_cores_means_one_thread_each() {
    check(CASES, |g| {
        let cores = g.usize_in(1, 32);
        let k = cores + g.usize_in(1, 64);
        let sizes: Vec<usize> = g.vec(k, |g| g.usize_in(1, 10_000));
        let map = gen_map(g, cores);
        let alloc = allocate(PartWeights::Sizes(&sizes), &map, AllocPolicy::PrunDef);
        assert!(alloc.threads().iter().all(|&c| c == 1), "k={k} cores={cores}");
    });
}

#[test]
fn allocation_monotone_in_size() {
    // A strictly larger part never receives fewer threads.
    check(CASES, |g| {
        let sizes = gen_sizes(g);
        let cores = g.usize_in(1, 64);
        let alloc = allocate(
            PartWeights::Sizes(&sizes),
            &gen_map(g, cores),
            AllocPolicy::PrunDef,
        )
        .into_threads();
        for i in 0..sizes.len() {
            for j in 0..sizes.len() {
                if sizes[i] > sizes[j] {
                    assert!(
                        alloc[i] >= alloc[j],
                        "sizes[{i}]={} > sizes[{j}]={} but alloc {} < {} ({sizes:?} -> {alloc:?})",
                        sizes[i], sizes[j], alloc[i], alloc[j]
                    );
                }
            }
        }
    });
}

#[test]
fn equal_sizes_get_near_equal_threads() {
    check(CASES, |g| {
        let cores = g.usize_in(1, 64);
        let k = g.usize_in(1, 64);
        let size = g.usize_in(1, 10_000);
        let alloc = allocate(
            PartWeights::Sizes(&vec![size; k]),
            &gen_map(g, cores),
            AllocPolicy::PrunDef,
        )
        .into_threads();
        let min = *alloc.iter().min().unwrap();
        let max = *alloc.iter().max().unwrap();
        assert!(max - min <= 1, "equal parts differ by >1: {alloc:?}");
    });
}

#[test]
fn permutation_equivariant() {
    // Reordering the inputs reorders the allocation the same way.
    check(CASES, |g| {
        let sizes = gen_sizes(g);
        let cores = g.usize_in(1, 64);
        let map = gen_map(g, cores);
        let alloc =
            allocate(PartWeights::Sizes(&sizes), &map, AllocPolicy::PrunDef).into_threads();
        let mut idx: Vec<usize> = (0..sizes.len()).collect();
        // deterministic rotation as the permutation
        let rot = g.usize_in(0, sizes.len() - 1);
        idx.rotate_left(rot);
        let permuted: Vec<usize> = idx.iter().map(|&i| sizes[i]).collect();
        let alloc_p =
            allocate(PartWeights::Sizes(&permuted), &map, AllocPolicy::PrunDef).into_threads();
        // sizes can repeat: compare as multisets keyed by size
        let mut a: Vec<(usize, usize)> = sizes.iter().cloned().zip(alloc.iter().cloned()).collect();
        let mut b: Vec<(usize, usize)> =
            permuted.iter().cloned().zip(alloc_p.iter().cloned()).collect();
        a.sort();
        b.sort();
        assert_eq!(a, b);
    });
}

#[test]
fn allocation_bounded_by_cores() {
    check(CASES, |g| {
        let sizes = gen_sizes(g);
        let cores = g.usize_in(1, 64);
        let alloc = allocate(
            PartWeights::Sizes(&sizes),
            &gen_map(g, cores),
            AllocPolicy::PrunDef,
        );
        assert!(alloc.threads().iter().all(|&c| c <= cores), "{alloc:?}");
    });
}

#[test]
fn measured_weights_reproduce_the_size_path() {
    // Feeding the size-derived weights back through
    // `PartWeights::Measured` is the identity: the two entry shapes
    // share the Listing-1 code path bit for bit.
    check(CASES, |g| {
        let sizes = gen_sizes(g);
        let cores = g.usize_in(1, 64);
        let map = gen_map(g, cores);
        let w = size_weights(&sizes);
        let via_sizes = allocate(PartWeights::Sizes(&sizes), &map, AllocPolicy::PrunDef);
        let via_weights =
            allocate(PartWeights::Measured(&w), &map, AllocPolicy::PrunDef);
        assert_eq!(via_sizes, via_weights);
    });
}

#[test]
fn thread_counts_ignore_the_class_split() {
    // The machine's class composition must not change *how many*
    // threads each part gets — only the footprint summary. (Placement
    // is the scheduler's job, not the allocator's.)
    check(CASES, |g| {
        let sizes = gen_sizes(g);
        let cores = g.usize_in(2, 64);
        let fast = g.usize_in(1, cores - 1);
        let split = CoreMap::heterogeneous(fast, cores - fast);
        let flat = CoreMap::homogeneous(cores);
        for policy in [AllocPolicy::PrunDef, AllocPolicy::PrunOne, AllocPolicy::PrunEq] {
            let a = allocate(PartWeights::Sizes(&sizes), &split, policy);
            let b = allocate(PartWeights::Sizes(&sizes), &flat, policy);
            assert_eq!(a.threads(), b.threads(), "{policy:?}");
        }
    });
}

#[test]
fn per_class_footprint_is_fast_first_and_bounded() {
    // The first-wave footprint charges Fast before Slow, never exceeds
    // a class's core count, and sums to min(total_threads, C).
    check(CASES, |g| {
        let sizes = gen_sizes(g);
        let cores = g.usize_in(1, 64);
        let map = gen_map(g, cores);
        let a = allocate(PartWeights::Sizes(&sizes), &map, AllocPolicy::PrunDef);
        let [fast, slow] = a.per_class();
        assert!(fast <= map.count(CoreClass::Fast), "{a:?}");
        assert!(slow <= map.count(CoreClass::Slow), "{a:?}");
        assert_eq!(fast + slow, a.total_threads().min(cores), "{a:?}");
        // fast-first: Slow is only charged once Fast is saturated
        if slow > 0 {
            assert_eq!(fast, map.count(CoreClass::Fast), "{a:?}");
        }
        // `Allocation::of` round-trips the same plan
        assert_eq!(a, Allocation::of(a.threads().to_vec(), &map));
    });
}

#[test]
fn prun_eq_uniform() {
    check(CASES, |g| {
        let sizes = gen_sizes(g);
        let cores = g.usize_in(1, 64);
        let alloc = allocate(
            PartWeights::Sizes(&sizes),
            &gen_map(g, cores),
            AllocPolicy::PrunEq,
        );
        let expect = std::cmp::max(1, cores / sizes.len());
        assert!(alloc.threads().iter().all(|&c| c == expect));
    });
}

//! Property tests for the paper's Listing-1 allocator (DESIGN.md §8).

use dnc_serve::engine::allocator::{allocate, weights, AllocPolicy};
use dnc_serve::util::prop::check;

const CASES: u64 = 500;

fn gen_sizes(g: &mut dnc_serve::util::prop::Gen) -> Vec<usize> {
    let k = g.size(64);
    g.vec(k, |g| g.usize_in(1, 10_000))
}

#[test]
fn every_part_gets_at_least_one_thread() {
    check(CASES, |g| {
        let sizes = gen_sizes(g);
        let cores = g.usize_in(1, 64);
        for policy in [AllocPolicy::PrunDef, AllocPolicy::PrunOne, AllocPolicy::PrunEq] {
            let alloc = allocate(&sizes, cores, policy);
            assert_eq!(alloc.len(), sizes.len());
            assert!(alloc.iter().all(|&c| c >= 1), "{policy:?} {alloc:?}");
        }
    });
}

#[test]
fn prun_def_exactly_fills_cores_when_parts_fit() {
    // Listing 1's remainder distribution: when k <= C and no part was
    // clamped below its floor, the total allocation is exactly C.
    check(CASES, |g| {
        let cores = g.usize_in(1, 64);
        let k = g.usize_in(1, cores);
        let sizes: Vec<usize> = g.vec(k, |g| g.usize_in(1, 10_000));
        let alloc = allocate(&sizes, cores, AllocPolicy::PrunDef);
        let total: usize = alloc.iter().sum();
        // clamping to >=1 can push the total above C, never below
        assert!(total >= cores, "sizes={sizes:?} cores={cores} alloc={alloc:?}");
        // without clamping pressure (every floor >= 1), total == C
        let w = weights(&sizes);
        if w.iter().all(|&wi| wi * cores as f64 >= 1.0) {
            assert_eq!(total, cores, "sizes={sizes:?} alloc={alloc:?}");
        }
    });
}

#[test]
fn more_parts_than_cores_means_one_thread_each() {
    check(CASES, |g| {
        let cores = g.usize_in(1, 32);
        let k = cores + g.usize_in(1, 64);
        let sizes: Vec<usize> = g.vec(k, |g| g.usize_in(1, 10_000));
        let alloc = allocate(&sizes, cores, AllocPolicy::PrunDef);
        assert!(alloc.iter().all(|&c| c == 1), "k={k} cores={cores}");
    });
}

#[test]
fn allocation_monotone_in_size() {
    // A strictly larger part never receives fewer threads.
    check(CASES, |g| {
        let sizes = gen_sizes(g);
        let cores = g.usize_in(1, 64);
        let alloc = allocate(&sizes, cores, AllocPolicy::PrunDef);
        for i in 0..sizes.len() {
            for j in 0..sizes.len() {
                if sizes[i] > sizes[j] {
                    assert!(
                        alloc[i] >= alloc[j],
                        "sizes[{i}]={} > sizes[{j}]={} but alloc {} < {} ({sizes:?} -> {alloc:?})",
                        sizes[i], sizes[j], alloc[i], alloc[j]
                    );
                }
            }
        }
    });
}

#[test]
fn equal_sizes_get_near_equal_threads() {
    check(CASES, |g| {
        let cores = g.usize_in(1, 64);
        let k = g.usize_in(1, 64);
        let size = g.usize_in(1, 10_000);
        let alloc = allocate(&vec![size; k], cores, AllocPolicy::PrunDef);
        let min = *alloc.iter().min().unwrap();
        let max = *alloc.iter().max().unwrap();
        assert!(max - min <= 1, "equal parts differ by >1: {alloc:?}");
    });
}

#[test]
fn permutation_equivariant() {
    // Reordering the inputs reorders the allocation the same way.
    check(CASES, |g| {
        let sizes = gen_sizes(g);
        let cores = g.usize_in(1, 64);
        let alloc = allocate(&sizes, cores, AllocPolicy::PrunDef);
        let mut idx: Vec<usize> = (0..sizes.len()).collect();
        // deterministic rotation as the permutation
        let rot = g.usize_in(0, sizes.len() - 1);
        idx.rotate_left(rot);
        let permuted: Vec<usize> = idx.iter().map(|&i| sizes[i]).collect();
        let alloc_p = allocate(&permuted, cores, AllocPolicy::PrunDef);
        // sizes can repeat: compare as multisets keyed by size
        let mut a: Vec<(usize, usize)> = sizes.iter().cloned().zip(alloc.iter().cloned()).collect();
        let mut b: Vec<(usize, usize)> =
            permuted.iter().cloned().zip(alloc_p.iter().cloned()).collect();
        a.sort();
        b.sort();
        assert_eq!(a, b);
    });
}

#[test]
fn allocation_bounded_by_cores() {
    check(CASES, |g| {
        let sizes = gen_sizes(g);
        let cores = g.usize_in(1, 64);
        let alloc = allocate(&sizes, cores, AllocPolicy::PrunDef);
        assert!(alloc.iter().all(|&c| c <= cores), "{alloc:?}");
    });
}

#[test]
fn weights_normalized_and_proportional() {
    check(CASES, |g| {
        let sizes = gen_sizes(g);
        let w = weights(&sizes);
        let sum: f64 = w.iter().sum();
        assert!((sum - 1.0).abs() < 1e-9);
        let total: usize = sizes.iter().sum();
        for (wi, &si) in w.iter().zip(sizes.iter()) {
            assert!((wi - si as f64 / total as f64).abs() < 1e-12);
        }
    });
}

#[test]
fn prun_eq_uniform() {
    check(CASES, |g| {
        let sizes = gen_sizes(g);
        let cores = g.usize_in(1, 64);
        let alloc = allocate(&sizes, cores, AllocPolicy::PrunEq);
        let expect = std::cmp::max(1, cores / sizes.len());
        assert!(alloc.iter().all(|&c| c == expect));
    });
}

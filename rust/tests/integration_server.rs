//! Server round-trip: TCP JSON-lines protocol over the full stack.

use std::sync::Arc;

use dnc_serve::config::Config;
use dnc_serve::coordinator::{Client, Server, ServerState};
use dnc_serve::nlp::BertServer;
use dnc_serve::engine::Session;
use dnc_serve::ocr::{OcrMeta, OcrPipeline};
use dnc_serve::runtime::{artifacts_dir, Manifest};
use dnc_serve::util::json::{arr, num, obj, s, Json};

type Running = (
    dnc_serve::coordinator::StopHandle,
    std::thread::JoinHandle<()>,
    String,
    Arc<ServerState>,
);

fn start_server() -> Option<Running> {
    start_server_with(|_| {})
}

fn start_server_with(tweak: impl FnOnce(&mut Config)) -> Option<Running> {
    let dir = artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    let manifest = Arc::new(Manifest::load(&dir).unwrap());
    let session = Arc::new(Session::new(manifest, 16, 2).unwrap());
    let bert = BertServer::new(Arc::clone(&session));
    let ocr = OcrPipeline::new(session, OcrMeta::load(&dir).unwrap());
    let mut config = Config::default();
    config.port = 0; // pick a free port
    config.max_wait_ms = 2;
    tweak(&mut config);
    let state = ServerState::new(bert, ocr, config);
    let server = Server::bind(Arc::clone(&state)).unwrap();
    let addr = server.local_addr().to_string();
    let (stop, join) = server.serve_background();
    Some((stop, join, addr, state))
}

#[test]
fn full_protocol_round_trip() {
    let Some((stop, join, addr, _state)) = start_server() else { return };
    let mut client = Client::connect(&addr).unwrap();

    // ping
    let resp = client
        .call(&obj(vec![("op", s("ping")), ("id", num(1.0))]))
        .unwrap();
    assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
    assert_eq!(resp.get("id").unwrap().as_i64(), Some(1));

    // embed text
    let resp = client
        .call(&obj(vec![
            ("op", s("embed")),
            ("id", num(2.0)),
            ("text", s("divide and conquer inference")),
        ]))
        .unwrap();
    let emb = resp.get("embedding").expect("embedding").f32_arr().unwrap();
    assert_eq!(emb.len(), 128);
    assert!(emb.iter().all(|x| x.is_finite()));

    // embed_tokens: same tokens -> same embedding (determinism through
    // the whole router/batcher/prun path)
    let tokens = arr((0..16).map(|i| num((i % 8000) as f64)));
    let r1 = client
        .call(&obj(vec![("op", s("embed_tokens")), ("tokens", tokens.clone())]))
        .unwrap();
    let r2 = client
        .call(&obj(vec![("op", s("embed_tokens")), ("tokens", tokens)]))
        .unwrap();
    assert_eq!(
        r1.get("embedding").unwrap().f32_arr().unwrap(),
        r2.get("embedding").unwrap().f32_arr().unwrap()
    );

    // ocr round trip with exact ground-truth echo
    let resp = client
        .call(&obj(vec![
            ("op", s("ocr")),
            ("seed", num(7.0)),
            ("boxes", num(3.0)),
            ("variant", s("prun-def")),
        ]))
        .unwrap();
    let texts = resp.get("texts").unwrap().as_arr().unwrap();
    let truth = resp.get("ground_truth").unwrap().as_arr().unwrap();
    assert_eq!(texts.len(), truth.len());
    for (t, g) in texts.iter().zip(truth.iter()) {
        assert_eq!(t.as_str(), g.as_str(), "OCR output matches ground truth");
    }
    assert!(resp.get("det_ms").unwrap().as_f64().unwrap() > 0.0);

    // stats reflect the traffic, including the scheduler section
    let resp = client.call(&obj(vec![("op", s("stats"))])).unwrap();
    assert!(resp.get("counter.requests").unwrap().as_i64().unwrap() >= 5);
    assert!(resp.get("latency.request").is_some());
    assert_eq!(resp.get("sched.capacity").unwrap().as_i64(), Some(16));
    assert!(resp.get("sched.completed").unwrap().as_i64().unwrap() >= 1);
    let busy = resp.get("sched.cores_busy").unwrap().as_i64().unwrap();
    assert!((0..=16).contains(&busy), "cores_busy {busy}");
    // cancellation + per-priority queue observability is always present
    assert_eq!(resp.get("sched.cancelled").unwrap().as_i64(), Some(0));
    let qh = resp.get("sched.queue_depth_high").unwrap().as_i64().unwrap();
    let qn = resp.get("sched.queue_depth_normal").unwrap().as_i64().unwrap();
    let ql = resp.get("sched.queue_depth_low").unwrap().as_i64().unwrap();
    let qd = resp.get("sched.queue_depth").unwrap().as_i64().unwrap();
    assert_eq!(qh + qn + ql, qd, "per-priority gauges must sum to queue_depth");
    // both halves of the embed pipeline are gauged: accumulated and
    // flushed-but-unresolved
    assert!(resp.get("counter.embed_pending").is_some());
    assert!(resp.get("counter.embed_inflight").is_some());

    // errors are structured
    let resp = client.call(&obj(vec![("op", s("nope"))])).unwrap();
    assert!(resp.get("error").unwrap().as_str().unwrap().contains("unknown op"));
    let resp = client.call(&Json::parse("{\"op\":\"embed\"}").unwrap()).unwrap();
    assert!(resp.get("error").is_some());

    // a negative OCR seed is rejected structurally, not wrapped
    let resp = client
        .call(&obj(vec![("op", s("ocr")), ("seed", num(-1.0)), ("boxes", num(2.0))]))
        .unwrap();
    let msg = resp.get("error").expect("negative seed must error").as_str().unwrap();
    assert!(msg.contains("non-negative"), "unexpected error: {msg}");

    stop.stop();
    join.join().unwrap();
}

#[test]
fn concurrent_clients_get_batched() {
    let Some((stop, join, addr, _state)) = start_server() else { return };
    let mut joins = Vec::new();
    for t in 0..4i64 {
        let addr = addr.clone();
        joins.push(std::thread::spawn(move || {
            let mut client = Client::connect(&addr).unwrap();
            for i in 0..3i64 {
                let tokens = arr((0..16).map(|j| num(((t * 37 + i * 11 + j) % 8000) as f64)));
                let resp = client
                    .call(&obj(vec![("op", s("embed_tokens")), ("tokens", tokens)]))
                    .unwrap();
                assert!(resp.get("embedding").is_some(), "{resp:?}");
            }
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    // check the batcher actually aggregated something
    let mut client = Client::connect(&addr).unwrap();
    let stats = client.call(&obj(vec![("op", s("stats"))])).unwrap();
    let batches = stats.get("counter.batches").unwrap().as_i64().unwrap();
    let reqs = stats.get("counter.batched_requests").unwrap().as_i64().unwrap();
    assert_eq!(reqs, 12);
    assert!(batches <= reqs, "batches={batches} reqs={reqs}");

    stop.stop();
    join.join().unwrap();
}

#[test]
fn malformed_json_line_reported() {
    let Some((stop, join, addr, _state)) = start_server() else { return };
    use std::io::{BufRead, BufReader, Write};
    let stream = std::net::TcpStream::connect(&addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = stream;
    writer.write_all(b"this is not json\n").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let resp = Json::parse(line.trim()).unwrap();
    assert!(resp.get("error").unwrap().as_str().unwrap().contains("bad json"));
    stop.stop();
    join.join().unwrap();
}

#[test]
fn concurrent_prun_jobs_share_the_scheduler() {
    // Mixed long/short prun work arriving from several connections at
    // once: everything must complete through the shared core ledger,
    // and afterwards the scheduler must be fully quiescent.
    let Some((stop, join, addr, state)) = start_server() else { return };
    let mut joins = Vec::new();
    for t in 0..4i64 {
        let addr = addr.clone();
        joins.push(std::thread::spawn(move || {
            let mut client = Client::connect(&addr).unwrap();
            for i in 0..3i64 {
                // long part mix: a bigger OCR page...
                let resp = client
                    .call(&obj(vec![
                        ("op", s("ocr")),
                        ("seed", num((t * 7 + i) as f64)),
                        ("boxes", num(4.0)),
                        ("variant", s("prun-def")),
                    ]))
                    .unwrap();
                assert!(resp.get("texts").is_some(), "{resp:?}");
                // ...interleaved with small embed parts
                let tokens = arr((0..16).map(|j| num(((t * 31 + i * 13 + j) % 8000) as f64)));
                let resp = client
                    .call(&obj(vec![("op", s("embed_tokens")), ("tokens", tokens)]))
                    .unwrap();
                assert!(resp.get("embedding").is_some(), "{resp:?}");
            }
        }));
    }
    for j in joins {
        j.join().unwrap();
    }

    let mut client = Client::connect(&addr).unwrap();
    let stats = client.call(&obj(vec![("op", s("stats"))])).unwrap();
    let completed = stats.get("sched.completed").unwrap().as_i64().unwrap();
    assert!(completed >= 12, "sched.completed {completed}");
    assert_eq!(stats.get("sched.failed").unwrap().as_i64(), Some(0));
    let busy = stats.get("sched.cores_busy").unwrap().as_i64().unwrap();
    assert!((0..=16).contains(&busy), "cores_busy {busy}");

    stop.stop();
    join.join().unwrap();
    // all replies were received before stop, so the ledger must be empty
    let st = state.bert.session().scheduler().stats();
    assert_eq!(st.inflight, 0);
    assert_eq!(st.queue_depth, 0);
    assert_eq!(st.cores_busy, 0);
}

#[test]
fn ocr_request_times_out_structurally() {
    // A 1ms OCR budget cannot cover even detection: the op must return
    // the structured timeout error promptly (instead of pinning the
    // connection thread for the whole pipeline), count ocr_timeouts,
    // and cancel its token so the pipeline's scheduler tasks release
    // their cores — the server then still quiesces on stop.
    let Some((stop, join, addr, state)) = start_server_with(|c| c.ocr_timeout_ms = 1)
    else {
        return;
    };
    let mut client = Client::connect(&addr).unwrap();
    let resp = client
        .call(&obj(vec![("op", s("ocr")), ("seed", num(3.0)), ("boxes", num(6.0))]))
        .unwrap();
    // Two correct refusal paths race at the 1ms mark: the connection
    // thread's recv timeout ("request timed out", counted in
    // ocr_timeouts), or the pipeline's own typed budget error arriving
    // first ("request budget exhausted" from the scheduler sweep).
    let msg = resp.get("error").expect("1ms OCR budget must trip").as_str().unwrap();
    assert!(
        msg.contains("timed out") || msg.contains("budget exhausted") || msg.contains("cancelled"),
        "unexpected error: {msg}"
    );
    let timeouts = state
        .metrics
        .counter("ocr_timeouts")
        .load(std::sync::atomic::Ordering::Relaxed);
    if msg.contains("timed out") {
        assert!(timeouts >= 1, "ocr_timeouts not counted: {timeouts}");
    }

    stop.stop();
    join.join().unwrap();
    let st = state.bert.session().scheduler().stats();
    assert_eq!(st.inflight, 0, "cancelled OCR work must drain: {st:?}");
    assert_eq!(st.cores_busy, 0, "{st:?}");
}

#[test]
fn shutdown_quiesces_scheduler_and_handlers() {
    let Some((stop, join, addr, state)) = start_server() else { return };
    // leave a connection open and idle to prove handlers are joined,
    // not leaked
    let mut idle = Client::connect(&addr).unwrap();
    let resp = idle.call(&obj(vec![("op", s("ping"))])).unwrap();
    assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
    let tokens = arr((0..16).map(|j| num(j as f64)));
    let resp = idle
        .call(&obj(vec![("op", s("embed_tokens")), ("tokens", tokens)]))
        .unwrap();
    assert!(resp.get("embedding").is_some());

    stop.stop();
    // serve() returns only after every connection handler joined and
    // the scheduler drained — even with `idle` still connected.
    join.join().unwrap();
    let st = state.bert.session().scheduler().stats();
    assert_eq!(st.inflight, 0, "in-flight tasks must drain on stop: {st:?}");
    assert_eq!(st.queue_depth, 0);
}

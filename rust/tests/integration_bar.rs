//! End-to-end tests for the `pallas-bar` barometer: the checked-in
//! scenario suite and recorded baseline stay coherent (the matrix CI
//! gates on actually exists), the arrival plans are deterministic, and
//! small-scale cell runs pin the behavioral claims the retired Rust
//! gate scenarios used to assert.

use std::path::{Path, PathBuf};

use dnc_serve::bar::{
    by_name, check_bars, legacy_name, load_dir, parse_csv, plans, run_cell, to_csv, Mode,
    Scenario,
};

fn scenario_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("bench/scenarios")
}

fn load_suite() -> Vec<Scenario> {
    load_dir(&scenario_dir()).expect("checked-in scenario suite loads")
}

fn baseline() -> Vec<dnc_serve::bar::Measurement> {
    let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("bench/record/ci16/quick.csv");
    let text = std::fs::read_to_string(&path).expect("checked-in baseline CSV");
    parse_csv(&text).expect("baseline CSV parses")
}

#[test]
fn suite_has_the_eight_migrated_scenarios_on_at_least_three_engines() {
    let suite = load_suite();
    let names: Vec<&str> = suite.iter().map(|s| s.name.as_str()).collect();
    assert_eq!(
        names,
        [
            "cancel_churn",
            "cancel_storm",
            "hetero_inversion",
            "longshort",
            "open_mix",
            "priority_inversion",
            "sched_smoke",
            "submit_storm",
        ],
        "load_dir is sorted by file name and every stem matches its scenario"
    );
    for sc in &suite {
        assert!(
            sc.engines.len() >= 3,
            "acceptance: `{}` must run against >= 3 engines, has {:?}",
            sc.name,
            sc.engines
        );
        for e in &sc.engines {
            assert!(by_name(e).is_some(), "`{}` lists unknown engine {e}", sc.name);
        }
    }
}

#[test]
fn legacy_mapping_is_backed_by_the_suite() {
    // Every retired JSON-gate scenario must map onto a (scenario,
    // engine) cell the suite actually runs — otherwise BENCH_pr.json
    // consumers silently lose rows.
    let suite = load_suite();
    let pairs = [
        ("sched_smoke", "static"),
        ("longshort", "static"),
        ("longshort", "adaptive"),
        ("cancel_storm", "static"),
        ("priority_inversion", "static"),
        ("hetero_inversion", "static"),
        ("hetero_inversion", "blind"),
        ("submit_storm", "sharded2"),
        ("submit_storm", "static"),
    ];
    for (scenario, engine) in pairs {
        assert!(legacy_name(scenario, engine).is_some(), "{scenario}/{engine} unmapped");
        let sc = suite
            .iter()
            .find(|s| s.name == scenario)
            .unwrap_or_else(|| panic!("legacy scenario `{scenario}` missing from the suite"));
        assert!(
            sc.engines.iter().any(|e| e == engine),
            "legacy cell {scenario}/{engine} not in the scenario's engine list"
        );
    }
}

#[test]
fn recorded_baseline_covers_the_exact_quick_matrix() {
    let suite = load_suite();
    let base = baseline();
    let mut expected = 0usize;
    for sc in &suite {
        for engine in &sc.engines {
            expected += 1;
            let cells: Vec<_> = base
                .iter()
                .filter(|m| m.scenario == sc.name && m.engine == *engine)
                .collect();
            assert_eq!(cells.len(), 1, "exactly one baseline cell for {}/{engine}", sc.name);
            let m = cells[0];
            assert_eq!(m.mode, Mode::Quick);
            assert_eq!(
                m.jobs,
                sc.arrival.submitters * sc.arrival.jobs_for(Mode::Quick),
                "{}/{engine}: baseline job count must match the scenario definition",
                sc.name
            );
            assert!(
                m.estimated,
                "{}/{engine}: hand-estimated rows must say so until re-recorded",
                sc.name
            );
        }
    }
    assert_eq!(base.len(), expected, "no orphan baseline cells");
    // The estimated baseline must already satisfy every scenario's
    // self-relative bar — otherwise the first real `bench-bar diff`
    // run is incoherent about what it is defending.
    let failures = check_bars(&suite, &base);
    assert!(failures.is_empty(), "{failures:?}");
    assert!(
        suite.iter().map(|s| s.bars.len()).sum::<usize>() >= 3,
        "the three retired gate bars must survive as scenario bars"
    );
}

#[test]
fn baseline_csv_round_trips_exactly() {
    let base = baseline();
    assert_eq!(parse_csv(&to_csv(&base)).expect("re-parse"), base);
}

#[test]
fn arrival_plans_are_deterministic_per_scenario() {
    // The jittered open-loop scenario is the one with real randomness:
    // same seed, same schedule, every time — the property cross-engine
    // comparability rests on.
    let suite = load_suite();
    for sc in &suite {
        let a = plans(sc, Mode::Quick);
        let b = plans(sc, Mode::Quick);
        assert_eq!(a, b, "`{}` arrival schedule must be seed-deterministic", sc.name);
        assert_eq!(a.len(), sc.arrival.submitters);
    }
    let open_mix = suite.iter().find(|s| s.name == "open_mix").unwrap();
    let p = plans(open_mix, Mode::Quick);
    assert!(
        p[0].gaps_us.iter().any(|g| *g != open_mix.arrival.spacing_us),
        "uniform jitter must actually perturb the gaps"
    );
    let churn = suite.iter().find(|s| s.name == "cancel_churn").unwrap();
    let flips: Vec<bool> = plans(churn, Mode::Quick)
        .iter()
        .flat_map(|p| p.cancels.iter().flatten().copied())
        .collect();
    assert!(
        flips.iter().any(|f| *f) && flips.iter().any(|f| !*f),
        "a 0.5 cancel coin over 30 jobs lands on both sides: {flips:?}"
    );
}

/// Small-scale behavioral pins over real scheduler runs — the claims
/// the retired Rust gate scenarios asserted, now driven entirely from
/// the checked-in TOMLs.
#[test]
fn cancel_storm_cell_is_not_starved_by_doomed_parts() {
    let suite = load_suite();
    let mut sc = suite.into_iter().find(|s| s.name == "cancel_storm").unwrap();
    sc.arrival.quick_jobs = 3;
    let m = run_cell(&sc, by_name("static").unwrap(), Mode::Quick).expect("cell runs");
    assert_eq!(m.jobs, 3);
    // Doomed parts declare 1000ms; if cancellation failed to reclaim
    // their cores the survivor's wall would blow far past this.
    assert!(
        m.p95_ms < 500.0,
        "survivor p95 {:.1}ms — cancellation is not reclaiming cores",
        m.p95_ms
    );
}

#[test]
fn priority_inversion_cell_keeps_the_urgent_part_fast() {
    let suite = load_suite();
    let mut sc = suite.into_iter().find(|s| s.name == "priority_inversion").unwrap();
    sc.arrival.quick_jobs = 3;
    let m = run_cell(&sc, by_name("static").unwrap(), Mode::Quick).expect("cell runs");
    // Eight 100ms hogs are in the queue; priority admission must get
    // the urgent part out well before a FIFO drain (~2 hog waves).
    assert!(
        m.p95_ms < 55.0,
        "urgent p95 {:.1}ms — priority admission is not jumping the hog queue",
        m.p95_ms
    );
}

#[test]
fn hetero_cell_prefers_class_aware_placement() {
    let suite = load_suite();
    let mut sc = suite.into_iter().find(|s| s.name == "hetero_inversion").unwrap();
    sc.arrival.quick_jobs = 4;
    let aware = run_cell(&sc, by_name("static").unwrap(), Mode::Quick).expect("static cell");
    let blind = run_cell(&sc, by_name("blind").unwrap(), Mode::Quick).expect("blind cell");
    // Direction only at this tiny scale; the full >=10% margin is the
    // scenario's [[bar]], enforced by `bench-bar diff` at real counts.
    assert!(
        aware.p95_ms < blind.p95_ms,
        "class-aware p95 {:.2}ms must beat blind {:.2}ms on the hetero machine",
        aware.p95_ms,
        blind.p95_ms
    );
}

#[test]
fn submit_storm_cell_floods_and_drains() {
    let suite = load_suite();
    let mut sc = suite.into_iter().find(|s| s.name == "submit_storm").unwrap();
    sc.arrival.submitters = 2;
    sc.arrival.quick_jobs = 10;
    let m = run_cell(&sc, by_name("sharded2").unwrap(), Mode::Quick).expect("cell runs");
    assert_eq!(m.jobs, 20, "every flooded job must drain to a wall");
    assert!(m.throughput_jobs_s > 0.0 && m.p95_ms > 0.0);
}

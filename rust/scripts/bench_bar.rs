//! `bench-bar` — the rebar-style scheduler barometer CLI.
//!
//! Scenarios are data (`rust/bench/scenarios/*.toml`), engines are the
//! named scheduler configurations in `dnc_serve::bar::ENGINES`, and
//! measurements are recorded CSVs under `rust/bench/record/<machine>/`
//! (schema: `rust/bench/FORMAT.md`).
//!
//! ```text
//! bench-bar run    [--quick] [--scenarios DIR] [--out FILE]
//! bench-bar record [--quick] [--scenarios DIR] [--record-dir DIR] [--machine NAME]
//! bench-bar diff   [--quick] [--scenarios DIR] [--record-dir DIR] [--machine NAME]
//!                  [--out FILE] [--legacy-json FILE]
//! bench-bar rank   [--quick] [--scenarios DIR] [--input FILE]
//! ```
//!
//! - `run`    run the full scenario × engine matrix and print it;
//!            `--out` also writes the measurements CSV
//! - `record` run the matrix and (re)write the recorded baseline CSV —
//!            run on a quiet machine, then commit the file
//! - `diff`   run the matrix and gate it against the recorded baseline
//!            (per-scenario `tolerance_pct`) plus every scenario's
//!            self-relative bars; this is CI's blocking bench gate.
//!            `--legacy-json` additionally emits the retired
//!            `BENCH_pr.json` shape (kept for one release)
//! - `rank`   geometric-mean p95/throughput ranking of engines across
//!            the suite, from a fresh run or `--input` CSV
//!
//! - `--quick`     smoke-sized job counts (what CI runs per PR)
//! - `--machine`   record-file subdirectory (default `ci16`)
//! - `--scenarios` scenario dir (default: `bench/scenarios`, then
//!                 `rust/bench/scenarios` — so it works from `rust/`
//!                 or the repo root)
//! - `--record-dir` record root (default: `bench/record`, then
//!                 `rust/bench/record`)
//!
//! Exit codes: 0 pass, 1 gate/measurement failure, 2 config error.

use std::path::PathBuf;
use std::process::exit;

use dnc_serve::bar::{
    self, by_name, legacy_json, rank, record_path, render_rank, Measurement, Mode, Scenario,
};
use dnc_serve::util::args::Args;

fn main() {
    let args = Args::parse_env();
    let code = match dispatch(&args) {
        Ok(code) => code,
        Err(e) => {
            eprintln!("error: {e}");
            2
        }
    };
    exit(code);
}

fn dispatch(args: &Args) -> Result<i32, String> {
    let sub = args
        .subcommand
        .clone()
        .ok_or_else(|| "missing subcommand — expected run, record, diff, or rank".to_string())?;
    let mode = if args.flag("quick") { Mode::Quick } else { Mode::Full };
    match sub.as_str() {
        "run" => cmd_run(args, mode),
        "record" => cmd_record(args, mode),
        "diff" => cmd_diff(args, mode),
        "rank" => cmd_rank(args, mode),
        other => Err(format!(
            "unknown subcommand `{other}` — expected run, record, diff, or rank"
        )),
    }
}

/// Resolve a directory option against the two supported invocation
/// roots (`rust/` and the repo root).
fn resolve_dir(explicit: Option<&str>, candidates: [&str; 2], what: &str) -> Result<PathBuf, String> {
    if let Some(p) = explicit {
        let p = PathBuf::from(p);
        if !p.is_dir() {
            return Err(format!("{what} dir {} does not exist", p.display()));
        }
        return Ok(p);
    }
    candidates
        .iter()
        .map(PathBuf::from)
        .find(|p| p.is_dir())
        .ok_or_else(|| format!("no {what} dir at {} or {}", candidates[0], candidates[1]))
}

fn load_scenarios(args: &Args) -> Result<Vec<Scenario>, String> {
    let dir = resolve_dir(
        args.get("scenarios"),
        ["bench/scenarios", "rust/bench/scenarios"],
        "scenario",
    )?;
    bar::load_dir(&dir)
}

/// Run the scenario × engine matrix cell by cell, narrating progress.
/// Returns measurement failures as `Err` tagged for exit code 1 — by
/// this point the config has validated, so anything that goes wrong is
/// the scheduler misbehaving, not the operator.
fn run_cells(scenarios: &[Scenario], mode: Mode) -> Result<Vec<Measurement>, String> {
    let mut rows = Vec::new();
    for sc in scenarios {
        for engine in &sc.engines {
            let eng = by_name(engine).expect("validated against ENGINES");
            let m = bar::run_cell(sc, eng, mode)
                .map_err(|e| format!("{}/{engine}: {e}", sc.name))?;
            println!(
                "  {:<20} {:<9} {:>6} jobs  {:>12.1}/s  p95 {:>8.2} ms",
                m.scenario, m.engine, m.jobs, m.throughput_jobs_s, m.p95_ms
            );
            rows.push(m);
        }
    }
    rows.sort_by(|a, b| (&a.scenario, &a.engine).cmp(&(&b.scenario, &b.engine)));
    Ok(rows)
}

fn write_file(path: &PathBuf, contents: &str) -> Result<(), String> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)
            .map_err(|e| format!("creating {}: {e}", parent.display()))?;
    }
    std::fs::write(path, contents).map_err(|e| format!("writing {}: {e}", path.display()))
}

fn cmd_run(args: &Args, mode: Mode) -> Result<i32, String> {
    let scenarios = load_scenarios(args)?;
    let out = args.get("out").map(PathBuf::from);
    args.finish().map_err(|e| format!("{e:#}"))?;
    println!("# bench-bar run ({} mode): {} scenarios", mode.as_str(), scenarios.len());
    let rows = match run_cells(&scenarios, mode) {
        Ok(rows) => rows,
        Err(e) => {
            eprintln!("FAIL: {e}");
            return Ok(1);
        }
    };
    if let Some(path) = out {
        write_file(&path, &bar::to_csv(&rows))?;
        println!("wrote {}", path.display());
    }
    Ok(0)
}

fn cmd_record(args: &Args, mode: Mode) -> Result<i32, String> {
    let scenarios = load_scenarios(args)?;
    let record_dir = resolve_dir(
        args.get("record-dir"),
        ["bench/record", "rust/bench/record"],
        "record",
    )?;
    let machine = args.get_or("machine", "ci16");
    args.finish().map_err(|e| format!("{e:#}"))?;
    println!("# bench-bar record ({} mode) for machine `{machine}`", mode.as_str());
    let rows = match run_cells(&scenarios, mode) {
        Ok(rows) => rows,
        Err(e) => {
            eprintln!("FAIL: {e}");
            return Ok(1);
        }
    };
    let path = record_path(&record_dir, machine, mode);
    write_file(&path, &bar::to_csv(&rows))?;
    println!("recorded {} cells to {}", rows.len(), path.display());
    Ok(0)
}

fn cmd_diff(args: &Args, mode: Mode) -> Result<i32, String> {
    let scenarios = load_scenarios(args)?;
    let record_dir = resolve_dir(
        args.get("record-dir"),
        ["bench/record", "rust/bench/record"],
        "record",
    )?;
    let machine = args.get_or("machine", "ci16");
    let out = args.get("out").map(PathBuf::from);
    let legacy = args.get("legacy-json").map(PathBuf::from);
    args.finish().map_err(|e| format!("{e:#}"))?;

    let base_path = record_path(&record_dir, machine, mode);
    let base_text = std::fs::read_to_string(&base_path).map_err(|e| {
        format!(
            "no recorded baseline at {} ({e}); record one with `bench-bar record`",
            base_path.display()
        )
    })?;
    let baseline = bar::parse_csv(&base_text).map_err(|e| format!("{}: {e}", base_path.display()))?;

    println!("# bench-bar diff ({} mode) vs {}", mode.as_str(), base_path.display());
    let rows = match run_cells(&scenarios, mode) {
        Ok(rows) => rows,
        Err(e) => {
            eprintln!("FAIL: {e}");
            return Ok(1);
        }
    };
    if let Some(path) = out {
        write_file(&path, &bar::to_csv(&rows))?;
        println!("wrote {}", path.display());
    }
    if let Some(path) = legacy {
        write_file(&path, &legacy_json(&rows).to_string())?;
        println!("wrote legacy {}", path.display());
    }

    let outcome = bar::diff(&rows, &baseline, &scenarios);
    println!();
    for line in &outcome.lines {
        println!("{line}");
    }
    if outcome.passed() {
        println!("\ngate PASS: {} cells within tolerance, all bars hold", outcome.lines.len());
        Ok(0)
    } else {
        eprintln!("\ngate FAIL:");
        for f in &outcome.failures {
            eprintln!("  - {f}");
        }
        Ok(1)
    }
}

fn cmd_rank(args: &Args, mode: Mode) -> Result<i32, String> {
    let input = args.get("input").map(PathBuf::from);
    let rows = match input {
        Some(path) => {
            args.finish().map_err(|e| format!("{e:#}"))?;
            let text = std::fs::read_to_string(&path)
                .map_err(|e| format!("reading {}: {e}", path.display()))?;
            bar::parse_csv(&text).map_err(|e| format!("{}: {e}", path.display()))?
        }
        None => {
            let scenarios = load_scenarios(args)?;
            args.finish().map_err(|e| format!("{e:#}"))?;
            println!("# bench-bar rank ({} mode)", mode.as_str());
            match run_cells(&scenarios, mode) {
                Ok(rows) => rows,
                Err(e) => {
                    eprintln!("FAIL: {e}");
                    return Ok(1);
                }
            }
        }
    };
    println!();
    print!("{}", render_rank(&rank(&rows)));
    Ok(0)
}

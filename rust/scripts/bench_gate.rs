//! `bench-gate` — the CI bench-regression gate.
//!
//! Runs the artifact-free scheduler/adaptive smoke scenarios
//! (`dnc_serve::bench::gate`), writes the results to `BENCH_pr.json`,
//! and compares them against the checked-in `BENCH_baseline.json`:
//! a scenario whose throughput drops (or p95 rises) beyond the
//! tolerance fails the run — rebar-style recorded baselines keeping a
//! performance-focused codebase honest.
//!
//! ```text
//! bench-gate [--quick] [--out FILE] [--baseline FILE]
//!            [--tolerance PCT] [--record]
//! ```
//!
//! - `--quick`     smoke-sized job counts (what CI runs per PR)
//! - `--out`       where to write the PR results (default BENCH_pr.json,
//!                 resolved next to the baseline file)
//! - `--baseline`  recorded baseline (default: BENCH_baseline.json in
//!                 the current dir, then the parent — i.e. the repo
//!                 root when invoked from rust/)
//! - `--tolerance` default allowed drift in percent (15; a baseline
//!                 scenario may override with its own "tolerance_pct")
//! - `--record`    (re)write the baseline from this run instead of
//!                 comparing — run on a quiet machine, then commit
//!
//! Exit codes: 0 pass/recorded, 1 regression (or the adaptive policy
//! losing to static), 2 usage/environment error.

use std::path::PathBuf;
use std::process::exit;

use dnc_serve::bench::gate;
use dnc_serve::util::args::Args;
use dnc_serve::util::json::Json;

fn main() {
    let args = Args::parse_env();
    let quick = args.flag("quick");
    let record = args.flag("record");
    let tolerance = args.f64_or("tolerance", 15.0);
    let baseline_path = match args.get("baseline") {
        Some(p) => PathBuf::from(p),
        None => {
            // Invoked from rust/ the trajectory files live one level up.
            // An *existing* file wins in both modes — --record must
            // overwrite the baseline CI compares against, not drop a
            // fresh one in the crate dir.
            let local = PathBuf::from("BENCH_baseline.json");
            let parent = PathBuf::from("../BENCH_baseline.json");
            if local.exists() {
                local
            } else if parent.exists() {
                parent
            } else {
                local
            }
        }
    };
    let out_path = match args.get("out") {
        Some(p) => PathBuf::from(p),
        None => baseline_path.with_file_name("BENCH_pr.json"),
    };
    if let Err(e) = args.finish() {
        eprintln!("error: {e:#}");
        exit(2);
    }

    println!(
        "# bench-gate ({} mode) — scheduler + adaptive-policy smoke scenarios",
        if quick { "quick" } else { "full" }
    );
    let results = gate::run_all(quick);
    println!(
        "{:<22} {:>6} {:>14} {:>9} {:>9}",
        "scenario", "jobs", "throughput/s", "p50 ms", "p95 ms"
    );
    for r in &results {
        println!(
            "{:<22} {:>6} {:>14.1} {:>9.2} {:>9.2}",
            r.name, r.jobs, r.throughput_jobs_s, r.p50_ms, r.p95_ms
        );
    }
    let pr_json = gate::results_to_json(&results);
    if let Err(e) = std::fs::write(&out_path, pr_json.to_string()) {
        eprintln!("error: writing {}: {e}", out_path.display());
        exit(2);
    }
    println!("\nwrote {}", out_path.display());

    // Self-relative acceptance criterion, independent of any baseline:
    // on the misleading-size long/short workload, profiled core sizing
    // must beat the static size-proportional split by >= 10% p95. In
    // --record mode this only warns — recording must always be able to
    // refresh a stale baseline.
    let find = |name: &str| results.iter().find(|r| r.name == name);
    if let (Some(st), Some(ad)) = (find("longshort_static"), find("longshort_adaptive")) {
        if ad.p95_ms > 0.9 * st.p95_ms {
            eprintln!(
                "{}: adaptive p95 {:.2} ms not >=10% better than static {:.2} ms",
                if record { "WARN" } else { "FAIL" },
                ad.p95_ms,
                st.p95_ms
            );
            if !record {
                exit(1);
            }
        } else {
            println!(
                "adaptive beats static by {:.0}% p95 ({:.2} -> {:.2} ms)",
                100.0 * (1.0 - ad.p95_ms / st.p95_ms),
                st.p95_ms,
                ad.p95_ms
            );
        }
    }

    // Second self-relative bar: on the many-producer submit flood, the
    // sharded dispatcher (N >= 2 scheduler shards) must strictly beat
    // the single-shard configuration on submit throughput — the whole
    // point of splitting the event channel and the ledger. As above,
    // --record only warns so a stale baseline can always be refreshed.
    if let (Some(sh), Some(si)) = (find("submit_storm"), find("submit_storm_single")) {
        if sh.throughput_jobs_s <= si.throughput_jobs_s {
            eprintln!(
                "{}: sharded submit throughput {:.0} ops/s not above single-shard {:.0} ops/s",
                if record { "WARN" } else { "FAIL" },
                sh.throughput_jobs_s,
                si.throughput_jobs_s
            );
            if !record {
                exit(1);
            }
        } else {
            println!(
                "sharding lifts submit throughput {:.0} -> {:.0} ops/s (+{:.0}%)",
                si.throughput_jobs_s,
                sh.throughput_jobs_s,
                100.0 * (sh.throughput_jobs_s / si.throughput_jobs_s - 1.0)
            );
        }
    }

    // Third self-relative bar: on the heterogeneous core map (fast +
    // half-speed slow classes), class-aware placement must beat
    // class-blind placement by >= 10% p95 — otherwise the core ledger's
    // classes are decorative. The bar itself lives in the gate
    // (`gate::hetero_bar`) so its threshold is unit-tested.
    if let (Some(aw), Some(bl)) =
        (find("hetero_inversion"), find("hetero_inversion_blind"))
    {
        match gate::hetero_bar(aw, bl) {
            Some(msg) => {
                eprintln!("{}: {msg}", if record { "WARN" } else { "FAIL" });
                if !record {
                    exit(1);
                }
            }
            None => println!(
                "class-aware placement beats blind by {:.0}% p95 ({:.2} -> {:.2} ms)",
                100.0 * (1.0 - aw.p95_ms / bl.p95_ms),
                bl.p95_ms,
                aw.p95_ms
            ),
        }
    }

    if record {
        // Preserve the hand-set per-scenario tolerance_pct overrides
        // from the previous baseline — re-recording refreshes the
        // numbers, not the noise model.
        let mut recorded = pr_json.clone();
        if let Ok(old) = Json::parse_file(&baseline_path) {
            if let Json::Obj(root) = &mut recorded {
                if let Some((_, Json::Obj(scen))) =
                    root.iter_mut().find(|(k, _)| k == "scenarios")
                {
                    for (name, entry) in scen.iter_mut() {
                        let tol = old
                            .get("scenarios")
                            .and_then(|s| s.get(name.as_str()))
                            .and_then(|e| e.get("tolerance_pct"))
                            .cloned();
                        if let (Json::Obj(fields), Some(t)) = (entry, tol) {
                            fields.push(("tolerance_pct".to_string(), t));
                        }
                    }
                }
            }
        }
        if let Err(e) = std::fs::write(&baseline_path, recorded.to_string()) {
            eprintln!("error: writing {}: {e}", baseline_path.display());
            exit(2);
        }
        println!("recorded baseline {}", baseline_path.display());
        return;
    }

    let baseline = match Json::parse_file(&baseline_path) {
        Ok(j) => j,
        Err(e) => {
            eprintln!(
                "error: no usable baseline at {} ({e:#}); record one with --record",
                baseline_path.display()
            );
            exit(2);
        }
    };
    let failures = gate::compare(&pr_json, &baseline, tolerance);
    if failures.is_empty() {
        println!("gate PASS: within tolerance of {}", baseline_path.display());
    } else {
        eprintln!("\ngate FAIL vs {}:", baseline_path.display());
        for f in &failures {
            eprintln!("  - {f}");
        }
        exit(1);
    }
}

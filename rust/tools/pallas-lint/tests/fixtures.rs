//! Pins every rule's behaviour against the fixture corpus: each rule
//! has a must-fire and a must-not-fire case, and the path-scoped rules
//! additionally prove their scoping by re-checking the same source
//! under an exempt virtual path.

use pallas_lint::{check_source, Finding};

fn check(virtual_path: &str, src: &str) -> Vec<Finding> {
    check_source(virtual_path, src).expect("fixture must parse")
}

fn rules(findings: &[Finding]) -> Vec<&'static str> {
    findings.iter().map(|f| f.rule).collect()
}

// ------------------------------------------------------------------ PL001

#[test]
fn pl001_fires_on_both_spawn_forms_outside_runtime() {
    let f = check("coordinator/evil.rs", include_str!("../fixtures/pl001_fire.rs"));
    assert_eq!(rules(&f), vec!["PL001", "PL001"], "findings: {f:#?}");
    // one per spawn form, on the right lines
    assert_eq!(f[0].line, 7, "std::thread::spawn call");
    assert_eq!(f[1].line, 10, "Builder .spawn call");
}

#[test]
fn pl001_exempts_runtime_and_the_scheduler() {
    let src = include_str!("../fixtures/pl001_fire.rs");
    assert!(check("runtime/evil.rs", src).is_empty(), "runtime/ may spawn");
    assert!(check("runtime/pool.rs", src).is_empty());
    assert!(check("engine/sched.rs", src).is_empty(), "the shards may spawn");
}

#[test]
fn pl001_ignores_domain_spawn_methods_and_test_threads() {
    let f = check("coordinator/ok.rs", include_str!("../fixtures/pl001_clean.rs"));
    assert!(f.is_empty(), "findings: {f:#?}");
}

// ------------------------------------------------------------------ PL002

#[test]
fn pl002_fires_on_guard_unwrap_and_expect() {
    let f = check("engine/anywhere.rs", include_str!("../fixtures/pl002_fire.rs"));
    assert_eq!(rules(&f), vec!["PL002", "PL002", "PL002"], "findings: {f:#?}");
    assert!(f[0].message.contains("lock_recover"));
    assert!(f[1].message.contains("read_recover"));
    assert!(f[2].message.contains("write_recover"));
}

#[test]
fn pl002_applies_in_every_file() {
    // No path exemption: even the scheduler may not unwrap guards.
    let f = check("engine/sched.rs", include_str!("../fixtures/pl002_fire.rs"));
    assert_eq!(f.len(), 3);
}

#[test]
fn pl002_ignores_recovering_helpers_io_reads_and_tests() {
    let f = check("util/ok.rs", include_str!("../fixtures/pl002_clean.rs"));
    assert!(f.is_empty(), "findings: {f:#?}");
}

// ------------------------------------------------------------------ PL003

#[test]
fn pl003_fires_on_raw_instant_in_hot_path_files() {
    let src = include_str!("../fixtures/pl003_fire.rs");
    let sched = check("engine/sched.rs", src);
    assert_eq!(rules(&sched), vec!["PL003", "PL003"], "findings: {sched:#?}");
    let pool = check("runtime/pool.rs", src);
    assert_eq!(pool.len(), 2, "pool.rs is in scope too");
}

#[test]
fn pl003_only_scopes_the_hot_path_files() {
    let src = include_str!("../fixtures/pl003_fire.rs");
    assert!(check("nlp/serving.rs", src).is_empty(), "serving edge reads real time");
    assert!(check("engine/profile.rs", src).is_empty());
}

#[test]
fn pl003_accepts_the_clock_shim_and_test_time() {
    let f = check("engine/sched.rs", include_str!("../fixtures/pl003_clean.rs"));
    assert!(f.is_empty(), "findings: {f:#?}");
}

// ------------------------------------------------------------------ PL004

#[test]
fn pl004_fires_on_mid_stack_minting() {
    let f = check("coordinator/batcher.rs", include_str!("../fixtures/pl004_fire.rs"));
    assert_eq!(rules(&f), vec!["PL004", "PL004", "PL004"], "findings: {f:#?}");
    assert!(f[0].message.contains("Budget::new"));
    assert!(f[1].message.contains("CancelToken::new"));
    assert!(f[2].message.contains("RequestCtx::default"));
}

#[test]
fn pl004_exempts_defining_and_ingress_modules() {
    let src = include_str!("../fixtures/pl004_fire.rs");
    for path in [
        "engine/ctx.rs",
        "engine/budget.rs",
        "runtime/cancel.rs",
        "coordinator/router.rs",
        "main.rs",
        "bench/gate.rs",
    ] {
        assert!(check(path, src).is_empty(), "{path} may mint request state");
    }
}

#[test]
fn pl004_ignores_ctx_threading_and_test_mints() {
    let f = check("coordinator/batcher.rs", include_str!("../fixtures/pl004_clean.rs"));
    assert!(f.is_empty(), "findings: {f:#?}");
}

// ------------------------------------------------------------------ PL005

#[test]
fn pl005_fires_on_shim_names_even_in_tests() {
    let f = check("engine/session.rs", include_str!("../fixtures/pl005_fire.rs"));
    assert_eq!(
        rules(&f),
        vec!["PL005"; 6],
        "impl JobPart builder + definition + call site + test-mod use + \
         the two PR-8 names; findings: {f:#?}"
    );
    assert!(
        f.iter().any(|x| x.message.contains("JobPart::with_cancel")),
        "the structural JobPart check must fire"
    );
}

#[test]
fn pl005_spares_the_live_builder_names_and_prose() {
    let f = check("engine/part.rs", include_str!("../fixtures/pl005_clean.rs"));
    assert!(f.is_empty(), "findings: {f:#?}");
}

// --------------------------------------------------------------- ordering

#[test]
fn findings_carry_one_indexed_lines_and_render_grep_style() {
    let f = check("coordinator/evil.rs", include_str!("../fixtures/pl001_fire.rs"));
    let rendered = f[0].to_string();
    assert!(
        rendered.starts_with("coordinator/evil.rs:7 PL001 "),
        "got: {rendered}"
    );
}

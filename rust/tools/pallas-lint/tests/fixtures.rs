//! Pins every rule's behaviour against the fixture corpus: each rule
//! has a must-fire and a must-not-fire case, and the path-scoped rules
//! additionally prove their scoping by re-checking the same source
//! under an exempt virtual path.

use pallas_lint::{check_source, check_sources, parse_lock_order, Finding, LockOrder, TreeReport};

fn check(virtual_path: &str, src: &str) -> Vec<Finding> {
    check_source(virtual_path, src).expect("fixture must parse")
}

/// Run the full eight-rule analysis (per-file + crate-wide) over one
/// fixture under a virtual path.
fn check_crate(virtual_path: &str, src: &str, order: Option<&LockOrder>) -> TreeReport {
    check_sources(&[(virtual_path.to_string(), src.to_string())], order)
        .expect("fixture must parse")
}

fn rules(findings: &[Finding]) -> Vec<&'static str> {
    findings.iter().map(|f| f.rule).collect()
}

/// The two-lock hierarchy the PL006 fixtures are written against.
const AB_ORDER: &str = r#"
[[lock]]
name = "locks.alpha"
field = "alpha"

[[lock]]
name = "locks.beta"
field = "beta"

order = "locks.alpha < locks.beta"
"#;

// ------------------------------------------------------------------ PL001

#[test]
fn pl001_fires_on_both_spawn_forms_outside_runtime() {
    let f = check("coordinator/evil.rs", include_str!("../fixtures/pl001_fire.rs"));
    assert_eq!(rules(&f), vec!["PL001", "PL001"], "findings: {f:#?}");
    // one per spawn form, on the right lines
    assert_eq!(f[0].line, 7, "std::thread::spawn call");
    assert_eq!(f[1].line, 10, "Builder .spawn call");
}

#[test]
fn pl001_exempts_runtime_and_the_scheduler() {
    let src = include_str!("../fixtures/pl001_fire.rs");
    assert!(check("runtime/evil.rs", src).is_empty(), "runtime/ may spawn");
    assert!(check("runtime/pool.rs", src).is_empty());
    assert!(check("engine/sched.rs", src).is_empty(), "the shards may spawn");
}

#[test]
fn pl001_ignores_domain_spawn_methods_and_test_threads() {
    let f = check("coordinator/ok.rs", include_str!("../fixtures/pl001_clean.rs"));
    assert!(f.is_empty(), "findings: {f:#?}");
}

// ------------------------------------------------------------------ PL002

#[test]
fn pl002_fires_on_guard_unwrap_and_expect() {
    let f = check("engine/anywhere.rs", include_str!("../fixtures/pl002_fire.rs"));
    assert_eq!(rules(&f), vec!["PL002", "PL002", "PL002"], "findings: {f:#?}");
    assert!(f[0].message.contains("lock_recover"));
    assert!(f[1].message.contains("read_recover"));
    assert!(f[2].message.contains("write_recover"));
}

#[test]
fn pl002_applies_in_every_file() {
    // No path exemption: even the scheduler may not unwrap guards.
    let f = check("engine/sched.rs", include_str!("../fixtures/pl002_fire.rs"));
    assert_eq!(f.len(), 3);
}

#[test]
fn pl002_ignores_recovering_helpers_io_reads_and_tests() {
    let f = check("util/ok.rs", include_str!("../fixtures/pl002_clean.rs"));
    assert!(f.is_empty(), "findings: {f:#?}");
}

// ------------------------------------------------------------------ PL003

#[test]
fn pl003_fires_on_raw_instant_in_hot_path_files() {
    let src = include_str!("../fixtures/pl003_fire.rs");
    let sched = check("engine/sched.rs", src);
    assert_eq!(rules(&sched), vec!["PL003", "PL003"], "findings: {sched:#?}");
    let pool = check("runtime/pool.rs", src);
    assert_eq!(pool.len(), 2, "pool.rs is in scope too");
}

#[test]
fn pl003_only_scopes_the_hot_path_files() {
    let src = include_str!("../fixtures/pl003_fire.rs");
    assert!(check("nlp/serving.rs", src).is_empty(), "serving edge reads real time");
    assert!(check("engine/profile.rs", src).is_empty());
}

#[test]
fn pl003_accepts_the_clock_shim_and_test_time() {
    let f = check("engine/sched.rs", include_str!("../fixtures/pl003_clean.rs"));
    assert!(f.is_empty(), "findings: {f:#?}");
}

// ------------------------------------------------------------------ PL004

#[test]
fn pl004_fires_on_mid_stack_minting() {
    let f = check("coordinator/batcher.rs", include_str!("../fixtures/pl004_fire.rs"));
    assert_eq!(rules(&f), vec!["PL004", "PL004", "PL004"], "findings: {f:#?}");
    assert!(f[0].message.contains("Budget::new"));
    assert!(f[1].message.contains("CancelToken::new"));
    assert!(f[2].message.contains("RequestCtx::default"));
}

#[test]
fn pl004_exempts_defining_and_ingress_modules() {
    let src = include_str!("../fixtures/pl004_fire.rs");
    for path in [
        "engine/ctx.rs",
        "engine/budget.rs",
        "runtime/cancel.rs",
        "coordinator/router.rs",
        "main.rs",
        "bench/gate.rs",
    ] {
        assert!(check(path, src).is_empty(), "{path} may mint request state");
    }
}

#[test]
fn pl004_ignores_ctx_threading_and_test_mints() {
    let f = check("coordinator/batcher.rs", include_str!("../fixtures/pl004_clean.rs"));
    assert!(f.is_empty(), "findings: {f:#?}");
}

// ------------------------------------------------------------------ PL005

#[test]
fn pl005_fires_on_shim_names_even_in_tests() {
    let f = check("engine/session.rs", include_str!("../fixtures/pl005_fire.rs"));
    assert_eq!(
        rules(&f),
        vec!["PL005"; 6],
        "impl JobPart builder + definition + call site + test-mod use + \
         the two PR-8 names; findings: {f:#?}"
    );
    assert!(
        f.iter().any(|x| x.message.contains("JobPart::with_cancel")),
        "the structural JobPart check must fire"
    );
}

#[test]
fn pl005_spares_the_live_builder_names_and_prose() {
    let f = check("engine/part.rs", include_str!("../fixtures/pl005_clean.rs"));
    assert!(f.is_empty(), "findings: {f:#?}");
}

// ------------------------------------------------------------------ PL006

#[test]
fn pl006_fires_on_inverted_and_undeclared_acquisitions() {
    let order = parse_lock_order(AB_ORDER).expect("test hierarchy parses");
    let rep = check_crate(
        "engine/work.rs",
        include_str!("../fixtures/pl006_fire.rs"),
        Some(&order),
    );
    let f = &rep.findings;
    assert_eq!(rules(f), vec!["PL006", "PL006", "PL006"], "findings: {f:#?}");
    assert_eq!(f[0].line, 24, "direct inversion");
    assert!(f[0].message.contains("inverts the declared order"), "got: {}", f[0].message);
    assert_eq!(f[1].line, 31, "inversion one call level deep");
    assert!(f[1].message.contains("via call to `Work::grab_alpha`"), "got: {}", f[1].message);
    assert_eq!(f[2].line, 41, "undeclared lock");
    assert!(f[2].message.contains("matches no [[lock]] entry"), "got: {}", f[2].message);
    // the illegal pair is also reported as a non-ok observed edge
    assert!(
        rep.lock_edges.iter().any(|e| e.from == "locks.beta" && e.to == "locks.alpha" && !e.ok),
        "edges: {:?}",
        rep.lock_edges
    );
}

#[test]
fn pl006_accepts_in_order_nesting_tail_guards_and_tests() {
    let order = parse_lock_order(AB_ORDER).expect("test hierarchy parses");
    let rep = check_crate(
        "engine/work.rs",
        include_str!("../fixtures/pl006_clean.rs"),
        Some(&order),
    );
    assert!(rep.findings.is_empty(), "findings: {:#?}", rep.findings);
    // the legal alpha→beta nesting is observed and marked ok — this is
    // what the DOT artifact renders as a dashed blue edge
    assert!(
        rep.lock_edges.iter().all(|e| e.ok) && !rep.lock_edges.is_empty(),
        "edges: {:?}",
        rep.lock_edges
    );
}

#[test]
fn pl006_is_inert_without_a_declared_order() {
    let rep = check_crate("engine/work.rs", include_str!("../fixtures/pl006_fire.rs"), None);
    assert!(rep.findings.is_empty(), "findings: {:#?}", rep.findings);
    assert!(rep.lock_edges.is_empty());
}

// ------------------------------------------------------------------ PL007

#[test]
fn pl007_fires_on_blocking_and_nested_acquires_under_a_guard() {
    let rep = check_crate(
        "engine/sched.rs",
        include_str!("../fixtures/pl007_fire.rs"),
        None,
    );
    let f = &rep.findings;
    assert_eq!(rules(f), vec!["PL007"; 5], "findings: {f:#?}");
    assert_eq!(f[0].line, 25, "zero-arg join under the for-head temporary");
    assert!(f[0].message.contains(".join()"), "got: {}", f[0].message);
    assert_eq!(f[1].line, 31, "recv under a named guard");
    assert_eq!(f[2].line, 38, "recv_timeout under a named guard");
    assert_eq!(f[3].line, 45, "thread::sleep under a named guard");
    assert!(f[3].message.contains("thread::sleep()"), "got: {}", f[3].message);
    assert_eq!(f[4].line, 51, "nested lock_recover");
    assert!(f[4].message.contains("nested lock acquisition"), "got: {}", f[4].message);
}

#[test]
fn pl007_only_scopes_the_hot_path_files() {
    let rep = check_crate(
        "engine/profile.rs",
        include_str!("../fixtures/pl007_fire.rs"),
        None,
    );
    assert!(rep.findings.is_empty(), "findings: {:#?}", rep.findings);
}

#[test]
fn pl007_accepts_condvar_waits_collect_then_join_and_tests() {
    let rep = check_crate(
        "coordinator/batcher.rs",
        include_str!("../fixtures/pl007_clean.rs"),
        None,
    );
    assert!(rep.findings.is_empty(), "findings: {:#?}", rep.findings);
}

// ------------------------------------------------------------------ PL008

#[test]
fn pl008_fires_on_literal_names_and_unknown_constants() {
    let rep = check_crate(
        "coordinator/router.rs",
        include_str!("../fixtures/pl008_fire.rs"),
        None,
    );
    let f = &rep.findings;
    assert_eq!(rules(f), vec!["PL008", "PL008", "PL008"], "findings: {f:#?}");
    assert_eq!(f[0].line, 23, "string-literal .add");
    assert!(f[0].message.contains("raw string literal"), "got: {}", f[0].message);
    assert_eq!(f[1].line, 24, "string-literal .record");
    assert_eq!(f[2].line, 25, "unknown names:: constant");
    assert!(
        f[2].message.contains("`names::QUEUE_DEPTH` is not a constant"),
        "got: {}",
        f[2].message
    );
}

#[test]
fn pl008_accepts_registry_paths_imports_and_non_string_args() {
    let rep = check_crate(
        "coordinator/router.rs",
        include_str!("../fixtures/pl008_clean.rs"),
        None,
    );
    assert!(rep.findings.is_empty(), "findings: {:#?}", rep.findings);
}

// --------------------------------------------------------- fixture corpus

/// Meta-test: adding a PL00N rule without both fixture halves is
/// itself a test failure — the corpus cannot silently drift behind the
/// rule table.
#[test]
fn every_rule_has_fire_and_clean_fixtures() {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("fixtures");
    for (id, _) in pallas_lint::RULES {
        let stem = id.to_lowercase();
        for kind in ["fire", "clean"] {
            let path = dir.join(format!("{stem}_{kind}.rs"));
            let meta = std::fs::metadata(&path).unwrap_or_else(|_| {
                panic!("rule {id} is missing its must-{kind} fixture at {}", path.display())
            });
            assert!(meta.len() > 0, "rule {id}'s {kind} fixture is empty");
        }
    }
}

// --------------------------------------------------------------- ordering

#[test]
fn findings_carry_one_indexed_lines_and_render_grep_style() {
    let f = check("coordinator/evil.rs", include_str!("../fixtures/pl001_fire.rs"));
    let rendered = f[0].to_string();
    assert!(
        rendered.starts_with("coordinator/evil.rs:7 PL001 "),
        "got: {rendered}"
    );
}

//! Runs pallas-lint against the real `rust/src/` tree as part of
//! `cargo test`, with the checked-in allowlist applied. This is the
//! same check CI's `lint-invariants` job runs via the binary — keeping
//! it in the test suite means a plain `cargo test` in `rust/` cannot
//! pass while the tree violates a concurrency contract, and that the
//! allowlist cannot rot (a stale entry fails this test too).

use std::path::Path;

use pallas_lint::{apply_allowlist, check_tree, parse_allowlist};

fn crate_root() -> &'static Path {
    // tools/pallas-lint -> tools -> rust
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .and_then(Path::parent)
        .expect("pallas-lint lives two levels under the rust crate root")
}

#[test]
fn real_source_tree_is_lint_clean_under_the_checked_in_allowlist() {
    let src = crate_root().join("src");
    let allow_path = crate_root().join("lint-allow.toml");

    let findings = check_tree(&src).expect("rust/src must parse");
    let allow_text =
        std::fs::read_to_string(&allow_path).expect("rust/lint-allow.toml must exist");
    let allow = parse_allowlist(&allow_text).expect("lint-allow.toml must parse");

    let report = apply_allowlist(&findings, &allow);

    assert!(
        report.over_budget.is_empty(),
        "allowlist entries over budget:\n{}",
        report.over_budget.join("\n")
    );
    assert!(
        report.active.is_empty(),
        "invariant violations in rust/src (fix the code or justify an \
         allowlist entry in rust/lint-allow.toml):\n{}",
        report
            .active
            .iter()
            .map(ToString::to_string)
            .collect::<Vec<_>>()
            .join("\n")
    );
    assert!(
        report.unused.is_empty(),
        "stale lint-allow.toml entries (delete them):\n{}",
        report
            .unused
            .iter()
            .map(|e| format!("{} in {} ({})", e.rule, e.file, e.reason))
            .collect::<Vec<_>>()
            .join("\n")
    );
}

#[test]
fn the_allowlist_suppresses_something() {
    // Guards against the allowlist and tree drifting apart silently in
    // the other direction: if every entry stopped matching at once the
    // `unused` check above would catch it, but this pins the intent —
    // the tree currently *needs* exceptions (ingress spawns, default
    // kill-switch tokens), and `suppressed` counts them.
    let src = crate_root().join("src");
    let allow_text =
        std::fs::read_to_string(crate_root().join("lint-allow.toml")).unwrap();
    let findings = check_tree(&src).expect("rust/src must parse");
    let allow = parse_allowlist(&allow_text).expect("lint-allow.toml must parse");
    let report = apply_allowlist(&findings, &allow);
    assert!(report.suppressed > 0, "expected the justified exceptions to match");
}

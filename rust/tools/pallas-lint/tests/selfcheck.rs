//! Runs pallas-lint against the real `rust/src/` tree as part of
//! `cargo test`, with the checked-in allowlist and lock hierarchy
//! applied — all eight rules, exactly as CI's `lint-invariants` job
//! runs them via the binary. Keeping this in the test suite means a
//! plain `cargo test` in `rust/` cannot pass while the tree violates a
//! concurrency contract, and that neither config file can rot (a stale
//! allowlist entry or a cyclic lock hierarchy fails here too).

use std::path::Path;

use pallas_lint::{
    apply_allowlist, check_tree, parse_allowlist, parse_lock_order, LockOrder, TreeReport,
};

fn crate_root() -> &'static Path {
    // tools/pallas-lint -> tools -> rust
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .and_then(Path::parent)
        .expect("pallas-lint lives two levels under the rust crate root")
}

fn lock_order() -> LockOrder {
    let text = std::fs::read_to_string(crate_root().join("lint-order.toml"))
        .expect("rust/lint-order.toml must exist");
    parse_lock_order(&text).expect("lint-order.toml must parse and be acyclic")
}

fn run_full_check() -> TreeReport {
    let order = lock_order();
    check_tree(&crate_root().join("src"), Some(&order)).expect("rust/src must parse")
}

#[test]
fn real_source_tree_is_lint_clean_under_the_checked_in_allowlist() {
    let allow_path = crate_root().join("lint-allow.toml");

    let tree = run_full_check();
    let allow_text =
        std::fs::read_to_string(&allow_path).expect("rust/lint-allow.toml must exist");
    let allow = parse_allowlist(&allow_text).expect("lint-allow.toml must parse");

    let report = apply_allowlist(&tree.findings, &allow);

    assert!(
        report.over_budget.is_empty(),
        "allowlist entries over budget:\n{}",
        report.over_budget.join("\n")
    );
    assert!(
        report.active.is_empty(),
        "invariant violations in rust/src (fix the code or justify an \
         allowlist entry in rust/lint-allow.toml):\n{}",
        report
            .active
            .iter()
            .map(ToString::to_string)
            .collect::<Vec<_>>()
            .join("\n")
    );
    assert!(
        report.unused.is_empty(),
        "stale lint-allow.toml entries (delete them):\n{}",
        report
            .unused
            .iter()
            .map(|e| format!("{} in {} ({})", e.rule, e.file, e.reason))
            .collect::<Vec<_>>()
            .join("\n")
    );
}

#[test]
fn the_allowlist_suppresses_something() {
    // Guards against the allowlist and tree drifting apart silently in
    // the other direction: if every entry stopped matching at once the
    // `unused` check above would catch it, but this pins the intent —
    // the tree currently *needs* exceptions (ingress spawns, default
    // kill-switch tokens), and `suppressed` counts them.
    let allow_text =
        std::fs::read_to_string(crate_root().join("lint-allow.toml")).unwrap();
    let tree = run_full_check();
    let allow = parse_allowlist(&allow_text).expect("lint-allow.toml must parse");
    let report = apply_allowlist(&tree.findings, &allow);
    assert!(report.suppressed > 0, "expected the justified exceptions to match");
}

#[test]
fn observed_lock_edges_respect_the_declared_hierarchy() {
    // PL006 would already have failed the first test on a violation;
    // this pins the *shape* of the result: every held→acquired pair
    // observed in the real tree is a legal (ok) edge of the declared
    // order. Today the tree nests no locks at all, so the edge set is
    // empty — if a legal nesting appears later this stays green, and
    // the DOT artifact starts showing the dashed observed edge.
    let tree = run_full_check();
    let bad: Vec<String> = tree
        .lock_edges
        .iter()
        .filter(|e| !e.ok)
        .map(|e| format!("{} -> {}", e.from, e.to))
        .collect();
    assert!(bad.is_empty(), "illegal observed lock edges: {bad:?}");
}

#[test]
fn the_declared_hierarchy_names_the_known_locks() {
    // The hierarchy file is load-bearing data: if a lock is renamed or
    // added in src without updating lint-order.toml, PL006's
    // undeclared-acquisition check fails the selfcheck above; this
    // test pins the reverse direction — the declared names themselves.
    let order = lock_order();
    let names = order.lock_names();
    for expected in [
        "sched.shards",
        "profile.store",
        "metrics.counters",
        "metrics.histograms",
        "batcher.queue",
    ] {
        assert!(names.contains(&expected), "lint-order.toml lost `{expected}`: {names:?}");
    }
}

//! PL008 must-not-fire fixture: every emission site goes through the
//! registry. Expected finding count: zero — `names::` paths resolve,
//! a direct `use`-imported constant ident is accepted, non-string
//! first arguments (`Cell::set(5)`) are not wire names, and
//! `#[cfg(test)]` literals are exempt.

pub mod names {
    pub const REQUESTS: &str = "requests";
    pub const BATCHES: &str = "batches";
}

use names::BATCHES;

pub struct Metrics;

impl Metrics {
    pub fn add(&self, _name: &str, _v: u64) {}
    pub fn set(&self, _name: &str, _v: u64) {}
}

pub fn emit(m: &Metrics, cell: &std::cell::Cell<u64>) {
    m.add(names::REQUESTS, 1);
    m.add(BATCHES, 1);
    m.set(names::REQUESTS, 7);
    cell.set(5);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literals_are_fine_in_tests() {
        Metrics.add("test_metric", 1);
    }
}

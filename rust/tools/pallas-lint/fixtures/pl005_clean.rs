//! PL005 must-not-fire fixture: `with_cancel` / `with_budget` live on
//! legitimately on `PartTask` and `RequestCtx` — only the `JobPart`
//! builders were deleted. And prose may discuss history: this doc
//! comment mentions `run_cancellable`, `PrunOptions` and `BatchSubmit`
//! without tripping anything, because doc text is not an identifier.

pub struct PartTask;

pub struct RequestCtx;

pub struct CancelToken;

pub struct Budget;

impl PartTask {
    pub fn with_cancel(self, _token: CancelToken) -> PartTask {
        self
    }

    pub fn with_budget(self, _budget: Budget) -> PartTask {
        self
    }
}

impl RequestCtx {
    pub fn with_cancel(self, _token: CancelToken) -> RequestCtx {
        self
    }

    pub fn with_budget(self, _budget: Budget) -> RequestCtx {
        self
    }
}

//! PL001 must-fire fixture: raw thread creation outside the pool.
//! Checked under a non-exempt virtual path (e.g. `coordinator/evil.rs`)
//! this yields exactly two findings — one per spawn form. Checked under
//! `runtime/evil.rs` it yields none (the pool may create threads).

pub fn sneaky_parallelism() {
    let a = std::thread::spawn(|| 40 + 2);
    let b = std::thread::Builder::new()
        .name("rogue".into())
        .spawn(|| ())
        .unwrap();
    let _ = a.join();
    let _ = b.join();
}

//! PL007 must-fire fixture: blocking while holding a guard on a hot
//! path (checked under the virtual path `engine/sched.rs`; the same
//! source under `engine/profile.rs` must yield zero findings — the
//! rule is scoped to the three hot-path files). Expected findings:
//!
//! - line 25: zero-arg `.join()` while the for-head guard temporary
//!   is live
//! - line 31: `.recv()` while `q` is held
//! - line 38: `.recv_timeout(..)` while `q` is held
//! - line 45: `thread::sleep(..)` while `q` is held
//! - line 51: nested `lock_recover` while `outer` is held

use crate::util::sync::lock_recover;
use std::sync::mpsc::Receiver;

pub struct Shards {
    handles: std::sync::Mutex<Vec<std::thread::JoinHandle<()>>>,
    queue: std::sync::Mutex<Vec<u64>>,
    inner: std::sync::Mutex<u64>,
}

impl Shards {
    pub fn join_under_guard(&self) {
        for h in lock_recover(&self.handles).drain(..) {
            let _ = h.join();
        }
    }

    pub fn recv_under_guard(&self, rx: &Receiver<u64>) {
        let mut q = lock_recover(&self.queue);
        if let Ok(v) = rx.recv() {
            q.push(v);
        }
    }

    pub fn recv_timeout_under_guard(&self, rx: &Receiver<u64>) {
        let mut q = lock_recover(&self.queue);
        if let Ok(v) = rx.recv_timeout(std::time::Duration::from_millis(5)) {
            q.push(v);
        }
    }

    pub fn sleep_under_guard(&self) {
        let q = lock_recover(&self.queue);
        std::thread::sleep(std::time::Duration::from_millis(1));
        q.len();
    }

    pub fn nested_under_guard(&self) -> u64 {
        let outer = lock_recover(&self.queue);
        let v = *lock_recover(&self.inner);
        outer.len() as u64 + v
    }
}

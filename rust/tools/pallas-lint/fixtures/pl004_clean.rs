//! PL004 must-not-fire fixture: deriving from an existing ctx (clone,
//! builder methods) is threading, not minting — and tests may mint.

use crate::engine::{Priority, RequestCtx};

pub fn threads_the_one_ctx(ctx: &RequestCtx) -> RequestCtx {
    ctx.clone().with_priority(Priority::High)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tests_may_mint() {
        let ctx = RequestCtx::new();
        let _ = threads_the_one_ctx(&ctx);
    }
}

//! PL002 must-not-fire fixture: poison-recovering helpers, non-lock
//! unwraps, argumentful `.read(..)` calls, and test-gated guard unwraps.

use std::sync::mpsc::Receiver;
use std::sync::{Mutex, MutexGuard, PoisonError};

pub fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

pub fn recovered(m: &Mutex<u32>) -> u32 {
    *lock_recover(m)
}

pub fn non_lock_unwraps(rx: &Receiver<u32>, buf: &mut Vec<u8>) -> u32 {
    use std::io::Read;
    let mut f = std::fs::File::open("/dev/null").unwrap();
    // `.read(buf)` takes an argument — io::Read, not RwLock::read.
    f.read(buf).unwrap();
    rx.recv().unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tests_may_unwrap_guards() {
        let m = Mutex::new(7);
        assert_eq!(*m.lock().unwrap(), 7);
    }
}

//! PL003 must-fire fixture: raw time reads on a hot path. Checked under
//! `engine/sched.rs` this yields exactly two findings — the direct call
//! and the fn-pointer form. Checked under a file outside the rule's
//! scope (e.g. `nlp/serving.rs`) it yields none.

use std::time::Instant;

pub fn stamps() -> Instant {
    Instant::now()
}

pub fn lazy_stamp(slot: &mut Option<Instant>) -> Instant {
    *slot.get_or_insert_with(Instant::now)
}

//! PL002 must-fire fixture: guard acquisition via unwrap/expect.
//! Exactly three findings: lock().unwrap, read().expect, write().unwrap.

use std::sync::{Mutex, RwLock};

pub fn poison_propagators(m: &Mutex<u32>, l: &RwLock<u32>) -> u32 {
    let a = *m.lock().unwrap();
    let b = *l.read().expect("poisoned");
    let mut g = l.write().unwrap();
    *g += a + b;
    *g
}

//! PL003 must-not-fire fixture: hot-path time through the clock shim,
//! and real time in tests. Clean even under `engine/sched.rs`.

use std::time::Instant;

use crate::util::clock;

pub fn stamps() -> Instant {
    clock::now()
}

pub fn lazy_stamp(slot: &mut Option<Instant>) -> Instant {
    *slot.get_or_insert_with(clock::now)
}

#[cfg(test)]
mod tests {
    #[test]
    fn tests_may_read_real_time() {
        let t = std::time::Instant::now();
        assert!(t.elapsed().as_secs() < 1);
    }
}

//! PL006 must-fire fixture: inverted and undeclared lock acquisitions.
//!
//! Checked by `tests/fixtures.rs` with a two-lock hierarchy declaring
//! `locks.alpha < locks.beta` and nothing else. Expected findings:
//!
//! - line 24: acquiring `locks.alpha` while holding `locks.beta` — a
//!   direct inversion of the declared order
//! - line 31: the same inversion one call level deep, via
//!   `Work::grab_alpha`
//! - line 41: `gamma` matches no `[[lock]]` declaration

use crate::util::sync::lock_recover;
use std::sync::Mutex;

pub struct Work {
    alpha: Mutex<Vec<u32>>,
    beta: Mutex<Vec<u32>>,
    gamma: Mutex<u32>,
}

impl Work {
    pub fn inverted_inline(&self) {
        let b = lock_recover(&self.beta);
        let a = lock_recover(&self.alpha);
        a.len();
        b.len();
    }

    pub fn inverted_via_call(&self) {
        let b = lock_recover(&self.beta);
        self.grab_alpha();
        b.len();
    }

    fn grab_alpha(&self) -> usize {
        let a = lock_recover(&self.alpha);
        a.len()
    }

    pub fn undeclared(&self) -> u32 {
        *lock_recover(&self.gamma)
    }
}

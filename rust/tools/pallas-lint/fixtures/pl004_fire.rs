//! PL004 must-fire fixture: minting request state mid-stack. Checked
//! under a non-mint path (e.g. `coordinator/batcher.rs`) this yields
//! exactly three findings. Checked under an ingress path
//! (`coordinator/router.rs`) it yields none.

use std::time::Duration;

use crate::engine::{Budget, RequestCtx};
use crate::runtime::CancelToken;

pub fn reminted_mid_stack() -> (Budget, CancelToken, RequestCtx) {
    let b = Budget::new(Duration::from_millis(5));
    let t = CancelToken::new();
    let c = RequestCtx::default();
    (b, t, c)
}

//! PL006 must-not-fire fixture: the same two-lock hierarchy as the
//! fire fixture (`locks.alpha < locks.beta`), used correctly. The
//! expected finding count is zero: in-order nesting, drop-then-
//! acquire, a tail-returned guard helper, and a test-gated inversion
//! (crate-wide rules skip `#[cfg(test)]` subtrees).

use crate::util::sync::lock_recover;
use std::sync::Mutex;

pub struct Work {
    alpha: Mutex<Vec<u32>>,
    beta: Mutex<Vec<u32>>,
}

impl Work {
    fn alpha_guard(&self) -> std::sync::MutexGuard<'_, Vec<u32>> {
        lock_recover(&self.alpha)
    }

    pub fn in_order(&self) {
        let a = lock_recover(&self.alpha);
        let b = lock_recover(&self.beta);
        b.len();
        a.len();
    }

    pub fn drop_then_acquire(&self) {
        let b = lock_recover(&self.beta);
        drop(b);
        let a = lock_recover(&self.alpha);
        a.len();
    }

    pub fn via_tail_guard(&self) {
        let a = self.alpha_guard();
        let b = lock_recover(&self.beta);
        b.len();
        a.len();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inverted_is_fine_in_tests() {
        let w = Work { alpha: Mutex::new(vec![]), beta: Mutex::new(vec![]) };
        let b = lock_recover(&w.beta);
        let a = lock_recover(&w.alpha);
        drop(a);
        drop(b);
    }
}

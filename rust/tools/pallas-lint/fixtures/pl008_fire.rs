//! PL008 must-fire fixture (virtual path `coordinator/router.rs`):
//! metrics emission sites that bypass the wire-name registry. The
//! fixture carries its own miniature `names` module — the analyzer
//! collects any `mod names` in the file set. Expected findings:
//!
//! - line 23: `.add("requests_raw", ..)` — raw string literal
//! - line 24: `.record("latency", ..)` — raw string literal
//! - line 25: `names::QUEUE_DEPTH` — not a registry constant

pub mod names {
    pub const REQUESTS: &str = "requests";
}

pub struct Metrics;

impl Metrics {
    pub fn add(&self, _name: &str, _v: u64) {}
    pub fn record(&self, _name: &str, _ms: f64) {}
    pub fn set(&self, _name: &str, _v: u64) {}
}

pub fn emit(m: &Metrics) {
    m.add("requests_raw", 1);
    m.record("latency", 3.5);
    m.set(names::QUEUE_DEPTH, 4);
    m.add(names::REQUESTS, 1);
}

//! PL005 must-fire fixture: resurrecting deleted shim names.
//! Exactly six findings: the `impl JobPart` builder, the banned fn
//! name at its definition, the banned name at a call site, a banned
//! name inside `#[cfg(test)]` — PL005 applies to tests too — and the
//! two PR-8 names (the collapsed scheduler constructor variant and the
//! untyped allocator entry point).

pub struct JobPart;

pub struct CancelToken;

impl JobPart {
    pub fn with_cancel(self, _token: CancelToken) -> JobPart {
        self
    }
}

pub fn run_cancellable() {}

pub fn old_call_site() {
    run_cancellable();
}

pub fn start_with_policy() {}

pub fn allocate_weighted() {}

#[cfg(test)]
mod tests {
    #[test]
    fn shims_are_banned_even_here() {
        super::run_cancellable();
    }
}

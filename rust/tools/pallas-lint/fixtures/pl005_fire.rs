//! PL005 must-fire fixture: resurrecting deleted PR-5 shim names.
//! Exactly four findings: the `impl JobPart` builder, the banned fn
//! name at its definition, the banned name at a call site, and a banned
//! name inside `#[cfg(test)]` — PL005 applies to tests too.

pub struct JobPart;

pub struct CancelToken;

impl JobPart {
    pub fn with_cancel(self, _token: CancelToken) -> JobPart {
        self
    }
}

pub fn run_cancellable() {}

pub fn old_call_site() {
    run_cancellable();
}

#[cfg(test)]
mod tests {
    #[test]
    fn shims_are_banned_even_here() {
        super::run_cancellable();
    }
}

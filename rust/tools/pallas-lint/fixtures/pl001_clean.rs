//! PL001 must-not-fire fixture: `.spawn()` on a non-thread receiver is
//! someone's domain API, and test helpers may use real threads.

pub struct Job;

pub struct Pool;

impl Pool {
    pub fn spawn(&self, _job: Job) {}
}

pub fn uses_the_pool(pool: &Pool) {
    pool.spawn(Job);
}

#[cfg(test)]
mod tests {
    #[test]
    fn helper_threads_are_fine_in_tests() {
        let h = std::thread::spawn(|| 1 + 1);
        assert_eq!(h.join().unwrap(), 2);
    }
}

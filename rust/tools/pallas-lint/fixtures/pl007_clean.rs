//! PL007 must-not-fire fixture (virtual path
//! `coordinator/batcher.rs`): the legal shapes around blocking calls.
//! Expected finding count: zero. Condvar waits release the guard
//! while parked; handles are collected under the lock and joined
//! after it drops; `.join(", ")` with an argument is string joining,
//! not thread joining; a bare `.recv()` with no guard live is the
//! event-driven wakeup idiom; and `#[cfg(test)]` code is exempt.

use crate::util::sync::lock_recover;
use std::sync::mpsc::Receiver;
use std::sync::{Condvar, Mutex};

pub struct Batcher {
    queue: (Mutex<Vec<u64>>, Condvar),
    handles: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl Batcher {
    pub fn flusher_wait(&self) -> usize {
        let (lock, cv) = &self.queue;
        let mut q = lock_recover(lock);
        while q.is_empty() {
            q = cv.wait_timeout(q, std::time::Duration::from_millis(5)).unwrap().0;
        }
        q.len()
    }

    pub fn shutdown(&self) {
        let joins: Vec<std::thread::JoinHandle<()>> =
            lock_recover(&self.handles).drain(..).collect();
        for j in joins {
            let _ = j.join();
        }
    }

    pub fn label(&self, parts: &[String]) -> String {
        parts.join(", ")
    }

    pub fn pump(&self, rx: &Receiver<u64>) {
        while let Ok(v) = rx.recv() {
            let mut q = lock_recover(&self.queue.0);
            q.push(v);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn blocking_under_guard_is_fine_in_tests() {
        let b = Batcher {
            queue: (Mutex::new(vec![]), Condvar::new()),
            handles: Mutex::new(vec![]),
        };
        let q = lock_recover(&b.queue.0);
        std::thread::sleep(std::time::Duration::from_millis(1));
        q.len();
    }
}

//! `pallas-lint` — the serving crate's concurrency and budget contracts
//! as named, machine-checked rules.
//!
//! Six PRs of scheduler growth piled up invariants that lived only in
//! prose ("all parallelism flows through the pool", "mint the budget
//! once at ingress", "never `unwrap()` a lock guard in the
//! dispatcher"), and each had already been violated and re-fixed at
//! least once. This crate parses `rust/src/**` with `syn` and enforces
//! them:
//!
//! - **PL001** — no `std::thread::spawn` (or `thread::Builder` spawns)
//!   outside `runtime/` and `engine/sched.rs`. The divide-and-conquer
//!   design routes all parallelism through the executor pool and the
//!   scheduler's shards; a rogue thread is invisible to the core
//!   ledger, so it oversubscribes exactly the resource the paper's
//!   allocation math is managing.
//! - **PL002** — no `.unwrap()` / `.expect()` on `Mutex`/`RwLock`
//!   guard acquisition outside `#[cfg(test)]`. A panicking holder
//!   poisons the lock and every later unwrap re-panics in innocent
//!   threads; non-test code must use `util::sync::{lock_recover,
//!   read_recover, write_recover}`.
//! - **PL003** — no raw `Instant::now()` in `engine/sched.rs` /
//!   `runtime/pool.rs` outside `#[cfg(test)]`: hot-path time reads go
//!   through `util::clock::now()` so event-driven wakeups and EWMA
//!   placement stay mockable.
//! - **PL004** — `Budget` / `CancelToken` / `RequestCtx` are
//!   constructed only in their defining modules (`engine/ctx.rs`,
//!   `engine/budget.rs`, `runtime/cancel.rs`) and the ingress modules
//!   (`coordinator/router.rs`, `main.rs`, `bench/gate.rs`,
//!   `bar/engine.rs`). This is the
//!   one-mint invariant: request state is minted once at the edge and
//!   *threaded*, never re-minted mid-stack (a fresh token mid-stack is
//!   a request the client can no longer cancel).
//! - **PL005** — no references to deleted shim names: the PR-5 set
//!   (`run_cancellable`, `prun_submit`, `serve_submit*`,
//!   `process_budgeted`, `start_pipelined_with_reaper`, `PrunOptions`,
//!   `BatchSubmit`), the PR-8 collapsed variants (`start_with_policy`,
//!   `allocate_weighted`), and no `with_cancel`/`with_budget` methods
//!   on `JobPart`. Applies *everywhere*, tests included — dead API must
//!   stay dead. Prose (doc comments) is exempt: names are matched as
//!   code identifiers, not text.
//!
//! Three further rules are *crate-wide* — they need a symbol table and
//! call graph over all of `rust/src` at once, built by the two-pass
//! analyzer in [`graph`]:
//!
//! - **PL006** — lock acquisitions must follow the hierarchy declared
//!   in `rust/lint-order.toml` (`--order`). Guards are tracked across
//!   intra-procedural flow and one call level deep; an inverted,
//!   unordered, re-entrant, or undeclared acquisition is a finding,
//!   and the declared order itself must be acyclic (a cycle is a
//!   config error, exit 2).
//! - **PL007** — no blocking call (`recv`/`recv_timeout`/
//!   `recv_deadline`, zero-arg `join`, `thread::sleep`/`park`) and no
//!   nested `*_recover` while a guard is live on the hot-path files
//!   (`engine/sched.rs`, `runtime/pool.rs`, `coordinator/batcher.rs`).
//!   Condvar `wait`/`wait_timeout` are exempt by design — they release
//!   the guard while parked.
//! - **PL008** — every metrics emission site (`.add`/`.set`/`.record`
//!   with a wire-name first argument) must reference a constant from
//!   the `coordinator/stats.rs` `names` registry module; raw string
//!   literals and unknown `names::*` paths are findings.
//!
//! Rules PL001–PL004 and PL006–PL008 skip `#[cfg(test)]`-gated
//! subtrees and `#[test]` functions; PL005 does not. Findings not
//! covered by a `lint-allow.toml` entry (each with a written
//! justification and a `max` budget) make the binary exit nonzero; so
//! do allowlist entries that no longer match anything — exceptions
//! must not outlive their reason.
//!
//! # JSON report schema (`--json`)
//!
//! One object with five fixed keys, stable across releases (consumers:
//! the `lint-invariants` CI job and its artifact):
//!
//! ```text
//! {
//!   "rules":    { "PL001": "<summary>", ... },          // full catalog
//!   "findings": [ {"rule", "file", "line", "message"} ],// active (unsuppressed)
//!   "suppressed": <int>,                                // count absorbed by allowlist
//!   "unused_allow_entries": [ {"rule", "file"} ],       // stale exceptions (fail)
//!   "lock_edges": [ {"from", "to", "ok"} ]              // observed PL006 pairs
//! }
//! ```
//!
//! Exit codes (the binary): 0 = clean, 1 = findings or stale allowlist
//! entries, 2 = usage / IO / parse error.

use std::collections::BTreeMap;
use std::fmt;
use std::path::Path;

use proc_macro2::TokenTree;
use syn::visit::Visit;

mod graph;

/// The TOML-subset parser is shared source with the serving crate
/// (`dnc_serve`'s `util::toml`): `pallas-lint` cannot depend on the
/// crate it lints (that would pull the PJRT build into the lint job),
/// so it includes the one file by path instead. Public so the helpers
/// the configs here don't exercise (list values, bools) aren't dead
/// code in this crate.
#[path = "../../../src/util/toml.rs"]
pub mod toml;

pub use graph::{lock_order_dot, parse_lock_order, LockDecl, LockEdge, LockOrder};

/// Rule catalog: (id, one-line summary) — the JSON report embeds it so
/// downstream tooling doesn't need this crate's docs.
pub const RULES: &[(&str, &str)] = &[
    ("PL001", "no raw thread creation outside runtime/ and engine/sched.rs"),
    ("PL002", "no unwrap/expect on Mutex/RwLock guards outside tests"),
    ("PL003", "no raw Instant::now() on scheduler/pool hot paths"),
    ("PL004", "Budget/CancelToken/RequestCtx minted only at defining modules and ingress"),
    ("PL005", "deleted shim names must stay dead (tests included)"),
    ("PL006", "lock acquisitions follow the declared hierarchy in lint-order.toml"),
    ("PL007", "no blocking call while holding a guard on sched/pool/batcher hot paths"),
    ("PL008", "metrics wire names come from the coordinator stats names registry"),
];

/// One rule violation at a source location. `file` is the path relative
/// to the scanned source root, with `/` separators on every platform.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    pub rule: &'static str,
    pub file: String,
    pub line: usize,
    pub message: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{} {} {}", self.file, self.line, self.rule, self.message)
    }
}

// ---------------------------------------------------------------- scope

fn pl001_exempt(file: &str) -> bool {
    file.starts_with("runtime/") || file == "engine/sched.rs"
}

fn pl003_applies(file: &str) -> bool {
    matches!(file, "engine/sched.rs" | "runtime/pool.rs")
}

fn pl004_exempt(file: &str) -> bool {
    matches!(
        file,
        // defining modules: the constructors themselves live here
        "engine/ctx.rs" | "engine/budget.rs" | "runtime/cancel.rs"
        // ingress modules: where the one mint per request happens
        // (bar/engine.rs is the barometer's load generator — it plays
        // the client, so each simulated request is minted there)
        | "coordinator/router.rs" | "main.rs" | "bench/gate.rs" | "bar/engine.rs"
    )
}

/// Idents banned everywhere by PL005 — the PR-5 shim surface deleted
/// after one deprecation cycle, plus the PR-8 constructor/allocator
/// variants collapsed into `Scheduler::start(SchedConfig { adaptive,
/// cores: CoreMap, .. })` and `allocate(PartWeights, &CoreMap, policy)`.
/// (`with_cancel`/`with_budget` are *not* here: they live on
/// legitimately on `PartTask` and `RequestCtx`; the `JobPart` builders
/// are caught structurally via `impl JobPart`.)
const PL005_BANNED: &[&str] = &[
    "run_cancellable",
    "prun_submit",
    "serve_submit",
    "serve_submit_cancellable",
    "serve_submit_budgeted",
    "process_budgeted",
    "start_pipelined_with_reaper",
    "PrunOptions",
    "BatchSubmit",
    "start_with_policy",
    "allocate_weighted",
];

// -------------------------------------------------------------- checking

/// Run the per-file rules (PL001–PL005) over one file's source.
/// `rel_path` scopes the path-sensitive rules (PL001/PL003/PL004) —
/// pass the path relative to the crate's `src/`, `/`-separated.
/// Returns `Err` if the file does not parse as Rust. The crate-wide
/// rules (PL006–PL008) need the whole file set — use [`check_sources`]
/// or [`check_tree`] for those.
pub fn check_source(rel_path: &str, src: &str) -> Result<Vec<Finding>, String> {
    let ast = syn::parse_file(src).map_err(|e| format!("{rel_path}: parse error: {e}"))?;
    let mut v = Rules { file: rel_path, test_depth: 0, findings: Vec::new() };
    v.visit_file(&ast);
    Ok(v.findings)
}

/// Everything one full run produces: the merged findings of all eight
/// rules plus the observed lock-order edges (for the DOT artifact).
#[derive(Debug, Default)]
pub struct TreeReport {
    pub findings: Vec<Finding>,
    /// held→acquired pairs observed by PL006, by declared lock name;
    /// empty when no `--order` was given
    pub lock_edges: Vec<LockEdge>,
}

/// Run all rules — per-file *and* crate-wide — over an in-memory file
/// set of `(rel_path, source)` pairs. `order == None` disables PL006.
/// Findings are sorted by (file, line, rule) for deterministic output.
pub fn check_sources(
    files: &[(String, String)],
    order: Option<&LockOrder>,
) -> Result<TreeReport, String> {
    let mut findings = Vec::new();
    for (rel, src) in files {
        findings.extend(check_source(rel, src)?);
    }
    let crate_rep = graph::check_crate(files, order)?;
    findings.extend(crate_rep.findings);
    findings.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule, a.message.as_str())
            .cmp(&(b.file.as_str(), b.line, b.rule, b.message.as_str()))
    });
    Ok(TreeReport { findings, lock_edges: crate_rep.edges })
}

/// Recursively check every `*.rs` under `root` (deterministic order)
/// with all eight rules.
pub fn check_tree(root: &Path, order: Option<&LockOrder>) -> Result<TreeReport, String> {
    let mut paths = Vec::new();
    collect_rs(root, &mut paths)?;
    paths.sort();
    let mut files = Vec::with_capacity(paths.len());
    for path in paths {
        let rel = path
            .strip_prefix(root)
            .map_err(|_| format!("{}: not under source root", path.display()))?
            .components()
            .map(|c| c.as_os_str().to_string_lossy().into_owned())
            .collect::<Vec<_>>()
            .join("/");
        let src = std::fs::read_to_string(&path)
            .map_err(|e| format!("{}: {e}", path.display()))?;
        files.push((rel, src));
    }
    check_sources(&files, order)
}

fn collect_rs(dir: &Path, out: &mut Vec<std::path::PathBuf>) -> Result<(), String> {
    let entries =
        std::fs::read_dir(dir).map_err(|e| format!("{}: {e}", dir.display()))?;
    for entry in entries {
        let entry = entry.map_err(|e| format!("{}: {e}", dir.display()))?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

// --------------------------------------------------------------- visitor

struct Rules<'a> {
    file: &'a str,
    /// > 0 while inside a `#[cfg(test)]` / `#[test]` subtree; rules
    /// PL001–PL004 are inert there, PL005 is not.
    test_depth: usize,
    findings: Vec<Finding>,
}

impl Rules<'_> {
    fn push(&mut self, rule: &'static str, line: usize, message: String) {
        self.findings.push(Finding { rule, file: self.file.to_string(), line, message });
    }
}

/// Does any attribute gate this node to test builds? Catches `#[test]`
/// and any `#[cfg(...)]` whose argument tokens mention the ident `test`
/// (so `#[cfg(any(test, feature = "x"))]` counts — conservative in the
/// safe direction for PL001–PL004's *exemption*). Shared with the
/// crate-wide analyzer, which skips the same subtrees.
pub(crate) fn is_test_gated(attrs: &[syn::Attribute]) -> bool {
    attrs.iter().any(|a| {
        if a.path().is_ident("test") {
            return true;
        }
        if !a.path().is_ident("cfg") {
            return false;
        }
        match &a.meta {
            syn::Meta::List(list) => tokens_mention_test(list.tokens.clone()),
            _ => false,
        }
    })
}

fn tokens_mention_test(ts: proc_macro2::TokenStream) -> bool {
    ts.into_iter().any(|tt| match tt {
        TokenTree::Ident(id) => id == "test",
        TokenTree::Group(g) => tokens_mention_test(g.stream()),
        _ => false,
    })
}

fn item_attrs(item: &syn::Item) -> &[syn::Attribute] {
    match item {
        syn::Item::Const(i) => &i.attrs,
        syn::Item::Enum(i) => &i.attrs,
        syn::Item::ExternCrate(i) => &i.attrs,
        syn::Item::Fn(i) => &i.attrs,
        syn::Item::ForeignMod(i) => &i.attrs,
        syn::Item::Impl(i) => &i.attrs,
        syn::Item::Macro(i) => &i.attrs,
        syn::Item::Mod(i) => &i.attrs,
        syn::Item::Static(i) => &i.attrs,
        syn::Item::Struct(i) => &i.attrs,
        syn::Item::Trait(i) => &i.attrs,
        syn::Item::TraitAlias(i) => &i.attrs,
        syn::Item::Type(i) => &i.attrs,
        syn::Item::Union(i) => &i.attrs,
        syn::Item::Use(i) => &i.attrs,
        _ => &[],
    }
}

fn impl_item_attrs(item: &syn::ImplItem) -> &[syn::Attribute] {
    match item {
        syn::ImplItem::Const(i) => &i.attrs,
        syn::ImplItem::Fn(i) => &i.attrs,
        syn::ImplItem::Type(i) => &i.attrs,
        syn::ImplItem::Macro(i) => &i.attrs,
        _ => &[],
    }
}

fn seg_names(path: &syn::Path) -> Vec<String> {
    path.segments.iter().map(|s| s.ident.to_string()).collect()
}

fn ends_with(segs: &[String], suffix: &[&str]) -> bool {
    segs.len() >= suffix.len()
        && segs[segs.len() - suffix.len()..]
            .iter()
            .zip(suffix)
            .all(|(a, b)| a == b)
}

/// Structural "does this receiver look like a thread builder": any path
/// inside the expression mentioning `thread` or `Builder`. Keeps
/// `.spawn()` on pools/processes from false-firing while catching
/// `std::thread::Builder::new().name(..).spawn(..)` chains.
fn expr_mentions(e: &syn::Expr, names: &[&str]) -> bool {
    match e {
        syn::Expr::Path(p) => p
            .path
            .segments
            .iter()
            .any(|s| names.iter().any(|n| s.ident == *n)),
        syn::Expr::Call(c) => {
            expr_mentions(&c.func, names) || c.args.iter().any(|a| expr_mentions(a, names))
        }
        syn::Expr::MethodCall(mc) => {
            expr_mentions(&mc.receiver, names)
                || mc.args.iter().any(|a| expr_mentions(a, names))
        }
        syn::Expr::Paren(p) => expr_mentions(&p.expr, names),
        syn::Expr::Reference(r) => expr_mentions(&r.expr, names),
        syn::Expr::Field(f) => expr_mentions(&f.base, names),
        _ => false,
    }
}

impl<'ast> Visit<'ast> for Rules<'_> {
    fn visit_item(&mut self, node: &'ast syn::Item) {
        if is_test_gated(item_attrs(node)) {
            self.test_depth += 1;
            syn::visit::visit_item(self, node);
            self.test_depth -= 1;
        } else {
            syn::visit::visit_item(self, node);
        }
    }

    fn visit_impl_item(&mut self, node: &'ast syn::ImplItem) {
        if is_test_gated(impl_item_attrs(node)) {
            self.test_depth += 1;
            syn::visit::visit_impl_item(self, node);
            self.test_depth -= 1;
        } else {
            syn::visit::visit_impl_item(self, node);
        }
    }

    fn visit_expr_path(&mut self, node: &'ast syn::ExprPath) {
        // An ExprPath covers both call position (`Instant::now()`) and
        // value position (`get_or_insert_with(Instant::now)`), so the
        // path rules hook here rather than at ExprCall.
        if self.test_depth == 0 {
            let segs = seg_names(&node.path);
            let line = node
                .path
                .segments
                .last()
                .map(|s| s.ident.span().start().line)
                .unwrap_or(0);
            if !pl001_exempt(self.file) && ends_with(&segs, &["thread", "spawn"]) {
                self.push(
                    "PL001",
                    line,
                    "raw std::thread::spawn — all parallelism flows through the \
                     executor pool / scheduler shards"
                        .to_string(),
                );
            }
            if pl003_applies(self.file) && ends_with(&segs, &["Instant", "now"]) {
                self.push(
                    "PL003",
                    line,
                    "raw Instant::now() on a hot path — use crate::util::clock::now()"
                        .to_string(),
                );
            }
            if !pl004_exempt(self.file) && segs.len() >= 2 {
                let ty = &segs[segs.len() - 2];
                let ctor = &segs[segs.len() - 1];
                if matches!(ty.as_str(), "Budget" | "CancelToken" | "RequestCtx")
                    && matches!(ctor.as_str(), "new" | "default")
                {
                    self.push(
                        "PL004",
                        line,
                        format!(
                            "{ty}::{ctor}() outside the mint modules — request state \
                             is minted once at the ingress and threaded through"
                        ),
                    );
                }
            }
        }
        syn::visit::visit_expr_path(self, node);
    }

    fn visit_expr_method_call(&mut self, node: &'ast syn::ExprMethodCall) {
        if self.test_depth == 0 {
            let method = node.method.to_string();
            let line = node.method.span().start().line;
            if method == "unwrap" || method == "expect" {
                if let syn::Expr::MethodCall(inner) = &*node.receiver {
                    let acquire = inner.method.to_string();
                    if matches!(acquire.as_str(), "lock" | "read" | "write")
                        && inner.args.is_empty()
                    {
                        let helper = match acquire.as_str() {
                            "lock" => "lock_recover",
                            "read" => "read_recover",
                            _ => "write_recover",
                        };
                        self.push(
                            "PL002",
                            line,
                            format!(
                                ".{acquire}().{method}() on a lock guard — use \
                                 util::sync::{helper} so one panicking holder \
                                 cannot cascade"
                            ),
                        );
                    }
                }
            }
            if method == "spawn"
                && !pl001_exempt(self.file)
                && expr_mentions(&node.receiver, &["thread", "Builder"])
            {
                self.push(
                    "PL001",
                    line,
                    "thread::Builder spawn — all parallelism flows through the \
                     executor pool / scheduler shards"
                        .to_string(),
                );
            }
        }
        syn::visit::visit_expr_method_call(self, node);
    }

    fn visit_item_impl(&mut self, node: &'ast syn::ItemImpl) {
        // PL005 structural half: the deleted JobPart builder methods
        // must not be re-added (the bare names stay legal on PartTask
        // and RequestCtx).
        if let syn::Type::Path(tp) = &*node.self_ty {
            let is_jobpart = tp
                .path
                .segments
                .last()
                .map(|s| s.ident == "JobPart")
                .unwrap_or(false);
            if is_jobpart {
                for item in &node.items {
                    if let syn::ImplItem::Fn(f) = item {
                        let name = f.sig.ident.to_string();
                        if name == "with_cancel" || name == "with_budget" {
                            self.push(
                                "PL005",
                                f.sig.ident.span().start().line,
                                format!(
                                    "JobPart::{name} was deleted in the RequestCtx \
                                     redesign — attach a ctx via with_ctx"
                                ),
                            );
                        }
                    }
                }
            }
        }
        syn::visit::visit_item_impl(self, node);
    }

    fn visit_ident(&mut self, node: &'ast proc_macro2::Ident) {
        // PL005 ident half: fires in tests too. Doc comments are
        // attribute string literals, not idents, so prose never trips it.
        if PL005_BANNED.iter().any(|b| node == b) {
            self.push(
                "PL005",
                node.span().start().line,
                format!("`{node}` is a deleted PR-5 shim name — use the RequestCtx / InferenceService API"),
            );
        }
    }
}

// ------------------------------------------------------------- allowlist

/// One documented exception: suppresses up to `max` findings of `rule`
/// in `file`. `reason` is mandatory — an exception without a written
/// justification is a parse error, and an entry matching nothing is a
/// lint failure (stale exceptions must be deleted).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct AllowEntry {
    pub rule: String,
    pub file: String,
    pub max: usize,
    pub reason: String,
}

/// Parse the `lint-allow.toml` subset: `#` comments, `[[allow]]`
/// blocks, `key = "value"` / `max = N` pairs. Built on the shared
/// hand-rolled [`toml`] subset parser (also the barometer's scenario
/// loader) — the tool must not grow a dependency for 40 lines of
/// config.
pub fn parse_allowlist(text: &str) -> Result<Vec<AllowEntry>, String> {
    let doc = toml::Doc::parse(text)?;
    if let Some(item) = doc.top.first() {
        return Err(format!("line {}: key outside an [[allow]] block", item.line));
    }

    let mut entries = Vec::new();
    for sec in &doc.sections {
        if !sec.array || sec.name != "allow" {
            return Err(format!(
                "line {}: expected `[[allow]]`, got section `{}`",
                sec.line, sec.name
            ));
        }
        let at = format!("[[allow]] block at line {}", sec.line);
        let (mut rule, mut file, mut max, mut reason) = (None, None, None, None);
        for item in &sec.items {
            match item.key.as_str() {
                "rule" => rule = Some(item.str()?.to_string()),
                "file" => file = Some(item.str()?.to_string()),
                "reason" => reason = Some(item.str()?.to_string()),
                "max" => {
                    let n = item
                        .int()
                        .ok()
                        .filter(|n| *n >= 0)
                        .ok_or_else(|| format!("line {}: `max` must be an integer", item.line))?;
                    max = Some(n as usize);
                }
                other => return Err(format!("line {}: unknown key `{other}`", item.line)),
            }
        }
        let rule = rule.ok_or_else(|| format!("{at}: missing `rule`"))?;
        let file = file.ok_or_else(|| format!("{at}: missing `file`"))?;
        let reason = reason.ok_or_else(|| format!("{at}: missing `reason`"))?;
        if reason.trim().is_empty() {
            return Err(format!("{at}: empty `reason` — every exception needs a justification"));
        }
        if !RULES.iter().any(|(id, _)| *id == rule) {
            let valid: Vec<&str> = RULES.iter().map(|(id, _)| *id).collect();
            return Err(format!(
                "{at}: unknown rule `{rule}` (valid rules: {})",
                valid.join(", ")
            ));
        }
        let max = max.unwrap_or(1);
        if max == 0 {
            return Err(format!(
                "{at}: `max = 0` is stale by construction — an exception that \
                 suppresses nothing must be deleted"
            ));
        }
        entries.push(AllowEntry { rule, file, max, reason });
    }
    // Duplicate (rule, file) pairs are an error, not a merge: matching
    // is first-entry-wins, so a second entry would silently never fire
    // and its `reason`/`max` would be dead text.
    for (i, e) in entries.iter().enumerate() {
        if entries[..i]
            .iter()
            .any(|prev| prev.rule == e.rule && prev.file == e.file)
        {
            return Err(format!(
                "duplicate [[allow]] entry for ({}, {}) — merge the budgets into one \
                 entry",
                e.rule, e.file
            ));
        }
    }
    Ok(entries)
}

/// Result of matching findings against the allowlist. Exit-zero
/// requires `active` *and* `unused` to be empty.
#[derive(Debug, Default)]
pub struct AllowReport {
    /// findings not covered by any entry — including every finding of
    /// an entry whose `max` budget was exceeded (an over-budget
    /// exception suppresses nothing: all its findings surface)
    pub active: Vec<Finding>,
    /// findings suppressed by in-budget entries
    pub suppressed: usize,
    /// entries that matched nothing — stale, must be deleted
    pub unused: Vec<AllowEntry>,
    /// human-readable notes for entries over their `max`
    pub over_budget: Vec<String>,
}

pub fn apply_allowlist(findings: &[Finding], allow: &[AllowEntry]) -> AllowReport {
    let mut matched: BTreeMap<usize, Vec<&Finding>> = BTreeMap::new();
    let mut report = AllowReport::default();
    for f in findings {
        match allow
            .iter()
            .position(|e| e.rule == f.rule && e.file == f.file)
        {
            Some(i) => matched.entry(i).or_default().push(f),
            None => report.active.push(f.clone()),
        }
    }
    for (i, entry) in allow.iter().enumerate() {
        match matched.get(&i) {
            None => report.unused.push(entry.clone()),
            Some(hits) if hits.len() > entry.max => {
                report.over_budget.push(format!(
                    "{} in {}: {} findings exceed the allowed max of {}",
                    entry.rule,
                    entry.file,
                    hits.len(),
                    entry.max
                ));
                report.active.extend(hits.iter().map(|f| (*f).clone()));
            }
            Some(hits) => report.suppressed += hits.len(),
        }
    }
    report
}

// ------------------------------------------------------------------ json

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Machine-readable report (rule catalog + active findings + allowlist
/// accounting + observed lock edges). Hand-rolled writer — same
/// no-new-deps rule as the config parser. The schema is documented in
/// the crate root and is stable: consumers parse it from CI artifacts.
pub fn json_report(report: &AllowReport, lock_edges: &[LockEdge]) -> String {
    let mut out = String::from("{\n  \"rules\": {");
    for (i, (id, desc)) in RULES.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!("\n    \"{id}\": \"{}\"", json_escape(desc)));
    }
    out.push_str("\n  },\n  \"findings\": [");
    for (i, f) in report.active.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "\n    {{\"rule\": \"{}\", \"file\": \"{}\", \"line\": {}, \"message\": \"{}\"}}",
            f.rule,
            json_escape(&f.file),
            f.line,
            json_escape(&f.message)
        ));
    }
    out.push_str("\n  ],");
    out.push_str(&format!("\n  \"suppressed\": {},", report.suppressed));
    out.push_str("\n  \"unused_allow_entries\": [");
    for (i, e) in report.unused.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "\n    {{\"rule\": \"{}\", \"file\": \"{}\"}}",
            json_escape(&e.rule),
            json_escape(&e.file)
        ));
    }
    out.push_str("\n  ],");
    out.push_str("\n  \"lock_edges\": [");
    for (i, e) in lock_edges.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "\n    {{\"from\": \"{}\", \"to\": \"{}\", \"ok\": {}}}",
            json_escape(&e.from),
            json_escape(&e.to),
            e.ok
        ));
    }
    out.push_str("\n  ]\n}\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allowlist_round_trip() {
        let text = r#"
# documented exceptions
[[allow]]
rule = "PL001"
file = "coordinator/server.rs"
max = 2
reason = "connection threads are I/O-bound, not compute"
"#;
        let entries = parse_allowlist(text).unwrap();
        assert_eq!(entries.len(), 1);
        assert_eq!(entries[0].rule, "PL001");
        assert_eq!(entries[0].max, 2);
    }

    #[test]
    fn allowlist_requires_reason_and_known_rule() {
        assert!(parse_allowlist("[[allow]]\nrule = \"PL001\"\nfile = \"a.rs\"").is_err());
        assert!(parse_allowlist(
            "[[allow]]\nrule = \"PL999\"\nfile = \"a.rs\"\nreason = \"x\""
        )
        .is_err());
        assert!(parse_allowlist(
            "[[allow]]\nrule = \"PL001\"\nfile = \"a.rs\"\nreason = \"  \""
        )
        .is_err());
        assert!(parse_allowlist("rule = \"PL001\"").is_err(), "key outside a block");
    }

    #[test]
    fn allowlist_unknown_rule_lists_valid_rules() {
        let err = parse_allowlist(
            "[[allow]]\nrule = \"PL999\"\nfile = \"a.rs\"\nreason = \"x\"",
        )
        .unwrap_err();
        for (id, _) in RULES {
            assert!(err.contains(id), "error should list `{id}`, got: {err}");
        }
    }

    #[test]
    fn allowlist_rejects_zero_max() {
        let err = parse_allowlist(
            "[[allow]]\nrule = \"PL001\"\nfile = \"a.rs\"\nmax = 0\nreason = \"x\"",
        )
        .unwrap_err();
        assert!(err.contains("stale by construction"), "got: {err}");
    }

    #[test]
    fn allowlist_rejects_duplicate_rule_file_pairs() {
        let text = r#"
[[allow]]
rule = "PL001"
file = "a.rs"
reason = "first"

[[allow]]
rule = "PL001"
file = "a.rs"
reason = "second — would never fire"
"#;
        let err = parse_allowlist(text).unwrap_err();
        assert!(err.contains("duplicate"), "got: {err}");
        // same rule in a different file is fine
        let ok = r#"
[[allow]]
rule = "PL001"
file = "a.rs"
reason = "r"

[[allow]]
rule = "PL001"
file = "b.rs"
reason = "r"
"#;
        assert_eq!(parse_allowlist(ok).unwrap().len(), 2);
    }

    #[test]
    fn lock_order_parses_and_rejects_cycles() {
        let good = r#"
# hierarchy
[[lock]]
name = "a"
field = "fa"

[[lock]]
name = "b"
field = "fb"
field = "fb2"

order = "a < b"
"#;
        let order = parse_lock_order(good).unwrap();
        assert_eq!(order.lock_names(), vec!["a", "b"]);

        let cyclic = r#"
[[lock]]
name = "a"
field = "fa"

[[lock]]
name = "b"
field = "fb"

order = "a < b"
order = "b < a"
"#;
        let err = parse_lock_order(cyclic).unwrap_err();
        assert!(err.contains("cycle"), "got: {err}");

        let unknown = "[[lock]]\nname = \"a\"\nfield = \"fa\"\norder = \"a < zz\"";
        assert!(parse_lock_order(unknown).unwrap_err().contains("unknown lock"));

        let dup_field = r#"
[[lock]]
name = "a"
field = "shared"

[[lock]]
name = "b"
field = "shared"
"#;
        assert!(parse_lock_order(dup_field).unwrap_err().contains("claimed by both"));
    }

    #[test]
    fn allowlist_budgets_and_staleness() {
        let findings = vec![
            Finding { rule: "PL001", file: "a.rs".into(), line: 1, message: "x".into() },
            Finding { rule: "PL001", file: "a.rs".into(), line: 2, message: "x".into() },
        ];
        let within = vec![AllowEntry {
            rule: "PL001".into(),
            file: "a.rs".into(),
            max: 2,
            reason: "ok".into(),
        }];
        let r = apply_allowlist(&findings, &within);
        assert!(r.active.is_empty());
        assert_eq!(r.suppressed, 2);

        let over = vec![AllowEntry {
            rule: "PL001".into(),
            file: "a.rs".into(),
            max: 1,
            reason: "ok".into(),
        }];
        let r = apply_allowlist(&findings, &over);
        assert_eq!(r.active.len(), 2, "an over-budget entry suppresses nothing");
        assert_eq!(r.over_budget.len(), 1);

        let stale = vec![AllowEntry {
            rule: "PL002".into(),
            file: "b.rs".into(),
            max: 1,
            reason: "gone".into(),
        }];
        let r = apply_allowlist(&findings, &stale);
        assert_eq!(r.unused.len(), 1, "stale entries are reported");
        assert_eq!(r.active.len(), 2);
    }

    #[test]
    fn parse_errors_carry_the_file() {
        let err = check_source("engine/broken.rs", "fn oops( {").unwrap_err();
        assert!(err.contains("engine/broken.rs"), "got: {err}");
    }

    #[test]
    fn json_report_is_parseable_shape() {
        let report = AllowReport {
            active: vec![Finding {
                rule: "PL002",
                file: "x.rs".into(),
                line: 3,
                message: "quote \" and\nnewline".into(),
            }],
            suppressed: 4,
            unused: vec![],
            over_budget: vec![],
        };
        let edges = vec![LockEdge { from: "a".into(), to: "b".into(), ok: true }];
        let j = json_report(&report, &edges);
        assert!(j.contains("\"PL002\""));
        assert!(j.contains("\\\" and\\nnewline"));
        assert!(j.contains("\"suppressed\": 4"));
        assert!(j.contains("\"lock_edges\""));
        assert!(j.contains("\"ok\": true"));
    }
}

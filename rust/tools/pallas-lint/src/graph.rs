//! Crate-wide analysis: the two-pass half of pallas-lint.
//!
//! The per-file rules in `lib.rs` (PL001–PL005) are syntactic — each
//! file is judged alone. The rules here need the *crate*: which
//! function acquires which lock, who calls whom, and which metrics
//! names exist in the registry. Pass 1 builds that model; pass 2 walks
//! every function body with a live-guard stack and enforces:
//!
//! - **PL006** — lock acquisitions must follow the hierarchy declared
//!   in `rust/lint-order.toml`. Every `util::sync::{lock,read,write}_
//!   recover` call site must resolve (by the field/binding ident of its
//!   argument) to a declared lock, and an acquisition made while
//!   another guard is live must go *down* the declared order — an
//!   inversion, an unordered pair, or a re-acquisition is a finding.
//!   Edges are tracked intra-procedurally and one call level deep.
//! - **PL007** — on the hot-path files (`engine/sched.rs`,
//!   `runtime/pool.rs`, `coordinator/batcher.rs`), no blocking call
//!   (`recv`, `recv_timeout`, `recv_deadline`, zero-arg `join`,
//!   `thread::sleep`, `thread::park[_timeout]`) and no nested
//!   `*_recover` acquisition while a guard binding is live. Condvar
//!   `wait`/`wait_timeout` are deliberately *not* blocking here: they
//!   take the guard by value and release it while parked.
//! - **PL008** — metrics emission sites (`.add(..)` / `.set(..)` /
//!   `.record(..)`) must name their gauge/counter via a constant from
//!   the `coordinator/stats.rs` `names` registry module, never a raw
//!   string literal — and a `names::X` path must actually exist there.
//!
//! The guard-liveness model is deliberately simple and documented:
//! a `let g = <acquire>;` guard lives to the end of its enclosing
//! block (or an explicit `drop(g)`); an acquire embedded in a larger
//! expression (a method-chain receiver, a `for` head, a `match`
//! scrutinee) lives as a temporary to the end of the enclosing
//! *statement*, including any blocks that statement owns. `let _ =
//! <acquire>` drops immediately, matching Rust. Closure bodies are
//! analyzed with a fresh (empty) guard stack — a closure's body does
//! not run at its definition site — and a closure's acquisitions do
//! not count as its defining function's for call-edge purposes.
//!
//! Call resolution is heuristic on purpose (no type inference): a
//! `self.m(..)` call resolves against the enclosing impl's type, a
//! `Type::f(..)` path call against `Type`, and a bare `f(..)` call by
//! unique name (same file first). Anything ambiguous resolves to
//! nothing — the analysis under-approximates calls rather than invent
//! edges. A function whose body *tail-returns* an acquisition (e.g.
//! `ProfileStore::guard`) is treated as an acquire at its call sites,
//! so returned guards stay tracked.

use std::collections::{BTreeMap, BTreeSet};

use syn::visit::Visit;

use crate::{is_test_gated, Finding};

/// Acquire helpers from `util::sync` — the only lock anchors the
/// analysis recognizes (the per-file rule PL002 already forces all
/// non-test guard acquisition through them).
const ACQUIRE_FNS: &[&str] = &["lock_recover", "read_recover", "write_recover"];

/// Method names that block the calling thread. `join` counts only with
/// zero args (`JoinHandle::join`), so `Vec::join(", ")` never fires.
const BLOCKING_METHODS: &[&str] = &["recv", "recv_timeout", "recv_deadline"];

/// `thread::`-qualified free functions that block.
const BLOCKING_THREAD_FNS: &[&str] = &["sleep", "park", "park_timeout"];

/// Metrics emission methods whose first argument is a wire name.
const EMIT_METHODS: &[&str] = &["add", "set", "record"];

/// PL007's scope: the files where a stalled guard stalls the paper's
/// core-allocation machinery itself.
fn hot_path(file: &str) -> bool {
    matches!(file, "engine/sched.rs" | "runtime/pool.rs" | "coordinator/batcher.rs")
}

// ------------------------------------------------------------ lock order

/// One declared lock: a wire name plus the source idents (struct fields
/// or local bindings) its acquisition sites use.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LockDecl {
    pub name: String,
    pub fields: Vec<String>,
}

/// The declared acquisition hierarchy from `rust/lint-order.toml`:
/// named locks plus `a < b` ordering chains. Construction validates
/// the declaration itself — duplicate names/fields, unknown names in a
/// chain, and cycles are all errors.
#[derive(Debug, Clone)]
pub struct LockOrder {
    locks: Vec<LockDecl>,
    /// direct declared edges (before, after), for the DOT rendering
    declared: Vec<(usize, usize)>,
    /// transitive closure: `reach[a]` contains `b` iff `a < b`
    reach: Vec<BTreeSet<usize>>,
}

impl LockOrder {
    pub fn lock_names(&self) -> Vec<&str> {
        self.locks.iter().map(|l| l.name.as_str()).collect()
    }

    fn by_field(&self, ident: &str) -> Option<usize> {
        self.locks.iter().position(|l| l.fields.iter().any(|f| f == ident))
    }

    fn name(&self, i: usize) -> &str {
        &self.locks[i].name
    }

    fn before(&self, a: usize, b: usize) -> bool {
        self.reach[a].contains(&b)
    }
}

/// Parse the `lint-order.toml` subset: `#` comments, `[[lock]]` blocks
/// with a `name` and one or more `field` aliases, and top-level
/// `order = "a < b < c"` chains (repeatable; the union must be
/// acyclic). Built on the shared [`crate::toml`] subset parser — same
/// no-new-deps rule as the allowlist.
pub fn parse_lock_order(text: &str) -> Result<LockOrder, String> {
    let doc = crate::toml::Doc::parse(text)?;
    let mut locks: Vec<LockDecl> = Vec::new();
    let mut chains: Vec<(usize, String)> = Vec::new();

    // `order` is global: chains may appear before, between, or after
    // [[lock]] blocks (the generic parser attributes trailing ones to
    // the last block, so both item streams are scanned).
    for item in &doc.top {
        match item.key.as_str() {
            "order" => chains.push((item.line, item.str()?.to_string())),
            "name" => return Err(format!("line {}: `name` outside [[lock]]", item.line)),
            "field" => return Err(format!("line {}: `field` outside [[lock]]", item.line)),
            other => return Err(format!("line {}: unknown key `{other}`", item.line)),
        }
    }
    for sec in &doc.sections {
        if !sec.array || sec.name != "lock" {
            return Err(format!(
                "line {}: expected `[[lock]]`, got section `{}`",
                sec.line, sec.name
            ));
        }
        let mut decl = LockDecl { name: String::new(), fields: Vec::new() };
        for item in &sec.items {
            match item.key.as_str() {
                "order" => chains.push((item.line, item.str()?.to_string())),
                "name" => {
                    if decl.name.is_empty() {
                        decl.name = item.str()?.to_string();
                    } else {
                        return Err(format!(
                            "line {}: `{}` already has a name",
                            item.line, decl.name
                        ));
                    }
                }
                "field" => decl.fields.push(item.str()?.to_string()),
                other => return Err(format!("line {}: unknown key `{other}`", item.line)),
            }
        }
        if decl.name.is_empty() {
            return Err("[[lock]] block missing `name`".into());
        }
        if decl.fields.is_empty() {
            return Err(format!("[[lock]] `{}` declares no `field`", decl.name));
        }
        locks.push(decl);
    }

    // Validate declarations: names and field aliases must be unique
    // crate-wide (an alias names exactly one lock).
    for (i, l) in locks.iter().enumerate() {
        for other in &locks[i + 1..] {
            if l.name == other.name {
                return Err(format!("duplicate lock name `{}`", l.name));
            }
            if let Some(f) = l.fields.iter().find(|f| other.fields.contains(f)) {
                return Err(format!(
                    "field `{f}` is claimed by both `{}` and `{}`",
                    l.name, other.name
                ));
            }
        }
    }

    // Chains -> direct edges.
    let mut declared: Vec<(usize, usize)> = Vec::new();
    for (line_no, chain) in &chains {
        let parts: Vec<&str> = chain.split('<').map(str::trim).collect();
        if parts.len() < 2 {
            return Err(format!("line {line_no}: order chain needs at least `a < b`"));
        }
        let mut prev: Option<usize> = None;
        for p in parts {
            let idx = locks
                .iter()
                .position(|l| l.name == p)
                .ok_or_else(|| format!("line {line_no}: order names unknown lock `{p}`"))?;
            if let Some(a) = prev {
                if a == idx {
                    return Err(format!("line {line_no}: `{p}` ordered against itself"));
                }
                if !declared.contains(&(a, idx)) {
                    declared.push((a, idx));
                }
            }
            prev = Some(idx);
        }
    }

    // Transitive closure + cycle check.
    let n = locks.len();
    let mut reach: Vec<BTreeSet<usize>> = vec![BTreeSet::new(); n];
    for &(a, b) in &declared {
        reach[a].insert(b);
    }
    // Floyd–Warshall-style saturation; the lock count is single-digit.
    loop {
        let mut grew = false;
        for a in 0..n {
            let via: Vec<usize> = reach[a].iter().copied().collect();
            for m in via {
                let add: Vec<usize> = reach[m].difference(&reach[a]).copied().collect();
                for b in add {
                    reach[a].insert(b);
                    grew = true;
                }
            }
        }
        if !grew {
            break;
        }
    }
    for (a, r) in reach.iter().enumerate() {
        if r.contains(&a) {
            return Err(format!(
                "declared order contains a cycle through `{}`",
                locks[a].name
            ));
        }
    }

    Ok(LockOrder { locks, declared, reach })
}

/// One observed held→acquired pair, by declared lock name. `ok` is
/// whether the declared order permits it — a clean tree only ships
/// `ok` edges (the finding for a bad one fails the lint).
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct LockEdge {
    pub from: String,
    pub to: String,
    pub ok: bool,
}

/// Render the declared hierarchy plus the observed acquisition edges
/// as Graphviz DOT (CI uploads this next to `lint-report.json`).
/// Declared edges are solid; observed ones dashed (red if illegal).
pub fn lock_order_dot(order: &LockOrder, observed: &[LockEdge]) -> String {
    let mut out = String::from("digraph lock_order {\n");
    out.push_str("  rankdir=LR;\n  node [shape=box, fontname=\"monospace\"];\n");
    for l in &order.locks {
        out.push_str(&format!("  \"{}\";\n", l.name));
    }
    for &(a, b) in &order.declared {
        out.push_str(&format!(
            "  \"{}\" -> \"{}\" [label=\"declared\"];\n",
            order.name(a),
            order.name(b)
        ));
    }
    for e in observed {
        let color = if e.ok { "blue" } else { "red" };
        out.push_str(&format!(
            "  \"{}\" -> \"{}\" [style=dashed, color={color}, label=\"observed\"];\n",
            e.from, e.to
        ));
    }
    out.push_str("}\n");
    out
}

// ------------------------------------------------------------ pass 1

/// What pass 2 needs to know about a function without re-reading it:
/// the locks it acquires directly, whether it blocks directly, and
/// whether its body tail-returns a guard.
struct FnSummary {
    file: String,
    self_ty: Option<String>,
    name: String,
    /// idents of locks acquired directly in the body (closures excluded)
    acquires: Vec<String>,
    /// first directly-blocking call, if any: (what, line)
    blocking: Option<(String, usize)>,
    /// the body's tail expression is an acquire of this ident — call
    /// sites treat the call itself as an acquisition
    tail_acquire: Option<String>,
}

struct SymbolTable {
    fns: Vec<FnSummary>,
}

impl SymbolTable {
    fn resolve_method(&self, ty: &str, name: &str) -> Option<&FnSummary> {
        let mut hits = self
            .fns
            .iter()
            .filter(|f| f.name == name && f.self_ty.as_deref() == Some(ty));
        match (hits.next(), hits.next()) {
            (Some(f), None) => Some(f),
            _ => None,
        }
    }

    fn resolve_free(&self, file: &str, name: &str) -> Option<&FnSummary> {
        let all: Vec<&FnSummary> = self.fns.iter().filter(|f| f.name == name).collect();
        match all.len() {
            1 => Some(all[0]),
            0 => None,
            _ => {
                let mut local = all.into_iter().filter(|f| f.file == file);
                match (local.next(), local.next()) {
                    (Some(f), None) => Some(f),
                    _ => None,
                }
            }
        }
    }
}

/// The `coordinator/stats.rs` `names` module contents: const ident ->
/// wire-name value. Collected from any `mod names` in the file set so
/// fixtures can carry their own miniature registry.
#[derive(Default)]
struct Registry {
    consts: BTreeMap<String, String>,
}

/// The ident a `*_recover(..)` argument names its lock by: the last
/// *named* field or path segment, skipping `&`, `*`, parens, and tuple
/// indices — `&self.queue.0` -> `queue`, a local `lock` -> `lock`.
fn lock_ident(e: &syn::Expr) -> Option<String> {
    match e {
        syn::Expr::Reference(r) => lock_ident(&r.expr),
        syn::Expr::Paren(p) => lock_ident(&p.expr),
        syn::Expr::Group(g) => lock_ident(&g.expr),
        syn::Expr::Unary(u) => lock_ident(&u.expr),
        syn::Expr::Index(i) => lock_ident(&i.expr),
        syn::Expr::MethodCall(m) => lock_ident(&m.receiver),
        syn::Expr::Field(f) => match &f.member {
            syn::Member::Named(id) => Some(id.to_string()),
            syn::Member::Unnamed(_) => lock_ident(&f.base),
        },
        syn::Expr::Path(p) => p.path.segments.last().map(|s| s.ident.to_string()),
        _ => None,
    }
}

/// `lock_recover(&x)` and friends: Some((acquire-fn, lock ident, line)).
fn as_acquire(call: &syn::ExprCall) -> Option<(String, Option<String>, usize)> {
    let syn::Expr::Path(p) = &*call.func else { return None };
    let last = p.path.segments.last()?;
    let name = last.ident.to_string();
    if !ACQUIRE_FNS.contains(&name.as_str()) {
        return None;
    }
    let line = last.ident.span().start().line;
    Some((name, call.args.first().and_then(lock_ident), line))
}

/// Collects a function's direct acquires and blocking calls, skipping
/// closure bodies (they do not run at the definition site).
struct SummaryCollector {
    acquires: Vec<String>,
    blocking: Option<(String, usize)>,
}

impl<'ast> Visit<'ast> for SummaryCollector {
    fn visit_expr_closure(&mut self, _node: &'ast syn::ExprClosure) {}

    fn visit_expr_call(&mut self, node: &'ast syn::ExprCall) {
        if let Some((_, ident, _)) = as_acquire(node) {
            self.acquires.push(ident.unwrap_or_else(|| "<expr>".into()));
        } else if let Some((what, line)) = blocking_path_call(node) {
            self.blocking.get_or_insert((what, line));
        }
        syn::visit::visit_expr_call(self, node);
    }

    fn visit_expr_method_call(&mut self, node: &'ast syn::ExprMethodCall) {
        if let Some(what) = blocking_method(node) {
            self.blocking
                .get_or_insert((what, node.method.span().start().line));
        }
        syn::visit::visit_expr_method_call(self, node);
    }
}

fn blocking_method(node: &syn::ExprMethodCall) -> Option<String> {
    let name = node.method.to_string();
    if BLOCKING_METHODS.contains(&name.as_str())
        || (name == "join" && node.args.is_empty())
    {
        Some(format!(".{name}()"))
    } else {
        None
    }
}

fn blocking_path_call(node: &syn::ExprCall) -> Option<(String, usize)> {
    let syn::Expr::Path(p) = &*node.func else { return None };
    let segs: Vec<String> = p.path.segments.iter().map(|s| s.ident.to_string()).collect();
    let last = segs.last()?;
    if BLOCKING_THREAD_FNS.contains(&last.as_str())
        && segs.len() >= 2
        && segs[segs.len() - 2] == "thread"
    {
        let line = p.path.segments.last().map(|s| s.ident.span().start().line)?;
        Some((format!("thread::{last}()"), line))
    } else {
        None
    }
}

/// Does this block's tail expression acquire a lock (through parens)?
fn tail_acquire(block: &syn::Block) -> Option<String> {
    fn of_expr(e: &syn::Expr) -> Option<String> {
        match e {
            syn::Expr::Paren(p) => of_expr(&p.expr),
            syn::Expr::Group(g) => of_expr(&g.expr),
            syn::Expr::Call(c) => as_acquire(c).map(|(_, id, _)| id.unwrap_or_default()),
            _ => None,
        }
    }
    match block.stmts.last()? {
        syn::Stmt::Expr(e, None) => of_expr(e),
        _ => None,
    }
}

/// Walk a file's items, yielding every non-test fn (with its impl type)
/// and every `mod names` const into the tables.
fn collect_file(
    file: &str,
    items: &[syn::Item],
    self_ty: Option<&str>,
    table: &mut SymbolTable,
    registry: &mut Registry,
) {
    for item in items {
        match item {
            syn::Item::Fn(f) => {
                if is_test_gated(&f.attrs) {
                    continue;
                }
                table.fns.push(summarize(file, self_ty, &f.sig.ident, &f.block));
            }
            syn::Item::Impl(imp) => {
                if is_test_gated(&imp.attrs) {
                    continue;
                }
                let ty = impl_type_name(imp);
                for ii in &imp.items {
                    if let syn::ImplItem::Fn(f) = ii {
                        if is_test_gated(&f.attrs) {
                            continue;
                        }
                        table.fns.push(summarize(
                            file,
                            ty.as_deref(),
                            &f.sig.ident,
                            &f.block,
                        ));
                    }
                }
            }
            syn::Item::Mod(m) => {
                if is_test_gated(&m.attrs) {
                    continue;
                }
                if let Some((_, inner)) = &m.content {
                    if m.ident == "names" {
                        for it in inner {
                            if let syn::Item::Const(c) = it {
                                if let syn::Expr::Lit(l) = &*c.expr {
                                    if let syn::Lit::Str(s) = &l.lit {
                                        registry
                                            .consts
                                            .insert(c.ident.to_string(), s.value());
                                    }
                                }
                            }
                        }
                    }
                    collect_file(file, inner, self_ty, table, registry);
                }
            }
            _ => {}
        }
    }
}

fn impl_type_name(imp: &syn::ItemImpl) -> Option<String> {
    if let syn::Type::Path(tp) = &*imp.self_ty {
        tp.path.segments.last().map(|s| s.ident.to_string())
    } else {
        None
    }
}

fn summarize(
    file: &str,
    self_ty: Option<&str>,
    ident: &proc_macro2::Ident,
    block: &syn::Block,
) -> FnSummary {
    let mut c = SummaryCollector { acquires: Vec::new(), blocking: None };
    c.visit_block(block);
    FnSummary {
        file: file.to_string(),
        self_ty: self_ty.map(str::to_string),
        name: ident.to_string(),
        acquires: c.acquires,
        blocking: c.blocking,
        tail_acquire: tail_acquire(block),
    }
}

// ------------------------------------------------------------ pass 2

/// A guard currently live at some program point: the lock ident its
/// acquisition named, the declared lock it resolved to (if any), the
/// binding that owns it (None for statement temporaries), and where it
/// was acquired.
#[derive(Clone)]
struct LiveGuard {
    ident: String,
    lock: Option<usize>,
    binding: Option<String>,
    line: usize,
}

struct Walker<'a> {
    file: &'a str,
    self_ty: Option<&'a str>,
    hot: bool,
    order: Option<&'a LockOrder>,
    table: &'a SymbolTable,
    registry: &'a Registry,
    live: Vec<LiveGuard>,
    findings: &'a mut Vec<Finding>,
    edges: &'a mut BTreeSet<LockEdge>,
}

impl Walker<'_> {
    fn push_finding(&mut self, rule: &'static str, line: usize, message: String) {
        self.findings.push(Finding {
            rule,
            file: self.file.to_string(),
            line,
            message,
        });
    }

    /// PL006 edge check for "acquiring `to` while holding `held`",
    /// intra-procedural or via the named call.
    fn check_edge(&mut self, held: &LiveGuard, to_ident: &str, line: usize, via: Option<&str>) {
        let Some(order) = self.order else { return };
        let (Some(from), Some(to)) = (held.lock, order.by_field(to_ident)) else {
            // Undeclared locks are reported at their own acquire site;
            // an edge against one cannot be order-checked.
            return;
        };
        let via = via.map(|v| format!(" via call to `{v}`")).unwrap_or_default();
        if from == to {
            self.push_finding(
                "PL006",
                line,
                format!(
                    "re-acquiring `{}`{via} while already holding it (acquired line {}) \
                     — self-deadlock",
                    order.name(from),
                    held.line
                ),
            );
            return;
        }
        let ok = order.before(from, to);
        self.edges.insert(LockEdge {
            from: order.name(from).to_string(),
            to: order.name(to).to_string(),
            ok,
        });
        if ok {
            return;
        }
        if order.before(to, from) {
            self.push_finding(
                "PL006",
                line,
                format!(
                    "acquiring `{}`{via} while holding `{}` inverts the declared order \
                     `{}` < `{}` (lint-order.toml)",
                    order.name(to),
                    order.name(from),
                    order.name(to),
                    order.name(from),
                ),
            );
        } else {
            self.push_finding(
                "PL006",
                line,
                format!(
                    "no declared order between `{}` (held) and `{}`{via} — extend an \
                     `order` chain in lint-order.toml",
                    order.name(from),
                    order.name(to),
                ),
            );
        }
    }

    /// Everything that happens at an acquisition site: undeclared-lock
    /// check, PL006 edges against every live guard, PL007 nested-guard
    /// check on hot paths. Returns the guard value.
    fn on_acquire(&mut self, ident: Option<String>, line: usize) -> LiveGuard {
        let ident = ident.unwrap_or_else(|| "<expr>".into());
        let lock = self.order.and_then(|o| o.by_field(&ident));
        if self.order.is_some() && lock.is_none() {
            self.push_finding(
                "PL006",
                line,
                format!(
                    "lock acquisition `{ident}` matches no [[lock]] entry in \
                     lint-order.toml — declare it and place it in the order"
                ),
            );
        }
        if self.hot {
            if let Some(held) = self.live.last() {
                let holder = held
                    .binding
                    .clone()
                    .unwrap_or_else(|| format!("`{}` (temporary)", held.ident));
                self.push_finding(
                    "PL007",
                    line,
                    format!(
                        "acquiring `{ident}` while guard {holder} (line {}) is live — \
                         nested lock acquisition on a hot path",
                        held.line
                    ),
                );
            }
        }
        let helds: Vec<LiveGuard> = self.live.clone();
        for held in &helds {
            self.check_edge(held, &ident, line, None);
        }
        LiveGuard { ident, lock, binding: None, line }
    }

    /// Everything that happens at a blocking call site (PL007).
    fn on_blocking(&mut self, what: &str, line: usize) {
        if !self.hot {
            return;
        }
        if let Some(held) = self.live.last() {
            let holder = held
                .binding
                .clone()
                .unwrap_or_else(|| format!("`{}` (temporary)", held.ident));
            self.push_finding(
                "PL007",
                line,
                format!(
                    "{what} while guard {holder} (acquired line {}) is live — shrink \
                     the critical section or collect-then-drop before blocking",
                    held.line
                ),
            );
        }
    }

    /// A resolved call to a crate function while guards may be held:
    /// one-call-deep PL006 edges and PL007 blocking propagation.
    fn on_resolved_call(&mut self, callee: &FnSummary, line: usize) -> Option<LiveGuard> {
        let label = match &callee.self_ty {
            Some(t) => format!("{t}::{}", callee.name),
            None => callee.name.clone(),
        };
        if self.hot && !self.live.is_empty() {
            if let Some((what, at)) = &callee.blocking {
                let held = self.live.last().expect("checked non-empty");
                let holder = held
                    .binding
                    .clone()
                    .unwrap_or_else(|| format!("`{}` (temporary)", held.ident));
                self.push_finding(
                    "PL007",
                    line,
                    format!(
                        "call to `{label}` (blocks: {what} at {}:{at}) while guard \
                         {holder} is live",
                        callee.file
                    ),
                );
            }
        }
        let helds: Vec<LiveGuard> = self.live.clone();
        for acq in &callee.acquires {
            if callee.tail_acquire.as_deref() == Some(acq.as_str()) {
                // the tail acquire is handled below as a real acquire at
                // this site — do not double-report its edges
                continue;
            }
            for held in &helds {
                self.check_edge(held, acq, line, Some(&label));
            }
        }
        callee
            .tail_acquire
            .clone()
            .map(|ident| self.on_acquire(Some(ident), line))
    }

    /// PL008: emission sites name their metric from the registry.
    fn check_emission(&mut self, node: &syn::ExprMethodCall) {
        let method = node.method.to_string();
        if !EMIT_METHODS.contains(&method.as_str()) {
            return;
        }
        let Some(arg0) = node.args.first() else { return };
        let line = node.method.span().start().line;
        match arg0 {
            syn::Expr::Lit(l) => {
                if let syn::Lit::Str(s) = &l.lit {
                    self.push_finding(
                        "PL008",
                        line,
                        format!(
                            ".{method}(\"{}\", ..) names its metric with a raw string \
                             literal — hoist it into coordinator/stats.rs `names` and \
                             reference the constant",
                            s.value()
                        ),
                    );
                }
            }
            syn::Expr::Path(p) => {
                let segs: Vec<String> =
                    p.path.segments.iter().map(|s| s.ident.to_string()).collect();
                let Some(last) = segs.last() else { return };
                let via_names = segs.iter().any(|s| s == "names");
                if via_names && !self.registry.consts.contains_key(last) {
                    self.push_finding(
                        "PL008",
                        line,
                        format!(
                            "`names::{last}` is not a constant in the stats wire-name \
                             registry — add it to coordinator/stats.rs `names`"
                        ),
                    );
                }
            }
            _ => {}
        }
    }

    fn walk_block(&mut self, b: &syn::Block) {
        let base = self.live.len();
        for stmt in &b.stmts {
            self.walk_stmt(stmt);
        }
        self.live.truncate(base);
    }

    fn walk_stmt(&mut self, s: &syn::Stmt) {
        match s {
            syn::Stmt::Local(l) => {
                let base = self.live.len();
                let guard = l.init.as_ref().and_then(|init| self.walk_expr(&init.expr));
                // statement temporaries die here; a guard bound by the
                // `let` survives to the end of the enclosing block
                self.live.truncate(base);
                if let Some(g) = guard {
                    if let Some(name) = pat_binding(&l.pat) {
                        self.live.push(LiveGuard { binding: Some(name), ..g });
                    }
                    // `let _ = <acquire>` drops the guard immediately
                }
            }
            syn::Stmt::Expr(e, _) => {
                let base = self.live.len();
                let _ = self.walk_expr(e);
                self.live.truncate(base);
            }
            syn::Stmt::Item(_) | syn::Stmt::Macro(_) => {}
        }
    }

    /// Walk a sub-expression whose value is *consumed* here: if it
    /// evaluates to a guard, that guard becomes a live temporary for
    /// the rest of the enclosing statement.
    fn walk_child(&mut self, e: &syn::Expr) {
        if let Some(g) = self.walk_expr(e) {
            self.live.push(g);
        }
    }

    /// Returns Some when this expression's value *is* a guard (a direct
    /// acquire, a call to a guard-returning fn, or one of those behind
    /// parens) — the caller decides whether it becomes a named binding
    /// or a statement temporary.
    fn walk_expr(&mut self, e: &syn::Expr) -> Option<LiveGuard> {
        match e {
            syn::Expr::Call(c) => self.walk_call(c),
            syn::Expr::MethodCall(m) => {
                self.walk_child(&m.receiver);
                if let Some(what) = blocking_method(m) {
                    self.on_blocking(&what, m.method.span().start().line);
                }
                self.check_emission(m);
                // copy the table reference out so the resolved summary
                // is not borrow-tied to `self`
                let table = self.table;
                let guard = if is_self_path(&m.receiver) {
                    match self
                        .self_ty
                        .and_then(|ty| table.resolve_method(ty, &m.method.to_string()))
                    {
                        Some(callee) => {
                            self.on_resolved_call(callee, m.method.span().start().line)
                        }
                        None => None,
                    }
                } else {
                    None
                };
                for a in &m.args {
                    self.walk_child(a);
                }
                guard
            }
            syn::Expr::Paren(p) => self.walk_expr(&p.expr),
            syn::Expr::Group(g) => self.walk_expr(&g.expr),
            syn::Expr::Reference(r) => self.walk_expr(&r.expr),
            syn::Expr::ForLoop(f) => {
                self.walk_child(&f.expr);
                self.walk_block(&f.body);
                None
            }
            syn::Expr::While(w) => {
                self.walk_child(&w.cond);
                self.walk_block(&w.body);
                None
            }
            syn::Expr::Loop(l) => {
                self.walk_block(&l.body);
                None
            }
            syn::Expr::If(i) => {
                self.walk_child(&i.cond);
                self.walk_block(&i.then_branch);
                if let Some((_, else_e)) = &i.else_branch {
                    self.walk_child(else_e);
                }
                None
            }
            syn::Expr::Match(m) => {
                self.walk_child(&m.expr);
                for arm in &m.arms {
                    if let Some((_, g)) = &arm.guard {
                        self.walk_child(g);
                    }
                    self.walk_child(&arm.body);
                }
                None
            }
            syn::Expr::Let(l) => {
                // `if let <pat> = <expr>`: a guard in the scrutinee
                // stays live through the bound arm (statement scope).
                self.walk_child(&l.expr);
                None
            }
            syn::Expr::Block(b) => {
                self.walk_block(&b.block);
                None
            }
            syn::Expr::Unsafe(u) => {
                self.walk_block(&u.block);
                None
            }
            syn::Expr::Async(a) => {
                self.walk_block(&a.block);
                None
            }
            syn::Expr::TryBlock(t) => {
                self.walk_block(&t.block);
                None
            }
            syn::Expr::Closure(c) => {
                // The body runs later, with whatever is live *then* —
                // analyze it against an empty guard stack.
                let saved = std::mem::take(&mut self.live);
                let _ = self.walk_expr(&c.body);
                self.live = saved;
                None
            }
            syn::Expr::Assign(a) => {
                self.walk_child(&a.right);
                self.walk_child(&a.left);
                None
            }
            syn::Expr::Binary(b) => {
                self.walk_child(&b.left);
                self.walk_child(&b.right);
                None
            }
            syn::Expr::Unary(u) => {
                self.walk_child(&u.expr);
                None
            }
            syn::Expr::Field(f) => {
                self.walk_child(&f.base);
                None
            }
            syn::Expr::Index(i) => {
                self.walk_child(&i.expr);
                self.walk_child(&i.index);
                None
            }
            syn::Expr::Await(a) => {
                self.walk_child(&a.base);
                None
            }
            syn::Expr::Try(t) => {
                self.walk_child(&t.expr);
                None
            }
            syn::Expr::Cast(c) => {
                self.walk_child(&c.expr);
                None
            }
            syn::Expr::Return(r) => {
                if let Some(e) = &r.expr {
                    self.walk_child(e);
                }
                None
            }
            syn::Expr::Break(b) => {
                if let Some(e) = &b.expr {
                    self.walk_child(e);
                }
                None
            }
            syn::Expr::Tuple(t) => {
                for e in &t.elems {
                    self.walk_child(e);
                }
                None
            }
            syn::Expr::Array(a) => {
                for e in &a.elems {
                    self.walk_child(e);
                }
                None
            }
            syn::Expr::Struct(s) => {
                for f in &s.fields {
                    self.walk_child(&f.expr);
                }
                if let Some(rest) = &s.rest {
                    self.walk_child(rest);
                }
                None
            }
            syn::Expr::Range(r) => {
                if let Some(s) = &r.start {
                    self.walk_child(s);
                }
                if let Some(e) = &r.end {
                    self.walk_child(e);
                }
                None
            }
            syn::Expr::Repeat(r) => {
                self.walk_child(&r.expr);
                self.walk_child(&r.len);
                None
            }
            // paths, literals, macros (unparsed tokens), and the rest
            // carry no guard flow
            _ => None,
        }
    }

    fn walk_call(&mut self, c: &syn::ExprCall) -> Option<LiveGuard> {
        // Acquire?
        if let Some((_, ident, line)) = as_acquire(c) {
            for a in &c.args {
                self.walk_child(a);
            }
            return Some(self.on_acquire(ident, line));
        }
        // drop(g) ends a named guard early.
        if let syn::Expr::Path(p) = &*c.func {
            let segs: Vec<String> =
                p.path.segments.iter().map(|s| s.ident.to_string()).collect();
            if segs.last().is_some_and(|s| s == "drop") && c.args.len() == 1 {
                if let syn::Expr::Path(arg) = &c.args[0] {
                    if let Some(name) = arg.path.get_ident().map(|i| i.to_string()) {
                        if let Some(pos) = self
                            .live
                            .iter()
                            .rposition(|g| g.binding.as_deref() == Some(&name))
                        {
                            self.live.remove(pos);
                            return None;
                        }
                    }
                }
            }
            if let Some((what, line)) = blocking_path_call(c) {
                self.on_blocking(&what, line);
            }
            // Resolve `Type::f(..)` and bare `f(..)` crate calls.
            let line = p
                .path
                .segments
                .last()
                .map(|s| s.ident.span().start().line)
                .unwrap_or(0);
            let table = self.table;
            let file = self.file;
            let resolved = if segs.len() >= 2
                && segs[segs.len() - 2]
                    .chars()
                    .next()
                    .is_some_and(|ch| ch.is_uppercase())
            {
                table.resolve_method(&segs[segs.len() - 2], segs.last().expect("non-empty"))
            } else if segs.len() == 1 {
                table.resolve_free(file, &segs[0])
            } else {
                None
            };
            if let Some(callee) = resolved {
                let guard = self.on_resolved_call(callee, line);
                for a in &c.args {
                    self.walk_child(a);
                }
                return guard;
            }
        } else {
            self.walk_child(&c.func);
        }
        for a in &c.args {
            self.walk_child(a);
        }
        None
    }
}

fn is_self_path(e: &syn::Expr) -> bool {
    matches!(e, syn::Expr::Path(p) if p.path.is_ident("self"))
}

fn pat_binding(pat: &syn::Pat) -> Option<String> {
    match pat {
        syn::Pat::Ident(p) => Some(p.ident.to_string()),
        syn::Pat::Type(p) => pat_binding(&p.pat),
        _ => None,
    }
}

// ----------------------------------------------------------- entry point

pub(crate) struct CrateReport {
    pub findings: Vec<Finding>,
    pub edges: Vec<LockEdge>,
}

/// Run the crate-wide rules (PL006–PL008) over a set of already-read
/// files. `order == None` disables PL006 entirely (including the
/// undeclared-lock check); PL007/PL008 always run.
pub(crate) fn check_crate(
    files: &[(String, String)],
    order: Option<&LockOrder>,
) -> Result<CrateReport, String> {
    let mut asts: Vec<(String, syn::File)> = Vec::with_capacity(files.len());
    for (rel, src) in files {
        let ast =
            syn::parse_file(src).map_err(|e| format!("{rel}: parse error: {e}"))?;
        asts.push((rel.clone(), ast));
    }

    // Pass 1: symbol table + registry.
    let mut table = SymbolTable { fns: Vec::new() };
    let mut registry = Registry::default();
    for (rel, ast) in &asts {
        collect_file(rel, &ast.items, None, &mut table, &mut registry);
    }

    // Pass 2: walk every non-test fn body with the crate context.
    let mut findings = Vec::new();
    let mut edges: BTreeSet<LockEdge> = BTreeSet::new();
    for (rel, ast) in &asts {
        walk_items(
            rel,
            &ast.items,
            None,
            order,
            &table,
            &registry,
            &mut findings,
            &mut edges,
        );
    }
    Ok(CrateReport { findings, edges: edges.into_iter().collect() })
}

#[allow(clippy::too_many_arguments)] // internal plumbing, not API
fn walk_items(
    file: &str,
    items: &[syn::Item],
    self_ty: Option<&str>,
    order: Option<&LockOrder>,
    table: &SymbolTable,
    registry: &Registry,
    findings: &mut Vec<Finding>,
    edges: &mut BTreeSet<LockEdge>,
) {
    for item in items {
        match item {
            syn::Item::Fn(f) => {
                if is_test_gated(&f.attrs) {
                    continue;
                }
                let mut w = Walker {
                    file,
                    self_ty,
                    hot: hot_path(file),
                    order,
                    table,
                    registry,
                    live: Vec::new(),
                    // explicit reborrows: a bare `findings` in a struct
                    // literal would *move* the &mut out of the loop
                    findings: &mut *findings,
                    edges: &mut *edges,
                };
                w.walk_block(&f.block);
            }
            syn::Item::Impl(imp) => {
                if is_test_gated(&imp.attrs) {
                    continue;
                }
                let ty = impl_type_name(imp);
                for ii in &imp.items {
                    if let syn::ImplItem::Fn(f) = ii {
                        if is_test_gated(&f.attrs) {
                            continue;
                        }
                        let mut w = Walker {
                            file,
                            self_ty: ty.as_deref(),
                            hot: hot_path(file),
                            order,
                            table,
                            registry,
                            live: Vec::new(),
                            findings: &mut *findings,
                            edges: &mut *edges,
                        };
                        w.walk_block(&f.block);
                    }
                }
            }
            syn::Item::Mod(m) => {
                if is_test_gated(&m.attrs) {
                    continue;
                }
                if let Some((_, inner)) = &m.content {
                    walk_items(
                        file, inner, self_ty, order, table, registry, findings, edges,
                    );
                }
            }
            _ => {}
        }
    }
}

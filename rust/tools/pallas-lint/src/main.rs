//! `pallas-lint` CLI.
//!
//! ```text
//! pallas-lint [--allow lint-allow.toml] [--json report.json] SRC_ROOT
//! ```
//!
//! Prints findings as `file:line RULE message`, one per line, plus an
//! allowlist accounting summary. Optionally writes a JSON report.
//!
//! Exit codes:
//! - `0` — no active findings, no stale allowlist entries
//! - `1` — findings survive the allowlist, an entry is over its `max`
//!   budget, or an entry matches nothing (stale)
//! - `2` — usage, I/O, config-parse, or Rust-parse error

use std::path::PathBuf;
use std::process::ExitCode;

use pallas_lint::{apply_allowlist, check_tree, json_report, parse_allowlist, AllowEntry};

fn usage() -> ExitCode {
    eprintln!("usage: pallas-lint [--allow FILE] [--json FILE] SRC_ROOT");
    ExitCode::from(2)
}

fn main() -> ExitCode {
    let mut allow_path: Option<PathBuf> = None;
    let mut json_path: Option<PathBuf> = None;
    let mut root: Option<PathBuf> = None;
    let mut args = std::env::args().skip(1);
    while let Some(arg) = args.next() {
        match arg.as_str() {
            "--allow" => match args.next() {
                Some(v) => allow_path = Some(PathBuf::from(v)),
                None => return usage(),
            },
            "--json" => match args.next() {
                Some(v) => json_path = Some(PathBuf::from(v)),
                None => return usage(),
            },
            "--help" | "-h" => {
                println!("pallas-lint: dnc_serve concurrency/budget contract checker");
                for (id, desc) in pallas_lint::RULES {
                    println!("  {id}  {desc}");
                }
                println!("\nusage: pallas-lint [--allow FILE] [--json FILE] SRC_ROOT");
                return ExitCode::SUCCESS;
            }
            _ if root.is_none() && !arg.starts_with('-') => root = Some(PathBuf::from(arg)),
            _ => return usage(),
        }
    }
    let Some(root) = root else { return usage() };

    let findings = match check_tree(&root) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("pallas-lint: {e}");
            return ExitCode::from(2);
        }
    };

    let allow: Vec<AllowEntry> = match &allow_path {
        None => Vec::new(),
        Some(path) => {
            let text = match std::fs::read_to_string(path) {
                Ok(t) => t,
                Err(e) => {
                    eprintln!("pallas-lint: {}: {e}", path.display());
                    return ExitCode::from(2);
                }
            };
            match parse_allowlist(&text) {
                Ok(a) => a,
                Err(e) => {
                    eprintln!("pallas-lint: {}: {e}", path.display());
                    return ExitCode::from(2);
                }
            }
        }
    };

    let report = apply_allowlist(&findings, &allow);

    for f in &report.active {
        println!("{f}");
    }
    for note in &report.over_budget {
        println!("over-budget allowlist entry: {note}");
    }
    for e in &report.unused {
        println!(
            "stale allowlist entry: {} in {} matches nothing — delete it",
            e.rule, e.file
        );
    }

    if let Some(path) = &json_path {
        if let Err(e) = std::fs::write(path, json_report(&report)) {
            eprintln!("pallas-lint: writing {}: {e}", path.display());
            return ExitCode::from(2);
        }
    }

    let clean = report.active.is_empty() && report.unused.is_empty();
    eprintln!(
        "pallas-lint: {} active finding(s), {} suppressed by allowlist, {} stale entr{}",
        report.active.len(),
        report.suppressed,
        report.unused.len(),
        if report.unused.len() == 1 { "y" } else { "ies" },
    );
    if clean {
        ExitCode::SUCCESS
    } else {
        ExitCode::from(1)
    }
}

//! `pallas-lint` CLI.
//!
//! ```text
//! pallas-lint [--allow lint-allow.toml] [--order lint-order.toml]
//!             [--json report.json] [--dot lock-order.dot] SRC_ROOT
//! ```
//!
//! Prints findings as `file:line RULE message`, one per line, plus an
//! allowlist accounting summary. Optionally writes a JSON report
//! (schema documented in the library crate root) and, when `--order`
//! is given, a Graphviz DOT rendering of the declared lock hierarchy
//! plus the acquisition edges actually observed in the tree.
//!
//! Without `--order`, rule PL006 is disabled; PL007/PL008 always run.
//! `--dot` requires `--order` (there is no graph without a hierarchy).
//!
//! Exit codes (stable — CI consumers rely on them):
//! - `0` — no active findings, no stale allowlist entries
//! - `1` — findings survive the allowlist, an entry is over its `max`
//!   budget, or an entry matches nothing (stale)
//! - `2` — usage, I/O, config-parse (allowlist or lock order, including
//!   a cyclic declared hierarchy), or Rust-parse error

use std::path::PathBuf;
use std::process::ExitCode;

use pallas_lint::{
    apply_allowlist, check_tree, json_report, lock_order_dot, parse_allowlist,
    parse_lock_order, AllowEntry, LockOrder,
};

fn usage() -> ExitCode {
    eprintln!(
        "usage: pallas-lint [--allow FILE] [--order FILE] [--json FILE] [--dot FILE] \
         SRC_ROOT"
    );
    ExitCode::from(2)
}

fn main() -> ExitCode {
    let mut allow_path: Option<PathBuf> = None;
    let mut order_path: Option<PathBuf> = None;
    let mut json_path: Option<PathBuf> = None;
    let mut dot_path: Option<PathBuf> = None;
    let mut root: Option<PathBuf> = None;
    let mut args = std::env::args().skip(1);
    while let Some(arg) = args.next() {
        match arg.as_str() {
            "--allow" => match args.next() {
                Some(v) => allow_path = Some(PathBuf::from(v)),
                None => return usage(),
            },
            "--order" => match args.next() {
                Some(v) => order_path = Some(PathBuf::from(v)),
                None => return usage(),
            },
            "--json" => match args.next() {
                Some(v) => json_path = Some(PathBuf::from(v)),
                None => return usage(),
            },
            "--dot" => match args.next() {
                Some(v) => dot_path = Some(PathBuf::from(v)),
                None => return usage(),
            },
            "--help" | "-h" => {
                println!("pallas-lint: dnc_serve concurrency/budget contract checker");
                for (id, desc) in pallas_lint::RULES {
                    println!("  {id}  {desc}");
                }
                println!(
                    "\nusage: pallas-lint [--allow FILE] [--order FILE] [--json FILE] \
                     [--dot FILE] SRC_ROOT"
                );
                return ExitCode::SUCCESS;
            }
            _ if root.is_none() && !arg.starts_with('-') => root = Some(PathBuf::from(arg)),
            _ => return usage(),
        }
    }
    let Some(root) = root else { return usage() };
    if dot_path.is_some() && order_path.is_none() {
        eprintln!("pallas-lint: --dot requires --order (no graph without a hierarchy)");
        return usage();
    }

    let order: Option<LockOrder> = match &order_path {
        None => None,
        Some(path) => {
            let text = match std::fs::read_to_string(path) {
                Ok(t) => t,
                Err(e) => {
                    eprintln!("pallas-lint: {}: {e}", path.display());
                    return ExitCode::from(2);
                }
            };
            match parse_lock_order(&text) {
                Ok(o) => Some(o),
                Err(e) => {
                    eprintln!("pallas-lint: {}: {e}", path.display());
                    return ExitCode::from(2);
                }
            }
        }
    };

    let tree = match check_tree(&root, order.as_ref()) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("pallas-lint: {e}");
            return ExitCode::from(2);
        }
    };

    let allow: Vec<AllowEntry> = match &allow_path {
        None => Vec::new(),
        Some(path) => {
            let text = match std::fs::read_to_string(path) {
                Ok(t) => t,
                Err(e) => {
                    eprintln!("pallas-lint: {}: {e}", path.display());
                    return ExitCode::from(2);
                }
            };
            match parse_allowlist(&text) {
                Ok(a) => a,
                Err(e) => {
                    eprintln!("pallas-lint: {}: {e}", path.display());
                    return ExitCode::from(2);
                }
            }
        }
    };

    let report = apply_allowlist(&tree.findings, &allow);

    for f in &report.active {
        println!("{f}");
    }
    for note in &report.over_budget {
        println!("over-budget allowlist entry: {note}");
    }
    for e in &report.unused {
        println!(
            "stale allowlist entry: {} in {} matches nothing — delete it",
            e.rule, e.file
        );
    }

    if let Some(path) = &json_path {
        if let Err(e) = std::fs::write(path, json_report(&report, &tree.lock_edges)) {
            eprintln!("pallas-lint: writing {}: {e}", path.display());
            return ExitCode::from(2);
        }
    }
    if let (Some(path), Some(order)) = (&dot_path, &order) {
        if let Err(e) = std::fs::write(path, lock_order_dot(order, &tree.lock_edges)) {
            eprintln!("pallas-lint: writing {}: {e}", path.display());
            return ExitCode::from(2);
        }
    }

    let clean = report.active.is_empty() && report.unused.is_empty();
    eprintln!(
        "pallas-lint: {} active finding(s), {} suppressed by allowlist, {} stale entr{}",
        report.active.len(),
        report.suppressed,
        report.unused.len(),
        if report.unused.len() == 1 { "y" } else { "ies" },
    );
    if clean {
        ExitCode::SUCCESS
    } else {
        ExitCode::from(1)
    }
}

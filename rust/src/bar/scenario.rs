//! Scenario definitions as data: the `rust/bench/scenarios/*.toml`
//! loader and its validation.
//!
//! A scenario file describes a complete benchmark with zero
//! per-scenario Rust:
//!
//! ```toml
//! [scenario]
//! name = "longshort"               # must match the file stem
//! summary = "misleading sizes"
//! engines = ["static", "adaptive"] # matrix columns (default: all)
//! tolerance_pct = 35               # diff-gate drift budget
//!
//! [machine]                        # optional; default 16 homogeneous
//! cores = "fast=4,slow=12@0.5"     # CoreMap spec, or an integer
//! workers = 4
//!
//! [arrival]
//! mode = "closed"                  # or "open"
//! submitters = 1
//! jobs = 60                        # per submitter, full mode
//! quick_jobs = 20                  # per submitter, --quick mode
//! seed = 7                         # deterministic arrival/cancel RNG
//! spacing_us = 0                   # inter-job pacing (open loop)
//! jitter = "none"                  # or "uniform" (±50% of spacing)
//!
//! [[part]]                         # one entry per job part
//! name = "heavy"
//! count = 1
//! base_ms = 40.0                   # SimRunner single-thread cost
//! size = 16                        # declared input size (static split)
//! threads = 0                      # 0 = auto (size/profile-driven)
//! priority = "normal"              # "low" | "normal" | "high"
//! # budget_ms = 250                # optional request budget
//! # cancel_after_ms = 2.0          # optional client cancel offset
//! # cancel_prob = 0.5              # cancel probability (default 1.0)
//! # measured = false               # exclude from walls (default true)
//!
//! [[bar]]                          # optional self-relative bars
//! metric = "p95_ms"                # or "throughput_jobs_s"
//! better = "adaptive"
//! than = "static"
//! margin_pct = 10                  # better must win by this much
//! ```
//!
//! Validation is pallas-lint-style: unknown keys, unknown or duplicate
//! sections, and out-of-range values are all hard errors — `bench-bar`
//! exits 2 rather than measuring against a half-read file.

use std::path::Path;

use crate::bench::gate::SIM_CORES;
use crate::engine::{CoreMap, Priority};
use crate::util::toml::{Doc, Item, Section};

use super::engine::ENGINES;
use super::measure::Mode;

/// Arrival-process shape: `closed` submitters wait for each job before
/// the next; `open` producers flood jobs at their pacing regardless of
/// completions.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Loop {
    Closed,
    Open,
}

/// The arrival process: who submits, how often, and how many times.
#[derive(Debug, Clone, PartialEq)]
pub struct Arrival {
    pub mode: Loop,
    pub submitters: usize,
    /// jobs per submitter in full mode
    pub jobs: usize,
    /// jobs per submitter in `--quick` mode
    pub quick_jobs: usize,
    /// seed for the deterministic arrival/cancel RNG
    pub seed: u64,
    /// inter-job pacing in microseconds (0 = as fast as submit returns)
    pub spacing_us: u64,
    /// `true`: each gap is drawn uniformly from ±50% of `spacing_us`
    pub uniform_jitter: bool,
}

impl Arrival {
    pub fn jobs_for(&self, mode: Mode) -> usize {
        match mode {
            Mode::Quick => self.quick_jobs,
            Mode::Full => self.jobs,
        }
    }
}

/// One part of every job: `count` instances of a simulated model, with
/// the declared size the static split sees and the knobs (priority,
/// budget, cancellation) the distributions exercise.
#[derive(Debug, Clone, PartialEq)]
pub struct PartSpec {
    pub name: String,
    pub count: usize,
    pub base_ms: f64,
    pub size: usize,
    /// explicit thread count; 0 = auto (allocated from sizes or
    /// profiled weights, depending on the engine)
    pub threads: usize,
    pub priority: Priority,
    pub budget_ms: Option<f64>,
    /// client cancels this part `cancel_after_ms` after submit…
    pub cancel_after_ms: Option<f64>,
    /// …with this probability (per instance, seeded RNG)
    pub cancel_prob: f64,
    /// measured parts define the job wall; unmeasured ones are drained
    pub measured: bool,
}

/// Which metric a self-relative bar compares.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BarMetric {
    /// lower is better
    P95Ms,
    /// higher is better
    ThroughputJobsS,
}

impl BarMetric {
    pub fn as_str(self) -> &'static str {
        match self {
            BarMetric::P95Ms => "p95_ms",
            BarMetric::ThroughputJobsS => "throughput_jobs_s",
        }
    }
}

/// A self-relative acceptance bar: engine `better` must beat engine
/// `than` on `metric` by at least `margin_pct` on this scenario. These
/// subsume the old gate's three hard-coded bars (adaptive ≥10% p95
/// over static, sharded > single-shard throughput, class-aware ≥10%
/// p95 over class-blind).
#[derive(Debug, Clone, PartialEq)]
pub struct BarSpec {
    pub metric: BarMetric,
    pub better: String,
    pub than: String,
    pub margin_pct: f64,
}

/// One fully validated scenario.
#[derive(Debug, Clone, PartialEq)]
pub struct Scenario {
    pub name: String,
    pub summary: String,
    /// engine-matrix columns this scenario runs against
    pub engines: Vec<String>,
    /// diff-gate drift budget, percent
    pub tolerance_pct: f64,
    pub cores: CoreMap,
    /// the original `cores` spec text, for display
    pub cores_spec: String,
    pub workers: usize,
    pub arrival: Arrival,
    pub parts: Vec<PartSpec>,
    pub bars: Vec<BarSpec>,
}

impl Scenario {
    /// Parse and validate one scenario document.
    pub fn parse(text: &str) -> Result<Scenario, String> {
        let doc = Doc::parse(text)?;
        if let Some(item) = doc.top.first() {
            return Err(format!("line {}: key outside a section", item.line));
        }
        for sec in &doc.sections {
            match (sec.name.as_str(), sec.array) {
                ("scenario", false) | ("machine", false) | ("arrival", false) => {}
                ("part", true) | ("bar", true) => {}
                ("part", false) | ("bar", false) => {
                    return Err(format!(
                        "line {}: `[{}]` must be an array-of-tables — use `[[{}]]`",
                        sec.line, sec.name, sec.name
                    ));
                }
                (other, _) => {
                    return Err(format!("line {}: unknown section `{other}`", sec.line));
                }
            }
        }

        let sc = doc
            .section("scenario")
            .ok_or_else(|| "missing [scenario] section".to_string())?;
        let mut name = None;
        let mut summary = String::new();
        let mut engines: Option<Vec<String>> = None;
        let mut tolerance_pct = 50.0;
        for item in no_dup_keys(sc)? {
            match item.key.as_str() {
                "name" => name = Some(item.str()?.to_string()),
                "summary" => summary = item.str()?.to_string(),
                "engines" => engines = Some(item.str_list()?),
                "tolerance_pct" => tolerance_pct = pos_f64(item)?,
                other => return Err(format!("line {}: unknown key `{other}`", item.line)),
            }
        }
        let name = name.ok_or_else(|| format!("line {}: [scenario] missing `name`", sc.line))?;
        if name.is_empty()
            || !name.chars().all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_')
        {
            return Err(format!(
                "[scenario] name `{name}` must be non-empty [a-z0-9_] (it names files and CSV rows)"
            ));
        }
        let engines = match engines {
            Some(list) => list,
            None => ENGINES.iter().map(|e| e.name.to_string()).collect(),
        };
        if engines.is_empty() {
            return Err(format!("scenario `{name}`: `engines` must not be empty"));
        }
        for e in &engines {
            if !ENGINES.iter().any(|spec| spec.name == e) {
                let known: Vec<&str> = ENGINES.iter().map(|s| s.name).collect();
                return Err(format!(
                    "scenario `{name}`: unknown engine `{e}` (known engines: {})",
                    known.join(", ")
                ));
            }
            if engines.iter().filter(|x| *x == e).count() > 1 {
                return Err(format!("scenario `{name}`: duplicate engine `{e}`"));
            }
        }

        let (cores, cores_spec, workers) = match doc.section("machine") {
            None => (CoreMap::homogeneous(SIM_CORES), SIM_CORES.to_string(), 4),
            Some(sec) => {
                let mut cores = CoreMap::homogeneous(SIM_CORES);
                let mut spec = SIM_CORES.to_string();
                let mut workers = 4usize;
                for item in no_dup_keys(sec)? {
                    match item.key.as_str() {
                        "cores" => {
                            (cores, spec) = match &item.value {
                                crate::util::toml::Value::Int(n) if *n >= 1 => {
                                    (CoreMap::homogeneous(*n as usize), n.to_string())
                                }
                                crate::util::toml::Value::Str(s) => (
                                    CoreMap::parse(s).map_err(|e| {
                                        format!("line {}: bad `cores` spec: {e}", item.line)
                                    })?,
                                    s.clone(),
                                ),
                                _ => {
                                    return Err(format!(
                                        "line {}: `cores` expects a positive integer or a \
                                         CoreMap spec string",
                                        item.line
                                    ))
                                }
                            };
                        }
                        "workers" => workers = pos_usize(item)?,
                        other => {
                            return Err(format!("line {}: unknown key `{other}`", item.line))
                        }
                    }
                }
                (cores, spec, workers)
            }
        };

        let ar = doc
            .section("arrival")
            .ok_or_else(|| format!("scenario `{name}`: missing [arrival] section"))?;
        let mut mode = Loop::Closed;
        let mut submitters = 1usize;
        let (mut jobs, mut quick_jobs) = (None, None);
        let mut seed = 0xD1C0DE_u64;
        let mut spacing_us = 0u64;
        let mut uniform_jitter = false;
        for item in no_dup_keys(ar)? {
            match item.key.as_str() {
                "mode" => {
                    mode = match item.str()? {
                        "closed" => Loop::Closed,
                        "open" => Loop::Open,
                        other => {
                            return Err(format!(
                                "line {}: unknown arrival mode `{other}` — expected \
                                 `closed` or `open`",
                                item.line
                            ))
                        }
                    }
                }
                "submitters" => submitters = pos_usize(item)?,
                "jobs" => jobs = Some(pos_usize(item)?),
                "quick_jobs" => quick_jobs = Some(pos_usize(item)?),
                "seed" => {
                    seed = item
                        .int()
                        .ok()
                        .filter(|n| *n >= 0)
                        .ok_or_else(|| {
                            format!("line {}: `seed` must be a non-negative integer", item.line)
                        })? as u64
                }
                "spacing_us" => {
                    spacing_us = item
                        .int()
                        .ok()
                        .filter(|n| *n >= 0)
                        .ok_or_else(|| {
                            format!(
                                "line {}: `spacing_us` must be a non-negative integer",
                                item.line
                            )
                        })? as u64
                }
                "jitter" => {
                    uniform_jitter = match item.str()? {
                        "none" => false,
                        "uniform" => true,
                        other => {
                            return Err(format!(
                                "line {}: unknown jitter `{other}` — expected `none` or \
                                 `uniform`",
                                item.line
                            ))
                        }
                    }
                }
                other => return Err(format!("line {}: unknown key `{other}`", item.line)),
            }
        }
        let jobs = jobs.ok_or_else(|| format!("scenario `{name}`: [arrival] missing `jobs`"))?;
        let quick_jobs = quick_jobs
            .ok_or_else(|| format!("scenario `{name}`: [arrival] missing `quick_jobs`"))?;
        if uniform_jitter && spacing_us == 0 {
            return Err(format!(
                "scenario `{name}`: `jitter = \"uniform\"` needs `spacing_us > 0`"
            ));
        }
        let arrival =
            Arrival { mode, submitters, jobs, quick_jobs, seed, spacing_us, uniform_jitter };

        let part_secs = doc.array_sections("part");
        if part_secs.is_empty() {
            return Err(format!("scenario `{name}`: needs at least one [[part]]"));
        }
        let mut parts = Vec::with_capacity(part_secs.len());
        for sec in part_secs {
            parts.push(parse_part(&name, sec, &arrival, cores.total())?);
        }
        if !parts.iter().any(|p| p.measured) {
            return Err(format!(
                "scenario `{name}`: every part is `measured = false` — nothing defines \
                 the job wall"
            ));
        }
        for p in &parts {
            if parts.iter().filter(|q| q.name == p.name).count() > 1 {
                return Err(format!("scenario `{name}`: duplicate part name `{}`", p.name));
            }
        }

        let mut bars = Vec::new();
        for sec in doc.array_sections("bar") {
            bars.push(parse_bar(&name, sec, &engines)?);
        }

        Ok(Scenario {
            name,
            summary,
            engines,
            tolerance_pct,
            cores,
            cores_spec,
            workers,
            arrival,
            parts,
            bars,
        })
    }
}

fn parse_part(
    scenario: &str,
    sec: &Section,
    arrival: &Arrival,
    total_cores: usize,
) -> Result<PartSpec, String> {
    let mut name = None;
    let mut count = 1usize;
    let mut base_ms = None;
    let mut size = 1usize;
    let mut threads = None;
    let mut priority = Priority::Normal;
    let mut budget_ms = None;
    let mut cancel_after_ms = None;
    let mut cancel_prob: Option<f64> = None;
    let mut measured = true;
    for item in no_dup_keys(sec)? {
        match item.key.as_str() {
            "name" => name = Some(item.str()?.to_string()),
            "count" => count = pos_usize(item)?,
            "base_ms" => base_ms = Some(pos_f64(item)?),
            "size" => size = pos_usize(item)?,
            "threads" => {
                threads = Some(item.int().ok().filter(|n| *n >= 0).ok_or_else(|| {
                    format!("line {}: `threads` must be a non-negative integer", item.line)
                })? as usize)
            }
            "priority" => {
                priority = match item.str()? {
                    "low" => Priority::Low,
                    "normal" => Priority::Normal,
                    "high" => Priority::High,
                    other => {
                        return Err(format!(
                            "line {}: unknown priority `{other}` — expected `low`, \
                             `normal`, or `high`",
                            item.line
                        ))
                    }
                }
            }
            "budget_ms" => budget_ms = Some(pos_f64(item)?),
            "cancel_after_ms" => {
                let v = item.f64()?;
                if !(v.is_finite() && v >= 0.0) {
                    return Err(format!(
                        "line {}: `cancel_after_ms` must be finite and >= 0",
                        item.line
                    ));
                }
                cancel_after_ms = Some(v);
            }
            "cancel_prob" => {
                let v = item.f64()?;
                if !(0.0..=1.0).contains(&v) {
                    return Err(format!(
                        "line {}: `cancel_prob` must be within [0, 1]",
                        item.line
                    ));
                }
                cancel_prob = Some(v);
            }
            "measured" => measured = item.bool()?,
            other => return Err(format!("line {}: unknown key `{other}`", item.line)),
        }
    }
    let at = format!("scenario `{scenario}` [[part]] at line {}", sec.line);
    let name = name.ok_or_else(|| format!("{at}: missing `name`"))?;
    let base_ms = base_ms.ok_or_else(|| format!("{at}: missing `base_ms`"))?;
    let threads = threads.ok_or_else(|| format!("{at}: missing `threads` (0 = auto)"))?;
    if threads > total_cores {
        return Err(format!(
            "{at}: `threads = {threads}` exceeds the machine's {total_cores} cores"
        ));
    }
    if cancel_prob.is_some() && cancel_after_ms.is_none() {
        return Err(format!("{at}: `cancel_prob` needs `cancel_after_ms`"));
    }
    if cancel_after_ms.is_some() {
        if measured {
            return Err(format!(
                "{at}: a cancelled part cannot be `measured` — a cancelled wall is \
                 meaningless; set `measured = false`"
            ));
        }
        if arrival.mode == Loop::Open {
            return Err(format!(
                "{at}: cancel distributions are closed-loop only (an open-loop \
                 producer has moved on before `cancel_after_ms` elapses)"
            ));
        }
    }
    Ok(PartSpec {
        name,
        count,
        base_ms,
        size,
        threads,
        priority,
        budget_ms,
        cancel_after_ms,
        cancel_prob: cancel_prob.unwrap_or(1.0),
        measured,
    })
}

fn parse_bar(scenario: &str, sec: &Section, engines: &[String]) -> Result<BarSpec, String> {
    let mut metric = None;
    let (mut better, mut than) = (None, None);
    let mut margin_pct = 0.0;
    for item in no_dup_keys(sec)? {
        match item.key.as_str() {
            "metric" => {
                metric = Some(match item.str()? {
                    "p95_ms" => BarMetric::P95Ms,
                    "throughput_jobs_s" => BarMetric::ThroughputJobsS,
                    other => {
                        return Err(format!(
                            "line {}: unknown bar metric `{other}` — expected `p95_ms` \
                             or `throughput_jobs_s`",
                            item.line
                        ))
                    }
                })
            }
            "better" => better = Some(item.str()?.to_string()),
            "than" => than = Some(item.str()?.to_string()),
            "margin_pct" => {
                let v = item.f64()?;
                if !(v.is_finite() && (0.0..100.0).contains(&v)) {
                    return Err(format!(
                        "line {}: `margin_pct` must be within [0, 100)",
                        item.line
                    ));
                }
                margin_pct = v;
            }
            other => return Err(format!("line {}: unknown key `{other}`", item.line)),
        }
    }
    let at = format!("scenario `{scenario}` [[bar]] at line {}", sec.line);
    let metric = metric.ok_or_else(|| format!("{at}: missing `metric`"))?;
    let better = better.ok_or_else(|| format!("{at}: missing `better`"))?;
    let than = than.ok_or_else(|| format!("{at}: missing `than`"))?;
    for e in [&better, &than] {
        if !engines.contains(e) {
            return Err(format!(
                "{at}: engine `{e}` is not in this scenario's `engines` list"
            ));
        }
    }
    if better == than {
        return Err(format!("{at}: `better` and `than` are both `{better}`"));
    }
    Ok(BarSpec { metric, better, than, margin_pct })
}

/// Scenario sections have no repeatable keys, so any duplicate is a
/// config error (last-wins would quietly ignore the earlier line).
fn no_dup_keys(sec: &Section) -> Result<&[Item], String> {
    for (i, item) in sec.items.iter().enumerate() {
        if sec.items[..i].iter().any(|prev| prev.key == item.key) {
            return Err(format!("line {}: duplicate key `{}`", item.line, item.key));
        }
    }
    Ok(&sec.items)
}

fn pos_usize(item: &Item) -> Result<usize, String> {
    item.int()
        .ok()
        .filter(|n| *n >= 1)
        .map(|n| n as usize)
        .ok_or_else(|| format!("line {}: `{}` must be a positive integer", item.line, item.key))
}

fn pos_f64(item: &Item) -> Result<f64, String> {
    let v = item.f64()?;
    if v.is_finite() && v > 0.0 {
        Ok(v)
    } else {
        Err(format!("line {}: `{}` must be a positive number", item.line, item.key))
    }
}

/// Load every `*.toml` under `dir`, sorted by file name. Each file's
/// stem must equal its declared scenario name — the file system is the
/// scenario index, so a mismatch would make `diff` compare the wrong
/// baselines.
pub fn load_dir(dir: &Path) -> Result<Vec<Scenario>, String> {
    let mut files: Vec<_> = std::fs::read_dir(dir)
        .map_err(|e| format!("cannot read scenario dir {}: {e}", dir.display()))?
        .filter_map(|entry| entry.ok().map(|e| e.path()))
        .filter(|p| p.extension().and_then(|x| x.to_str()) == Some("toml"))
        .collect();
    files.sort();
    if files.is_empty() {
        return Err(format!("no scenario TOMLs under {}", dir.display()));
    }
    let mut scenarios = Vec::with_capacity(files.len());
    for path in files {
        let text = std::fs::read_to_string(&path)
            .map_err(|e| format!("cannot read {}: {e}", path.display()))?;
        let sc = Scenario::parse(&text).map_err(|e| format!("{}: {e}", path.display()))?;
        let stem = path.file_stem().and_then(|s| s.to_str()).unwrap_or_default();
        if sc.name != stem {
            return Err(format!(
                "{}: scenario name `{}` does not match the file stem `{stem}`",
                path.display(),
                sc.name
            ));
        }
        scenarios.push(sc);
    }
    Ok(scenarios)
}

#[cfg(test)]
mod tests {
    use super::*;

    const MINIMAL: &str = r#"
[scenario]
name = "mini"
engines = ["static"]

[arrival]
jobs = 4
quick_jobs = 2

[[part]]
name = "work"
base_ms = 5.0
threads = 2
"#;

    #[test]
    fn minimal_scenario_parses_with_defaults() {
        let sc = Scenario::parse(MINIMAL).unwrap();
        assert_eq!(sc.name, "mini");
        assert_eq!(sc.engines, vec!["static"]);
        assert_eq!(sc.tolerance_pct, 50.0);
        assert_eq!(sc.cores.total(), SIM_CORES);
        assert_eq!(sc.workers, 4);
        assert_eq!(sc.arrival.mode, Loop::Closed);
        assert_eq!(sc.arrival.submitters, 1);
        assert_eq!(sc.arrival.jobs_for(Mode::Quick), 2);
        assert_eq!(sc.arrival.jobs_for(Mode::Full), 4);
        assert_eq!(sc.parts.len(), 1);
        let p = &sc.parts[0];
        assert_eq!((p.count, p.size, p.threads), (1, 1, 2));
        assert_eq!(p.priority, crate::engine::Priority::Normal);
        assert!(p.measured && p.budget_ms.is_none() && p.cancel_after_ms.is_none());
        assert!(sc.bars.is_empty());
    }

    #[test]
    fn engines_default_to_the_full_matrix() {
        let text = MINIMAL.replace("engines = [\"static\"]\n", "");
        let sc = Scenario::parse(&text).unwrap();
        assert_eq!(sc.engines.len(), ENGINES.len());
    }

    #[test]
    fn full_featured_scenario_parses() {
        let sc = Scenario::parse(
            r#"
[scenario]
name = "storm"
summary = "cancellation under hetero placement"
engines = ["static", "blind"]
tolerance_pct = 60

[machine]
cores = "fast=4,slow=12@0.5"
workers = 4

[arrival]
mode = "closed"
submitters = 2
jobs = 30
quick_jobs = 10
seed = 42

[[part]]
name = "doomed"
count = 3
base_ms = 1000
threads = 4
priority = "low"
cancel_after_ms = 2.0
cancel_prob = 0.5
measured = false

[[part]]
name = "survivor"
base_ms = 8.0
threads = 8
priority = "high"
budget_ms = 5000

[[bar]]
metric = "p95_ms"
better = "static"
than = "blind"
margin_pct = 10
"#,
        )
        .unwrap();
        assert_eq!(sc.cores.total(), 16);
        assert_eq!(sc.cores_spec, "fast=4,slow=12@0.5");
        assert_eq!(sc.arrival.seed, 42);
        assert_eq!(sc.parts[0].cancel_prob, 0.5);
        assert!(!sc.parts[0].measured);
        assert_eq!(sc.parts[1].budget_ms, Some(5000.0));
        assert_eq!(sc.bars.len(), 1);
        assert_eq!(sc.bars[0].metric, BarMetric::P95Ms);
    }

    /// The reject fixtures: each mutation of the minimal scenario must
    /// fail validation with a message containing the marker.
    #[test]
    fn reject_fixtures() {
        let cases: &[(&str, &str, &str)] = &[
            // (mutation-from, mutation-to, expected error marker)
            ("name = \"mini\"", "name = \"mini\"\ntypo_key = 1", "unknown key `typo_key`"),
            ("[arrival]", "[oops]", "unknown section"),
            ("[[part]]", "[part]", "use `[[part]]`"),
            ("jobs = 4\n", "jobs = 4\njobs = 4\n", "duplicate key `jobs`"),
            ("engines = [\"static\"]", "engines = [\"warp9\"]", "unknown engine `warp9`"),
            ("engines = [\"static\"]", "engines = []", "must not be empty"),
            ("name = \"mini\"", "name = \"Mini Bench\"", "[a-z0-9_]"),
            ("base_ms = 5.0", "base_ms = -5.0", "positive number"),
            ("base_ms = 5.0\n", "", "missing `base_ms`"),
            ("threads = 2", "threads = 64", "exceeds the machine"),
            ("jobs = 4", "jobs = 0", "positive integer"),
        ];
        for (from, to, marker) in cases {
            let text = MINIMAL.replace(from, to);
            assert_ne!(&text, MINIMAL, "mutation `{from}` did not apply");
            let err = Scenario::parse(&text).unwrap_err();
            assert!(err.contains(marker), "for `{to}` expected `{marker}`, got: {err}");
        }
    }

    #[test]
    fn reject_missing_sections() {
        for section in ["[scenario]", "[arrival]", "[[part]]"] {
            // chop the section header and everything after it up to the
            // next header, leaving the rest of the document intact
            let start = MINIMAL.find(section).unwrap();
            let rest = &MINIMAL[start + section.len()..];
            let end = rest.find("\n[").map(|i| start + section.len() + i).unwrap_or(MINIMAL.len());
            let text = format!("{}{}", &MINIMAL[..start], &MINIMAL[end..]);
            let err = Scenario::parse(&text).unwrap_err();
            assert!(err.contains("missing") || err.contains("at least one"), "{section}: {err}");
        }
    }

    #[test]
    fn reject_bad_distributions() {
        // cancel_prob out of range
        let text = MINIMAL.replace(
            "threads = 2",
            "threads = 2\nmeasured = false\ncancel_after_ms = 1.0\ncancel_prob = 1.5",
        );
        let err = Scenario::parse(&text).unwrap_err();
        assert!(err.contains("within [0, 1]"), "{err}");
        // cancel_prob without a cancel point
        let text = MINIMAL.replace("threads = 2", "threads = 2\ncancel_prob = 0.5");
        let err = Scenario::parse(&text).unwrap_err();
        assert!(err.contains("needs `cancel_after_ms`"), "{err}");
        // a measured cancelled part
        let text = MINIMAL.replace("threads = 2", "threads = 2\ncancel_after_ms = 1.0");
        let err = Scenario::parse(&text).unwrap_err();
        assert!(err.contains("cannot be `measured`"), "{err}");
        // jitter without spacing
        let text = MINIMAL.replace("[arrival]", "[arrival]\njitter = \"uniform\"");
        let err = Scenario::parse(&text).unwrap_err();
        assert!(err.contains("spacing_us > 0"), "{err}");
        // cancels in an open loop
        let text = MINIMAL
            .replace("[arrival]", "[arrival]\nmode = \"open\"")
            .replace("threads = 2", "threads = 2\nmeasured = false\ncancel_after_ms = 1.0")
            .replace("name = \"work\"", "name = \"work\"\n")
            + "\n[[part]]\nname = \"w2\"\nbase_ms = 1.0\nthreads = 1\n";
        let err = Scenario::parse(&text).unwrap_err();
        assert!(err.contains("closed-loop only"), "{err}");
    }

    #[test]
    fn reject_every_part_unmeasured() {
        let text = MINIMAL.replace("threads = 2", "threads = 2\nmeasured = false");
        let err = Scenario::parse(&text).unwrap_err();
        assert!(err.contains("nothing defines"), "{err}");
    }

    #[test]
    fn reject_bad_bars() {
        let bar = "\n[[bar]]\nmetric = \"p95_ms\"\nbetter = \"adaptive\"\nthan = \"static\"\n";
        let err = Scenario::parse(&(MINIMAL.to_string() + bar)).unwrap_err();
        assert!(err.contains("not in this scenario's `engines`"), "{err}");
        let bar = "\n[[bar]]\nmetric = \"p42\"\nbetter = \"static\"\nthan = \"static\"\n";
        let err = Scenario::parse(&(MINIMAL.to_string() + bar)).unwrap_err();
        assert!(err.contains("unknown bar metric"), "{err}");
        let bar = "\n[[bar]]\nmetric = \"p95_ms\"\nbetter = \"static\"\nthan = \"static\"\n";
        let err = Scenario::parse(&(MINIMAL.to_string() + bar)).unwrap_err();
        assert!(err.contains("`better` and `than`"), "{err}");
    }
}

//! Comparison tooling over recorded measurements: the regression gate
//! (`bench-bar diff`), the scenarios' self-relative bars, the
//! cross-engine ranking (`bench-bar rank`), and the legacy
//! `BENCH_pr.json` bridge.

use std::collections::BTreeMap;

use crate::bench::gate::{results_to_json, ScenarioResult};
use crate::util::json::Json;
use crate::util::stats::geomean;

use super::measure::Measurement;
use super::scenario::{BarMetric, Scenario};

/// The ranking's denominator: every engine's speedups are relative to
/// this one, which therefore always ranks with geomean 1.0.
pub const REFERENCE_ENGINE: &str = "static";

fn cell<'a>(rows: &'a [Measurement], scenario: &str, engine: &str) -> Option<&'a Measurement> {
    rows.iter().find(|m| m.scenario == scenario && m.engine == engine)
}

// ---------------------------------------------------------------- diff

/// Outcome of a baseline diff: a human-readable line per compared cell
/// plus the failures that should gate.
#[derive(Debug, Default)]
pub struct DiffOutcome {
    pub lines: Vec<String>,
    pub failures: Vec<String>,
}

impl DiffOutcome {
    pub fn passed(&self) -> bool {
        self.failures.is_empty()
    }
}

/// Gate `current` against the recorded `baseline`.
///
/// Regression math is the old JSON gate's, generalized: per scenario,
/// the TOML's `tolerance_pct` bounds how far p50/p95/p99 may rise and
/// throughput may fall relative to the recorded cell. Only regressions
/// gate — improvements are reported but never fail (the baseline is
/// refreshed by re-recording, see `bench/FORMAT.md`). Structural
/// drift is always a failure: a cell missing from either side, or a
/// job-count mismatch (the quick-vs-full mode guard). The scenarios'
/// own self-relative bars are checked on `current` too, so the gate
/// subsumes the old hetero/adaptive/sharded acceptance checks.
pub fn diff(current: &[Measurement], baseline: &[Measurement], scenarios: &[Scenario]) -> DiffOutcome {
    let mut out = DiffOutcome::default();
    let tolerances: BTreeMap<&str, f64> =
        scenarios.iter().map(|s| (s.name.as_str(), s.tolerance_pct)).collect();
    for m in current {
        let tol = match tolerances.get(m.scenario.as_str()) {
            Some(t) => *t,
            None => {
                out.failures.push(format!(
                    "{}/{}: no scenario definition supplies a tolerance",
                    m.scenario, m.engine
                ));
                continue;
            }
        };
        let Some(base) = cell(baseline, &m.scenario, &m.engine) else {
            out.failures.push(format!(
                "{}/{}: missing from the recorded baseline — run `bench-bar record`",
                m.scenario, m.engine
            ));
            continue;
        };
        if base.jobs != m.jobs {
            out.failures.push(format!(
                "{}/{}: job count {} vs recorded {} — mode/scenario drift, re-record the baseline",
                m.scenario, m.engine, m.jobs, base.jobs
            ));
            continue;
        }
        let mut cell_fail = false;
        for (what, cur, rec) in [
            ("p50_ms", m.p50_ms, base.p50_ms),
            ("p95_ms", m.p95_ms, base.p95_ms),
            ("p99_ms", m.p99_ms, base.p99_ms),
        ] {
            if cur > rec * (1.0 + tol / 100.0) {
                cell_fail = true;
                out.failures.push(format!(
                    "{}/{}: {what} {cur:.2} exceeds recorded {rec:.2} by more than {tol}%",
                    m.scenario, m.engine
                ));
            }
        }
        if m.throughput_jobs_s < base.throughput_jobs_s * (1.0 - tol / 100.0) {
            cell_fail = true;
            out.failures.push(format!(
                "{}/{}: throughput {:.1} jobs/s fell more than {tol}% below recorded {:.1}",
                m.scenario, m.engine, m.throughput_jobs_s, base.throughput_jobs_s
            ));
        }
        out.lines.push(format!(
            "{} {}/{}: p95 {:.2}ms (recorded {:.2}{}), throughput {:.1} jobs/s (recorded {:.1})",
            if cell_fail { "FAIL" } else { "  ok" },
            m.scenario,
            m.engine,
            m.p95_ms,
            base.p95_ms,
            if base.estimated { ", estimated" } else { "" },
            m.throughput_jobs_s,
            base.throughput_jobs_s,
        ));
    }
    // baseline cells the run never produced are drift too
    for base in baseline {
        if cell(current, &base.scenario, &base.engine).is_none() {
            out.failures.push(format!(
                "{}/{}: recorded in the baseline but absent from this run",
                base.scenario, base.engine
            ));
        }
    }
    out.failures.extend(check_bars(scenarios, current));
    out
}

/// Evaluate every scenario's self-relative bars against a set of
/// measurements; returns the failures. These are the suite's absolute
/// acceptance claims (adaptive beats static on the misleading mix,
/// sharding out-submits a single dispatcher, class-aware placement
/// beats blind on the hetero machine) — they compare cells *within*
/// one run, so they hold or fail independent of any baseline.
pub fn check_bars(scenarios: &[Scenario], rows: &[Measurement]) -> Vec<String> {
    let mut failures = Vec::new();
    for sc in scenarios {
        for bar in &sc.bars {
            let (Some(better), Some(than)) =
                (cell(rows, &sc.name, &bar.better), cell(rows, &sc.name, &bar.than))
            else {
                failures.push(format!(
                    "{}: bar needs both `{}` and `{}` cells in this run",
                    sc.name, bar.better, bar.than
                ));
                continue;
            };
            let ok = match bar.metric {
                BarMetric::P95Ms => better.p95_ms <= than.p95_ms * (1.0 - bar.margin_pct / 100.0),
                BarMetric::ThroughputJobsS => {
                    better.throughput_jobs_s > than.throughput_jobs_s * (1.0 + bar.margin_pct / 100.0)
                }
            };
            if !ok {
                let (bv, tv) = match bar.metric {
                    BarMetric::P95Ms => (better.p95_ms, than.p95_ms),
                    BarMetric::ThroughputJobsS => (better.throughput_jobs_s, than.throughput_jobs_s),
                };
                failures.push(format!(
                    "{}: bar failed — {} of `{}` ({bv:.2}) is not {}% better than `{}` ({tv:.2})",
                    sc.name,
                    bar.metric.as_str(),
                    bar.better,
                    bar.margin_pct,
                    bar.than,
                ));
            }
        }
    }
    failures
}

// ---------------------------------------------------------------- rank

/// One engine's row in the cross-suite ranking.
#[derive(Debug, Clone, PartialEq)]
pub struct RankRow {
    pub engine: String,
    /// geomean over scenarios of `static_p95 / engine_p95` — above 1.0
    /// means the engine's tail is faster than the reference overall
    pub p95_speedup: f64,
    /// geomean over scenarios of `engine_throughput / static_throughput`
    pub throughput_ratio: f64,
    /// scenarios contributing (cells present for both this engine and
    /// the reference)
    pub scenarios: usize,
}

/// Rank engines across the suite by geometric-mean p95 speedup over
/// [`REFERENCE_ENGINE`] (rebar's summary statistic: a geomean of
/// ratios, so no one scenario's absolute scale dominates). Input order
/// never affects the output: cells are keyed and sorted before
/// aggregation, and ties break by engine name.
pub fn rank(rows: &[Measurement]) -> Vec<RankRow> {
    let mut engines: Vec<&str> = rows.iter().map(|m| m.engine.as_str()).collect();
    engines.sort_unstable();
    engines.dedup();
    let mut scenarios: Vec<&str> = rows.iter().map(|m| m.scenario.as_str()).collect();
    scenarios.sort_unstable();
    scenarios.dedup();
    let mut out: Vec<RankRow> = engines
        .into_iter()
        .map(|eng| {
            let mut speedups = Vec::new();
            let mut ratios = Vec::new();
            for sc in &scenarios {
                let (Some(mine), Some(reference)) =
                    (cell(rows, sc, eng), cell(rows, sc, REFERENCE_ENGINE))
                else {
                    continue;
                };
                if mine.p95_ms > 0.0 && reference.p95_ms > 0.0 {
                    speedups.push(reference.p95_ms / mine.p95_ms);
                }
                if mine.throughput_jobs_s > 0.0 && reference.throughput_jobs_s > 0.0 {
                    ratios.push(mine.throughput_jobs_s / reference.throughput_jobs_s);
                }
            }
            RankRow {
                engine: eng.to_string(),
                p95_speedup: geomean(&speedups),
                throughput_ratio: geomean(&ratios),
                scenarios: speedups.len(),
            }
        })
        .collect();
    out.sort_by(|a, b| {
        b.p95_speedup
            .total_cmp(&a.p95_speedup)
            .then_with(|| a.engine.cmp(&b.engine))
    });
    out
}

/// Render a ranking as an aligned text table.
pub fn render_rank(rows: &[RankRow]) -> String {
    let mut out = String::from("engine      p95 speedup   throughput    scenarios\n");
    for r in rows {
        out.push_str(&format!(
            "{:<10}  {:>10.3}x  {:>10.3}x  {:>10}\n",
            r.engine, r.p95_speedup, r.throughput_ratio, r.scenarios
        ));
    }
    out
}

// -------------------------------------------------------- legacy JSON

/// Map a matrix cell back to the scenario name the retired
/// `BENCH_baseline.json` gate used, for consumers still reading
/// `BENCH_pr.json` (kept for one release; see `bench/FORMAT.md`).
pub fn legacy_name(scenario: &str, engine: &str) -> Option<&'static str> {
    Some(match (scenario, engine) {
        ("sched_smoke", "static") => "sched_smoke",
        ("longshort", "static") => "longshort_static",
        ("longshort", "adaptive") => "longshort_adaptive",
        ("cancel_storm", "static") => "cancel_storm",
        ("priority_inversion", "static") => "priority_inversion",
        ("hetero_inversion", "static") => "hetero_inversion",
        ("hetero_inversion", "blind") => "hetero_inversion_blind",
        ("submit_storm", "sharded2") => "submit_storm",
        ("submit_storm", "static") => "submit_storm_single",
        _ => return None,
    })
}

/// Project measurements onto the legacy `BENCH_pr.json` shape: only
/// the cells with a legacy name, in legacy-name order.
pub fn legacy_json(rows: &[Measurement]) -> Json {
    let mut results: Vec<ScenarioResult> = rows
        .iter()
        .filter_map(|m| {
            legacy_name(&m.scenario, &m.engine).map(|name| ScenarioResult {
                name: name.to_string(),
                jobs: m.jobs,
                throughput_jobs_s: m.throughput_jobs_s,
                p50_ms: m.p50_ms,
                p95_ms: m.p95_ms,
            })
        })
        .collect();
    results.sort_by(|a, b| a.name.cmp(&b.name));
    results_to_json(&results)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bar::measure::{Measurement, Mode};

    fn cell_with(scenario: &str, engine: &str, jobs: usize, thr: f64, p95: f64) -> Measurement {
        Measurement {
            scenario: scenario.to_string(),
            engine: engine.to_string(),
            mode: Mode::Quick,
            jobs,
            throughput_jobs_s: thr,
            p50_ms: p95 * 0.8,
            p95_ms: p95,
            p99_ms: p95 * 1.1,
            steals: 0,
            timer_wakeups: 0,
            class_degraded: 0,
            estimated: false,
        }
    }

    fn one_scenario(toml_tail: &str) -> Scenario {
        Scenario::parse(&format!(
            r#"
[scenario]
name = "s1"
engines = ["static", "adaptive"]
tolerance_pct = 20.0

[arrival]
submitters = 1
jobs = 10
quick_jobs = 10

[[part]]
name = "w"
base_ms = 5.0
threads = 1
{toml_tail}
"#
        ))
        .unwrap()
    }

    #[test]
    fn diff_passes_within_tolerance_and_fails_beyond() {
        let sc = one_scenario("");
        let base = vec![cell_with("s1", "static", 10, 100.0, 10.0)];
        let ok = diff(&[cell_with("s1", "static", 10, 90.0, 11.5)], &base, &[sc.clone()]);
        assert!(ok.passed(), "{:?}", ok.failures);
        assert_eq!(ok.lines.len(), 1);

        let slow = diff(&[cell_with("s1", "static", 10, 100.0, 12.5)], &base, &[sc.clone()]);
        assert!(slow.failures.iter().any(|f| f.contains("p95_ms")), "{:?}", slow.failures);

        let starved = diff(&[cell_with("s1", "static", 10, 70.0, 10.0)], &base, &[sc]);
        assert!(
            starved.failures.iter().any(|f| f.contains("throughput")),
            "{:?}",
            starved.failures
        );
    }

    #[test]
    fn diff_catches_structural_drift() {
        let sc = one_scenario("");
        let base = vec![cell_with("s1", "static", 10, 100.0, 10.0)];
        let missing = diff(&[], &base, &[sc.clone()]);
        assert!(missing.failures.iter().any(|f| f.contains("absent from this run")));

        let unrecorded = diff(
            &[cell_with("s1", "adaptive", 10, 100.0, 10.0)],
            &base,
            &[sc.clone()],
        );
        assert!(unrecorded
            .failures
            .iter()
            .any(|f| f.contains("missing from the recorded baseline")));

        let jobs = diff(&[cell_with("s1", "static", 7, 100.0, 10.0)], &base, &[sc.clone()]);
        assert!(jobs.failures.iter().any(|f| f.contains("job count 7 vs recorded 10")));

        let orphan = diff(
            &[cell_with("ghost", "static", 10, 100.0, 10.0)],
            &[cell_with("ghost", "static", 10, 100.0, 10.0)],
            &[sc],
        );
        assert!(orphan.failures.iter().any(|f| f.contains("no scenario definition")));
    }

    #[test]
    fn bars_gate_on_relative_margin() {
        let sc = one_scenario(
            "\n[[bar]]\nmetric = \"p95_ms\"\nbetter = \"adaptive\"\nthan = \"static\"\nmargin_pct = 10.0",
        );
        // 8.8 <= 0.9 * 10.0 → holds
        let pass = check_bars(
            &[sc.clone()],
            &[
                cell_with("s1", "static", 10, 100.0, 10.0),
                cell_with("s1", "adaptive", 10, 100.0, 8.8),
            ],
        );
        assert!(pass.is_empty(), "{pass:?}");
        // 9.5 > 0.9 * 10.0 → fails
        let fail = check_bars(
            &[sc.clone()],
            &[
                cell_with("s1", "static", 10, 100.0, 10.0),
                cell_with("s1", "adaptive", 10, 100.0, 9.5),
            ],
        );
        assert!(fail.iter().any(|f| f.contains("bar failed")), "{fail:?}");
        // a bar with a missing cell is a failure, not a skip
        let missing = check_bars(&[sc], &[cell_with("s1", "static", 10, 100.0, 10.0)]);
        assert!(missing.iter().any(|f| f.contains("needs both")), "{missing:?}");
    }

    #[test]
    fn rank_is_order_independent_and_reference_anchored() {
        let mut rows = vec![
            cell_with("s1", "static", 10, 100.0, 10.0),
            cell_with("s1", "adaptive", 10, 110.0, 5.0),
            cell_with("s2", "static", 10, 50.0, 40.0),
            cell_with("s2", "adaptive", 10, 50.0, 20.0),
            cell_with("s2", "blind", 10, 25.0, 80.0),
        ];
        let a = rank(&rows);
        rows.reverse();
        rows.swap(0, 2);
        assert_eq!(rank(&rows), a, "rank must not depend on input order");

        assert_eq!(a[0].engine, "adaptive");
        assert!((a[0].p95_speedup - 2.0).abs() < 1e-9, "geomean of 2x and 2x");
        let reference = a.iter().find(|r| r.engine == "static").unwrap();
        assert!((reference.p95_speedup - 1.0).abs() < 1e-9);
        assert!((reference.throughput_ratio - 1.0).abs() < 1e-9);
        let blind = a.iter().find(|r| r.engine == "blind").unwrap();
        assert_eq!(blind.scenarios, 1, "blind only ran s2");
        assert!((blind.p95_speedup - 0.5).abs() < 1e-9);
        assert_eq!(a.last().unwrap().engine, "blind");

        let table = render_rank(&a);
        assert!(table.contains("engine"), "{table}");
        assert!(table.contains("adaptive"), "{table}");
    }

    #[test]
    fn legacy_projection_covers_the_nine_retired_scenarios() {
        let pairs = [
            ("sched_smoke", "static"),
            ("longshort", "static"),
            ("longshort", "adaptive"),
            ("cancel_storm", "static"),
            ("priority_inversion", "static"),
            ("hetero_inversion", "static"),
            ("hetero_inversion", "blind"),
            ("submit_storm", "sharded2"),
            ("submit_storm", "static"),
        ];
        let names: Vec<&str> = pairs
            .iter()
            .map(|(s, e)| legacy_name(s, e).expect("legacy mapping"))
            .collect();
        let mut unique = names.clone();
        unique.sort_unstable();
        unique.dedup();
        assert_eq!(unique.len(), 9, "nine distinct legacy scenario names");
        assert_eq!(legacy_name("sched_smoke", "blind"), None);

        let rows: Vec<Measurement> = pairs
            .iter()
            .map(|(s, e)| cell_with(s, e, 10, 100.0, 10.0))
            .collect();
        let json = legacy_json(&rows);
        let text = json.to_string();
        for name in names {
            assert!(text.contains(name), "legacy json missing {name}: {text}");
        }
    }
}

//! One matrix cell's measured outcome.

use crate::engine::SchedStats;
use crate::util::stats::percentiles;

/// Measurement mode: `quick` is the per-PR CI smoke (small job
/// counts), `full` the long-form run. The two are never comparable —
/// job counts shift the percentiles and steady-state throughput — so
/// the mode is part of the cell identity and records live in separate
/// files.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    Quick,
    Full,
}

impl Mode {
    pub fn as_str(self) -> &'static str {
        match self {
            Mode::Quick => "quick",
            Mode::Full => "full",
        }
    }

    pub fn parse(s: &str) -> Result<Mode, String> {
        match s {
            "quick" => Ok(Mode::Quick),
            "full" => Ok(Mode::Full),
            other => Err(format!("unknown mode `{other}` — expected `quick` or `full`")),
        }
    }
}

/// One (scenario, engine, mode) cell: the latency/throughput outcome
/// plus the scheduler counters that explain *why* (a p95 win from
/// stealing looks different from one bought by class degradation).
#[derive(Debug, Clone, PartialEq)]
pub struct Measurement {
    pub scenario: String,
    pub engine: String,
    pub mode: Mode,
    /// measured job walls behind the percentiles (mode guard: a
    /// quick-vs-full mismatch shows up here before the numbers lie)
    pub jobs: usize,
    pub throughput_jobs_s: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    /// queued tasks pulled over from a loaded peer shard
    pub steals: u64,
    /// armed-deadline timer expirations (the only clock-driven wakeups)
    pub timer_wakeups: u64,
    /// tasks launched on a class other than their preferred one
    pub class_degraded: u64,
    /// `true` for hand-estimated baseline rows that have not yet been
    /// re-recorded on a toolchain box (see `rust/bench/FORMAT.md`)
    pub estimated: bool,
}

impl Measurement {
    /// Build a cell from measured job walls (ms), the wall-clock span
    /// of the measured phase, and the scheduler's final counters.
    pub fn from_walls(
        scenario: &str,
        engine: &str,
        mode: Mode,
        walls_ms: &[f64],
        total_s: f64,
        stats: &SchedStats,
    ) -> Measurement {
        let ps = percentiles(walls_ms, &[50.0, 95.0, 99.0]);
        Measurement {
            scenario: scenario.to_string(),
            engine: engine.to_string(),
            mode,
            jobs: walls_ms.len(),
            throughput_jobs_s: walls_ms.len() as f64 / total_s.max(1e-9),
            p50_ms: ps[0],
            p95_ms: ps[1],
            p99_ms: ps[2],
            steals: stats.steals,
            timer_wakeups: stats.timer_wakeups,
            class_degraded: stats.class_degraded,
            estimated: false,
        }
    }
}

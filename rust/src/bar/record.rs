//! The recorded measurement format.
//!
//! rebar's core discipline: measurements are *recorded* — written to a
//! small flat file, checked into the repo, and diffed against — rather
//! than recomputed ad hoc. Ours is one CSV per (machine, mode) under
//! `rust/bench/record/<machine>/<mode>.csv`; the schema is documented
//! in `rust/bench/FORMAT.md` and enforced here, in both directions.
//!
//! Floats are serialized with Rust's shortest-round-trip `Display`, so
//! `parse_csv(to_csv(v)) == v` exactly — the round-trip property the
//! tests pin. No quoting or escaping: every field the schema admits is
//! comma-free by construction (scenario/engine names are validated
//! identifiers).

use std::path::{Path, PathBuf};

use super::measure::{Measurement, Mode};

/// Column order is the schema; a baseline with any other header is
/// rejected rather than guessed at.
pub const CSV_HEADER: &str = "scenario,engine,mode,jobs,throughput_jobs_s,p50_ms,p95_ms,p99_ms,steals,timer_wakeups,class_degraded,estimated";

/// Path of one record file: `<dir>/<machine>/<mode>.csv`.
pub fn record_path(dir: &Path, machine: &str, mode: Mode) -> PathBuf {
    dir.join(machine).join(format!("{}.csv", mode.as_str()))
}

/// Serialize measurements in the given order (callers sort for a
/// canonical checked-in form; `bench-bar record` sorts by scenario
/// then engine).
pub fn to_csv(rows: &[Measurement]) -> String {
    let mut out = String::with_capacity(64 * (rows.len() + 1));
    out.push_str(CSV_HEADER);
    out.push('\n');
    for m in rows {
        out.push_str(&format!(
            "{},{},{},{},{},{},{},{},{},{},{},{}\n",
            m.scenario,
            m.engine,
            m.mode.as_str(),
            m.jobs,
            m.throughput_jobs_s,
            m.p50_ms,
            m.p95_ms,
            m.p99_ms,
            m.steals,
            m.timer_wakeups,
            m.class_degraded,
            m.estimated,
        ));
    }
    out
}

fn field<'a>(parts: &[&'a str], i: usize) -> &'a str {
    parts[i].trim()
}

fn num<T: std::str::FromStr>(parts: &[&str], i: usize, line: usize, what: &str) -> Result<T, String> {
    field(parts, i)
        .parse()
        .map_err(|_| format!("line {line}: bad {what} `{}`", field(parts, i)))
}

/// Parse a record file back into measurements, validating the header,
/// the field count, and every field's type. Blank lines are ignored;
/// anything else malformed is an error, tagged with its line number.
pub fn parse_csv(text: &str) -> Result<Vec<Measurement>, String> {
    let mut lines = text
        .lines()
        .enumerate()
        .map(|(i, l)| (i + 1, l.trim()))
        .filter(|(_, l)| !l.is_empty());
    match lines.next() {
        Some((_, h)) if h == CSV_HEADER => {}
        Some((n, h)) => {
            return Err(format!(
                "line {n}: bad header `{h}` — expected `{CSV_HEADER}` (regenerate with `bench-bar record`)"
            ))
        }
        None => return Err("empty record file".to_string()),
    }
    let mut rows = Vec::new();
    for (n, line) in lines {
        let parts: Vec<&str> = line.split(',').collect();
        if parts.len() != 12 {
            return Err(format!(
                "line {n}: expected 12 comma-separated fields, got {}",
                parts.len()
            ));
        }
        let scenario = field(&parts, 0);
        let engine = field(&parts, 1);
        for (what, v) in [("scenario", scenario), ("engine", engine)] {
            if v.is_empty() || !v.chars().all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_') {
                return Err(format!("line {n}: bad {what} name `{v}`"));
            }
        }
        let mode = Mode::parse(field(&parts, 2)).map_err(|e| format!("line {n}: {e}"))?;
        let throughput_jobs_s: f64 = num(&parts, 4, n, "throughput_jobs_s")?;
        let p50_ms: f64 = num(&parts, 5, n, "p50_ms")?;
        let p95_ms: f64 = num(&parts, 6, n, "p95_ms")?;
        let p99_ms: f64 = num(&parts, 7, n, "p99_ms")?;
        for (what, v) in [
            ("throughput_jobs_s", throughput_jobs_s),
            ("p50_ms", p50_ms),
            ("p95_ms", p95_ms),
            ("p99_ms", p99_ms),
        ] {
            if !v.is_finite() || v < 0.0 {
                return Err(format!("line {n}: {what} must be a finite non-negative number"));
            }
        }
        let estimated = match field(&parts, 11) {
            "true" => true,
            "false" => false,
            other => return Err(format!("line {n}: bad estimated flag `{other}` — expected `true` or `false`")),
        };
        rows.push(Measurement {
            scenario: scenario.to_string(),
            engine: engine.to_string(),
            mode,
            jobs: num(&parts, 3, n, "jobs")?,
            throughput_jobs_s,
            p50_ms,
            p95_ms,
            p99_ms,
            steals: num(&parts, 8, n, "steals")?,
            timer_wakeups: num(&parts, 9, n, "timer_wakeups")?,
            class_degraded: num(&parts, 10, n, "class_degraded")?,
            estimated,
        });
    }
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cell(scenario: &str, engine: &str, p95: f64) -> Measurement {
        Measurement {
            scenario: scenario.to_string(),
            engine: engine.to_string(),
            mode: Mode::Quick,
            jobs: 20,
            throughput_jobs_s: 147.0612,
            p50_ms: 12.25,
            p95_ms: p95,
            p99_ms: p95 + 1.5,
            steals: 3,
            timer_wakeups: 7,
            class_degraded: 0,
            estimated: false,
        }
    }

    #[test]
    fn csv_round_trips_exactly() {
        // 0.30000000000000004 on purpose: Display's shortest
        // round-trip form must survive parse() bit-for-bit
        let rows = vec![cell("sched_smoke", "static", 0.1 + 0.2), cell("longshort", "adaptive", 8.8)];
        let text = to_csv(&rows);
        assert!(text.starts_with(CSV_HEADER));
        assert_eq!(parse_csv(&text).unwrap(), rows);
    }

    #[test]
    fn rejects_a_foreign_header() {
        let err = parse_csv("name,p95\nx,1\n").unwrap_err();
        assert!(err.contains("bad header"), "{err}");
        assert!(parse_csv("").unwrap_err().contains("empty record file"));
    }

    #[test]
    fn rejects_malformed_rows() {
        let row = |r: &str| parse_csv(&format!("{CSV_HEADER}\n{r}\n")).unwrap_err();
        let short = row("sched_smoke,static,quick,20,1,2,3");
        assert!(short.contains("expected 12"), "{short}");
        let mode = row("sched_smoke,static,warp,20,1,2,3,4,0,0,0,false");
        assert!(mode.contains("unknown mode"), "{mode}");
        let thr = row("sched_smoke,static,quick,20,fast,2,3,4,0,0,0,false");
        assert!(thr.contains("bad throughput_jobs_s"), "{thr}");
        let neg = row("sched_smoke,static,quick,20,-1,2,3,4,0,0,0,false");
        assert!(neg.contains("finite non-negative"), "{neg}");
        let flag = row("sched_smoke,static,quick,20,1,2,3,4,0,0,0,maybe");
        assert!(flag.contains("bad estimated flag"), "{flag}");
        let name = row("Sched Smoke,static,quick,20,1,2,3,4,0,0,0,false");
        assert!(name.contains("bad scenario name"), "{name}");
        assert!(row("sched_smoke,static,quick,20,1,2,3,4,0,0,0,false,extra").contains("got 13"));
    }

    #[test]
    fn record_path_layout() {
        let p = record_path(Path::new("bench/record"), "ci16", Mode::Quick);
        assert_eq!(p, Path::new("bench/record/ci16/quick.csv"));
    }
}

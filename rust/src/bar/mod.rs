//! `pallas-bar` — the rebar-style scheduler barometer.
//!
//! The paper's whole argument is quantitative: divide-and-conquer
//! placement wins only if p95/throughput say so. This subsystem makes
//! that claim checkable the way rebar made it checkable for regex
//! engines — many small *declaratively defined* benchmarks, a recorded
//! measurement format checked into the repo, and ranking tooling
//! across engines:
//!
//! - [`scenario`] — scenario definitions as data, not code:
//!   `rust/bench/scenarios/*.toml` describes the workload mix (sim
//!   model base-ms, part counts, declared sizes), the arrival process
//!   (open/closed loop, seeded deterministic RNG), budget / priority /
//!   cancel distributions, the `CoreMap`, and per-scenario acceptance
//!   bars. Parsed by the shared `util::toml` subset parser with
//!   pallas-lint-style validation: unknown keys, duplicate sections,
//!   or out-of-range values are a config error (`bench-bar` exits 2).
//! - [`engine`] — the engine matrix: named scheduler configurations
//!   (static, adaptive, sharded×2, class-blind) that every scenario
//!   runs against over the existing
//!   [`SimRunner`](crate::bench::gate::SimRunner).
//! - [`measure`] — one matrix cell's measured outcome: throughput,
//!   p50/p95/p99, and the scheduler counters that explain *why*
//!   (steals, timer wakeups, class degradations).
//! - [`record`] — the recorded measurement format: CSV files under
//!   `rust/bench/record/<machine>/<mode>.csv`, written by `bench-bar
//!   record` and checked in (rebar FORMAT.md style; schema in
//!   `rust/bench/FORMAT.md`).
//! - [`rank`] — comparison tooling: `bench-bar diff` gates a fresh run
//!   against the recorded baseline with per-scenario tolerances plus
//!   the scenarios' self-relative bars; `bench-bar rank` emits a
//!   geometric-mean speedup ranking of engines across the suite.
//!
//! The `bench-bar` binary (`rust/scripts/bench_bar.rs`) is the CLI
//! over all of this; CI's `bench-smoke` job runs `bench-bar diff
//! --quick` as a blocking gate.

pub mod engine;
pub mod measure;
pub mod rank;
pub mod record;
pub mod scenario;

pub use engine::{by_name, plans, run_cell, run_matrix, EngineSpec, SubmitterPlan, ENGINES};
pub use measure::{Measurement, Mode};
pub use rank::{
    check_bars, diff, legacy_json, legacy_name, rank, render_rank, DiffOutcome, RankRow,
    REFERENCE_ENGINE,
};
pub use record::{parse_csv, record_path, to_csv, CSV_HEADER};
pub use scenario::{load_dir, Arrival, BarMetric, BarSpec, Loop, PartSpec, Scenario};

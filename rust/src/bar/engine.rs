//! The engine matrix: named scheduler configurations every scenario
//! runs against, plus the deterministic load generator that drives
//! them over the [`SimRunner`].
//!
//! An "engine" in rebar's sense is one contender: here, one way of
//! configuring the scheduler stack. The matrix crosses every scenario
//! with every engine named in its `engines` list, and each cell runs
//! the *same* seeded arrival schedule (see [`plans`]) so cells differ
//! only by the engine under test.
//!
//! This module is the barometer's ingress: it plays the client, so
//! the per-request state ([`RequestCtx`], [`Budget`]) for each
//! simulated request is minted here (PL004 lists this file as an
//! ingress module), and its submitter/producer threads are the
//! documented PL001 exceptions — the load generator must live outside
//! the pool it measures.

use std::sync::{Arc, Barrier};
use std::time::{Duration, Instant};

use crate::bench::gate::{sim_model, SimRunner};
use crate::engine::{
    allocate, AdaptiveConfig, AdaptivePolicy, AllocPolicy, Budget, PartTask, PartWeights,
    Priority, ProfileStore, RequestCtx, SchedConfig, Scheduler, SubmitHandle,
};
use crate::util::prng::Rng;

use super::measure::{Measurement, Mode};
use super::scenario::{Loop, Scenario};

/// One named scheduler configuration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EngineSpec {
    pub name: &'static str,
    pub summary: &'static str,
    /// dispatcher shards; 0 = auto (1 per 16 cores)
    pub shards: usize,
    /// profile auto-sized parts first, then size them by measured cost
    /// ([`AdaptivePolicy::part_weights`]) instead of declared size
    pub adaptive: bool,
    /// `false` strips request priorities: every task submits with a
    /// plain ctx, so affinity is `Any` and priority admission is off —
    /// the class-blind ablation of the paper's placement argument
    pub class_aware: bool,
}

/// The engine matrix columns. `static` is the reference engine the
/// ranking normalizes against; the other three each ablate or extend
/// exactly one axis of it.
pub const ENGINES: &[EngineSpec] = &[
    EngineSpec {
        name: "static",
        summary: "size-proportional split, auto shards, ctx-derived class placement",
        shards: 0,
        adaptive: false,
        class_aware: true,
    },
    EngineSpec {
        name: "adaptive",
        summary: "static engine with profiled part weights (paper §3.1) for auto-sized parts",
        shards: 0,
        adaptive: true,
        class_aware: true,
    },
    EngineSpec {
        name: "sharded2",
        summary: "static engine with a 2-shard work-stealing dispatcher",
        shards: 2,
        adaptive: false,
        class_aware: true,
    },
    EngineSpec {
        name: "blind",
        summary: "static engine with priorities stripped: class-blind, admission-order placement",
        shards: 0,
        adaptive: false,
        class_aware: false,
    },
];

/// Look an engine up by its scenario-file name.
pub fn by_name(name: &str) -> Option<&'static EngineSpec> {
    ENGINES.iter().find(|e| e.name == name)
}

/// One submitter's precomputed schedule: the inter-job gap before each
/// submit, and for each job the cancel coin-flip per cancellable part
/// instance (in part file order, instances flattened). Computed from
/// the scenario seed alone, so the schedule is identical across
/// engines, runs, and machines — the determinism the recorded-baseline
/// discipline depends on.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SubmitterPlan {
    pub gaps_us: Vec<u64>,
    pub cancels: Vec<Vec<bool>>,
}

/// Derive every submitter's schedule for a scenario. Each submitter
/// forks its own RNG stream from the scenario seed, and each job draws
/// its gap then its cancel flips, so the draw structure is fixed and
/// the schedule reproducible.
pub fn plans(sc: &Scenario, mode: Mode) -> Vec<SubmitterPlan> {
    let jobs = sc.arrival.jobs_for(mode);
    let cancel_probs: Vec<f64> = sc
        .parts
        .iter()
        .flat_map(|p| std::iter::repeat(p).take(p.count))
        .filter(|p| p.cancel_after_ms.is_some())
        .map(|p| p.cancel_prob)
        .collect();
    let spacing = sc.arrival.spacing_us;
    let mut root = Rng::new(sc.arrival.seed);
    (0..sc.arrival.submitters)
        .map(|_| {
            let mut r = root.fork();
            let mut gaps_us = Vec::with_capacity(jobs);
            let mut cancels = Vec::with_capacity(jobs);
            for _ in 0..jobs {
                let gap = if spacing == 0 {
                    0
                } else if sc.arrival.uniform_jitter {
                    r.u64_in(spacing / 2, spacing + spacing / 2)
                } else {
                    spacing
                };
                gaps_us.push(gap);
                cancels.push(cancel_probs.iter().map(|&p| r.bool(p)).collect());
            }
            SubmitterPlan { gaps_us, cancels }
        })
        .collect()
}

/// One part instance with its allocation resolved.
#[derive(Clone)]
struct Instance {
    model: String,
    threads: usize,
    size: usize,
    auto: bool,
    priority: Priority,
    budget_ms: Option<f64>,
    cancel_after_ms: Option<f64>,
    measured: bool,
}

fn expand_instances(sc: &Scenario) -> Vec<Instance> {
    sc.parts
        .iter()
        .flat_map(|p| {
            std::iter::repeat(Instance {
                model: sim_model(p.base_ms),
                threads: p.threads,
                size: p.size,
                auto: p.threads == 0,
                priority: p.priority,
                budget_ms: p.budget_ms,
                cancel_after_ms: p.cancel_after_ms,
                measured: p.measured,
            })
            .take(p.count)
        })
        .collect()
}

/// Run one matrix cell: `scenario` under `engine`, at `mode`'s job
/// counts. Any part failure (a task error, a cancelled part that
/// completed anyway, a panicked submitter) poisons the whole cell —
/// a half-measured cell must not become a number.
pub fn run_cell(sc: &Scenario, eng: &EngineSpec, mode: Mode) -> Result<Measurement, String> {
    let sched = Scheduler::start(
        SchedConfig {
            cores: sc.cores,
            shards: eng.shards,
            aging: Duration::from_millis(50),
            backfill: true,
            deadline_running: None,
            ..SchedConfig::default()
        },
        Arc::new(SimRunner { workers: sc.workers }),
    );
    let mut instances = expand_instances(sc);
    resolve_auto_threads(sc, eng, &sched, &mut instances)?;
    let plans = plans(sc, mode);

    let (walls, total_s) = match sc.arrival.mode {
        Loop::Closed => run_closed(sc, eng, &sched, &instances, plans)?,
        Loop::Open => run_open(sc, eng, &sched, &instances, plans)?,
    };
    let stats = sched.stats();
    Ok(Measurement::from_walls(&sc.name, eng.name, mode, &walls, total_s, &stats))
}

/// Run every (scenario × listed engine) cell of the matrix.
pub fn run_matrix(
    scenarios: &[Scenario],
    mode: Mode,
) -> Result<Vec<Measurement>, String> {
    let mut out = Vec::new();
    for sc in scenarios {
        for name in &sc.engines {
            let eng = by_name(name)
                .ok_or_else(|| format!("scenario `{}`: unknown engine `{name}`", sc.name))?;
            out.push(run_cell(sc, eng, mode)?);
        }
    }
    Ok(out)
}

/// Fill in `threads` for auto-sized instances. The static engines size
/// them by declared size; the adaptive engine first runs the paper's
/// §3.1 profiling phase (each auto part at one thread, enough samples
/// to trust the distribution window) and sizes by measured cost.
/// Profiling happens on the same scheduler but before the measured
/// window opens, so it never pollutes the walls.
fn resolve_auto_threads(
    sc: &Scenario,
    eng: &EngineSpec,
    sched: &Arc<Scheduler>,
    instances: &mut [Instance],
) -> Result<(), String> {
    let autos: Vec<(String, usize)> = instances
        .iter()
        .filter(|i| i.auto)
        .map(|i| (i.model.clone(), i.size))
        .collect();
    if autos.is_empty() {
        return Ok(());
    }
    let threads = if eng.adaptive {
        let profiles = Arc::new(ProfileStore::new());
        let policy = AdaptivePolicy::new(Arc::clone(&profiles), AdaptiveConfig::default());
        for _ in 0..crate::engine::profile::MIN_DISTRIBUTION_SAMPLES {
            let handles: Vec<_> = autos
                .iter()
                .map(|(m, _)| sched.submit(PartTask::new(m.clone(), Vec::new(), 1)))
                .collect();
            for (h, (m, _)) in handles.into_iter().zip(autos.iter()) {
                let done = h
                    .wait()
                    .map_err(|e| format!("scenario `{}`: profiling failed: {e}", sc.name))?;
                profiles.observe(m, done.exec);
            }
        }
        let keyed: Vec<(&str, usize)> =
            autos.iter().map(|(m, s)| (m.as_str(), *s)).collect();
        allocate(
            PartWeights::Measured(&policy.part_weights(&keyed)),
            &sc.cores,
            AllocPolicy::PrunDef,
        )
        .into_threads()
    } else {
        let sizes: Vec<usize> = autos.iter().map(|(_, s)| *s).collect();
        allocate(PartWeights::Sizes(&sizes), &sc.cores, AllocPolicy::PrunDef).into_threads()
    };
    let mut it = threads.into_iter();
    for inst in instances.iter_mut().filter(|i| i.auto) {
        inst.threads = it.next().expect("one allocation per auto instance");
    }
    Ok(())
}

/// Closed loop: each submitter runs its jobs back to back (plus any
/// configured pacing), waiting for each job's measured parts before
/// the next submit. Walls are per-job: submit of the first part to
/// completion of the last measured part.
fn run_closed(
    sc: &Scenario,
    eng: &EngineSpec,
    sched: &Arc<Scheduler>,
    instances: &[Instance],
    plans: Vec<SubmitterPlan>,
) -> Result<(Vec<f64>, f64), String> {
    let t0 = Instant::now();
    let mut joins = Vec::with_capacity(plans.len());
    for plan in plans {
        let sched = Arc::clone(sched);
        let instances = instances.to_vec();
        let class_aware = eng.class_aware;
        joins.push(std::thread::spawn(move || -> Result<Vec<f64>, String> {
            let mut walls = Vec::with_capacity(plan.gaps_us.len());
            for (gap, cancels) in plan.gaps_us.iter().zip(plan.cancels.iter()) {
                if *gap > 0 {
                    std::thread::sleep(Duration::from_micros(*gap));
                }
                walls.push(run_one_job(&sched, &instances, cancels, class_aware)?);
            }
            Ok(walls)
        }));
    }
    let mut walls = Vec::new();
    for j in joins {
        let sub_walls = j
            .join()
            .map_err(|_| format!("scenario `{}`: submitter thread panicked", sc.name))??;
        walls.extend(sub_walls);
    }
    Ok((walls, t0.elapsed().as_secs_f64()))
}

/// Submit one job's parts, run the cancel pass, and wait it out.
fn run_one_job(
    sched: &Scheduler,
    instances: &[Instance],
    cancels: &[bool],
    class_aware: bool,
) -> Result<f64, String> {
    struct Pending {
        h: Option<SubmitHandle>,
        measured: bool,
        cancelled: bool,
    }
    // Same-priority parts share one request identity per job, like the
    // serving edge; cancellable parts get their own (a ctx token is
    // shared, and cancelling one doomed part must not kill its
    // siblings). The class-blind engine strips priorities entirely.
    let mk_ctx = |p: Priority| {
        if class_aware {
            RequestCtx::new().with_priority(p)
        } else {
            RequestCtx::new()
        }
    };
    let mut shared_ctx: [Option<RequestCtx>; 3] = [None, None, None];
    let t0 = Instant::now();
    let mut pending = Vec::with_capacity(instances.len());
    let mut cancel_pass: Vec<(f64, usize)> = Vec::new();
    let mut flip = 0usize;
    for inst in instances {
        let mut task = PartTask::new(inst.model.clone(), Vec::new(), inst.threads);
        match inst.cancel_after_ms {
            Some(after_ms) => {
                // own identity; priority only if the engine honors it
                if class_aware {
                    task = task.with_priority(inst.priority);
                }
                if cancels[flip] {
                    cancel_pass.push((after_ms, pending.len()));
                }
                flip += 1;
            }
            None => {
                let slot = &mut shared_ctx[inst.priority as usize];
                let ctx = slot.get_or_insert_with(|| mk_ctx(inst.priority));
                task = task.with_ctx(ctx);
            }
        }
        if let Some(ms) = inst.budget_ms {
            task = task.with_budget(Budget::new(Duration::from_secs_f64(ms / 1e3)));
        }
        let h = sched.submit(task);
        pending.push(Pending { h: Some(h), measured: inst.measured, cancelled: false });
    }
    // cancel pass, in offset order from the job submit instant
    cancel_pass.sort_by(|a, b| a.0.total_cmp(&b.0));
    for (after_ms, idx) in cancel_pass {
        let target = t0 + Duration::from_secs_f64(after_ms / 1e3);
        let now = Instant::now();
        if target > now {
            std::thread::sleep(target - now);
        }
        let p = &mut pending[idx];
        p.h.as_ref().expect("handle still pending").cancel();
        p.cancelled = true;
    }
    // the measured parts define the wall…
    for p in pending.iter_mut().filter(|p| p.measured) {
        p.h.take()
            .expect("measured handle")
            .wait()
            .map_err(|e| format!("measured part failed: {e}"))?;
    }
    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
    // …then the rest drains: cancelled parts must have died, the
    // other unmeasured parts must complete.
    for p in pending {
        let Some(h) = p.h else { continue };
        let res = h.wait();
        if p.cancelled {
            if res.is_ok() {
                return Err(
                    "cancelled part completed anyway — cancellation is broken".to_string()
                );
            }
        } else {
            res.map_err(|e| format!("drained part failed: {e}"))?;
        }
    }
    Ok(wall_ms)
}

/// Open loop: producers flood jobs at their pacing without waiting on
/// completions. Throughput is submit-phase ops/sec — the figure
/// sharding lifts — while walls (submit → last measured part done)
/// keep the latency regression net. Consecutive request ids spread the
/// flood round-robin across shards.
fn run_open(
    sc: &Scenario,
    eng: &EngineSpec,
    sched: &Arc<Scheduler>,
    instances: &[Instance],
    plans: Vec<SubmitterPlan>,
) -> Result<(Vec<f64>, f64), String> {
    let producers = plans.len();
    let jobs = plans.first().map(|p| p.gaps_us.len()).unwrap_or(0);
    let barrier = Arc::new(Barrier::new(producers + 1));
    let mut joins = Vec::with_capacity(producers);
    for (p, plan) in plans.into_iter().enumerate() {
        let sched = Arc::clone(sched);
        let barrier = Arc::clone(&barrier);
        let instances = instances.to_vec();
        let class_aware = eng.class_aware;
        joins.push(std::thread::spawn(
            move || -> Result<(Instant, Vec<f64>), String> {
                barrier.wait();
                let mut in_flight = Vec::with_capacity(jobs);
                for (i, gap) in plan.gaps_us.iter().enumerate() {
                    if *gap > 0 {
                        std::thread::sleep(Duration::from_micros(*gap));
                    }
                    let rid = (p * jobs + i) as u64;
                    let t = Instant::now();
                    let handles: Vec<(SubmitHandle, bool)> = instances
                        .iter()
                        .map(|inst| {
                            let mut task =
                                PartTask::new(inst.model.clone(), Vec::new(), inst.threads);
                            if class_aware && inst.priority != Priority::Normal {
                                task = task.with_priority(inst.priority);
                            }
                            if let Some(ms) = inst.budget_ms {
                                task = task
                                    .with_budget(Budget::new(Duration::from_secs_f64(ms / 1e3)));
                            }
                            (sched.submit(task.with_request_id(rid)), inst.measured)
                        })
                        .collect();
                    in_flight.push((t, handles));
                }
                let submits_done = Instant::now();
                let mut walls = Vec::with_capacity(jobs);
                for (t, handles) in in_flight {
                    let (measured, rest): (Vec<_>, Vec<_>) =
                        handles.into_iter().partition(|(_, m)| *m);
                    for (h, _) in measured {
                        h.wait().map_err(|e| format!("measured part failed: {e}"))?;
                    }
                    walls.push(t.elapsed().as_secs_f64() * 1e3);
                    for (h, _) in rest {
                        h.wait().map_err(|e| format!("drained part failed: {e}"))?;
                    }
                }
                Ok((submits_done, walls))
            },
        ));
    }
    let t0 = Instant::now();
    barrier.wait();
    let mut walls = Vec::new();
    let mut submit_phase = Duration::ZERO;
    for j in joins {
        let (done, w) = j
            .join()
            .map_err(|_| format!("scenario `{}`: producer thread panicked", sc.name))??;
        submit_phase = submit_phase.max(done.duration_since(t0));
        walls.extend(w);
    }
    Ok((walls, submit_phase.as_secs_f64()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bar::scenario::Scenario;

    fn scenario(extra_arrival: &str, extra_part: &str) -> Scenario {
        Scenario::parse(&format!(
            r#"
[scenario]
name = "t"
engines = ["static"]

[arrival]
submitters = 2
jobs = 8
quick_jobs = 3
seed = 7
{extra_arrival}

[[part]]
name = "work"
base_ms = 2.0
threads = 1
{extra_part}
"#
        ))
        .unwrap()
    }

    #[test]
    fn plans_are_deterministic_and_mode_sized() {
        let sc = scenario("spacing_us = 1000\njitter = \"uniform\"", "");
        let a = plans(&sc, Mode::Quick);
        let b = plans(&sc, Mode::Quick);
        assert_eq!(a, b, "same seed must give the identical arrival schedule");
        assert_eq!(a.len(), 2);
        assert_eq!(a[0].gaps_us.len(), 3);
        assert_eq!(plans(&sc, Mode::Full)[0].gaps_us.len(), 8);
        // jittered gaps stay within ±50% of the spacing
        for plan in &a {
            for g in &plan.gaps_us {
                assert!((500..=1500).contains(g), "gap {g}us out of jitter range");
            }
        }
        // submitters get distinct streams (forked, not shared)
        assert_ne!(a[0].gaps_us, a[1].gaps_us);
    }

    #[test]
    fn cancel_flips_follow_the_probability_edges() {
        let sure = scenario(
            "",
            "measured = false\ncancel_after_ms = 1.0\n\n\
             [[part]]\nname = \"w2\"\nbase_ms = 1.0\nthreads = 1",
        );
        for plan in plans(&sure, Mode::Full) {
            assert!(plan.cancels.iter().all(|c| c == &vec![true]), "prob defaults to 1");
        }
        let never = scenario(
            "",
            "measured = false\ncancel_after_ms = 1.0\ncancel_prob = 0.0\n\n\
             [[part]]\nname = \"w2\"\nbase_ms = 1.0\nthreads = 1",
        );
        for plan in plans(&never, Mode::Full) {
            assert!(plan.cancels.iter().all(|c| c == &vec![false]));
        }
    }

    #[test]
    fn engine_lookup_and_matrix_shape() {
        assert_eq!(by_name("static").unwrap().shards, 0);
        assert_eq!(by_name("sharded2").unwrap().shards, 2);
        assert!(by_name("adaptive").unwrap().adaptive);
        assert!(!by_name("blind").unwrap().class_aware);
        assert!(by_name("warp9").is_none());
        assert!(ENGINES.len() >= 3, "acceptance: the matrix crosses >= 3 engines");
    }

    #[test]
    fn run_cell_measures_a_tiny_closed_scenario() {
        let sc = scenario("", "");
        let m = run_cell(&sc, by_name("static").unwrap(), Mode::Quick).unwrap();
        assert_eq!((m.scenario.as_str(), m.engine), ("t", "static".to_string()));
        assert_eq!(m.jobs, 6, "2 submitters x 3 quick jobs");
        assert!(m.throughput_jobs_s > 0.0);
        assert!(m.p50_ms <= m.p95_ms && m.p95_ms <= m.p99_ms);
        assert!(!m.estimated);
    }
}

//! Serving configuration: JSON file + CLI/env overrides.
//!
//! Precedence (lowest to highest): built-in defaults < `--config file`
//! < individual CLI flags. `DNC_ARTIFACTS` keeps working for the
//! artifacts directory as elsewhere in the runtime.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::engine::{AllocPolicy, CoreMap};
use crate::util::args::Args;
use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct Config {
    /// the core inventory the allocator divides (paper: 16, one class).
    /// JSON/CLI accept either a plain count (`16`, homogeneous) or the
    /// class syntax `fast=4,slow=12` / `fast=4,slow=12@0.5`.
    pub cores: CoreMap,
    /// real executor threads (PJRT clients); default = machine cores
    pub workers: usize,
    /// default allocation policy for prun
    pub policy: AllocPolicy,
    /// serving endpoint
    pub host: String,
    pub port: u16,
    /// dynamic batcher limits
    pub max_batch: usize,
    pub max_wait_ms: u64,
    /// scheduler: max time the queue head may be bypassed by backfill
    pub aging_ms: u64,
    /// scheduler shards (dispatcher threads, each owning a disjoint
    /// slice of the core ledger); 0 = auto, one shard per 16 cores
    pub sched_shards: usize,
    /// adaptive mode: size parts by measured cost and re-derive the
    /// aging bound from observed p95 part latency (engine::adaptive)
    pub adaptive: bool,
    /// scheduler: cancel a task still *executing* after this long and
    /// reclaim its cores (0 = never)
    pub deadline_running_ms: u64,
    /// router: max time a connection thread waits for a batched reply
    /// (on expiry the request's scheduler tasks are cancelled). Also the
    /// embed request's end-to-end budget: every layer (batcher wait,
    /// scheduler queueing, execution) is charged against it.
    pub request_timeout_ms: u64,
    /// router: the OCR op's end-to-end budget — the pipeline runs on a
    /// worker thread under this deadline; on expiry the request's token
    /// is cancelled and its scheduler tasks release their cores
    /// (`ocr_timeouts` counter). Separate knob from
    /// `request_timeout_ms` because one OCR page costs many model
    /// invocations across three phases.
    pub ocr_timeout_ms: u64,
    /// server shutdown: max time to wait for in-flight scheduler tasks
    pub drain_timeout_ms: u64,
    pub artifacts: PathBuf,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            cores: CoreMap::homogeneous(16),
            workers: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
            policy: AllocPolicy::PrunDef,
            host: "127.0.0.1".to_string(),
            port: 7070,
            max_batch: 8,
            max_wait_ms: 5,
            aging_ms: 50,
            sched_shards: 0,
            adaptive: false,
            deadline_running_ms: 0,
            request_timeout_ms: 30_000,
            ocr_timeout_ms: 60_000,
            drain_timeout_ms: 10_000,
            artifacts: crate::runtime::artifacts_dir(),
        }
    }
}

impl Config {
    pub fn from_file(path: &Path) -> Result<Config> {
        let mut cfg = Config::default();
        cfg.apply_json(&Json::parse_file(path)?)?;
        Ok(cfg)
    }

    fn apply_json(&mut self, v: &Json) -> Result<()> {
        if let Some(x) = v.get("cores") {
            // number = homogeneous count; string = class syntax
            let spec = match x.as_usize() {
                Some(n) => n.to_string(),
                None => x.as_str().context("cores")?.to_string(),
            };
            self.cores = CoreMap::parse(&spec)
                .map_err(|e| anyhow::anyhow!("cores: {e}"))?;
        }
        if let Some(x) = v.get("workers") {
            self.workers = x.as_usize().context("workers")?;
        }
        if let Some(x) = v.get("policy") {
            let name = x.as_str().context("policy")?;
            self.policy = AllocPolicy::parse(name)
                .with_context(|| format!("unknown policy '{name}'"))?;
        }
        if let Some(x) = v.get("host") {
            self.host = x.as_str().context("host")?.to_string();
        }
        if let Some(x) = v.get("port") {
            self.port = x.as_usize().context("port")? as u16;
        }
        if let Some(x) = v.get("max_batch") {
            self.max_batch = x.as_usize().context("max_batch")?;
        }
        if let Some(x) = v.get("max_wait_ms") {
            self.max_wait_ms = x.as_usize().context("max_wait_ms")? as u64;
        }
        if let Some(x) = v.get("aging_ms") {
            self.aging_ms = x.as_usize().context("aging_ms")? as u64;
        }
        if let Some(x) = v.get("sched_shards") {
            self.sched_shards = x.as_usize().context("sched_shards")?;
        }
        if let Some(x) = v.get("adaptive") {
            self.adaptive = x.as_bool().context("adaptive")?;
        }
        if let Some(x) = v.get("deadline_running_ms") {
            self.deadline_running_ms = x.as_usize().context("deadline_running_ms")? as u64;
        }
        if let Some(x) = v.get("request_timeout_ms") {
            self.request_timeout_ms = x.as_usize().context("request_timeout_ms")? as u64;
        }
        if let Some(x) = v.get("ocr_timeout_ms") {
            self.ocr_timeout_ms = x.as_usize().context("ocr_timeout_ms")? as u64;
        }
        if let Some(x) = v.get("drain_timeout_ms") {
            self.drain_timeout_ms = x.as_usize().context("drain_timeout_ms")? as u64;
        }
        if let Some(x) = v.get("artifacts") {
            self.artifacts = PathBuf::from(x.as_str().context("artifacts")?);
        }
        Ok(())
    }

    /// Layer CLI flags on top (flags win over file values).
    pub fn apply_args(&mut self, args: &Args) -> Result<()> {
        if let Some(path) = args.get("config") {
            let file = Config::from_file(Path::new(path))?;
            *self = file;
        }
        if let Some(c) = args.get("cores") {
            self.cores = CoreMap::parse(c)
                .map_err(|e| anyhow::anyhow!("--cores {c}: {e}"))?;
        }
        self.workers = args.usize_or("workers", self.workers);
        if let Some(p) = args.get("policy") {
            self.policy =
                AllocPolicy::parse(p).with_context(|| format!("unknown policy '{p}'"))?;
        }
        if let Some(h) = args.get("host") {
            self.host = h.to_string();
        }
        self.port = args.usize_or("port", self.port as usize) as u16;
        self.max_batch = args.usize_or("max-batch", self.max_batch);
        self.max_wait_ms = args.u64_or("max-wait-ms", self.max_wait_ms);
        self.aging_ms = args.u64_or("aging-ms", self.aging_ms);
        self.sched_shards = args.usize_or("sched-shards", self.sched_shards);
        self.adaptive = self.adaptive || args.flag("adaptive");
        self.deadline_running_ms =
            args.u64_or("deadline-running-ms", self.deadline_running_ms);
        self.request_timeout_ms = args.u64_or("request-timeout-ms", self.request_timeout_ms);
        self.ocr_timeout_ms = args.u64_or("ocr-timeout-ms", self.ocr_timeout_ms);
        self.drain_timeout_ms = args.u64_or("drain-timeout-ms", self.drain_timeout_ms);
        if let Some(a) = args.get("artifacts") {
            self.artifacts = PathBuf::from(a);
        }
        Ok(())
    }

    pub fn addr(&self) -> String {
        format!("{}:{}", self.host, self.port)
    }

    /// Scheduler tuning derived from this config.
    pub fn sched(&self) -> crate::engine::SchedConfig {
        crate::engine::SchedConfig {
            cores: self.cores,
            shards: self.sched_shards,
            aging: std::time::Duration::from_millis(self.aging_ms),
            backfill: true,
            deadline_running: (self.deadline_running_ms > 0)
                .then(|| std::time::Duration::from_millis(self.deadline_running_ms)),
            ..Default::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn defaults_sane() {
        let c = Config::default();
        assert_eq!(c.cores, CoreMap::homogeneous(16));
        assert!(c.cores.is_homogeneous(), "default stays class-free");
        assert!(c.workers >= 1);
        assert_eq!(c.policy, AllocPolicy::PrunDef);
        assert_eq!(c.aging_ms, 50);
        assert!(!c.adaptive);
        assert_eq!(c.deadline_running_ms, 0);
        assert_eq!(c.request_timeout_ms, 30_000);
        assert_eq!(c.ocr_timeout_ms, 60_000);
        assert_eq!(c.drain_timeout_ms, 10_000);
        assert_eq!(c.sched_shards, 0);
        let s = c.sched();
        assert_eq!(s.cores.total(), 16);
        assert_eq!(s.shards, 0, "0 = auto: one shard per 16 ledger cores");
        assert_eq!(s.aging, std::time::Duration::from_millis(50));
        assert!(s.backfill);
        assert_eq!(s.deadline_running, None);
    }

    #[test]
    fn adaptive_knobs_from_file_and_cli() {
        let dir = std::env::temp_dir().join(format!("dnc_cfg4_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("cfg.json");
        std::fs::write(&p, r#"{"adaptive": true, "deadline_running_ms": 250}"#).unwrap();
        let c = Config::from_file(&p).unwrap();
        assert!(c.adaptive);
        assert_eq!(c.deadline_running_ms, 250);
        assert_eq!(
            c.sched().deadline_running,
            Some(std::time::Duration::from_millis(250))
        );
        // CLI: bare --adaptive flag + override of the running deadline
        let mut c = Config::default();
        c.apply_args(&args("serve --adaptive --deadline-running-ms 75")).unwrap();
        assert!(c.adaptive);
        assert_eq!(c.deadline_running_ms, 75);
    }

    #[test]
    fn sched_knobs_from_file_and_cli() {
        let dir = std::env::temp_dir().join(format!("dnc_cfg3_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("cfg.json");
        std::fs::write(
            &p,
            r#"{"aging_ms": 20, "sched_shards": 3, "request_timeout_ms": 1000, "ocr_timeout_ms": 4000, "drain_timeout_ms": 2000}"#,
        )
        .unwrap();
        let c = Config::from_file(&p).unwrap();
        assert_eq!(c.aging_ms, 20);
        assert_eq!(c.sched_shards, 3);
        assert_eq!(c.request_timeout_ms, 1000);
        assert_eq!(c.ocr_timeout_ms, 4000);
        assert_eq!(c.drain_timeout_ms, 2000);
        let mut c = Config::default();
        c.apply_args(&args(&format!(
            "serve --config {} --aging-ms 75 --sched-shards 2 --request-timeout-ms 500 --ocr-timeout-ms 2500 --drain-timeout-ms 1500",
            p.display()
        )))
        .unwrap();
        assert_eq!(c.aging_ms, 75);
        assert_eq!(c.sched_shards, 2, "CLI flag overrides the file value");
        assert_eq!(c.request_timeout_ms, 500);
        assert_eq!(c.ocr_timeout_ms, 2500);
        assert_eq!(c.drain_timeout_ms, 1500);
    }

    #[test]
    fn file_overrides() {
        let dir = std::env::temp_dir().join(format!("dnc_cfg_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("cfg.json");
        std::fs::write(&p, r#"{"cores": 8, "policy": "prun-eq", "port": 9999}"#).unwrap();
        let c = Config::from_file(&p).unwrap();
        assert_eq!(c.cores, CoreMap::homogeneous(8));
        assert_eq!(c.policy, AllocPolicy::PrunEq);
        assert_eq!(c.port, 9999);
        assert_eq!(c.max_batch, 8); // untouched default
    }

    #[test]
    fn cli_overrides_file() {
        let dir = std::env::temp_dir().join(format!("dnc_cfg2_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("cfg.json");
        std::fs::write(&p, r#"{"cores": 8}"#).unwrap();
        let mut c = Config::default();
        c.apply_args(&args(&format!("serve --config {} --cores 4 --policy one", p.display())))
            .unwrap();
        assert_eq!(c.cores, CoreMap::homogeneous(4));
        assert_eq!(c.policy, AllocPolicy::PrunOne);
    }

    #[test]
    fn heterogeneous_cores_from_file_and_cli() {
        use crate::engine::CoreClass;
        let dir = std::env::temp_dir().join(format!("dnc_cfg5_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("cfg.json");
        std::fs::write(&p, r#"{"cores": "fast=4,slow=12@0.5"}"#).unwrap();
        let c = Config::from_file(&p).unwrap();
        assert_eq!(c.cores.count(CoreClass::Fast), 4);
        assert_eq!(c.cores.count(CoreClass::Slow), 12);
        assert_eq!(c.cores.speed(CoreClass::Slow), 0.5);
        assert!(!c.cores.is_homogeneous());
        // CLI wins over the file, and rejects nonsense
        let mut c = Config::default();
        c.apply_args(&args(&format!(
            "serve --config {} --cores fast=2,slow=6",
            p.display()
        )))
        .unwrap();
        assert_eq!(c.cores, CoreMap::heterogeneous(2, 6));
        let mut c = Config::default();
        assert!(c.apply_args(&args("serve --cores turbo=4")).is_err());
    }

    #[test]
    fn bad_policy_rejected() {
        let mut c = Config::default();
        assert!(c.apply_args(&args("serve --policy nope")).is_err());
    }

    #[test]
    fn addr_formats() {
        let c = Config::default();
        assert_eq!(c.addr(), "127.0.0.1:7070");
    }
}

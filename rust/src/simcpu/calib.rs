//! Calibration constants: per-phase scalability profiles fitted to the
//! paper's *measured* anchor points, so the simulator reproduces the
//! shape of every figure on a machine where real 16-core scaling cannot
//! be measured (this CI box has one core — DESIGN.md §4, §5).
//!
//! Paper anchors used for fitting (all from §4):
//! - Fig. 2: PaddleOCR base latency 554 ms @1t -> 364 ms @4t -> 435 ms
//!   @16t (dip then rise); Text Classification 27 ms @1t -> 38 ms @16t
//!   (negative scaling); Text Recognition dominant, best around 4-8
//!   threads, regressing at 16.
//! - Fig. 5: rec-phase prun outperforms base by >2.4x @16t; end-to-end
//!   ~1.5x @16t (Text Detection is shared and dominant).
//! - Fig. 8: a 256-token sequence takes about the same time with 16
//!   threads as with 13 (flat top of the BERT scaling curve).
//! - §4.1: prun variants pay a per-invocation worker-pool creation cost
//!   (threads created, bound and destroyed per `prun` call).
//!
//! Resulting base-pipeline curve over the 500-image Fig.-3 dataset
//! (includes the base variant's batch-padding waste): 556 ms @1t,
//! 390 @4t, 461 @16t — within 7% of the paper's anchors, same shape.

use super::profile::ScalProfile;

/// Core count of the paper's testbed (OCI VM.Standard.E3.Flex).
pub const PAPER_CORES: usize = 16;

// ---------------------------------------------------------------------------
// OCR pipeline (paper §4.1)
// ---------------------------------------------------------------------------

/// Average detected-box width (px) the per-box costs are normalized to.
pub const OCR_AVG_BOX_W: f64 = 96.0;

/// Per-invocation framework dispatch cost (ms) — §2.3's overhead; paid
/// once per batched `run` in base, once per part in prun.
pub const OCR_FIXED_MS: f64 = 2.0;

/// The base pipeline batches up to this many boxes per `run` call
/// (PaddleOCR's `batch_num`, visible in the paper's Listing 2).
pub const OCR_BATCH_NUM: usize = 6;

/// Text Detection: single-thread 195 ms, mostly serial (the paper
/// attributes this to framework-inserted layout-conversion operators).
pub const DET_T1_MS: f64 = 195.0;
pub const DET_PROFILE: ScalProfile = ScalProfile::new(0.78, 1.0);

/// Text Classification per average-width box: 3.95 ms single-thread.
/// The per-invocation thread overhead (0.875 ms/extra thread) produces
/// the paper's negative scaling: per image, ~28 ms @1t -> ~40 ms @16t.
pub const CLS_T1_MS_PER_AVG_BOX: f64 = 3.95;
pub const CLS_PROFILE: ScalProfile = ScalProfile::new(0.85, 0.875);

/// Text Recognition per average-width box: 51.3 ms single-thread. The
/// heavy per-thread overhead (the paper blames inflated output-reorder
/// operators) puts the per-image optimum near 4-8 threads and makes 16
/// threads regress, matching Fig. 2's rec curve.
pub const REC_T1_MS_PER_AVG_BOX: f64 = 51.3;
pub const REC_PROFILE: ScalProfile = ScalProfile::new(0.35, 6.5);

/// Per-invocation worker-pool creation cost paid by the prun variants
/// (base reuses the session's persistent pool; prun creates, binds and
/// destroys a pool of c_i threads per part — §4.1).
pub const POOL_BASE_MS: f64 = 0.3;
pub const POOL_PER_THREAD_MS: f64 = 0.7;

/// Base-variant phase profile: framework dispatch cost only.
pub fn base_profile(p: ScalProfile) -> ScalProfile {
    p.with_pool_cost(OCR_FIXED_MS, 0.0)
}

/// Prun-variant phase profile: dispatch + per-part pool creation.
pub fn prun_profile(p: ScalProfile) -> ScalProfile {
    p.with_pool_cost(OCR_FIXED_MS + POOL_BASE_MS, POOL_PER_THREAD_MS)
}

/// Single-thread classification time for a box of `width_px`.
pub fn cls_t1_ms(width_px: usize) -> f64 {
    CLS_T1_MS_PER_AVG_BOX * width_px as f64 / OCR_AVG_BOX_W
}

/// Single-thread recognition time for a box of `width_px`.
pub fn rec_t1_ms(width_px: usize) -> f64 {
    REC_T1_MS_PER_AVG_BOX * width_px as f64 / OCR_AVG_BOX_W
}

// ---------------------------------------------------------------------------
// BERT (paper §4.2 / §4.3)
// ---------------------------------------------------------------------------

/// Transformer dimensions used by the cost model (our BERT-tiny; ratios
/// across sequence lengths — what the weights depend on — are preserved).
#[derive(Debug, Clone, Copy)]
pub struct BertDims {
    pub hidden: usize,
    pub ff: usize,
    pub layers: usize,
}

pub const BERT_DIMS: BertDims = BertDims { hidden: 128, ff: 512, layers: 2 };

/// Fixed per-inference framework cost (ms): kernel dispatch, layout
/// conversion, output assembly — §2.3's framework overhead. This is what
/// makes batching beat no-batch (Fig. 9) and bounds the benefit of
/// splitting off very short sequences (Fig. 8's decline past X≈3).
pub const BERT_FIXED_MS: f64 = 35.0;

/// Single-thread latency of the calibration point: batch 1, 256 tokens.
pub const BERT_T1_256_MS: f64 = 300.0;

/// BERT scalability: nearly no Amdahl-serial fraction but a per-thread
/// coordination cost, giving the paper's flat t(13)..t(16) top.
pub const BERT_PROFILE: ScalProfile = ScalProfile::new(0.02, 1.3);

/// Forward FLOPs (2*MACs) — mirrors `python/compile/model.py::bert_flops`.
pub fn bert_flops(batch: usize, seq: usize, d: BertDims) -> f64 {
    let (b, s, h, f) = (batch as f64, seq as f64, d.hidden as f64, d.ff as f64);
    d.layers as f64
        * (4.0 * 2.0 * b * s * h * h + 2.0 * 2.0 * b * s * s * h + 2.0 * 2.0 * b * s * h * f)
}

/// FLOP rate implied by the calibration point.
pub fn bert_rate_flops_per_ms() -> f64 {
    bert_flops(1, 256, BERT_DIMS) / (BERT_T1_256_MS - BERT_FIXED_MS)
}

/// Single-thread latency of a (batch, seq) inference.
pub fn bert_t1_ms(batch: usize, seq: usize) -> f64 {
    BERT_FIXED_MS + bert_flops(batch, seq, BERT_DIMS) / bert_rate_flops_per_ms()
}

#[cfg(test)]
mod tests {
    use super::*;

    // Qualitative anchor tests. The quantitative dataset-level anchors
    // (554/364/435 ms totals, 27->38 ms cls — which include the base
    // pipeline's *padding waste* over the Fig. 3 box-width mix) live in
    // `bench::figures::tests`, where the evaluation dataset is available.

    const AVG_BOXES: f64 = 4.3;
    /// Mean padding inflation of a base batched run over the Fig. 3
    /// width mix (boxes padded to the widest in their batch).
    const PAD_FACTOR: f64 = 1.49;

    /// Base pipeline on an average image: detection + one batched cls run
    /// + one batched rec run (4.3 boxes fit in a single batch of 6).
    fn ocr_base_total(c: usize) -> f64 {
        DET_PROFILE.time_ms(DET_T1_MS, c)
            + base_profile(CLS_PROFILE).time_ms(PAD_FACTOR * AVG_BOXES * CLS_T1_MS_PER_AVG_BOX, c)
            + base_profile(REC_PROFILE).time_ms(PAD_FACTOR * AVG_BOXES * REC_T1_MS_PER_AVG_BOX, c)
    }

    #[test]
    fn fig2_base_total_anchors() {
        // paper: 554 @1t, 364 @4t, 435 @16t (±10% at the analytic
        // average-image approximation; the dataset-level test is exact)
        let t1 = ocr_base_total(1);
        let t4 = ocr_base_total(4);
        let t16 = ocr_base_total(16);
        assert!((t1 - 554.0).abs() / 554.0 < 0.10, "t1={t1}");
        assert!((t4 - 364.0).abs() / 364.0 < 0.10, "t4={t4}");
        assert!((t16 - 435.0).abs() / 435.0 < 0.10, "t16={t16}");
        // the characteristic dip-then-rise
        assert!(t4 < t1 && t4 < t16, "t1={t1} t4={t4} t16={t16}");
    }

    #[test]
    fn fig2_cls_negative_scaling() {
        // paper: 27 ms @1t -> 38 ms @16t per image (1.4x slowdown)
        let p = base_profile(CLS_PROFILE);
        let w = PAD_FACTOR * AVG_BOXES * CLS_T1_MS_PER_AVG_BOX;
        let c1 = p.time_ms(w, 1);
        let c16 = p.time_ms(w, 16);
        assert!((c1 - 27.0).abs() / 27.0 < 0.15, "c1={c1}");
        assert!((c16 - 38.0).abs() / 38.0 < 0.15, "c16={c16}");
        assert!(c16 / c1 > 1.25, "slowdown {}", c16 / c1);
    }

    #[test]
    fn fig2_rec_optimum_mid_thread_counts() {
        let p = base_profile(REC_PROFILE);
        let t1 = AVG_BOXES * REC_T1_MS_PER_AVG_BOX;
        let best = p.optimal_threads(t1, 16);
        assert!((3..=8).contains(&best), "best={best}");
        // and regresses at 16 (paper's rec curve turns back up)
        assert!(p.time_ms(t1, 16) > 1.1 * p.time_ms(t1, best));
    }

    #[test]
    fn fig8_bert_flat_top_13_to_16() {
        let t13 = BERT_PROFILE.time_ms(BERT_T1_256_MS, 13);
        let t16 = BERT_PROFILE.time_ms(BERT_T1_256_MS, 16);
        assert!((t13 - t16).abs() / t16 < 0.02, "t13={t13} t16={t16}");
    }

    #[test]
    fn bert_t1_calibration_point() {
        assert!((bert_t1_ms(1, 256) - BERT_T1_256_MS).abs() < 1e-9);
        // FLOPs scale linearly in batch
        let f1 = bert_flops(1, 128, BERT_DIMS);
        let f4 = bert_flops(4, 128, BERT_DIMS);
        assert!((f4 / f1 - 4.0).abs() < 1e-12);
    }

    #[test]
    fn bert_fixed_cost_makes_batching_pay() {
        // Fig. 9 precondition: batch(k) cheaper than k x no-batch.
        let batch = BERT_PROFILE.time_ms(bert_t1_ms(4, 128), 16);
        let nobatch = 4.0 * BERT_PROFILE.time_ms(bert_t1_ms(1, 128), 16);
        assert!(batch < nobatch, "batch={batch} nobatch={nobatch}");
    }

    #[test]
    fn ocr_per_box_costs_scale_with_width() {
        assert!((rec_t1_ms(96) - REC_T1_MS_PER_AVG_BOX).abs() < 1e-9);
        assert!((rec_t1_ms(192) / rec_t1_ms(96) - 2.0).abs() < 1e-9);
        assert!(cls_t1_ms(48) < cls_t1_ms(96));
    }

    #[test]
    fn prun_profile_adds_pool_cost() {
        let base = base_profile(REC_PROFILE).time_ms(75.0, 4);
        let prun = prun_profile(REC_PROFILE).time_ms(75.0, 4);
        let expect = POOL_BASE_MS + 4.0 * POOL_PER_THREAD_MS;
        assert!((prun - base - expect).abs() < 1e-9);
    }
}

//! Extended-Amdahl scalability profiles.
//!
//! The paper's §2 catalogue of why inference doesn't scale (non-scalable
//! operators, framework overhead, per-invocation pool setup) maps onto a
//! three-term cost model for a job of single-thread time `t1` run with
//! `c` threads:
//!
//! ```text
//! t(c) = t1 * (serial + (1-serial)/c)   // Amdahl split
//!      + ovh_per_thread * (c-1)         // coordination cost per extra thread
//!      + pool_base + pool_per_thread*c  // per-invocation pool setup (§4.1)
//! ```
//!
//! `ovh_per_thread` is what produces the paper's *negative scaling*
//! (Text Classification: 27 ms @1t -> 38 ms @16t) and the rec-phase
//! regression beyond 4 threads.

/// Scalability profile of one model/phase.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ScalProfile {
    /// Amdahl serial fraction in [0, 1].
    pub serial: f64,
    /// Per-extra-thread coordination cost (ms).
    pub ovh_ms_per_thread: f64,
    /// Fixed thread-pool creation cost per invocation (ms).
    pub pool_base_ms: f64,
    /// Pool creation cost per pool thread (ms).
    pub pool_per_thread_ms: f64,
}

impl ScalProfile {
    pub const fn new(serial: f64, ovh_ms_per_thread: f64) -> ScalProfile {
        ScalProfile { serial, ovh_ms_per_thread, pool_base_ms: 0.0, pool_per_thread_ms: 0.0 }
    }

    pub const fn with_pool_cost(mut self, base_ms: f64, per_thread_ms: f64) -> ScalProfile {
        self.pool_base_ms = base_ms;
        self.pool_per_thread_ms = per_thread_ms;
        self
    }

    /// Execution time of a `t1_ms` single-thread job on `c` threads.
    pub fn time_ms(&self, t1_ms: f64, c: usize) -> f64 {
        assert!(c >= 1, "thread count must be >= 1");
        debug_assert!((0.0..=1.0).contains(&self.serial));
        let c_f = c as f64;
        t1_ms * (self.serial + (1.0 - self.serial) / c_f)
            + self.ovh_ms_per_thread * (c_f - 1.0)
            + self.pool_base_ms
            + self.pool_per_thread_ms * c_f
    }

    /// [`time_ms`](Self::time_ms) on cores of relative speed `speed`
    /// (1.0 = the baseline class). The whole three-term cost divides by
    /// the speed: on a half-speed core the compute, the coordination
    /// *and* the pool setup all take twice the wall-clock — which is
    /// what makes class-blind placement invert latency on mixed
    /// fast/slow machines (`engine::ledger`).
    pub fn time_ms_at(&self, t1_ms: f64, c: usize, speed: f64) -> f64 {
        assert!(speed > 0.0, "relative core speed must be positive");
        self.time_ms(t1_ms, c) / speed
    }

    /// Speedup over 1 thread (can be < 1: negative scaling).
    pub fn speedup(&self, t1_ms: f64, c: usize) -> f64 {
        self.time_ms(t1_ms, 1) / self.time_ms(t1_ms, c)
    }

    /// Thread count minimizing `time_ms` over 1..=max (the paper's "best
    /// performance at 4 threads" style observation).
    pub fn optimal_threads(&self, t1_ms: f64, max: usize) -> usize {
        (1..=max)
            .min_by(|&a, &b| {
                self.time_ms(t1_ms, a)
                    .partial_cmp(&self.time_ms(t1_ms, b))
                    .unwrap()
            })
            .unwrap_or(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn one_thread_is_t1_plus_pool() {
        let p = ScalProfile::new(0.3, 1.0);
        assert!((p.time_ms(100.0, 1) - 100.0).abs() < 1e-9);
        let q = p.with_pool_cost(2.0, 0.5);
        assert!((q.time_ms(100.0, 1) - 102.5).abs() < 1e-9);
    }

    #[test]
    fn fully_parallel_scales_linearly() {
        let p = ScalProfile::new(0.0, 0.0);
        assert!((p.time_ms(160.0, 16) - 10.0).abs() < 1e-9);
        assert!((p.speedup(160.0, 16) - 16.0).abs() < 1e-9);
    }

    #[test]
    fn amdahl_ceiling() {
        let p = ScalProfile::new(0.5, 0.0);
        // speedup bounded by 1/serial = 2
        assert!(p.speedup(100.0, 1024) < 2.0);
        assert!(p.speedup(100.0, 1024) > 1.9);
    }

    #[test]
    fn negative_scaling_with_overhead() {
        // Mimics paper Text Classification: more threads -> slower.
        let p = ScalProfile::new(0.6, 0.9);
        let t1 = p.time_ms(27.0, 1);
        let t16 = p.time_ms(27.0, 16);
        assert!(t16 > t1, "t16={t16} t1={t1}");
        // the optimum sits at a very low thread count, far below 16
        assert!(p.optimal_threads(27.0, 16) <= 3);
    }

    #[test]
    fn sweet_spot_in_the_middle() {
        // Mimics paper Text Recognition: fastest around 4 threads.
        let p = ScalProfile::new(0.25, 2.5);
        let best = p.optimal_threads(80.0, 16);
        assert!((3..=6).contains(&best), "best={best}");
        assert!(p.time_ms(80.0, 16) > p.time_ms(80.0, best));
        assert!(p.time_ms(80.0, 1) > p.time_ms(80.0, best));
    }

    #[test]
    fn time_monotone_in_t1() {
        let p = ScalProfile::new(0.2, 1.0);
        assert!(p.time_ms(200.0, 8) > p.time_ms(100.0, 8));
    }

    #[test]
    fn slow_cores_stretch_the_whole_cost() {
        let p = ScalProfile::new(0.3, 1.0).with_pool_cost(2.0, 0.5);
        let fast = p.time_ms_at(100.0, 4, 1.0);
        assert!((fast - p.time_ms(100.0, 4)).abs() < 1e-12, "speed 1.0 is the identity");
        let slow = p.time_ms_at(100.0, 4, 0.5);
        assert!((slow - 2.0 * fast).abs() < 1e-9, "half speed doubles wall-clock");
    }
}

//! Virtual-time OCR pipeline (paper §4.1, Figures 2, 4, 5).
//!
//! An image is summarized by its detected-box widths; the three phases
//! compose sequentially (detection -> classification -> recognition,
//! Fig. 1). The cls/rec phases run either as the unmodified pipeline
//! (`base`: boxes processed in padded batches of `OCR_BATCH_NUM`, each
//! batch a `run` with all cores — the paper's Listing 2) or via `prun`
//! (one part per box at exact width, threads from the allocator).

use crate::engine::allocator::{allocate, AllocPolicy, PartWeights};
use crate::engine::ledger::CoreMap;

use super::calib;
use super::des::{simulate, simulate_sequential, SimPart};
use super::profile::ScalProfile;

/// Pipeline variant under evaluation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OcrVariant {
    Base,
    Prun(AllocPolicy),
}

impl OcrVariant {
    pub fn name(&self) -> &'static str {
        match self {
            OcrVariant::Base => "base",
            OcrVariant::Prun(p) => p.name(),
        }
    }

    pub fn all() -> [OcrVariant; 4] {
        [
            OcrVariant::Base,
            OcrVariant::Prun(AllocPolicy::PrunDef),
            OcrVariant::Prun(AllocPolicy::PrunOne),
            OcrVariant::Prun(AllocPolicy::PrunEq),
        ]
    }
}

/// Per-phase virtual latency of one image.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OcrBreakdown {
    pub det_ms: f64,
    pub cls_ms: f64,
    pub rec_ms: f64,
}

impl OcrBreakdown {
    pub fn total_ms(&self) -> f64 {
        self.det_ms + self.cls_ms + self.rec_ms
    }
}

/// One phase over all boxes.
///
/// base: boxes grouped into padded batches of `OCR_BATCH_NUM` (every box
/// padded to the batch max width — the padding waste prun eliminates),
/// batches run sequentially with all cores.
///
/// prun: one part per box, exact width, allocator-assigned threads,
/// co-scheduled by the DES with per-part pool-creation cost.
fn phase_ms(
    t1_per_px: impl Fn(usize) -> f64,
    profile: ScalProfile,
    widths: &[usize],
    variant: OcrVariant,
    cores: usize,
) -> f64 {
    match variant {
        OcrVariant::Base => {
            let prof = calib::base_profile(profile);
            let parts: Vec<SimPart> = widths
                .chunks(calib::OCR_BATCH_NUM)
                .map(|chunk| {
                    let max_w = *chunk.iter().max().unwrap();
                    // every box padded to the widest in its batch
                    SimPart::new(t1_per_px(max_w) * chunk.len() as f64, prof)
                })
                .collect();
            simulate_sequential(&parts, cores).makespan_ms
        }
        OcrVariant::Prun(policy) => {
            let prof = calib::prun_profile(profile);
            let allocation =
                allocate(PartWeights::Sizes(widths), &CoreMap::homogeneous(cores), policy)
                    .into_threads();
            let parts: Vec<SimPart> =
                widths.iter().map(|&w| SimPart::new(t1_per_px(w), prof)).collect();
            simulate(&parts, &allocation, cores).makespan_ms
        }
    }
}

/// Like [`sim_image`] but with reusable worker pools: the paper's §4.1
/// future-work idea ("reusing thread pools between prun invocations")
/// modeled as prun paying no per-part pool-creation cost. Ablated in
/// `benches/ablation_pool_reuse.rs`.
pub fn sim_image_pool_reuse(
    box_widths: &[usize],
    variant: OcrVariant,
    cores: usize,
) -> OcrBreakdown {
    let det_ms = calib::DET_PROFILE.time_ms(calib::DET_T1_MS, cores);
    if box_widths.is_empty() {
        return OcrBreakdown { det_ms, cls_ms: 0.0, rec_ms: 0.0 };
    }
    let phase = |t1_per_px: fn(usize) -> f64, profile: ScalProfile| match variant {
        OcrVariant::Base => phase_ms(t1_per_px, profile, box_widths, variant, cores),
        OcrVariant::Prun(policy) => {
            // prun path with base-style (dispatch-only) profile: pools
            // are warm, creation cost gone.
            let prof = calib::base_profile(profile);
            let allocation =
                allocate(PartWeights::Sizes(box_widths), &CoreMap::homogeneous(cores), policy)
                    .into_threads();
            let parts: Vec<SimPart> = box_widths
                .iter()
                .map(|&w| SimPart::new(t1_per_px(w), prof))
                .collect();
            simulate(&parts, &allocation, cores).makespan_ms
        }
    };
    OcrBreakdown {
        det_ms,
        cls_ms: phase(calib::cls_t1_ms, calib::CLS_PROFILE),
        rec_ms: phase(calib::rec_t1_ms, calib::REC_PROFILE),
    }
}

/// Simulate one image whose detected boxes have the given pixel widths.
pub fn sim_image(box_widths: &[usize], variant: OcrVariant, cores: usize) -> OcrBreakdown {
    // Phase 1: detection — one job over the whole image, all cores, in
    // every variant (the paper applies prun only to phases 2 and 3).
    let det_ms = calib::DET_PROFILE.time_ms(calib::DET_T1_MS, cores);

    if box_widths.is_empty() {
        return OcrBreakdown { det_ms, cls_ms: 0.0, rec_ms: 0.0 };
    }

    let cls_ms = phase_ms(calib::cls_t1_ms, calib::CLS_PROFILE, box_widths, variant, cores);
    let rec_ms = phase_ms(calib::rec_t1_ms, calib::REC_PROFILE, box_widths, variant, cores);

    OcrBreakdown { det_ms, cls_ms, rec_ms }
}

/// Mean breakdown over a dataset of images (vec of box-width vectors).
pub fn sim_dataset(images: &[Vec<usize>], variant: OcrVariant, cores: usize) -> OcrBreakdown {
    assert!(!images.is_empty());
    let mut acc = OcrBreakdown { det_ms: 0.0, cls_ms: 0.0, rec_ms: 0.0 };
    for widths in images {
        let b = sim_image(widths, variant, cores);
        acc.det_ms += b.det_ms;
        acc.cls_ms += b.cls_ms;
        acc.rec_ms += b.rec_ms;
    }
    let n = images.len() as f64;
    OcrBreakdown { det_ms: acc.det_ms / n, cls_ms: acc.cls_ms / n, rec_ms: acc.rec_ms / n }
}

#[cfg(test)]
mod tests {
    use super::*;

    const C: usize = calib::PAPER_CORES;

    fn avg_image() -> Vec<usize> {
        vec![96; 4] // four average boxes
    }

    #[test]
    fn base_breakdown_sums() {
        let b = sim_image(&avg_image(), OcrVariant::Base, C);
        assert!((b.total_ms() - (b.det_ms + b.cls_ms + b.rec_ms)).abs() < 1e-12);
        assert!(b.rec_ms > b.cls_ms, "recognition dominates");
    }

    #[test]
    fn fig5_prun_beats_base_at_16_cores() {
        let widths = avg_image();
        let base = sim_image(&widths, OcrVariant::Base, C);
        let prun = sim_image(&widths, OcrVariant::Prun(AllocPolicy::PrunDef), C);
        assert!(prun.rec_ms < base.rec_ms / 2.0, "rec speedup >2x (paper: 2.4x): base {} prun {}", base.rec_ms, prun.rec_ms);
        assert!(prun.total_ms() < base.total_ms());
        // end-to-end speedup is capped by the shared detection phase
        let speedup = base.total_ms() / prun.total_ms();
        assert!((1.2..2.6).contains(&speedup), "total speedup {speedup} (paper: 1.5x)");
    }

    #[test]
    fn fig4_benefit_grows_with_box_count() {
        let speedup = |n: usize| {
            let widths = vec![96usize; n];
            let base = sim_image(&widths, OcrVariant::Base, C).total_ms();
            let prun = sim_image(&widths, OcrVariant::Prun(AllocPolicy::PrunDef), C).total_ms();
            base / prun
        };
        assert!(speedup(2) > 1.05, "some win at 2 boxes: {}", speedup(2));
        assert!(speedup(9) > speedup(2), "win grows with boxes: {} vs {}", speedup(9), speedup(2));
    }

    #[test]
    fn fig4a_prun_one_wins_cls_at_small_box_counts() {
        // paper: prun-1 produces the lowest cls latency at small counts
        // (negative scaling + cheapest pools); variants converge at 9+.
        let widths = vec![96usize; 2];
        let base = sim_image(&widths, OcrVariant::Base, C).cls_ms;
        let p1 = sim_image(&widths, OcrVariant::Prun(AllocPolicy::PrunOne), C).cls_ms;
        let pdef = sim_image(&widths, OcrVariant::Prun(AllocPolicy::PrunDef), C).cls_ms;
        let peq = sim_image(&widths, OcrVariant::Prun(AllocPolicy::PrunEq), C).cls_ms;
        assert!(p1 < base && p1 < pdef && p1 < peq, "prun-1 lowest: {p1} {base} {pdef} {peq}");

        // convergence: at 9 boxes prun-def within 20% of prun-1
        let many = vec![96usize; 9];
        let p1m = sim_image(&many, OcrVariant::Prun(AllocPolicy::PrunOne), C).cls_ms;
        let pdm = sim_image(&many, OcrVariant::Prun(AllocPolicy::PrunDef), C).cls_ms;
        assert!((pdm - p1m).abs() / p1m < 0.35, "converged: {pdm} vs {p1m}");
    }

    #[test]
    fn no_boxes_only_detection() {
        let b = sim_image(&[], OcrVariant::Prun(AllocPolicy::PrunDef), C);
        assert_eq!(b.cls_ms, 0.0);
        assert_eq!(b.rec_ms, 0.0);
        assert!(b.det_ms > 0.0);
    }

    #[test]
    fn single_box_prun_close_to_base() {
        // with one box, prun-def uses all cores like base; only the pool
        // creation differs (paper: prun adds no overhead in this case).
        let widths = vec![96usize];
        let base = sim_image(&widths, OcrVariant::Base, C).total_ms();
        let prun = sim_image(&widths, OcrVariant::Prun(AllocPolicy::PrunDef), C).total_ms();
        // the only delta is two per-part pool creations (~23 ms on ~340 ms)
        assert!((prun - base) / base < 0.08, "base {base} prun {prun}");
    }

    #[test]
    fn base_pays_padding_waste_on_mixed_widths() {
        // same total pixels, but the wide box forces padding of the rest
        let mixed = vec![48usize, 48, 48, 192];
        let uniform = vec![84usize; 4];
        let b_mixed = sim_image(&mixed, OcrVariant::Base, C).rec_ms;
        let b_uniform = sim_image(&uniform, OcrVariant::Base, C).rec_ms;
        assert!(b_mixed > b_uniform * 1.3, "padding waste: {b_mixed} vs {b_uniform}");
    }

    #[test]
    fn base_batches_of_six() {
        // 7 boxes -> 2 sequential batched runs; 6 -> 1
        let six = sim_image(&vec![96; 6], OcrVariant::Base, C).rec_ms;
        let seven = sim_image(&vec![96; 7], OcrVariant::Base, C).rec_ms;
        assert!(seven > six * 1.1, "second batch adds a run: {seven} vs {six}");
    }

    #[test]
    fn dataset_mean() {
        let imgs = vec![vec![96; 2], vec![96; 6]];
        let mean = sim_dataset(&imgs, OcrVariant::Base, C);
        let a = sim_image(&imgs[0], OcrVariant::Base, C);
        let b = sim_image(&imgs[1], OcrVariant::Base, C);
        assert!((mean.total_ms() - (a.total_ms() + b.total_ms()) / 2.0).abs() < 1e-9);
    }

    #[test]
    fn pool_reuse_strictly_helps_prun() {
        // future-work ablation: warm pools remove the per-part creation
        // cost, so prun with reuse is never slower.
        for n in [1usize, 2, 4, 9] {
            let widths = vec![96usize; n];
            let v = OcrVariant::Prun(AllocPolicy::PrunDef);
            let cold = sim_image(&widths, v, C).total_ms();
            let warm = sim_image_pool_reuse(&widths, v, C).total_ms();
            assert!(warm < cold, "n={n}: warm {warm} !< cold {cold}");
            // base is unaffected by pool reuse
            let b1 = sim_image(&widths, OcrVariant::Base, C).total_ms();
            let b2 = sim_image_pool_reuse(&widths, OcrVariant::Base, C).total_ms();
            assert!((b1 - b2).abs() < 1e-9);
        }
    }

    #[test]
    fn variant_names() {
        assert_eq!(OcrVariant::Base.name(), "base");
        assert_eq!(OcrVariant::Prun(AllocPolicy::PrunDef).name(), "prun-def");
        assert_eq!(OcrVariant::all().len(), 4);
    }
}

//! Virtual-time BERT serving strategies (paper §4.2/§4.3, Figures 6-9).
//!
//! Three ways to serve a batch of sequences with lengths `lens` on a
//! C-core machine, all returning virtual milliseconds:
//!
//! - `sim_pad_batch`: pad everything to the longest length, run one
//!   batched inference with all cores (the paper's `pad-batch`).
//! - `sim_no_batch`: run each sequence alone, one after another, with all
//!   cores (the paper's `no-batch`).
//! - `sim_prun`: the paper's contribution — one part per sequence at its
//!   *exact* length, threads allocated by `engine::allocator`, parts
//!   co-scheduled by the DES.

use crate::engine::allocator::{allocate, AllocPolicy, PartWeights};
use crate::engine::ledger::CoreMap;

use super::calib;
use super::des::{simulate, simulate_sequential, SimPart, SimReport};

fn bert_part(batch: usize, seq: usize) -> SimPart {
    SimPart::new(calib::bert_t1_ms(batch, seq), calib::BERT_PROFILE)
}

/// Pad-batch latency: one inference of batch=k at the max length.
pub fn sim_pad_batch(lens: &[usize], cores: usize) -> f64 {
    assert!(!lens.is_empty());
    let max_len = *lens.iter().max().unwrap();
    let part = bert_part(lens.len(), max_len);
    simulate(&[part], &[cores], cores).makespan_ms
}

/// No-batch latency: sequential single-sequence inferences.
pub fn sim_no_batch(lens: &[usize], cores: usize) -> f64 {
    let parts: Vec<SimPart> = lens.iter().map(|&l| bert_part(1, l)).collect();
    simulate_sequential(&parts, cores).makespan_ms
}

/// prun outcome: full DES report plus the allocation (Fig. 8 plots the
/// threads given to the long sequence).
pub fn sim_prun_report(lens: &[usize], cores: usize, policy: AllocPolicy) -> (SimReport, Vec<usize>) {
    let sizes: Vec<usize> = lens.to_vec(); // weight proxy = token count
    let allocation =
        allocate(PartWeights::Sizes(&sizes), &CoreMap::homogeneous(cores), policy)
            .into_threads();
    let parts: Vec<SimPart> = lens.iter().map(|&l| bert_part(1, l)).collect();
    let report = simulate(&parts, &allocation, cores);
    (report, allocation)
}

/// prun latency (makespan).
pub fn sim_prun(lens: &[usize], cores: usize, policy: AllocPolicy) -> f64 {
    sim_prun_report(lens, cores, policy).0.makespan_ms
}

/// Throughput in sequences/second given a batch latency in ms.
pub fn seqs_per_sec(n_seqs: usize, latency_ms: f64) -> f64 {
    n_seqs as f64 * 1000.0 / latency_ms
}

#[cfg(test)]
mod tests {
    use super::*;

    const C: usize = calib::PAPER_CORES;

    #[test]
    fn prun_beats_pad_batch_on_heterogeneous_lengths() {
        // Fig. 7's preset mixes: padding waste makes pad-batch lose.
        for mix in [&[16usize, 64, 256][..], &[16, 16, 512], &[32, 128, 384, 384]] {
            let pad = sim_pad_batch(mix, C);
            let prun = sim_prun(mix, C, AllocPolicy::PrunDef);
            assert!(prun < pad, "mix {mix:?}: prun {prun} !< pad {pad}");
        }
    }

    #[test]
    fn prun_overhead_negligible_for_single_chunk() {
        // Fig. 8 at X=0: both variants use all cores on the one sequence.
        let pad = sim_pad_batch(&[256], C);
        let prun = sim_prun(&[256], C, AllocPolicy::PrunDef);
        assert!((pad - prun).abs() / pad < 0.01, "pad={pad} prun={prun}");
    }

    #[test]
    fn batching_beats_no_batch_on_equal_lengths() {
        // Fig. 9's sanity baseline.
        for len in [64usize, 128, 256, 512] {
            let lens = vec![len; 4];
            assert!(sim_pad_batch(&lens, C) < sim_no_batch(&lens, C), "len={len}");
        }
    }

    #[test]
    fn prun_beats_batch_even_on_homogeneous_lengths() {
        // Fig. 9's headline: fewer cores per sequence => less non-scalable
        // overhead, so prun wins modestly even with no padding waste.
        for len in [64usize, 128, 256, 512] {
            let lens = vec![len; 4];
            let batch = sim_pad_batch(&lens, C);
            let prun = sim_prun(&lens, C, AllocPolicy::PrunDef);
            assert!(prun < batch, "len={len}: prun {prun} !< batch {batch}");
            // "modest": not the multi-x win of the heterogeneous case
            assert!(batch / prun < 3.0, "len={len}: implausibly large win {}", batch / prun);
        }
    }

    #[test]
    fn fig8_long_sequence_thread_curve_monotone() {
        // 1 long + X shorts: threads for the long sequence decrease in X.
        let mut prev = usize::MAX;
        for x in 0..=15 {
            let mut lens = vec![256usize];
            lens.extend(std::iter::repeat(16).take(x));
            let (_, alloc) = sim_prun_report(&lens, C, AllocPolicy::PrunDef);
            assert!(alloc[0] <= prev, "x={x}");
            prev = alloc[0];
        }
        assert!(prev < C, "long sequence should have shed threads");
    }

    #[test]
    fn fig8_throughput_rises_then_falls() {
        // seq/s climbs steeply to X≈3 (shorts are nearly free), then the
        // long sequence loses threads / shorts start queueing.
        let tp = |x: usize| {
            let mut lens = vec![256usize];
            lens.extend(std::iter::repeat(16).take(x));
            seqs_per_sec(lens.len(), sim_prun(&lens, C, AllocPolicy::PrunDef))
        };
        assert!(tp(3) > 2.0 * tp(0), "dramatic initial growth");
        // prun stays above pad-batch throughout (paper's key claim)
        for x in 0..=15 {
            let mut lens = vec![256usize];
            lens.extend(std::iter::repeat(16).take(x));
            let pad = seqs_per_sec(lens.len(), sim_pad_batch(&lens, C));
            let prun = seqs_per_sec(lens.len(), sim_prun(&lens, C, AllocPolicy::PrunDef));
            assert!(prun >= pad * 0.99, "x={x}: prun {prun} < pad {pad}");
        }
    }

    #[test]
    fn throughput_helper() {
        assert!((seqs_per_sec(4, 500.0) - 8.0).abs() < 1e-12);
    }
}

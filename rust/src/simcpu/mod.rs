//! `simcpu`: a discrete-event simulator of `prun` on a multi-core CPU.
//!
//! The paper's evaluation ran on a 16-core OCI VM; this repository's CI
//! machine has a single core, so real intra-op scaling is physically
//! unmeasurable here. `simcpu` substitutes a calibrated virtual-time
//! model (DESIGN.md §4/§5):
//!
//! - [`profile`] — extended-Amdahl per-phase scalability curves;
//! - [`calib`] — constants fitted to the paper's measured anchors, with
//!   anchor tests that fail if calibration drifts;
//! - [`des`] — FIFO-admission discrete-event execution of allocated parts;
//! - [`bert`] / [`ocr`] — the paper's two workload families composed on
//!   top, sharing the *production* allocator in `engine::allocator`.
//!
//! The policy code under test (allocation, admission ordering) is the
//! same code the real PJRT path runs; only the clock is virtual.

pub mod bert;
pub mod calib;
pub mod des;
pub mod ocr;
pub mod profile;

pub use des::{simulate, simulate_sequential, SimPart, SimReport};
pub use profile::ScalProfile;

//! Discrete-event simulation of `prun` on a C-core machine.
//!
//! Each job part has a single-thread cost `t1_ms` and a scalability
//! profile; the allocator has already assigned it `c_i` threads. Parts
//! are admitted FIFO in input order: a part starts when `c_i` cores are
//! free (strict FIFO — `engine::sched` with backfill disabled, matching
//! the paper's setup), runs for `profile.time_ms(t1, c_i)`
//! of virtual time, then releases its cores — reproducing the paper's
//! oversubscription behaviour ("some job parts will be run after other
//! job parts have finished", §3.1) without wall-clock measurement noise.

use super::profile::ScalProfile;

#[derive(Debug, Clone)]
pub struct SimPart {
    pub t1_ms: f64,
    pub profile: ScalProfile,
}

impl SimPart {
    pub fn new(t1_ms: f64, profile: ScalProfile) -> SimPart {
        SimPart { t1_ms, profile }
    }
}

#[derive(Debug, Clone)]
pub struct SimReport {
    /// Virtual time when each part started (ms from prun entry).
    pub start_ms: Vec<f64>,
    /// Virtual time when each part finished.
    pub end_ms: Vec<f64>,
    /// Total virtual time of the prun call (max end).
    pub makespan_ms: f64,
    /// Threads each part ran with (post-clamping to C).
    pub threads: Vec<usize>,
}

/// Simulate `parts` with the given per-part thread `allocation` on a
/// `cores`-core machine. Allocation entries are clamped to `cores`
/// (a single part may ask for the whole machine, as `run` does).
pub fn simulate(parts: &[SimPart], allocation: &[usize], cores: usize) -> SimReport {
    assert_eq!(parts.len(), allocation.len());
    assert!(cores >= 1);
    let k = parts.len();
    let threads: Vec<usize> = allocation.iter().map(|&c| c.clamp(1, cores)).collect();

    let mut start_ms = vec![0.0f64; k];
    let mut end_ms = vec![0.0f64; k];

    // Running set: (end_time, cores_held). Strict FIFO admission.
    let mut running: Vec<(f64, usize)> = Vec::new();
    let mut free = cores;
    let mut now = 0.0f64;
    let mut next = 0usize; // next part to admit

    while next < k || !running.is_empty() {
        // Admit as many queued parts (in order) as fit right now.
        while next < k && threads[next] <= free {
            let c = threads[next];
            let dur = parts[next].profile.time_ms(parts[next].t1_ms, c);
            assert!(dur.is_finite() && dur >= 0.0);
            start_ms[next] = now;
            end_ms[next] = now + dur;
            running.push((now + dur, c));
            free -= c;
            next += 1;
        }
        if running.is_empty() {
            // Can't happen while next < k because threads are clamped to
            // cores and free == cores when nothing runs.
            break;
        }
        // Advance to the earliest completion.
        let (idx, &(t_end, c)) = running
            .iter()
            .enumerate()
            .min_by(|a, b| a.1 .0.partial_cmp(&b.1 .0).unwrap())
            .unwrap();
        now = t_end;
        free += c;
        running.swap_remove(idx);
    }

    let makespan_ms = end_ms.iter().cloned().fold(0.0, f64::max);
    SimReport { start_ms, end_ms, makespan_ms, threads }
}

/// Simulate the *base* (no-prun) variant: parts run one after another,
/// each with all `cores` threads — what the unmodified pipeline does when
/// it loops over text boxes calling `run` (paper §4.1).
pub fn simulate_sequential(parts: &[SimPart], cores: usize) -> SimReport {
    let allocation = vec![cores; parts.len()];
    simulate(parts, &allocation, cores)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn flat(t1: f64) -> SimPart {
        SimPart::new(t1, ScalProfile::new(0.0, 0.0))
    }

    #[test]
    fn single_part_uses_profile_time() {
        let r = simulate(&[flat(100.0)], &[4], 16);
        assert!((r.makespan_ms - 25.0).abs() < 1e-9);
        assert_eq!(r.threads, vec![4]);
    }

    #[test]
    fn parallel_parts_overlap() {
        // two parts, 8 cores each on a 16-core machine: fully parallel
        let r = simulate(&[flat(80.0), flat(80.0)], &[8, 8], 16);
        assert!((r.makespan_ms - 10.0).abs() < 1e-9);
        assert_eq!(r.start_ms, vec![0.0, 0.0]);
    }

    #[test]
    fn oversubscription_queues_fifo() {
        // three parts x 8 cores on 16: third waits for the first to end
        let r = simulate(&[flat(80.0), flat(160.0), flat(80.0)], &[8, 8, 8], 16);
        assert_eq!(r.start_ms[2], r.end_ms[0]);
        assert!((r.end_ms[2] - (10.0 + 10.0)).abs() < 1e-9);
        assert!((r.makespan_ms - 20.0).abs() < 1e-9);
    }

    #[test]
    fn fifo_head_blocks_smaller_followers() {
        // part1 wants 16 cores and is behind part0 (8 cores); part2 (1
        // core) queues behind part1 — strict FIFO, as the no-backfill sched behaves.
        let r = simulate(&[flat(80.0), flat(16.0), flat(1.0)], &[8, 16, 1], 16);
        assert_eq!(r.start_ms[1], r.end_ms[0]);
        assert_eq!(r.start_ms[2], r.end_ms[1]);
    }

    #[test]
    fn sequential_equals_sum() {
        let parts = vec![flat(60.0), flat(40.0), flat(20.0)];
        let r = simulate_sequential(&parts, 4);
        // each runs alone on 4 cores: 15 + 10 + 5
        assert!((r.makespan_ms - 30.0).abs() < 1e-9);
        assert_eq!(r.start_ms[1], r.end_ms[0]);
    }

    #[test]
    fn allocation_clamped_to_machine() {
        let r = simulate(&[flat(100.0)], &[64], 16);
        assert_eq!(r.threads, vec![16]);
        assert!((r.makespan_ms - 100.0 / 16.0).abs() < 1e-9);
    }

    #[test]
    fn empty_parts() {
        let r = simulate(&[], &[], 16);
        assert_eq!(r.makespan_ms, 0.0);
    }

    #[test]
    fn negative_scaling_profile_in_sim() {
        // prun-1 beats all-cores when the profile scales negatively.
        let bad = ScalProfile::new(0.6, 0.9);
        let parts: Vec<SimPart> = (0..4).map(|_| SimPart::new(27.0, bad)).collect();
        let seq = simulate_sequential(&parts, 16); // base: 4x t(16)
        let one = simulate(&parts, &[1, 1, 1, 1], 16); // prun-1: parallel t(1)
        assert!(one.makespan_ms < seq.makespan_ms);
    }
}

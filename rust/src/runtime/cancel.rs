//! Cooperative task cancellation.
//!
//! A [`CancelToken`] is a cheap, clonable flag shared between whoever
//! submitted a task (the serving edge: a timed-out router request, a
//! dropped `PrunHandle`), the scheduler that queues it, and the executor
//! that runs it. Setting the flag never interrupts anything by force —
//! each layer polls it at its own safe points:
//!
//! - the scheduler's dispatcher removes cancelled tasks from the queue
//!   before they ever take ledger cores;
//! - an executor worker checks the token when it dequeues a job and
//!   skips execution entirely if it is already cancelled;
//! - the engine polls between its expensive steps (after JIT compile,
//!   before the model run), so a task cancelled mid-pipeline stops at
//!   the next seam instead of running to completion.
//!
//! Executors that skip or abort a cancelled task report it with the
//! typed [`TaskCancelled`] error, which the scheduler maps to its own
//! `SchedError::Cancelled` while releasing the task's cores — the
//! accounting that keeps an abandoned request from burning the budget
//! the paper's Listing 1 divides.

use std::fmt;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// Shared cancellation flag. Cloning shares the flag; cancelling is
/// idempotent and can never be undone.
#[derive(Clone, Debug, Default)]
pub struct CancelToken {
    flag: Arc<AtomicBool>,
}

impl CancelToken {
    pub fn new() -> CancelToken {
        CancelToken::default()
    }

    /// Request cancellation. Safe to call from any thread, any number
    /// of times; observers see it at their next poll.
    pub fn cancel(&self) {
        self.flag.store(true, Ordering::SeqCst);
    }

    pub fn is_cancelled(&self) -> bool {
        self.flag.load(Ordering::SeqCst)
    }

    /// True if `other` is a clone of this token (they share one flag).
    /// This is *identity*, not state equality — the ctx-propagation
    /// tests use it to prove every layer observes the token minted at
    /// the serving edge rather than a lookalike.
    pub fn same_flag(&self, other: &CancelToken) -> bool {
        Arc::ptr_eq(&self.flag, &other.flag)
    }
}

/// Typed error an executor returns when it skipped or aborted a task
/// because its [`CancelToken`] was set. The scheduler downcasts to this
/// to count the task as cancelled (not failed) and to surface
/// `SchedError::Cancelled` through the submit handle.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TaskCancelled;

impl fmt::Display for TaskCancelled {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "task cancelled")
    }
}

impl std::error::Error for TaskCancelled {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clones_share_the_flag() {
        let a = CancelToken::new();
        let b = a.clone();
        assert!(!a.is_cancelled());
        b.cancel();
        assert!(a.is_cancelled());
        a.cancel(); // idempotent
        assert!(b.is_cancelled());
    }

    #[test]
    fn fresh_tokens_are_independent() {
        let a = CancelToken::new();
        let b = CancelToken::new();
        a.cancel();
        assert!(!b.is_cancelled());
    }

    #[test]
    fn task_cancelled_is_a_typed_error() {
        let e = anyhow::Error::new(TaskCancelled);
        assert!(e.downcast_ref::<TaskCancelled>().is_some());
        assert_eq!(e.to_string(), "task cancelled");
    }
}

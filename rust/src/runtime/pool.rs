//! Executor pool: N persistent worker threads, each owning a private
//! `LocalEngine` (PJRT client + executable cache + device weights).
//!
//! This is the runtime's unit of *real* parallelism. `PjRtClient` is not
//! `Send`, so instead of sharing one client we give each worker its own —
//! the same topology OnnxRuntime uses for inter-op worker threads.
//!
//! Queueing: every worker owns a **private channel** (no shared queue),
//! so a caller can target a specific worker. `engine::sched` uses this to
//! place admitted tasks on the least-loaded worker, and `warmup` uses it
//! to pre-compile models on *every* worker exactly once (the old shared
//! queue could only approximate all-workers coverage probabilistically).
//! Untargeted `submit`/`run` round-robin across workers.
//!
//! Completion is callback-based: a job carries a [`ReplyFn`] invoked on
//! the worker thread when execution finishes. Channel-style use (the
//! `submit`/`run` API) wraps a channel sender in that callback; the
//! scheduler instead forwards completions into its own event loop, which
//! is what lets it release cores without a watcher thread per task.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{Context, Result};

use super::cancel::{CancelToken, TaskCancelled};
use super::local::LocalEngine;
use super::manifest::Manifest;
use super::tensor::Tensor;
use crate::util::clock;

/// Completion callback, invoked exactly once on the worker thread.
pub type ReplyFn = Box<dyn FnOnce(Result<ExecResult>) + Send + 'static>;

/// Per-worker load model for placement by *observed service time*.
///
/// Queue-depth-only placement treats every worker as equally fast, but a
/// worker can be durably slower than its siblings — a noisy neighbour on
/// its pinned core, a cold executable cache, asymmetric hardware. The
/// tracker keeps, per worker, the jobs currently dispatched-but-not-done
/// and an EWMA of *measured* execution latency (the engine's own
/// `exec_time`, which excludes queueing). [`WorkerLoadTracker::pick`]
/// scores each worker by `inflight x ewma` — the expected time a new job
/// would wait behind that worker's current backlog — so a slow worker
/// naturally receives fewer placements instead of an equal share it
/// cannot keep up with.
///
/// All state is atomic: the scheduler shards read `pick()` concurrently
/// with worker threads reporting completions, no locks on either path.
pub struct WorkerLoadTracker {
    workers: Vec<WorkerLoad>,
}

#[derive(Default)]
struct WorkerLoad {
    /// dispatched but not yet completed (includes queued-at-worker)
    inflight: AtomicUsize,
    /// EWMA of measured execution latency, microseconds; 0 = no sample
    /// yet (scored as 1µs so an unprofiled worker looks cheap and gets
    /// sampled early)
    ewma_us: AtomicU64,
}

/// EWMA smoothing: new = (old * 4 + sample) / 5 (alpha = 0.2) — heavy
/// enough to ride out one outlier, light enough to track a worker that
/// genuinely degrades within a few tens of jobs.
const EWMA_KEEP: u64 = 4;

impl WorkerLoadTracker {
    pub fn new(workers: usize) -> WorkerLoadTracker {
        WorkerLoadTracker {
            workers: (0..workers.max(1)).map(|_| WorkerLoad::default()).collect(),
        }
    }

    fn slot(&self, worker: usize) -> &WorkerLoad {
        &self.workers[worker % self.workers.len()]
    }

    /// A job was handed to `worker`.
    pub fn note_dispatch(&self, worker: usize) {
        self.slot(worker).inflight.fetch_add(1, Ordering::Relaxed);
    }

    /// A job on `worker` finished. `exec` is its measured execution
    /// latency when it actually ran — `None` for jobs that were skipped
    /// (cancelled before start) or failed, which still release the
    /// in-flight slot but must not pollute the latency estimate.
    pub fn note_done(&self, worker: usize, exec: Option<Duration>) {
        let slot = self.slot(worker);
        // saturating decrement: a racing double-report must never wrap
        // the count into "infinitely loaded"
        let _ = slot
            .inflight
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| v.checked_sub(1));
        if let Some(exec) = exec {
            let us = (exec.as_micros() as u64).max(1);
            let _ = slot.ewma_us.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |old| {
                Some(if old == 0 { us } else { (old * EWMA_KEEP + us) / (EWMA_KEEP + 1) })
            });
        }
    }

    /// The worker a new job should land on: minimal expected wait,
    /// `inflight x max(ewma, 1µs)`. Ties break toward the lowest index
    /// (deterministic, and idle workers always beat busy ones).
    pub fn pick(&self) -> usize {
        self.workers
            .iter()
            .enumerate()
            .min_by_key(|(_, w)| {
                let inflight = w.inflight.load(Ordering::Relaxed) as u128;
                let ewma = w.ewma_us.load(Ordering::Relaxed).max(1) as u128;
                inflight * ewma
            })
            .map(|(i, _)| i)
            .unwrap_or(0)
    }

    /// Current EWMA estimate for `worker`, if it has any sample.
    pub fn ewma(&self, worker: usize) -> Option<Duration> {
        match self.slot(worker).ewma_us.load(Ordering::Relaxed) {
            0 => None,
            us => Some(Duration::from_micros(us)),
        }
    }
}

pub struct ExecJob {
    pub model: String,
    pub inputs: Vec<Tensor>,
    /// cooperative cancellation: checked when the job is dequeued and
    /// polled by the engine between its expensive steps
    pub cancel: CancelToken,
    pub reply: ReplyFn,
}

#[derive(Debug, Clone)]
pub struct ExecResult {
    pub outputs: Vec<Tensor>,
    /// pure execute time inside the worker (excludes queueing)
    pub exec_time: Duration,
    pub worker: usize,
}

enum Msg {
    Run(ExecJob),
    Warmup(String, Sender<Result<()>>),
    Shutdown,
}

struct Worker {
    tx: Sender<Msg>,
    join: Option<JoinHandle<()>>,
}

pub struct ExecutorPool {
    workers: Vec<Worker>,
    pub size: usize,
    submitted: AtomicU64,
    rr: AtomicUsize,
    load: Arc<WorkerLoadTracker>,
}

impl ExecutorPool {
    /// Spawn `size` executor threads over the given artifact manifest.
    pub fn new(manifest: Arc<Manifest>, size: usize) -> Result<ExecutorPool> {
        assert!(size >= 1);
        let mut workers = Vec::with_capacity(size);
        for wid in 0..size {
            let (tx, rx) = channel::<Msg>();
            let manifest = Arc::clone(&manifest);
            let join = std::thread::Builder::new()
                .name(format!("dnc-exec-{wid}"))
                .spawn(move || worker_loop(wid, manifest, rx))
                .context("spawning executor thread")?;
            workers.push(Worker { tx, join: Some(join) });
        }
        Ok(ExecutorPool {
            workers,
            size,
            submitted: AtomicU64::new(0),
            rr: AtomicUsize::new(0),
            load: Arc::new(WorkerLoadTracker::new(size)),
        })
    }

    /// The pool's observed-service-time load model. The scheduler reads
    /// `pick()` from here for placement; external monitors may read the
    /// per-worker EWMAs.
    pub fn load(&self) -> &Arc<WorkerLoadTracker> {
        &self.load
    }

    /// Queue a job on a specific worker; `reply` fires on completion.
    /// If the worker is down (engine creation failed), `reply` fires
    /// immediately with an error instead of panicking. A job whose
    /// `cancel` token fires before the worker reaches it is skipped
    /// (reply: [`TaskCancelled`]) without touching the engine.
    pub fn dispatch(
        &self,
        worker: usize,
        model: &str,
        inputs: Vec<Tensor>,
        cancel: CancelToken,
        reply: ReplyFn,
    ) {
        let wid = worker % self.size;
        self.submitted.fetch_add(1, Ordering::Relaxed);
        // Load model: count the dispatch now, settle it (and feed the
        // measured exec latency into the worker's EWMA) when the reply
        // fires. Skipped/failed jobs release the slot with no sample.
        let load = Arc::clone(&self.load);
        load.note_dispatch(wid);
        let reply: ReplyFn = Box::new(move |result: Result<ExecResult>| {
            load.note_done(wid, result.as_ref().ok().map(|r| r.exec_time));
            reply(result);
        });
        let job = ExecJob { model: model.to_string(), inputs, cancel, reply };
        if let Err(e) = self.workers[wid].tx.send(Msg::Run(job)) {
            if let Msg::Run(job) = e.0 {
                (job.reply)(Err(anyhow::anyhow!("executor worker {wid} is down")));
            }
        }
    }

    /// Submit round-robin and return a receiver for the result.
    pub fn submit(&self, model: &str, inputs: Vec<Tensor>) -> Receiver<Result<ExecResult>> {
        let (reply, rx) = channel();
        let wid = self.rr.fetch_add(1, Ordering::Relaxed);
        self.dispatch(
            wid,
            model,
            inputs,
            CancelToken::new(),
            Box::new(move |result| {
                // Receiver may have given up (timeout) — that's fine.
                let _ = reply.send(result);
            }),
        );
        rx
    }

    /// Submit and block for the result (sync style).
    pub fn run(&self, model: &str, inputs: Vec<Tensor>) -> Result<ExecResult> {
        self.submit(model, inputs)
            .recv()
            .context("executor worker dropped reply channel")?
    }

    /// Pre-compile `models` on **every** worker so first requests aren't
    /// penalized by JIT compilation. Deterministic: per-worker queues let
    /// us address each worker exactly once (the old shared-queue pool
    /// could only issue `size` best-effort rounds and hope coverage).
    pub fn warmup(&self, models: &[&str]) -> Result<()> {
        let mut pending = Vec::with_capacity(self.size * models.len());
        for w in &self.workers {
            for m in models {
                let (tx, rx) = channel();
                if w.tx.send(Msg::Warmup(m.to_string(), tx)).is_err() {
                    anyhow::bail!("executor worker is down during warmup");
                }
                pending.push(rx);
            }
        }
        for rx in pending {
            rx.recv().context("warmup reply lost")??;
        }
        Ok(())
    }

    pub fn jobs_submitted(&self) -> u64 {
        self.submitted.load(Ordering::Relaxed)
    }
}

impl Drop for ExecutorPool {
    fn drop(&mut self) {
        for w in &self.workers {
            let _ = w.tx.send(Msg::Shutdown);
        }
        for w in &mut self.workers {
            if let Some(j) = w.join.take() {
                let _ = j.join();
            }
        }
    }
}

fn worker_loop(wid: usize, manifest: Arc<Manifest>, rx: Receiver<Msg>) {
    let mut engine = match LocalEngine::new(manifest) {
        Ok(e) => e,
        Err(e) => {
            crate::error!("executor {wid}: failed to create engine: {e:#}");
            return;
        }
    };
    loop {
        let msg = match rx.recv() {
            Ok(m) => m,
            Err(_) => return, // pool dropped
        };
        match msg {
            Msg::Shutdown => return,
            Msg::Warmup(model, reply) => {
                let _ = reply.send(engine.warmup(&model));
            }
            Msg::Run(job) => {
                // Cooperative cancellation: a task cancelled between
                // admission and this dequeue is skipped outright — no
                // compile, no execution. The typed reply still flows so
                // the scheduler releases the task's ledger cores.
                if job.cancel.is_cancelled() {
                    (job.reply)(Err(anyhow::Error::new(TaskCancelled)));
                    continue;
                }
                let t0 = clock::now();
                // A panic inside execute must still produce a reply:
                // the scheduler's core ledger frees on completion, so a
                // dropped reply would leak cores forever.
                let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    engine.execute_cancellable(&job.model, &job.inputs, &job.cancel)
                }));
                let result = match result {
                    Ok(r) => r.map(|outputs| ExecResult {
                        outputs,
                        exec_time: t0.elapsed(),
                        worker: wid,
                    }),
                    Err(_) => Err(anyhow::anyhow!(
                        "executor {wid} panicked running {}",
                        job.model
                    )),
                };
                (job.reply)(result);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slow_worker_receives_fewer_placements() {
        // Worker 0 is profiled 100x slower than its siblings. Nine
        // successive placements (dispatched, none completing — the
        // backlog builds) must concentrate on the fast workers: the
        // slow one may get at most its cheap first pick, never an
        // equal share.
        let t = WorkerLoadTracker::new(3);
        t.note_dispatch(0);
        t.note_done(0, Some(Duration::from_millis(100)));
        for w in [1, 2] {
            t.note_dispatch(w);
            t.note_done(w, Some(Duration::from_millis(1)));
        }
        let mut placements = [0usize; 3];
        for _ in 0..9 {
            let w = t.pick();
            placements[w] += 1;
            t.note_dispatch(w); // backlog builds; nothing completes
        }
        assert!(
            placements[0] <= 2,
            "slow worker got an equal share: {placements:?}"
        );
        assert!(
            placements[1] + placements[2] >= 7,
            "fast workers starved: {placements:?}"
        );
    }

    #[test]
    fn ewma_tracks_latest_samples() {
        let t = WorkerLoadTracker::new(1);
        t.note_dispatch(0);
        t.note_done(0, Some(Duration::from_micros(1000)));
        assert_eq!(t.ewma(0), Some(Duration::from_micros(1000)), "first sample seeds");
        for _ in 0..40 {
            t.note_dispatch(0);
            t.note_done(0, Some(Duration::from_micros(5000)));
        }
        let ewma = t.ewma(0).unwrap();
        assert!(
            ewma > Duration::from_micros(4000),
            "EWMA did not converge toward the new regime: {ewma:?}"
        );
    }

    #[test]
    fn skipped_jobs_release_slot_without_skewing_latency() {
        // A cancelled-before-start job reports no exec time: the
        // in-flight slot must free (the worker is pickable again) and
        // the latency estimate must stay untouched.
        let t = WorkerLoadTracker::new(2);
        t.note_dispatch(0);
        t.note_done(0, Some(Duration::from_micros(500)));
        t.note_dispatch(0);
        t.note_done(0, None); // skipped
        assert_eq!(t.ewma(0), Some(Duration::from_micros(500)));
        // double-report must not wrap the count
        t.note_done(0, None);
        t.note_done(0, None);
        // worker 0 idle with a profile, worker 1 idle without: both
        // score 0 in-flight; tie breaks to worker 0
        assert_eq!(t.pick(), 0);
        t.note_dispatch(0);
        assert_eq!(t.pick(), 1, "loaded worker must lose to an idle one");
    }
}

//! Executor pool: N persistent worker threads, each owning a private
//! `LocalEngine` (PJRT client + executable cache + device weights).
//!
//! This is the runtime's unit of *real* parallelism. `PjRtClient` is not
//! `Send`, so instead of sharing one client we give each worker its own —
//! the same topology OnnxRuntime uses for inter-op worker threads. Jobs
//! arrive on an mpsc channel guarded by a mutex (a simple shared queue);
//! results return on per-job reply channels.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::manifest::Manifest;
use super::local::LocalEngine;
use super::tensor::Tensor;

pub struct ExecJob {
    pub model: String,
    pub inputs: Vec<Tensor>,
    pub reply: Sender<Result<ExecResult>>,
}

#[derive(Debug, Clone)]
pub struct ExecResult {
    pub outputs: Vec<Tensor>,
    /// pure execute time inside the worker (excludes queueing)
    pub exec_time: Duration,
    pub worker: usize,
}

enum Msg {
    Run(ExecJob),
    Warmup(String, Sender<Result<()>>),
    Shutdown,
}

pub struct ExecutorPool {
    queue: Arc<Mutex<Receiver<Msg>>>,
    tx: Sender<Msg>,
    workers: Vec<JoinHandle<()>>,
    pub size: usize,
    submitted: AtomicU64,
}

impl ExecutorPool {
    /// Spawn `size` executor threads over the given artifact manifest.
    pub fn new(manifest: Arc<Manifest>, size: usize) -> Result<ExecutorPool> {
        assert!(size >= 1);
        let (tx, rx) = channel::<Msg>();
        let queue = Arc::new(Mutex::new(rx));
        let mut workers = Vec::with_capacity(size);
        for wid in 0..size {
            let queue = Arc::clone(&queue);
            let manifest = Arc::clone(&manifest);
            workers.push(
                std::thread::Builder::new()
                    .name(format!("dnc-exec-{wid}"))
                    .spawn(move || worker_loop(wid, manifest, queue))
                    .context("spawning executor thread")?,
            );
        }
        Ok(ExecutorPool { queue, tx, workers, size, submitted: AtomicU64::new(0) })
    }

    /// Submit and return a receiver for the result (async style).
    pub fn submit(&self, model: &str, inputs: Vec<Tensor>) -> Receiver<Result<ExecResult>> {
        let (reply, rx) = channel();
        self.submitted.fetch_add(1, Ordering::Relaxed);
        self.tx
            .send(Msg::Run(ExecJob { model: model.to_string(), inputs, reply }))
            .expect("executor pool is down");
        rx
    }

    /// Submit and block for the result (sync style).
    pub fn run(&self, model: &str, inputs: Vec<Tensor>) -> Result<ExecResult> {
        self.submit(model, inputs)
            .recv()
            .context("executor worker dropped reply channel")?
    }

    /// Pre-compile `models` on every worker so first requests aren't
    /// penalized by JIT compilation.
    pub fn warmup(&self, models: &[&str]) -> Result<()> {
        // Each Warmup message is taken by exactly one idle worker; issuing
        // `size` rounds with a barrier-ish join approximates all-workers
        // coverage. Precision is unnecessary: a missed worker just
        // compiles lazily on first use.
        for _round in 0..self.size {
            let mut pending = Vec::new();
            for m in models {
                let (tx, rx) = channel();
                self.tx.send(Msg::Warmup(m.to_string(), tx)).expect("pool down");
                pending.push(rx);
            }
            for rx in pending {
                rx.recv().context("warmup reply lost")??;
            }
        }
        Ok(())
    }

    pub fn jobs_submitted(&self) -> u64 {
        self.submitted.load(Ordering::Relaxed)
    }
}

impl Drop for ExecutorPool {
    fn drop(&mut self) {
        for _ in &self.workers {
            let _ = self.tx.send(Msg::Shutdown);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        let _ = self.queue; // keep the receiver alive until workers joined
    }
}

fn worker_loop(wid: usize, manifest: Arc<Manifest>, queue: Arc<Mutex<Receiver<Msg>>>) {
    let mut engine = match LocalEngine::new(manifest) {
        Ok(e) => e,
        Err(e) => {
            crate::error!("executor {wid}: failed to create engine: {e:#}");
            return;
        }
    };
    loop {
        // Hold the lock only while dequeueing.
        let msg = {
            let rx = queue.lock().expect("queue poisoned");
            match rx.recv() {
                Ok(m) => m,
                Err(_) => return, // pool dropped
            }
        };
        match msg {
            Msg::Shutdown => return,
            Msg::Warmup(model, reply) => {
                let _ = reply.send(engine.warmup(&model));
            }
            Msg::Run(job) => {
                let t0 = Instant::now();
                let result = engine.execute(&job.model, &job.inputs).map(|outputs| ExecResult {
                    outputs,
                    exec_time: t0.elapsed(),
                    worker: wid,
                });
                // Receiver may have given up (timeout) — that's fine.
                let _ = job.reply.send(result);
            }
        }
    }
}

//! Executor pool: N persistent worker threads, each owning a private
//! `LocalEngine` (PJRT client + executable cache + device weights).
//!
//! This is the runtime's unit of *real* parallelism. `PjRtClient` is not
//! `Send`, so instead of sharing one client we give each worker its own —
//! the same topology OnnxRuntime uses for inter-op worker threads.
//!
//! Queueing: every worker owns a **private channel** (no shared queue),
//! so a caller can target a specific worker. `engine::sched` uses this to
//! place admitted tasks on the least-loaded worker, and `warmup` uses it
//! to pre-compile models on *every* worker exactly once (the old shared
//! queue could only approximate all-workers coverage probabilistically).
//! Untargeted `submit`/`run` round-robin across workers.
//!
//! Completion is callback-based: a job carries a [`ReplyFn`] invoked on
//! the worker thread when execution finishes. Channel-style use (the
//! `submit`/`run` API) wraps a channel sender in that callback; the
//! scheduler instead forwards completions into its own event loop, which
//! is what lets it release cores without a watcher thread per task.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::cancel::{CancelToken, TaskCancelled};
use super::local::LocalEngine;
use super::manifest::Manifest;
use super::tensor::Tensor;

/// Completion callback, invoked exactly once on the worker thread.
pub type ReplyFn = Box<dyn FnOnce(Result<ExecResult>) + Send + 'static>;

pub struct ExecJob {
    pub model: String,
    pub inputs: Vec<Tensor>,
    /// cooperative cancellation: checked when the job is dequeued and
    /// polled by the engine between its expensive steps
    pub cancel: CancelToken,
    pub reply: ReplyFn,
}

#[derive(Debug, Clone)]
pub struct ExecResult {
    pub outputs: Vec<Tensor>,
    /// pure execute time inside the worker (excludes queueing)
    pub exec_time: Duration,
    pub worker: usize,
}

enum Msg {
    Run(ExecJob),
    Warmup(String, Sender<Result<()>>),
    Shutdown,
}

struct Worker {
    tx: Sender<Msg>,
    join: Option<JoinHandle<()>>,
}

pub struct ExecutorPool {
    workers: Vec<Worker>,
    pub size: usize,
    submitted: AtomicU64,
    rr: AtomicUsize,
}

impl ExecutorPool {
    /// Spawn `size` executor threads over the given artifact manifest.
    pub fn new(manifest: Arc<Manifest>, size: usize) -> Result<ExecutorPool> {
        assert!(size >= 1);
        let mut workers = Vec::with_capacity(size);
        for wid in 0..size {
            let (tx, rx) = channel::<Msg>();
            let manifest = Arc::clone(&manifest);
            let join = std::thread::Builder::new()
                .name(format!("dnc-exec-{wid}"))
                .spawn(move || worker_loop(wid, manifest, rx))
                .context("spawning executor thread")?;
            workers.push(Worker { tx, join: Some(join) });
        }
        Ok(ExecutorPool { workers, size, submitted: AtomicU64::new(0), rr: AtomicUsize::new(0) })
    }

    /// Queue a job on a specific worker; `reply` fires on completion.
    /// If the worker is down (engine creation failed), `reply` fires
    /// immediately with an error instead of panicking. A job whose
    /// `cancel` token fires before the worker reaches it is skipped
    /// (reply: [`TaskCancelled`]) without touching the engine.
    pub fn dispatch(
        &self,
        worker: usize,
        model: &str,
        inputs: Vec<Tensor>,
        cancel: CancelToken,
        reply: ReplyFn,
    ) {
        let wid = worker % self.size;
        self.submitted.fetch_add(1, Ordering::Relaxed);
        let job = ExecJob { model: model.to_string(), inputs, cancel, reply };
        if let Err(e) = self.workers[wid].tx.send(Msg::Run(job)) {
            if let Msg::Run(job) = e.0 {
                (job.reply)(Err(anyhow::anyhow!("executor worker {wid} is down")));
            }
        }
    }

    /// Submit round-robin and return a receiver for the result.
    pub fn submit(&self, model: &str, inputs: Vec<Tensor>) -> Receiver<Result<ExecResult>> {
        let (reply, rx) = channel();
        let wid = self.rr.fetch_add(1, Ordering::Relaxed);
        self.dispatch(
            wid,
            model,
            inputs,
            CancelToken::new(),
            Box::new(move |result| {
                // Receiver may have given up (timeout) — that's fine.
                let _ = reply.send(result);
            }),
        );
        rx
    }

    /// Submit and block for the result (sync style).
    pub fn run(&self, model: &str, inputs: Vec<Tensor>) -> Result<ExecResult> {
        self.submit(model, inputs)
            .recv()
            .context("executor worker dropped reply channel")?
    }

    /// Pre-compile `models` on **every** worker so first requests aren't
    /// penalized by JIT compilation. Deterministic: per-worker queues let
    /// us address each worker exactly once (the old shared-queue pool
    /// could only issue `size` best-effort rounds and hope coverage).
    pub fn warmup(&self, models: &[&str]) -> Result<()> {
        let mut pending = Vec::with_capacity(self.size * models.len());
        for w in &self.workers {
            for m in models {
                let (tx, rx) = channel();
                if w.tx.send(Msg::Warmup(m.to_string(), tx)).is_err() {
                    anyhow::bail!("executor worker is down during warmup");
                }
                pending.push(rx);
            }
        }
        for rx in pending {
            rx.recv().context("warmup reply lost")??;
        }
        Ok(())
    }

    pub fn jobs_submitted(&self) -> u64 {
        self.submitted.load(Ordering::Relaxed)
    }
}

impl Drop for ExecutorPool {
    fn drop(&mut self) {
        for w in &self.workers {
            let _ = w.tx.send(Msg::Shutdown);
        }
        for w in &mut self.workers {
            if let Some(j) = w.join.take() {
                let _ = j.join();
            }
        }
    }
}

fn worker_loop(wid: usize, manifest: Arc<Manifest>, rx: Receiver<Msg>) {
    let mut engine = match LocalEngine::new(manifest) {
        Ok(e) => e,
        Err(e) => {
            crate::error!("executor {wid}: failed to create engine: {e:#}");
            return;
        }
    };
    loop {
        let msg = match rx.recv() {
            Ok(m) => m,
            Err(_) => return, // pool dropped
        };
        match msg {
            Msg::Shutdown => return,
            Msg::Warmup(model, reply) => {
                let _ = reply.send(engine.warmup(&model));
            }
            Msg::Run(job) => {
                // Cooperative cancellation: a task cancelled between
                // admission and this dequeue is skipped outright — no
                // compile, no execution. The typed reply still flows so
                // the scheduler releases the task's ledger cores.
                if job.cancel.is_cancelled() {
                    (job.reply)(Err(anyhow::Error::new(TaskCancelled)));
                    continue;
                }
                let t0 = Instant::now();
                // A panic inside execute must still produce a reply:
                // the scheduler's core ledger frees on completion, so a
                // dropped reply would leak cores forever.
                let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    engine.execute_cancellable(&job.model, &job.inputs, &job.cancel)
                }));
                let result = match result {
                    Ok(r) => r.map(|outputs| ExecResult {
                        outputs,
                        exec_time: t0.elapsed(),
                        worker: wid,
                    }),
                    Err(_) => Err(anyhow::anyhow!(
                        "executor {wid} panicked running {}",
                        job.model
                    )),
                };
                (job.reply)(result);
            }
        }
    }
}

//! Per-thread PJRT engine: owns a CPU client, lazily compiles HLO-text
//! artifacts, keeps model weights device-resident, and executes models.
//!
//! `xla::PjRtClient` is `Rc`-based (not `Send`), so a `LocalEngine` never
//! crosses threads — the `pool` module gives each executor thread its own
//! engine, which is also how OnnxRuntime structures per-session worker
//! state. Weights are uploaded once per engine via
//! `buffer_from_host_buffer` and reused across every `execute_b` call, so
//! the request hot path copies only the (tiny) activations.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use super::cancel::{CancelToken, TaskCancelled};
use super::manifest::Manifest;
use super::tensor::{Tensor, TensorData};

pub struct LocalEngine {
    client: xla::PjRtClient,
    manifest: Arc<Manifest>,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
    /// weights_ref ("bert") -> device-resident parameter buffers
    weight_buffers: HashMap<String, Vec<xla::PjRtBuffer>>,
    /// cumulative compile time, surfaced through stats
    pub compile_time: Duration,
    pub executions: u64,
}

impl LocalEngine {
    pub fn new(manifest: Arc<Manifest>) -> Result<LocalEngine> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(LocalEngine {
            client,
            manifest,
            executables: HashMap::new(),
            weight_buffers: HashMap::new(),
            compile_time: Duration::ZERO,
            executions: 0,
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Compile (once) and cache the executable for `model`.
    fn ensure_compiled(&mut self, model: &str) -> Result<()> {
        if self.executables.contains_key(model) {
            return Ok(());
        }
        let entry = self.manifest.model(model)?.clone();
        let path = self.manifest.dir.join(&entry.hlo);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {model}"))?;
        self.compile_time += t0.elapsed();
        crate::debug!("compiled {model} in {:?}", t0.elapsed());
        self.executables.insert(model.to_string(), exe);

        if let Some(wref) = entry.weights_ref.as_deref() {
            self.ensure_weights(wref)?;
        }
        Ok(())
    }

    fn ensure_weights(&mut self, wref: &str) -> Result<()> {
        if self.weight_buffers.contains_key(wref) {
            return Ok(());
        }
        if wref != "bert" {
            bail!("unknown weights ref '{wref}'");
        }
        let t0 = Instant::now();
        let tensors = self.manifest.load_bert_weight_tensors()?;
        let mut buffers = Vec::with_capacity(tensors.len());
        for t in &tensors {
            let data = t.as_f32()?;
            buffers.push(
                self.client
                    .buffer_from_host_buffer(data, &t.shape, None)
                    .context("uploading weight tensor")?,
            );
        }
        crate::debug!("uploaded {} '{wref}' weight tensors in {:?}", buffers.len(), t0.elapsed());
        self.weight_buffers.insert(wref.to_string(), buffers);
        Ok(())
    }

    /// Warm the executable + weight caches for `model` without running it.
    pub fn warmup(&mut self, model: &str) -> Result<()> {
        self.ensure_compiled(model)
    }

    /// Execute `model` on `inputs` (the non-weight inputs only; weights are
    /// appended automatically from the device-resident cache).
    pub fn execute(&mut self, model: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        self.execute_cancellable(model, inputs, &CancelToken::new())
    }

    /// [`execute`](Self::execute) with cooperative cancellation: the
    /// token is polled at the execute path's seams — before (possibly
    /// slow) JIT compilation and again before the model run — so a task
    /// cancelled mid-pipeline stops at the next seam instead of running
    /// to completion. Returns the typed [`TaskCancelled`] error, which
    /// the scheduler maps to `SchedError::Cancelled` while releasing
    /// the task's ledger cores.
    pub fn execute_cancellable(
        &mut self,
        model: &str,
        inputs: &[Tensor],
        cancel: &CancelToken,
    ) -> Result<Vec<Tensor>> {
        if cancel.is_cancelled() {
            return Err(anyhow::Error::new(TaskCancelled));
        }
        self.ensure_compiled(model)?;
        // The compile above can take hundreds of ms cold; re-poll before
        // committing to the actual model run.
        if cancel.is_cancelled() {
            return Err(anyhow::Error::new(TaskCancelled));
        }
        let entry = self.manifest.model(model)?;
        let n_user = entry.inputs.len()
            - entry
                .weights_ref
                .as_deref()
                .map(|_| self.manifest.bert_weights.tensors.len())
                .unwrap_or(0);
        if inputs.len() != n_user {
            bail!(
                "model {model} expects {n_user} user input(s), got {}",
                inputs.len()
            );
        }
        // Validate declared shapes early — mismatches would otherwise
        // surface as opaque XLA errors.
        for (i, (t, spec)) in inputs.iter().zip(entry.inputs.iter()).enumerate() {
            if t.shape != spec.shape || t.dtype_name() != spec.dtype {
                bail!(
                    "model {model} input {i}: expected {:?}/{}, got {:?}/{}",
                    spec.shape, spec.dtype, t.shape, t.dtype_name()
                );
            }
        }

        let weights_ref = entry.weights_ref.clone();
        let mut args: Vec<xla::PjRtBuffer> = Vec::with_capacity(entry.inputs.len());
        for t in inputs {
            let buf = match &t.data {
                TensorData::F32(v) => self.client.buffer_from_host_buffer(v, &t.shape, None)?,
                TensorData::I32(v) => self.client.buffer_from_host_buffer(v, &t.shape, None)?,
            };
            args.push(buf);
        }

        let exe = self.executables.get(model).unwrap();
        let outputs = if let Some(wref) = weights_ref.as_deref() {
            let weights = &self.weight_buffers[wref];
            let mut all: Vec<&xla::PjRtBuffer> = Vec::with_capacity(args.len() + weights.len());
            all.extend(args.iter());
            all.extend(weights.iter());
            exe.execute_b(&all)?
        } else {
            let refs: Vec<&xla::PjRtBuffer> = args.iter().collect();
            exe.execute_b(&refs)?
        };
        self.executions += 1;

        // aot.py lowers with return_tuple=True: one tuple output.
        let lit = outputs[0][0].to_literal_sync()?;
        let elems = lit.to_tuple()?;
        elems.iter().map(Tensor::from_literal).collect()
    }
}

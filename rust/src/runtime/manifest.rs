//! Typed view of `artifacts/manifest.json` — the contract between
//! `python/compile/aot.py` and the Rust runtime.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

#[derive(Debug, Clone, PartialEq)]
pub struct IoSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl IoSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    fn parse(v: &Json) -> Result<IoSpec> {
        Ok(IoSpec {
            shape: v.req("shape")?.usize_arr()?,
            dtype: v.req("dtype")?.as_str().context("dtype")?.to_string(),
        })
    }
}

#[derive(Debug, Clone)]
pub struct ModelEntry {
    pub name: String,
    pub hlo: String,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
    pub flops: u64,
    /// "bert" for models fed from the shared weight blob; None for
    /// weight-free (analytic) models.
    pub weights_ref: Option<String>,
    pub family: String,
    pub batch: Option<usize>,
    pub seq: Option<usize>,
    pub width: Option<usize>,
}

#[derive(Debug, Clone)]
pub struct WeightTensor {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub len: usize,
}

#[derive(Debug, Clone)]
pub struct WeightBlob {
    pub file: String,
    pub tensors: Vec<WeightTensor>,
}

#[derive(Debug, Clone)]
pub struct BertConfig {
    pub vocab: usize,
    pub hidden: usize,
    pub layers: usize,
    pub heads: usize,
    pub ff: usize,
    pub max_seq: usize,
    pub seq_buckets: Vec<usize>,
    pub batch_buckets: Vec<usize>,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub models: HashMap<String, ModelEntry>,
    pub bert_weights: WeightBlob,
    pub bert: BertConfig,
}

impl Manifest {
    pub fn load(artifacts_dir: &Path) -> Result<Manifest> {
        let root = Json::parse_file(&artifacts_dir.join("manifest.json"))?;
        let version = root.req("version")?.as_usize().context("version")?;
        if version != 1 {
            bail!("unsupported manifest version {version}");
        }

        let mut models = HashMap::new();
        for (name, entry) in root.req("models")?.as_obj().context("models")? {
            let inputs = entry
                .req("inputs")?
                .as_arr()
                .context("inputs")?
                .iter()
                .map(IoSpec::parse)
                .collect::<Result<Vec<_>>>()?;
            let outputs = entry
                .req("outputs")?
                .as_arr()
                .context("outputs")?
                .iter()
                .map(IoSpec::parse)
                .collect::<Result<Vec<_>>>()?;
            models.insert(
                name.clone(),
                ModelEntry {
                    name: name.clone(),
                    hlo: entry.req("hlo")?.as_str().context("hlo")?.to_string(),
                    inputs,
                    outputs,
                    flops: entry.req("flops")?.as_i64().context("flops")? as u64,
                    weights_ref: entry
                        .get("weights")
                        .and_then(|v| v.as_str())
                        .map(String::from),
                    family: entry
                        .get("family")
                        .and_then(|v| v.as_str())
                        .unwrap_or("unknown")
                        .to_string(),
                    batch: entry.get("batch").and_then(|v| v.as_usize()),
                    seq: entry.get("seq").and_then(|v| v.as_usize()),
                    width: entry.get("width").and_then(|v| v.as_usize()),
                },
            );
        }

        let bw = root.req("bert_weights")?;
        let tensors = bw
            .req("tensors")?
            .as_arr()
            .context("tensors")?
            .iter()
            .map(|t| {
                Ok(WeightTensor {
                    name: t.req("name")?.as_str().context("name")?.to_string(),
                    shape: t.req("shape")?.usize_arr()?,
                    offset: t.req("offset")?.as_usize().context("offset")?,
                    len: t.req("len")?.as_usize().context("len")?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let bert_weights = WeightBlob {
            file: bw.req("file")?.as_str().context("file")?.to_string(),
            tensors,
        };

        let bc = root.req("bert_config")?;
        let bert = BertConfig {
            vocab: bc.req("vocab")?.as_usize().context("vocab")?,
            hidden: bc.req("hidden")?.as_usize().context("hidden")?,
            layers: bc.req("layers")?.as_usize().context("layers")?,
            heads: bc.req("heads")?.as_usize().context("heads")?,
            ff: bc.req("ff")?.as_usize().context("ff")?,
            max_seq: bc.req("max_seq")?.as_usize().context("max_seq")?,
            seq_buckets: bc.req("seq_buckets")?.usize_arr()?,
            batch_buckets: bc.req("batch_buckets")?.usize_arr()?,
        };

        Ok(Manifest { dir: artifacts_dir.to_path_buf(), models, bert_weights, bert })
    }

    pub fn model(&self, name: &str) -> Result<&ModelEntry> {
        self.models
            .get(name)
            .with_context(|| format!("model '{name}' not in manifest"))
    }

    /// Smallest seq bucket >= len (paper's prun runs exact lengths; we
    /// quantize to the artifact grid — see DESIGN.md §4).
    pub fn seq_bucket(&self, len: usize) -> Result<usize> {
        self.bert
            .seq_buckets
            .iter()
            .copied()
            .find(|&b| b >= len)
            .with_context(|| format!("sequence length {len} exceeds largest bucket"))
    }

    pub fn batch_bucket(&self, k: usize) -> Result<usize> {
        self.bert
            .batch_buckets
            .iter()
            .copied()
            .find(|&b| b >= k)
            .with_context(|| format!("batch size {k} exceeds largest bucket"))
    }

    pub fn bert_model_name(&self, batch: usize, seq: usize) -> String {
        format!("bert_b{batch}_s{seq}")
    }

    /// Load the raw f32 weight blob and split it per-tensor.
    pub fn load_bert_weight_tensors(&self) -> Result<Vec<crate::runtime::Tensor>> {
        let path = self.dir.join(&self.bert_weights.file);
        let bytes = std::fs::read(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let mut out = Vec::with_capacity(self.bert_weights.tensors.len());
        for t in &self.bert_weights.tensors {
            let end = t.offset + t.len * 4;
            if end > bytes.len() {
                bail!("weight tensor {} overruns blob ({} > {})", t.name, end, bytes.len());
            }
            let mut data = Vec::with_capacity(t.len);
            for chunk in bytes[t.offset..end].chunks_exact(4) {
                data.push(f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]));
            }
            out.push(crate::runtime::Tensor::f32(t.shape.clone(), data));
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest_json() -> &'static str {
        r#"{
  "version": 1,
  "models": {
    "bert_b1_s16": {
      "hlo": "bert_b1_s16.hlo.txt",
      "inputs": [{"shape": [1, 16], "dtype": "s32"}],
      "outputs": [{"shape": [1, 128], "dtype": "f32"}],
      "flops": 1000,
      "weights": "bert",
      "family": "bert", "batch": 1, "seq": 16
    },
    "ocr_det": {
      "hlo": "ocr_det.hlo.txt",
      "inputs": [{"shape": [1, 3, 192, 256], "dtype": "f32"}],
      "outputs": [{"shape": [1, 48, 64], "dtype": "f32"}],
      "flops": 500,
      "family": "ocr_det"
    }
  },
  "bert_weights": {"file": "weights/bert.bin", "tensors": [
    {"name": "embedding", "shape": [4, 2], "offset": 0, "len": 8}
  ]},
  "bert_config": {
    "vocab": 8192, "hidden": 128, "layers": 2, "heads": 4, "ff": 512,
    "max_seq": 512, "seq_buckets": [16, 32, 64], "batch_buckets": [1, 2, 4, 8]
  }
}"#
    }

    fn load_fixture() -> Manifest {
        let dir = std::env::temp_dir().join(format!("dnc_manifest_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), manifest_json()).unwrap();
        Manifest::load(&dir).unwrap()
    }

    #[test]
    fn parses_models_and_config() {
        let m = load_fixture();
        let e = m.model("bert_b1_s16").unwrap();
        assert_eq!(e.inputs[0].shape, vec![1, 16]);
        assert_eq!(e.inputs[0].dtype, "s32");
        assert_eq!(e.flops, 1000);
        assert_eq!(e.weights_ref.as_deref(), Some("bert"));
        assert_eq!(e.batch, Some(1));
        let det = m.model("ocr_det").unwrap();
        assert_eq!(det.weights_ref, None);
        assert_eq!(det.family, "ocr_det");
        assert!(m.model("nope").is_err());
    }

    #[test]
    fn bucket_selection() {
        let m = load_fixture();
        assert_eq!(m.seq_bucket(1).unwrap(), 16);
        assert_eq!(m.seq_bucket(16).unwrap(), 16);
        assert_eq!(m.seq_bucket(17).unwrap(), 32);
        assert_eq!(m.seq_bucket(64).unwrap(), 64);
        assert!(m.seq_bucket(65).is_err());
        assert_eq!(m.batch_bucket(3).unwrap(), 4);
        assert_eq!(m.bert_model_name(2, 32), "bert_b2_s32");
    }

    #[test]
    fn weight_blob_split() {
        let m = load_fixture();
        let blob: Vec<u8> = (0..8u32)
            .flat_map(|i| (i as f32).to_le_bytes())
            .collect();
        std::fs::create_dir_all(m.dir.join("weights")).unwrap();
        std::fs::write(m.dir.join("weights/bert.bin"), &blob).unwrap();
        let tensors = m.load_bert_weight_tensors().unwrap();
        assert_eq!(tensors.len(), 1);
        assert_eq!(tensors[0].shape, vec![4, 2]);
        assert_eq!(tensors[0].as_f32().unwrap()[3], 3.0);
    }
}

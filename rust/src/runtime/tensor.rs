//! Host tensors crossing the Rust <-> PJRT boundary.
//!
//! Only the two dtypes our artifacts use (f32 activations/weights, i32
//! token ids). `Tensor` is the Send-able host representation; conversion
//! to/from `xla::Literal` happens inside the executor thread that owns the
//! PJRT client.

use anyhow::{bail, Result};

#[derive(Debug, Clone, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: TensorData,
}

impl Tensor {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor { shape, data: TensorData::F32(data) }
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor { shape, data: TensorData::I32(data) }
    }

    pub fn zeros_f32(shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor::f32(shape, vec![0.0; n])
    }

    pub fn len(&self) -> usize {
        match &self.data {
            TensorData::F32(v) => v.len(),
            TensorData::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total element count — the paper's job-part weight (its §3.1 sets
    /// w_i proportional to input tensor size).
    pub fn size(&self) -> usize {
        self.len()
    }

    pub fn dtype_name(&self) -> &'static str {
        match &self.data {
            TensorData::F32(_) => "f32",
            TensorData::I32(_) => "s32",
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            TensorData::F32(v) => Ok(v),
            _ => bail!("tensor is {} not f32", self.dtype_name()),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            TensorData::I32(v) => Ok(v),
            _ => bail!("tensor is {} not s32", self.dtype_name()),
        }
    }

    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        let lit = match &self.data {
            TensorData::F32(v) => xla::Literal::vec1(v),
            TensorData::I32(v) => xla::Literal::vec1(v),
        };
        Ok(lit.reshape(&dims)?)
    }

    pub fn from_literal(lit: &xla::Literal) -> Result<Tensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let data = match shape.ty() {
            xla::ElementType::F32 => TensorData::F32(lit.to_vec::<f32>()?),
            xla::ElementType::S32 => TensorData::I32(lit.to_vec::<i32>()?),
            other => bail!("unsupported element type {other:?}"),
        };
        Ok(Tensor { shape: dims, data })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn size_is_element_count() {
        let t = Tensor::f32(vec![2, 3, 4], vec![0.0; 24]);
        assert_eq!(t.size(), 24);
        assert_eq!(t.shape, vec![2, 3, 4]);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        Tensor::f32(vec![2, 2], vec![0.0; 5]);
    }

    #[test]
    fn dtype_accessors() {
        let t = Tensor::i32(vec![4], vec![1, 2, 3, 4]);
        assert!(t.as_i32().is_ok());
        assert!(t.as_f32().is_err());
        assert_eq!(t.dtype_name(), "s32");
    }

    #[test]
    fn literal_roundtrip_f32() {
        let t = Tensor::f32(vec![2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let lit = t.to_literal().unwrap();
        let back = Tensor::from_literal(&lit).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn literal_roundtrip_i32() {
        let t = Tensor::i32(vec![1, 4], vec![7, -2, 0, 42]);
        let lit = t.to_literal().unwrap();
        let back = Tensor::from_literal(&lit).unwrap();
        assert_eq!(back, t);
    }
}

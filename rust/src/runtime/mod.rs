//! Runtime layer: loads the AOT artifacts produced by `python/compile/`
//! (HLO text + weight blobs + manifest) and executes them on PJRT CPU.
//!
//! Structure:
//! - [`manifest`] — typed view of `artifacts/manifest.json`.
//! - [`tensor`] — Send-able host tensors and Literal conversion.
//! - [`local`] — per-thread engine (client, executable cache, weights).
//! - [`pool`] — N executor threads; the unit of real parallelism.
//! - [`cancel`] — cooperative cancellation tokens shared with the
//!   scheduler and the serving edge.
//!
//! Python never runs at serving time: once `make artifacts` has produced
//! the HLO text, the Rust binary is self-contained.

pub mod cancel;
pub mod local;
pub mod manifest;
pub mod pool;
pub mod tensor;

pub use cancel::{CancelToken, TaskCancelled};
pub use local::LocalEngine;
pub use manifest::{Manifest, ModelEntry};
pub use pool::{ExecResult, ExecutorPool, ReplyFn, WorkerLoadTracker};
pub use tensor::{Tensor, TensorData};

use std::path::PathBuf;

/// Locate the artifacts directory: `DNC_ARTIFACTS` env var or ./artifacts.
pub fn artifacts_dir() -> PathBuf {
    std::env::var("DNC_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

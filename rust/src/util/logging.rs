//! Leveled stderr logger with monotonic timestamps.
//!
//! `DNC_LOG=debug|info|warn|error` (default info). Kept deliberately
//! simple: one global atomic level, `log!`-style macros, no allocation
//! when the level is filtered out.

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

use once_cell::sync::Lazy;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Debug = 0,
    Info = 1,
    Warn = 2,
    Error = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(1);
static START: Lazy<Instant> = Lazy::new(Instant::now);

pub fn init_from_env() {
    let lvl = match std::env::var("DNC_LOG").as_deref() {
        Ok("debug") => Level::Debug,
        Ok("warn") => Level::Warn,
        Ok("error") => Level::Error,
        _ => Level::Info,
    };
    set_level(lvl);
    Lazy::force(&START);
}

pub fn set_level(lvl: Level) {
    LEVEL.store(lvl as u8, Ordering::Relaxed);
}

pub fn enabled(lvl: Level) -> bool {
    lvl as u8 >= LEVEL.load(Ordering::Relaxed)
}

pub fn log(lvl: Level, args: std::fmt::Arguments<'_>) {
    if enabled(lvl) {
        let t = START.elapsed();
        let tag = match lvl {
            Level::Debug => "DBG",
            Level::Info => "INF",
            Level::Warn => "WRN",
            Level::Error => "ERR",
        };
        eprintln!("[{:9.3}s {tag}] {args}", t.as_secs_f64());
    }
}

#[macro_export]
macro_rules! debug {
    ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Debug, format_args!($($t)*)) };
}
#[macro_export]
macro_rules! info {
    ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Info, format_args!($($t)*)) };
}
#[macro_export]
macro_rules! warn {
    ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Warn, format_args!($($t)*)) };
}
#[macro_export]
macro_rules! error {
    ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Error, format_args!($($t)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_filtering() {
        set_level(Level::Warn);
        assert!(!enabled(Level::Debug));
        assert!(!enabled(Level::Info));
        assert!(enabled(Level::Warn));
        assert!(enabled(Level::Error));
        set_level(Level::Info);
    }
}

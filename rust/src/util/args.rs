//! Tiny CLI argument parser (offline substitute for clap).
//!
//! Grammar: `prog <subcommand> [--key value]... [--flag]... [positional]...`
//! Flags must be declared as boolean via `flag()` lookups; everything else
//! written `--key value`. Unknown-key detection is the caller's job via
//! `finish()`.

use std::collections::HashMap;

#[derive(Debug, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    kv: HashMap<String, String>,
    flags: Vec<String>,
    consumed: std::cell::RefCell<Vec<String>>,
}

impl Args {
    pub fn parse_env() -> Args {
        Self::parse(std::env::args().skip(1))
    }

    pub fn parse<I: IntoIterator<Item = String>>(items: I) -> Args {
        let mut it = items.into_iter().peekable();
        let mut subcommand = None;
        let mut positional = Vec::new();
        let mut kv = HashMap::new();
        let mut flags = Vec::new();

        if let Some(first) = it.peek() {
            if !first.starts_with("--") {
                subcommand = Some(it.next().unwrap());
            }
        }
        while let Some(tok) = it.next() {
            if let Some(key) = tok.strip_prefix("--") {
                // --key=value or --key value or bare --flag
                if let Some((k, v)) = key.split_once('=') {
                    kv.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    kv.insert(key.to_string(), it.next().unwrap());
                } else {
                    flags.push(key.to_string());
                }
            } else {
                positional.push(tok);
            }
        }
        Args {
            subcommand,
            positional,
            kv,
            flags,
            consumed: std::cell::RefCell::new(Vec::new()),
        }
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.consumed.borrow_mut().push(key.to_string());
        self.kv.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects an integer, got '{v}'")))
            .unwrap_or(default)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects an integer, got '{v}'")))
            .unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects a number, got '{v}'")))
            .unwrap_or(default)
    }

    pub fn flag(&self, key: &str) -> bool {
        self.consumed.borrow_mut().push(key.to_string());
        self.flags.iter().any(|f| f == key)
            || self.kv.get(key).map(|v| v == "true" || v == "1").unwrap_or(false)
    }

    /// Comma-separated list: `--threads 1,2,4` -> vec![1,2,4].
    pub fn usize_list_or(&self, key: &str, default: &[usize]) -> Vec<usize> {
        match self.get(key) {
            None => default.to_vec(),
            Some(v) => v
                .split(',')
                .map(|x| x.trim().parse().unwrap_or_else(|_| panic!("--{key}: bad int '{x}'")))
                .collect(),
        }
    }

    /// Error on any provided --key the program never consulted (typos).
    pub fn finish(&self) -> anyhow::Result<()> {
        let consumed = self.consumed.borrow();
        for k in self.kv.keys().chain(self.flags.iter()) {
            if !consumed.iter().any(|c| c == k) {
                anyhow::bail!("unknown option --{k}");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_kv() {
        let a = parse("serve --port 8080 --threads 4");
        assert_eq!(a.subcommand.as_deref(), Some("serve"));
        assert_eq!(a.usize_or("port", 0), 8080);
        assert_eq!(a.usize_or("threads", 1), 4);
        a.finish().unwrap();
    }

    #[test]
    fn equals_form_and_flags() {
        let a = parse("bench --mode=sim --verbose");
        assert_eq!(a.get("mode"), Some("sim"));
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
        a.finish().unwrap();
    }

    #[test]
    fn defaults_apply() {
        let a = parse("run");
        assert_eq!(a.usize_or("cores", 16), 16);
        assert_eq!(a.f64_or("rate", 1.5), 1.5);
        assert_eq!(a.get_or("name", "x"), "x");
    }

    #[test]
    fn positional_collected() {
        let a = parse("ocr img1.png img2.png --variant prun");
        assert_eq!(a.positional, vec!["img1.png", "img2.png"]);
        assert_eq!(a.get("variant"), Some("prun"));
    }

    #[test]
    fn usize_list() {
        let a = parse("x --threads 1,2,4,8");
        assert_eq!(a.usize_list_or("threads", &[16]), vec![1, 2, 4, 8]);
        let b = parse("x");
        assert_eq!(b.usize_list_or("threads", &[16]), vec![16]);
    }

    #[test]
    fn finish_rejects_unknown() {
        let a = parse("x --oops 3");
        assert!(a.finish().is_err());
    }

    #[test]
    fn no_subcommand_when_first_is_option() {
        let a = parse("--help");
        assert_eq!(a.subcommand, None);
        assert!(a.flag("help"));
    }
}

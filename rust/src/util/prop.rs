//! Mini property-testing kit (offline substitute for proptest).
//!
//! `check(cases, |g| { ... })` runs the closure `cases` times with a
//! seeded `Gen`; on panic or `Err`, it reruns the failing seed to confirm
//! and reports it so the case is reproducible with `check_seed`.
//! No shrinking — generators are kept small-biased instead (sizes drawn
//! log-uniformly), which in practice yields readable counterexamples.

use super::prng::Rng;

pub struct Gen {
    pub rng: Rng,
    pub seed: u64,
}

impl Gen {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.usize_in(lo, hi)
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.f64_in(lo, hi)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.bool(0.5)
    }

    /// Log-uniform size in [1, hi]: biases toward small, still covers big.
    pub fn size(&mut self, hi: usize) -> usize {
        assert!(hi >= 1);
        let log_hi = (hi as f64).ln();
        let x = (self.rng.f64() * log_hi).exp();
        (x as usize).clamp(1, hi)
    }

    pub fn vec<T>(&mut self, len: usize, mut f: impl FnMut(&mut Gen) -> T) -> Vec<T> {
        (0..len).map(|_| f(self)).collect()
    }

    /// Positive weights that sum to ~1 (for allocator tests).
    pub fn weights(&mut self, k: usize) -> Vec<f64> {
        let raw: Vec<f64> = (0..k).map(|_| self.rng.f64() + 1e-6).collect();
        let total: f64 = raw.iter().sum();
        raw.into_iter().map(|w| w / total).collect()
    }

    pub fn choice<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        self.rng.choice(items)
    }
}

/// Run `f` for `cases` seeded cases; panic with the failing seed on error.
pub fn check(cases: u64, f: impl Fn(&mut Gen)) {
    let base = match std::env::var("DNC_PROP_SEED") {
        Ok(s) => s.parse().expect("DNC_PROP_SEED must be u64"),
        Err(_) => DEFAULT_BASE_SEED,
    };
    for i in 0..cases {
        let seed = base.wrapping_add(i);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut g = Gen { rng: Rng::new(seed), seed };
            f(&mut g);
        }));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| payload.downcast_ref::<&str>().copied())
                .unwrap_or("<non-string panic>");
            panic!(
                "property failed at case {i} (seed {seed}): {msg}\n\
                 reproduce with DNC_PROP_SEED={seed} and 1 case"
            );
        }
    }
}

/// Re-run a single seed (debugging helper).
pub fn check_seed(seed: u64, f: impl Fn(&mut Gen)) {
    let mut g = Gen { rng: Rng::new(seed), seed };
    f(&mut g);
}

const DEFAULT_BASE_SEED: u64 = 0xdc5e_11e0_0000_0001;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check(50, |g| {
            let n = g.usize_in(0, 100);
            assert!(n <= 100);
        });
    }

    #[test]
    fn reports_failing_seed() {
        let r = std::panic::catch_unwind(|| {
            check(50, |g| {
                let n = g.usize_in(0, 100);
                assert!(n < 95, "n={n}");
            });
        });
        let msg = match r {
            Err(p) => p.downcast_ref::<String>().unwrap().clone(),
            Ok(_) => panic!("property should have failed"),
        };
        assert!(msg.contains("seed"), "{msg}");
    }

    #[test]
    fn weights_normalized() {
        check(30, |g| {
            let k = g.usize_in(1, 20);
            let w = g.weights(k);
            assert_eq!(w.len(), k);
            let sum: f64 = w.iter().sum();
            assert!((sum - 1.0).abs() < 1e-9);
            assert!(w.iter().all(|&x| x > 0.0));
        });
    }

    #[test]
    fn size_biased_small_but_covers_range() {
        let mut g = Gen { rng: Rng::new(42), seed: 42 };
        let sizes: Vec<usize> = (0..2000).map(|_| g.size(1000)).collect();
        assert!(sizes.iter().any(|&s| s <= 3));
        assert!(sizes.iter().any(|&s| s > 500));
        assert!(sizes.iter().all(|&s| (1..=1000).contains(&s)));
    }
}

//! Hand-rolled TOML-subset parser shared by `pallas-lint`'s config
//! files (`lint-allow.toml`, `lint-order.toml`) and the scheduler
//! barometer's scenario files (`rust/bench/scenarios/*.toml`).
//!
//! Hand-rolled on purpose — neither consumer may grow a dependency for
//! a page of config syntax. The subset is deliberately small:
//!
//! - `#` full-line comments and blank lines
//! - `[section]` tables and `[[section]]` array-of-table headers
//! - `key = value` pairs, where a value is a double-quoted string
//!   (no escapes), an integer, a float, `true`/`false`, or a
//!   single-line `[list]` of those
//!
//! Anything outside the subset is a parse error carrying the 1-based
//! line number, so config typos fail loudly (pallas-lint and
//! `bench-bar` both exit 2 on a config error rather than linting or
//! measuring against a half-read file).
//!
//! This file is `#[path]`-included by the `pallas-lint` crate as well
//! as built into `dnc_serve` as `util::toml`, so it must stay
//! std-only and free of `crate::` references.

/// A parsed value. The subset has no dates, no nested tables inside
/// values, and no multi-line anything.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    List(Vec<Value>),
}

impl Value {
    pub fn type_name(&self) -> &'static str {
        match self {
            Value::Str(_) => "a string",
            Value::Int(_) => "an integer",
            Value::Float(_) => "a float",
            Value::Bool(_) => "a bool",
            Value::List(_) => "a list",
        }
    }
}

/// One `key = value` pair, tagged with its source line for error
/// reporting.
#[derive(Debug, Clone, PartialEq)]
pub struct Item {
    pub key: String,
    pub value: Value,
    pub line: usize,
}

impl Item {
    fn type_err(&self, want: &str) -> String {
        format!(
            "line {}: `{}` expects {want}, got {}",
            self.line,
            self.key,
            self.value.type_name()
        )
    }

    /// The value as a string, or a line-tagged type error.
    pub fn str(&self) -> Result<&str, String> {
        match &self.value {
            Value::Str(s) => Ok(s),
            _ => Err(self.type_err("a double-quoted string")),
        }
    }

    /// The value as an integer, or a line-tagged type error.
    pub fn int(&self) -> Result<i64, String> {
        match self.value {
            Value::Int(n) => Ok(n),
            _ => Err(self.type_err("an integer")),
        }
    }

    /// The value as a float (integers coerce), or a line-tagged type
    /// error.
    pub fn f64(&self) -> Result<f64, String> {
        match self.value {
            Value::Float(x) => Ok(x),
            Value::Int(n) => Ok(n as f64),
            _ => Err(self.type_err("a number")),
        }
    }

    /// The value as a bool, or a line-tagged type error.
    pub fn bool(&self) -> Result<bool, String> {
        match self.value {
            Value::Bool(b) => Ok(b),
            _ => Err(self.type_err("`true` or `false`")),
        }
    }

    /// The value as a list of strings, or a line-tagged type error.
    pub fn str_list(&self) -> Result<Vec<String>, String> {
        let items = match &self.value {
            Value::List(xs) => xs,
            _ => return Err(self.type_err("a [list] of strings")),
        };
        let mut out = Vec::with_capacity(items.len());
        for v in items {
            match v {
                Value::Str(s) => out.push(s.clone()),
                other => {
                    return Err(format!(
                        "line {}: `{}` expects a [list] of double-quoted strings, \
                         got a list holding {}",
                        self.line,
                        self.key,
                        other.type_name()
                    ))
                }
            }
        }
        Ok(out)
    }
}

/// One `[name]` or `[[name]]` section and the items under it.
#[derive(Debug, Clone, PartialEq)]
pub struct Section {
    pub name: String,
    /// `true` for `[[name]]` array-of-table headers (repeatable),
    /// `false` for plain `[name]` tables (unique per document).
    pub array: bool,
    pub line: usize,
    pub items: Vec<Item>,
}

/// A parsed document: top-level items (those before any section
/// header) plus sections in source order.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct Doc {
    pub top: Vec<Item>,
    pub sections: Vec<Section>,
}

impl Doc {
    /// Parse a document, or return a `line N: ...` error. Duplicate
    /// plain `[name]` tables are rejected here (TOML semantics);
    /// duplicate keys are left to the consumer, because some configs
    /// use repeatable keys (`field`, `order`) on purpose.
    pub fn parse(text: &str) -> Result<Doc, String> {
        let mut doc = Doc::default();
        for (idx, raw) in text.lines().enumerate() {
            let line_no = idx + 1;
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            if line.starts_with('[') {
                let (name, array) = parse_header(line, line_no)?;
                if !array
                    && doc.sections.iter().any(|s| !s.array && s.name == name)
                {
                    return Err(format!("line {line_no}: duplicate section [{name}]"));
                }
                doc.sections.push(Section { name, array, line: line_no, items: Vec::new() });
                continue;
            }
            let (key, value) = line
                .split_once('=')
                .ok_or_else(|| format!("line {line_no}: expected `key = value`, got `{line}`"))?;
            let key = key.trim();
            if key.is_empty() || !key.chars().all(|c| c.is_ascii_alphanumeric() || c == '_') {
                return Err(format!("line {line_no}: malformed key `{key}`"));
            }
            let item = Item {
                key: key.to_string(),
                value: parse_value(value.trim(), line_no)?,
                line: line_no,
            };
            match doc.sections.last_mut() {
                Some(sec) => sec.items.push(item),
                None => doc.top.push(item),
            }
        }
        Ok(doc)
    }

    /// The unique plain `[name]` section, if present.
    pub fn section(&self, name: &str) -> Option<&Section> {
        self.sections.iter().find(|s| !s.array && s.name == name)
    }

    /// All `[[name]]` array sections, in source order.
    pub fn array_sections(&self, name: &str) -> Vec<&Section> {
        self.sections.iter().filter(|s| s.array && s.name == name).collect()
    }
}

fn parse_header(line: &str, line_no: usize) -> Result<(String, bool), String> {
    let bad = || format!("line {line_no}: malformed section header `{line}`");
    let (inner, array) = if let Some(rest) = line.strip_prefix("[[") {
        (rest.strip_suffix("]]").ok_or_else(bad)?, true)
    } else {
        let rest = line.strip_prefix('[').ok_or_else(bad)?;
        (rest.strip_suffix(']').ok_or_else(bad)?, false)
    };
    let name = inner.trim();
    if name.is_empty()
        || !name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '.')
    {
        return Err(bad());
    }
    Ok((name.to_string(), array))
}

fn parse_value(v: &str, line_no: usize) -> Result<Value, String> {
    if let Some(rest) = v.strip_prefix('"') {
        let inner = rest
            .strip_suffix('"')
            .filter(|_| v.len() >= 2)
            .ok_or_else(|| {
                format!("line {line_no}: expected a double-quoted string, got `{v}`")
            })?;
        if inner.contains('"') || inner.contains('\\') {
            return Err(format!(
                "line {line_no}: string escapes and embedded quotes are not supported"
            ));
        }
        return Ok(Value::Str(inner.to_string()));
    }
    if v == "true" {
        return Ok(Value::Bool(true));
    }
    if v == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(rest) = v.strip_prefix('[') {
        let inner = rest
            .strip_suffix(']')
            .ok_or_else(|| format!("line {line_no}: unclosed `[list]`"))?;
        let mut out = Vec::new();
        for part in split_list(inner, line_no)? {
            if part.starts_with('[') {
                return Err(format!("line {line_no}: nested lists are not supported"));
            }
            out.push(parse_value(&part, line_no)?);
        }
        return Ok(Value::List(out));
    }
    // Only digit-shaped tokens are tried as numbers, so bare words
    // (including `inf` / `nan`, which `f64::from_str` would accept)
    // fall through to the catch-all error.
    if v.starts_with(|c: char| c.is_ascii_digit() || c == '-' || c == '+' || c == '.') {
        if let Ok(n) = v.parse::<i64>() {
            return Ok(Value::Int(n));
        }
        if let Ok(x) = v.parse::<f64>() {
            if x.is_finite() {
                return Ok(Value::Float(x));
            }
        }
    }
    Err(format!(
        "line {line_no}: expected a double-quoted string, number, bool, or [list], got `{v}`"
    ))
}

/// Split a list body on commas that are outside double quotes. Keeps
/// the parser single-pass and escape-free like the rest of the subset.
fn split_list(inner: &str, line_no: usize) -> Result<Vec<String>, String> {
    let mut parts = Vec::new();
    let mut cur = String::new();
    let mut in_str = false;
    for c in inner.chars() {
        match c {
            '"' => {
                in_str = !in_str;
                cur.push(c);
            }
            ',' if !in_str => {
                parts.push(cur.trim().to_string());
                cur.clear();
            }
            _ => cur.push(c),
        }
    }
    if in_str {
        return Err(format!("line {line_no}: unterminated string in list"));
    }
    let last = cur.trim().to_string();
    if !last.is_empty() {
        parts.push(last);
    } else if !parts.is_empty() {
        // trailing comma: `["a",]` is fine, `["a",,]` is not
        // (the empty middle element already landed in `parts`).
    }
    if parts.iter().any(|p| p.is_empty()) {
        return Err(format!("line {line_no}: empty element in [list]"));
    }
    Ok(parts)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_items_and_value_types() {
        let doc = Doc::parse(
            r#"
# comment
top = "level"

[scenario]
name = "longshort"
tolerance_pct = 35
base_ms = 2.5
measured = false
engines = ["static", "adaptive"]

[[part]]
count = 3
"#,
        )
        .unwrap();
        assert_eq!(doc.top.len(), 1);
        assert_eq!(doc.top[0].str().unwrap(), "level");
        let sc = doc.section("scenario").unwrap();
        assert_eq!(sc.items.len(), 5);
        assert_eq!(sc.items[0].str().unwrap(), "longshort");
        assert_eq!(sc.items[1].int().unwrap(), 35);
        assert_eq!(sc.items[1].f64().unwrap(), 35.0, "ints coerce to f64");
        assert_eq!(sc.items[2].f64().unwrap(), 2.5);
        assert!(!sc.items[3].bool().unwrap());
        assert_eq!(sc.items[4].str_list().unwrap(), vec!["static", "adaptive"]);
        let parts = doc.array_sections("part");
        assert_eq!(parts.len(), 1);
        assert!(parts[0].array);
        assert_eq!(parts[0].items[0].int().unwrap(), 3);
    }

    #[test]
    fn line_numbers_are_one_based_and_survive_comments() {
        let doc = Doc::parse("# one\n\n[s]\nk = 1\n").unwrap();
        assert_eq!(doc.section("s").unwrap().line, 3);
        assert_eq!(doc.section("s").unwrap().items[0].line, 4);
    }

    #[test]
    fn rejects_malformed_lines() {
        for (text, want) in [
            ("just words", "expected `key = value`"),
            ("[unclosed", "malformed section header"),
            ("[[half]", "malformed section header"),
            ("[]", "malformed section header"),
            ("k-ey = 1", "malformed key"),
            ("k = \"unterminated", "expected a double-quoted string"),
            ("k = bareword", "expected a double-quoted string, number, bool"),
            ("k = [1, [2]]", "nested lists"),
            ("k = [1, 2", "unclosed `[list]`"),
            ("k = [\"a\", , \"b\"]", "empty element"),
            ("k = inf", "expected a double-quoted string, number, bool"),
            ("k = \"has \\\\ escape\"", "escapes"),
        ] {
            let err = Doc::parse(text).unwrap_err();
            assert!(err.contains(want), "for `{text}` expected `{want}`, got: {err}");
            assert!(err.contains("line 1"), "for `{text}` got: {err}");
        }
    }

    #[test]
    fn rejects_duplicate_plain_sections_but_not_array_sections() {
        let err = Doc::parse("[m]\nk = 1\n[m]\nk = 2\n").unwrap_err();
        assert!(err.contains("duplicate section [m]"), "got: {err}");
        let doc = Doc::parse("[[a]]\nk = 1\n[[a]]\nk = 2\n").unwrap();
        assert_eq!(doc.array_sections("a").len(), 2);
    }

    #[test]
    fn type_errors_name_the_key_and_line() {
        let doc = Doc::parse("[s]\nk = 5\n").unwrap();
        let err = doc.section("s").unwrap().items[0].str().unwrap_err();
        assert!(err.contains("line 2"), "got: {err}");
        assert!(err.contains("`k`"), "got: {err}");
        assert!(err.contains("an integer"), "got: {err}");
    }

    #[test]
    fn negative_and_float_forms_parse() {
        let doc = Doc::parse("[s]\na = -4\nb = 0.5\nc = -1.5\n").unwrap();
        let s = doc.section("s").unwrap();
        assert_eq!(s.items[0].int().unwrap(), -4);
        assert_eq!(s.items[1].f64().unwrap(), 0.5);
        assert_eq!(s.items[2].f64().unwrap(), -1.5);
    }
}

//! Poison-recovering lock helpers — the one way this crate takes a
//! `Mutex`/`RwLock` guard outside `#[cfg(test)]` code.
//!
//! A panicking holder poisons a std lock, and every later
//! `.lock().unwrap()` then propagates that panic into threads that had
//! nothing to do with the original failure — a single crashed executor
//! taking down the dispatcher, the metrics snapshot, and every serving
//! connection. Each lock in this crate guards a structurally consistent
//! value (plain maps/vecs mutated by single inserts or drains), so the
//! right response to poison is to keep going with the data as it is,
//! not to spread the panic. `pallas-lint` rule **PL002** enforces the
//! contract: guard acquisition goes through these helpers, never
//! through `.unwrap()`/`.expect()` on the `LockResult`.

use std::sync::{
    Mutex, MutexGuard, PoisonError, RwLock, RwLockReadGuard, RwLockWriteGuard,
};

/// Lock `m`, recovering the guard if a previous holder panicked.
pub fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Read-lock `l`, recovering the guard if a previous holder panicked.
pub fn read_recover<T>(l: &RwLock<T>) -> RwLockReadGuard<'_, T> {
    l.read().unwrap_or_else(PoisonError::into_inner)
}

/// Write-lock `l`, recovering the guard if a previous holder panicked.
pub fn write_recover<T>(l: &RwLock<T>) -> RwLockWriteGuard<'_, T> {
    l.write().unwrap_or_else(PoisonError::into_inner)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn lock_recover_survives_poison() {
        let m = Arc::new(Mutex::new(7u32));
        let m2 = Arc::clone(&m);
        // Poison the mutex: panic while holding the guard.
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison it");
        })
        .join();
        assert!(m.lock().is_err(), "mutex must actually be poisoned");
        assert_eq!(*lock_recover(&m), 7, "recovered guard sees the data");
        *lock_recover(&m) += 1;
        assert_eq!(*lock_recover(&m), 8);
    }

    #[test]
    fn rwlock_recover_survives_poison() {
        let l = Arc::new(RwLock::new(vec![1, 2, 3]));
        let l2 = Arc::clone(&l);
        let _ = std::thread::spawn(move || {
            let _g = l2.write().unwrap();
            panic!("poison it");
        })
        .join();
        assert!(l.read().is_err(), "rwlock must actually be poisoned");
        assert_eq!(read_recover(&l).len(), 3);
        write_recover(&l).push(4);
        assert_eq!(read_recover(&l).len(), 4);
    }

    #[test]
    fn helpers_are_plain_locks_when_healthy() {
        let m = Mutex::new(String::from("ok"));
        lock_recover(&m).push('!');
        assert_eq!(*lock_recover(&m), "ok!");
        let l = RwLock::new(0u8);
        *write_recover(&l) = 9;
        assert_eq!(*read_recover(&l), 9);
    }
}

//! Deterministic PRNG (splitmix64 seeding + xoshiro256**) — the offline
//! substitute for the `rand` crate. Every workload generator and property
//! test in the repo draws from this so runs are reproducible from a seed.

#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9e3779b97f4a7c15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Independent child stream (for per-thread / per-image generators).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }

    pub fn next_u64(&mut self) -> u64 {
        // xoshiro256**
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform in [lo, hi] inclusive. Panics if lo > hi.
    pub fn u64_in(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        let span = hi - lo + 1;
        if span == 0 {
            return self.next_u64(); // full range
        }
        // Lemire-style rejection-free enough for non-crypto use.
        lo + self.next_u64() % span
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        self.u64_in(lo as u64, hi as u64) as usize
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    pub fn choice<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        assert!(!items.is_empty());
        &items[self.usize_in(0, items.len() - 1)]
    }

    /// Sample an index from unnormalized weights.
    pub fn weighted_index(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0);
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.usize_in(0, i);
            items.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn u64_in_bounds_inclusive() {
        let mut r = Rng::new(4);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..10_000 {
            let x = r.u64_in(5, 9);
            assert!((5..=9).contains(&x));
            seen_lo |= x == 5;
            seen_hi |= x == 9;
        }
        assert!(seen_lo && seen_hi);
    }

    #[test]
    fn normal_moments_roughly_standard() {
        let mut r = Rng::new(5);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn weighted_index_respects_weights() {
        let mut r = Rng::new(6);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..20_000 {
            counts[r.weighted_index(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((2.5..3.5).contains(&ratio), "ratio={ratio}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(8);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn fork_streams_independent() {
        let mut parent = Rng::new(9);
        let mut c1 = parent.fork();
        let mut c2 = parent.fork();
        let same = (0..64).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert!(same < 2);
    }
}

//! Minimal JSON parser/writer (offline substitute for serde_json).
//!
//! Covers the full JSON grammar we produce in `python/compile/aot.py`
//! (objects, arrays, strings with escapes, numbers, bools, null) plus a
//! typed accessor layer tailored to manifest/meta lookups. Object key
//! order is preserved (Vec of pairs) so writer output is deterministic.

use std::collections::HashMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---------------------------------------------------------------- parse

    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0, depth: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn parse_file(path: &std::path::Path) -> anyhow::Result<Json> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        Ok(Self::parse(&text).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?)
    }

    // ------------------------------------------------------------ accessors

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// `get` that errors with the key name — manifest lookups want context.
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing key '{key}'"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|f| if f >= 0.0 { Some(f as usize) } else { None })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(v) => Some(v),
            _ => None,
        }
    }

    /// Convenience: object -> HashMap view (borrows).
    pub fn obj_map(&self) -> HashMap<&str, &Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().map(|(k, v)| (k.as_str(), v)).collect(),
            _ => HashMap::new(),
        }
    }

    pub fn usize_arr(&self) -> anyhow::Result<Vec<usize>> {
        self.as_arr()
            .ok_or_else(|| anyhow::anyhow!("expected array"))?
            .iter()
            .map(|v| v.as_usize().ok_or_else(|| anyhow::anyhow!("expected usize")))
            .collect()
    }

    pub fn f32_arr(&self) -> anyhow::Result<Vec<f32>> {
        self.as_arr()
            .ok_or_else(|| anyhow::anyhow!("expected array"))?
            .iter()
            .map(|v| {
                v.as_f64()
                    .map(|f| f as f32)
                    .ok_or_else(|| anyhow::anyhow!("expected number"))
            })
            .collect()
    }

    // -------------------------------------------------------------- writing

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                // write! into the buffer directly — a temporary String per
                // number dominated serialize time for embedding responses
                // (§Perf).
                use std::fmt::Write;
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(pairs) => {
                out.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Builder helpers so call-sites stay terse.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
    Json::Arr(items.into_iter().collect())
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                use std::fmt::Write;
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Nesting bound for untrusted input. The parser recurses per `[`/`{`,
/// so a request body of a few KB of open brackets would otherwise
/// overflow the connection thread's stack — an *abort*, not a
/// catchable error. 128 is far beyond any manifest or request this
/// crate produces (their depth is < 10).
const MAX_DEPTH: usize = 128;

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
    depth: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    /// Guard one level of `[`/`{` recursion; the matching `depth -= 1`
    /// sits at each container's return points.
    fn descend(&mut self) -> Result<(), JsonError> {
        self.depth += 1;
        if self.depth > MAX_DEPTH {
            return Err(self.err("nesting too deep"));
        }
        Ok(())
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])
            .map_err(|_| self.err("invalid number"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // BMP only — enough for our ASCII manifests.
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().ok_or_else(|| self.err("invalid utf-8"))?;
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        self.descend()?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            self.depth -= 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    self.depth -= 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        self.descend()?;
        let mut pairs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            self.depth -= 1;
            return Ok(Json::Obj(pairs));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let value = self.value()?;
            pairs.push((key, value));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    self.depth -= 1;
                    return Ok(Json::Obj(pairs));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b").unwrap(), &Json::Null);
    }

    #[test]
    fn preserves_key_order_and_roundtrips() {
        let src = r#"{"z":1,"a":2,"m":[true,false,null]}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.to_string(), src);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{'single': 1}").is_err());
    }

    #[test]
    fn malformed_request_bodies_error_instead_of_panicking() {
        // Truncated / garbage shapes a client can actually send the
        // router; every one must come back as Err, never a panic.
        for body in [
            "",
            "{\"op\": \"embed\", \"texts\": [\"a\",", // truncated mid-array
            "{\"op\":",                               // truncated mid-object
            "\u{0}\u{1}\u{2}",                        // binary garbage
            "{\"n\": 1e}",                            // malformed number
            "nul",                                    // truncated literal
            "[1, 2",                                  // unterminated array
            "{\"a\" 1}",                              // missing colon
            "\"\\u12\"",                              // truncated \u escape
            "-",                                      // sign with no digits
        ] {
            assert!(Json::parse(body).is_err(), "must reject: {body:?}");
        }
    }

    #[test]
    fn deep_nesting_is_an_error_not_a_stack_overflow() {
        // A few KB of '[' used to abort the process by exhausting the
        // connection thread's stack before the parser ever saw EOF.
        let bomb = "[".repeat(100_000);
        let err = Json::parse(&bomb).unwrap_err();
        assert!(err.msg.contains("nesting too deep"), "got: {err}");
        let obj_bomb = "{\"k\":".repeat(100_000);
        assert!(Json::parse(&obj_bomb).is_err());
        // ...while anything a real manifest/request produces stays fine.
        let deep_ok = format!("{}1{}", "[".repeat(100), "]".repeat(100));
        assert!(Json::parse(&deep_ok).is_ok());
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Json::Str("line\n\"quote\"\t\\".into());
        let back = Json::parse(&v.to_string()).unwrap();
        assert_eq!(back, v);
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "Aé");
    }

    #[test]
    fn typed_accessors() {
        let v = Json::parse(r#"{"n": 7, "xs": [1.5, 2.5], "s": [3, 4]}"#).unwrap();
        assert_eq!(v.get("n").unwrap().as_usize().unwrap(), 7);
        assert_eq!(v.get("xs").unwrap().f32_arr().unwrap(), vec![1.5, 2.5]);
        assert_eq!(v.get("s").unwrap().usize_arr().unwrap(), vec![3, 4]);
        assert!(v.req("missing").is_err());
    }

    #[test]
    fn builder_helpers() {
        let v = obj(vec![("k", num(1.0)), ("l", arr([s("a"), Json::Null]))]);
        assert_eq!(v.to_string(), r#"{"k":1,"l":["a",null]}"#);
    }

    #[test]
    fn parses_python_indented_output() {
        // json.dump(indent=1) style
        let src = "{\n \"a\": 1,\n \"b\": [\n  1,\n  2\n ]\n}";
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("b").unwrap().usize_arr().unwrap(), vec![1, 2]);
    }
}

//! Hot-path clock shim.
//!
//! The scheduler's event-driven wakeups, EWMA worker placement, and
//! budget arithmetic all read wall time on hot paths. Reading
//! `Instant::now()` directly at every one of those sites makes the
//! timing untestable — a test that wants to prove "a blocked queue
//! takes zero wakeups for an hour" has to actually sleep. Routing the
//! reads through [`now`] keeps every hot-path time observation behind
//! one seam that tests can override; `pallas-lint` rule **PL003**
//! enforces that `engine/sched.rs` and `runtime/pool.rs` use it.
//!
//! In non-test builds [`now`] compiles down to `Instant::now()` — the
//! override hook only exists under `cfg(test)`.

use std::time::Instant;

/// The crate's hot-path time source. Equivalent to `Instant::now()`
/// unless a test on the *current thread* installed an override via
/// [`mock::freeze`].
pub fn now() -> Instant {
    #[cfg(test)]
    if let Some(t) = mock::frozen() {
        return t;
    }
    Instant::now()
}

/// Test-only clock control. The override is thread-local: it affects
/// `clock::now()` calls made by the test's own thread (unit tests that
/// drive scheduler state machines directly), not worker threads — those
/// keep real time, which is what the integration tests measure.
#[cfg(test)]
pub mod mock {
    use std::cell::Cell;
    use std::time::{Duration, Instant};

    thread_local! {
        static FROZEN: Cell<Option<Instant>> = const { Cell::new(None) };
    }

    pub(super) fn frozen() -> Option<Instant> {
        FROZEN.with(|f| f.get())
    }

    /// Freeze this thread's `clock::now()` at `t` until [`thaw`].
    pub fn freeze(t: Instant) {
        FROZEN.with(|f| f.set(Some(t)));
    }

    /// Advance a frozen clock by `d` (no-op when not frozen).
    pub fn advance(d: Duration) {
        FROZEN.with(|f| {
            if let Some(t) = f.get() {
                f.set(Some(t + d));
            }
        });
    }

    /// Return this thread's `clock::now()` to real time.
    pub fn thaw() {
        FROZEN.with(|f| f.set(None));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn real_time_by_default() {
        let a = now();
        let b = now();
        assert!(b >= a);
    }

    #[test]
    fn freeze_advance_thaw() {
        let t0 = Instant::now();
        mock::freeze(t0);
        assert_eq!(now(), t0);
        assert_eq!(now(), t0, "frozen clock does not tick");
        mock::advance(Duration::from_secs(5));
        assert_eq!(now(), t0 + Duration::from_secs(5));
        mock::thaw();
        assert!(now() >= t0, "thawed clock is real time again");
    }

    #[test]
    fn override_is_thread_local() {
        let t0 = Instant::now();
        mock::freeze(t0);
        let other = std::thread::spawn(move || now()).join().unwrap();
        // The spawned thread saw real time, strictly after our freeze
        // point was minted.
        assert!(other >= t0);
        mock::thaw();
    }
}

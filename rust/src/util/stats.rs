//! Small statistics helpers shared by metrics and the bench harness.

/// Mean of a slice (0.0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Sample standard deviation (0.0 for n < 2).
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    let var = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64;
    var.sqrt()
}

/// Percentile by linear interpolation on a *sorted* slice, q in [0, 100].
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=100.0).contains(&q));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q / 100.0 * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] + (sorted[hi] - sorted[lo]) * frac
}

/// Sort a copy and take percentiles in one pass.
pub fn percentiles(xs: &[f64], qs: &[f64]) -> Vec<f64> {
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    qs.iter().map(|&q| percentile_sorted(&sorted, q)).collect()
}

/// Geometric mean (for speedup summaries).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let log_sum: f64 = xs.iter().map(|x| x.max(1e-300).ln()).sum();
    (log_sum / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_stddev() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((stddev(&xs) - 2.138089935).abs() < 1e-6);
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(stddev(&[1.0]), 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile_sorted(&xs, 0.0), 1.0);
        assert_eq!(percentile_sorted(&xs, 100.0), 4.0);
        assert!((percentile_sorted(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn percentiles_unsorted_input() {
        let xs = [9.0, 1.0, 5.0];
        let ps = percentiles(&xs, &[0.0, 50.0, 100.0]);
        assert_eq!(ps, vec![1.0, 5.0, 9.0]);
    }

    #[test]
    fn geomean_of_speedups() {
        let xs = [2.0, 8.0];
        assert!((geomean(&xs) - 4.0).abs() < 1e-9);
    }
}

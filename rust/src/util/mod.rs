//! Infrastructure the offline environment requires us to own: JSON,
//! PRNG, CLI parsing, logging, stats, and a mini property-testing kit.

pub mod args;
pub mod json;
pub mod logging;
pub mod prng;
pub mod prop;
pub mod stats;

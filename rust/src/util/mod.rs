//! Infrastructure the offline environment requires us to own: JSON,
//! TOML-subset parsing, PRNG, CLI parsing, logging, stats, a mini
//! property-testing kit, and the crate-wide concurrency shims
//! (poison-recovering locks, the hot-path clock) that `pallas-lint`
//! holds the rest of the tree to.

pub mod args;
pub mod clock;
pub mod json;
pub mod logging;
pub mod prng;
pub mod prop;
pub mod stats;
pub mod sync;
pub mod toml;

//! Motion detection: frame differencing + connected components.
//!
//! The classic first stage of a video-analytics pipeline (the paper's §6
//! cites [21, 29] as prun targets): regions that changed since the last
//! frame become candidate objects. Because our objects move on a dark
//! background, a changed region is the union of the object's old and new
//! positions; we then snap to the *current* object rectangle by running
//! the same brightness-projection refine the OCR detector uses.

use crate::ocr::detect::{components, DetBox};
use crate::ocr::imagegen::Image;
use crate::ocr::meta::OcrMeta;

/// Per-pixel change threshold.
pub const DIFF_THRESH: f32 = 0.1;

/// Difference mask at score-map resolution: fraction of changed pixels
/// per stride x stride cell (cheap downsample so `components` reuses the
/// OCR grid machinery).
pub fn diff_mask(prev: &[f32], curr: &[f32], meta: &OcrMeta) -> Vec<f32> {
    let plane = meta.img_h * meta.img_w;
    assert_eq!(prev.len(), 3 * plane);
    assert_eq!(curr.len(), 3 * plane);
    let gh = meta.img_h.div_ceil(meta.stride);
    let gw = meta.img_w.div_ceil(meta.stride);
    let mut mask = vec![0.0f32; gh * gw];
    for r in 0..meta.img_h {
        for c in 0..meta.img_w {
            let idx = r * meta.img_w + c;
            // channel 0 is representative (channels are near-identical)
            if (curr[idx] - prev[idx]).abs() > DIFF_THRESH {
                mask[(r / meta.stride) * gw + c / meta.stride] = 1.0;
            }
        }
    }
    mask
}

/// Moving regions in the current frame: diff components refined against
/// the current pixels (snaps the old+new union to the new rectangle).
pub fn moving_regions(prev: &[f32], curr: &[f32], meta: &OcrMeta) -> Vec<DetBox> {
    let mask = diff_mask(prev, curr, meta);
    let gh = meta.img_h.div_ceil(meta.stride);
    let gw = meta.img_w.div_ceil(meta.stride);
    let img = Image { pixels: curr.to_vec(), boxes: vec![] };
    components(&mask, gh, gw)
        .iter()
        .filter_map(|rough| crate::ocr::detect::refine(&img, meta, rough))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifacts_dir;
    use crate::util::prng::Rng;
    use crate::video::framegen::{render_frame, scene};

    fn meta() -> Option<OcrMeta> {
        let dir = artifacts_dir();
        if !dir.join("ocr_meta.json").exists() {
            return None;
        }
        Some(OcrMeta::load(&dir).unwrap())
    }

    #[test]
    fn identical_frames_no_motion() {
        let Some(m) = meta() else { return };
        let mut rng = Rng::new(4);
        let sc = scene(&m, &mut rng, 2);
        let f = render_frame(&sc, &m, 0);
        assert!(moving_regions(&f, &f, &m).is_empty());
    }

    #[test]
    fn moving_object_found_at_current_position() {
        let Some(m) = meta() else { return };
        let mut rng = Rng::new(5);
        let sc = scene(&m, &mut rng, 1);
        let f0 = render_frame(&sc, &m, 0);
        let f1 = render_frame(&sc, &m, 1);
        let regions = moving_regions(&f0, &f1, &m);
        assert_eq!(regions.len(), 1);
        let (x, y) = sc.tracks[0].position(1, &m);
        assert_eq!(regions[0].x, x);
        assert_eq!(regions[0].y, y);
        assert_eq!(regions[0].width, sc.tracks[0].width);
    }

    #[test]
    fn multiple_separated_objects_all_found() {
        let Some(m) = meta() else { return };
        // hand-placed well-separated tracks to avoid union overlaps
        use crate::video::framegen::{ObjectTrack, Scene};
        let sc = Scene {
            tracks: vec![
                ObjectTrack { label: "abc".into(), width: m.text_width(3), x0: 10.0, y0: 10.0, vx: 3.0, vy: 0.0 },
                ObjectTrack { label: "xyz9".into(), width: m.text_width(4), x0: 150.0, y0: 120.0, vx: -3.0, vy: 0.0 },
            ],
        };
        let f0 = render_frame(&sc, &m, 0);
        let f1 = render_frame(&sc, &m, 1);
        let regions = moving_regions(&f0, &f1, &m);
        assert_eq!(regions.len(), 2, "{regions:?}");
    }
}

//! Synthetic video generator: labeled objects (glyph-coded boxes, the
//! same codebook as OCR) moving across a static background. Frame t is
//! fully determined by (seed, t), so motion detection has exact ground
//! truth and object labels are exactly decodable.

use crate::ocr::imagegen::column_pattern;
use crate::ocr::meta::OcrMeta;
use crate::runtime::Tensor;
use crate::util::prng::Rng;

/// A moving object: a glyph-labeled box on a linear trajectory.
#[derive(Debug, Clone)]
pub struct ObjectTrack {
    pub label: String,
    pub width: usize,
    /// position at t=0 (top-left)
    pub x0: f64,
    pub y0: f64,
    /// velocity px/frame
    pub vx: f64,
    pub vy: f64,
}

impl ObjectTrack {
    pub fn position(&self, t: usize, meta: &OcrMeta) -> (usize, usize) {
        let max_x = (meta.img_w - self.width) as f64;
        let max_y = (meta.img_h - meta.box_h) as f64;
        // bounce off the frame edges
        (
            bounce(self.x0 + self.vx * t as f64, max_x) as usize,
            bounce(self.y0 + self.vy * t as f64, max_y) as usize,
        )
    }
}

fn bounce(x: f64, max: f64) -> f64 {
    if max <= 0.0 {
        return 0.0;
    }
    let period = 2.0 * max;
    let m = x.rem_euclid(period);
    if m <= max {
        m
    } else {
        period - m
    }
}

#[derive(Debug, Clone)]
pub struct Scene {
    pub tracks: Vec<ObjectTrack>,
}

/// Generate a scene of `n_objects` with labels of 3..8 chars.
pub fn scene(meta: &OcrMeta, rng: &mut Rng, n_objects: usize) -> Scene {
    let tracks = (0..n_objects)
        .map(|_| {
            let len = rng.usize_in(3, 8);
            let label: String = (0..len)
                .map(|_| meta.charset[rng.usize_in(0, meta.charset.len() - 1)])
                .collect();
            let width = meta.text_width(len);
            ObjectTrack {
                label,
                width,
                x0: rng.f64_in(0.0, (meta.img_w - width) as f64),
                y0: rng.f64_in(0.0, (meta.img_h - meta.box_h) as f64),
                vx: rng.f64_in(2.0, 7.0) * if rng.bool(0.5) { 1.0 } else { -1.0 },
                vy: rng.f64_in(1.0, 4.0) * if rng.bool(0.5) { 1.0 } else { -1.0 },
            }
        })
        .collect();
    Scene { tracks }
}

/// Render frame `t` as channel-major pixels [3, H, W]. Overlapping
/// objects draw in track order (later tracks on top).
pub fn render_frame(scene: &Scene, meta: &OcrMeta, t: usize) -> Vec<f32> {
    let plane = meta.img_h * meta.img_w;
    let mut px = vec![0.0f32; 3 * plane];
    for track in &scene.tracks {
        let (x, y) = track.position(t, meta);
        let cols = column_pattern(meta, &track.label);
        for (j, &v) in cols.iter().enumerate() {
            for r in 0..meta.box_h {
                let base = (y + r) * meta.img_w + x + j;
                for ch in 0..3 {
                    px[ch * plane + base] = v;
                }
            }
        }
    }
    px
}

/// Frame as the recognizer-family input tensor [1, 3, H, W].
pub fn frame_tensor(pixels: &[f32], meta: &OcrMeta) -> Tensor {
    Tensor::f32(vec![1, 3, meta.img_h, meta.img_w], pixels.to_vec())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifacts_dir;

    fn meta() -> Option<OcrMeta> {
        let dir = artifacts_dir();
        if !dir.join("ocr_meta.json").exists() {
            return None;
        }
        Some(OcrMeta::load(&dir).unwrap())
    }

    #[test]
    fn bounce_stays_in_range() {
        for i in 0..200 {
            let x = bounce(i as f64 * 3.7 - 50.0, 100.0);
            assert!((0.0..=100.0).contains(&x), "{x}");
        }
    }

    #[test]
    fn positions_in_frame_forever() {
        let Some(m) = meta() else { return };
        let mut rng = Rng::new(1);
        let sc = scene(&m, &mut rng, 5);
        for t in 0..500 {
            for tr in &sc.tracks {
                let (x, y) = tr.position(t, &m);
                assert!(x + tr.width <= m.img_w);
                assert!(y + m.box_h <= m.img_h);
            }
        }
    }

    #[test]
    fn objects_actually_move() {
        let Some(m) = meta() else { return };
        let mut rng = Rng::new(2);
        let sc = scene(&m, &mut rng, 3);
        let a = render_frame(&sc, &m, 0);
        let b = render_frame(&sc, &m, 1);
        assert_ne!(a, b);
        // deterministic given (scene, t)
        assert_eq!(b, render_frame(&sc, &m, 1));
    }

    #[test]
    fn rendered_object_pixels_match_pattern() {
        let Some(m) = meta() else { return };
        let mut rng = Rng::new(3);
        let sc = scene(&m, &mut rng, 1);
        let t = 7;
        let px = render_frame(&sc, &m, t);
        let (x, y) = sc.tracks[0].position(t, &m);
        let pattern = column_pattern(&m, &sc.tracks[0].label);
        for (j, &want) in pattern.iter().enumerate() {
            assert_eq!(px[y * m.img_w + x + j], want, "col {j}");
        }
    }
}

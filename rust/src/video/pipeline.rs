//! Streaming video-analytics pipeline (paper §6's third prun target):
//! per frame, motion detection (rust) -> per-region label recognition
//! (the OCR recognizer artifacts) with `base` or `prun` execution —
//! structurally the OCR pipeline minus detection-by-model, plus state
//! (previous frame) carried across the stream.

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::engine::{JobPart, PrunOptions, Session};
use crate::ocr::decode;
use crate::ocr::imagegen::{crop_tensor, Image};
use crate::ocr::meta::OcrMeta;
use crate::simcpu::ocr::OcrVariant;

use super::motion;

#[derive(Debug)]
pub struct FrameResult {
    /// (x, y, decoded label) per moving region
    pub objects: Vec<(usize, usize, Option<String>)>,
    pub motion_time: Duration,
    pub recognize_time: Duration,
}

pub struct VideoPipeline {
    session: Arc<Session>,
    meta: OcrMeta,
    prev: Option<Vec<f32>>,
}

impl VideoPipeline {
    pub fn new(session: Arc<Session>, meta: OcrMeta) -> VideoPipeline {
        VideoPipeline { session, meta, prev: None }
    }

    pub fn meta(&self) -> &OcrMeta {
        &self.meta
    }

    /// Reset stream state (e.g. scene cut).
    pub fn reset(&mut self) {
        self.prev = None;
    }

    /// Process the next frame. The first frame only primes the
    /// differencer and reports no objects.
    pub fn next_frame(&mut self, pixels: &[f32], variant: OcrVariant) -> Result<FrameResult> {
        let Some(prev) = self.prev.replace(pixels.to_vec()) else {
            return Ok(FrameResult {
                objects: vec![],
                motion_time: Duration::ZERO,
                recognize_time: Duration::ZERO,
            });
        };

        let t0 = Instant::now();
        let regions = motion::moving_regions(&prev, pixels, &self.meta);
        let motion_time = t0.elapsed();

        let t1 = Instant::now();
        let img = Image { pixels: pixels.to_vec(), boxes: vec![] };
        let parts: Vec<JobPart> = regions
            .iter()
            .map(|b| {
                let bucket = self.meta.width_bucket(b.width)?;
                let crop = crop_tensor(&img, &self.meta, b.x, b.y, b.width, bucket, false);
                Ok(JobPart::new(format!("ocr_rec_w{bucket}"), vec![crop]))
            })
            .collect::<Result<_>>()?;
        let outputs = match variant {
            OcrVariant::Base => parts
                .into_iter()
                .map(|p| self.session.run(&p.model, p.inputs))
                .collect::<Result<Vec<_>>>()?,
            OcrVariant::Prun(policy) => {
                self.session
                    .prun(parts, PrunOptions { policy, ..Default::default() })?
                    .outputs
            }
        };
        let objects = regions
            .iter()
            .zip(outputs.iter())
            .map(|(b, out)| {
                let label = out[0]
                    .as_f32()
                    .ok()
                    .and_then(|logp| decode::decode(logp, out[0].shape[1], &self.meta).ok());
                (b.x, b.y, label)
            })
            .collect();
        Ok(FrameResult { objects, motion_time, recognize_time: t1.elapsed() })
    }
}

//! Streaming video-analytics pipeline (paper §6's third prun target):
//! per frame, motion detection (rust) -> per-region label recognition
//! (the OCR recognizer artifacts) with `base` or `prun` execution —
//! structurally the OCR pipeline minus detection-by-model, plus state
//! (previous frame) carried across the stream.
//!
//! The pipeline reaches the scheduler through the unified submission
//! API: [`VideoPipeline`] implements [`InferenceService`] over a
//! [`FrameJob`] (a stateless prev/next frame pair — the stream state
//! stays in [`VideoPipeline::next_frame`], which is a blocking
//! convenience over `submit`), so a frame's recognition runs under one
//! [`RequestCtx`] like every other workload: cancel it or let its
//! budget die and the region parts stop at the scheduler.

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::engine::{
    Allocation, InferenceService, JobPart, PrunRequest, RequestCtx, Session, SubmitError,
    SubmitTicket,
};
use crate::ocr::decode;
use crate::ocr::imagegen::{crop_tensor, Image};
use crate::ocr::meta::OcrMeta;
use crate::simcpu::ocr::OcrVariant;

use super::motion;

#[derive(Debug)]
pub struct FrameResult {
    /// (x, y, decoded label) per moving region
    pub objects: Vec<(usize, usize, Option<String>)>,
    pub motion_time: Duration,
    pub recognize_time: Duration,
}

/// One frame's work for [`VideoPipeline`]'s [`InferenceService`] impl:
/// the previous frame (differencing reference) and the frame to
/// analyse. Stateless by design — the streaming state lives in
/// [`VideoPipeline::next_frame`].
#[derive(Debug)]
pub struct FrameJob {
    pub prev: Vec<f32>,
    pub frame: Vec<f32>,
    pub variant: OcrVariant,
}

pub struct VideoPipeline {
    session: Arc<Session>,
    meta: Arc<OcrMeta>,
    prev: Option<Vec<f32>>,
}

impl VideoPipeline {
    pub fn new(session: Arc<Session>, meta: OcrMeta) -> VideoPipeline {
        VideoPipeline { session, meta: Arc::new(meta), prev: None }
    }

    pub fn meta(&self) -> &OcrMeta {
        &self.meta
    }

    /// Reset stream state (e.g. scene cut).
    pub fn reset(&mut self) {
        self.prev = None;
    }

    /// Process the next frame on behalf of `ctx`. The first frame only
    /// primes the differencer and reports no objects. Blocking
    /// convenience over [`InferenceService::submit`].
    pub fn next_frame(
        &mut self,
        pixels: &[f32],
        variant: OcrVariant,
        ctx: &RequestCtx,
    ) -> Result<FrameResult> {
        let Some(prev) = self.prev.replace(pixels.to_vec()) else {
            return Ok(FrameResult {
                objects: vec![],
                motion_time: Duration::ZERO,
                recognize_time: Duration::ZERO,
            });
        };
        let job = FrameJob { prev, frame: pixels.to_vec(), variant };
        let mut results = self
            .submit(job, ctx.clone())
            .wait()
            .map_err(anyhow::Error::new)?;
        Ok(results.pop().expect("one result per frame"))
    }
}

impl InferenceService for VideoPipeline {
    type Request = FrameJob;
    type Response = FrameResult;

    /// Motion-detect now (cheap CPU work), then hand every moving
    /// region's recognition to the scheduler under `ctx`. The
    /// single-item ticket settles the frame's [`FrameResult`]. The
    /// `base` variant executes lazily inside the wait (it is a
    /// sequential loop of full-budget runs by definition); `prun`
    /// submits all regions before returning.
    fn submit(&self, job: FrameJob, ctx: RequestCtx) -> SubmitTicket<FrameResult> {
        let t0 = Instant::now();
        let regions = motion::moving_regions(&job.prev, &job.frame, &self.meta);
        let motion_time = t0.elapsed();

        let t1 = Instant::now();
        let img = Image { pixels: job.frame, boxes: vec![] };
        let parts: Vec<JobPart> = match regions
            .iter()
            .map(|b| {
                let bucket = self.meta.width_bucket(b.width)?;
                let crop = crop_tensor(&img, &self.meta, b.x, b.y, b.width, bucket, false);
                Ok(JobPart::new(format!("ocr_rec_w{bucket}"), vec![crop]))
            })
            .collect::<Result<_>>()
        {
            Ok(parts) => parts,
            Err(e) => {
                return SubmitTicket::rejected(ctx, 1, SubmitError::Failed(format!("{e:#}")))
            }
        };
        let meta = Arc::clone(&self.meta);
        let positions: Vec<(usize, usize)> = regions.iter().map(|b| (b.x, b.y)).collect();
        let assemble = move |outputs: Vec<Vec<crate::runtime::Tensor>>| {
            let objects = positions
                .iter()
                .zip(outputs.iter())
                .map(|(&(x, y), out)| {
                    let label = out[0]
                        .as_f32()
                        .ok()
                        .and_then(|logp| decode::decode(logp, out[0].shape[1], &meta).ok());
                    (x, y, label)
                })
                .collect();
            FrameResult { objects, motion_time, recognize_time: t1.elapsed() }
        };

        match job.variant {
            OcrVariant::Base => {
                // Sequential full-budget runs: executed lazily when the
                // ticket is waited (each region still flows through the
                // scheduler under the request's ctx), honouring the
                // wait deadline between and *during* regions — a
                // deadline that strikes cancels the request and yields
                // `None`, the same contract as every other implementor.
                let session = Arc::clone(&self.session);
                let token = ctx.token();
                let lazy_ctx = ctx.clone();
                SubmitTicket::pending(
                    ctx,
                    Allocation::default(),
                    vec![token],
                    1,
                    Box::new(move |deadline| {
                        let mut outs = Vec::with_capacity(parts.len());
                        for p in parts {
                            if lazy_ctx.is_cancelled() {
                                return Some(vec![Err(SubmitError::Cancelled)]);
                            }
                            let t = session
                                .submit(PrunRequest::single(p), lazy_ctx.clone());
                            let results = match deadline {
                                None => t.wait_each(),
                                Some(d) => match t.wait_each_timeout(
                                    d.saturating_duration_since(Instant::now()),
                                ) {
                                    Some(r) => r,
                                    None => {
                                        // the region's ticket already
                                        // cancelled lazy_ctx's token
                                        return None;
                                    }
                                },
                            };
                            match results.into_iter().next() {
                                Some(Ok(done)) => outs.push(done.outputs),
                                Some(Err(e)) => return Some(vec![Err(e)]),
                                None => {
                                    return Some(vec![Err(SubmitError::Failed(
                                        "region part returned no result".to_string(),
                                    ))])
                                }
                            }
                        }
                        Some(vec![Ok(assemble(outs))])
                    }),
                )
            }
            OcrVariant::Prun(policy) => self
                .session
                .submit(PrunRequest::new(parts).with_policy(policy), ctx)
                .collapse(move |dones| {
                    assemble(dones.into_iter().map(|d| d.outputs).collect())
                }),
        }
    }
}

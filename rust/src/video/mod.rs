//! Video-analytics substrate — the paper's §6 third prun use case
//! ("other ML models that feature a pipeline-based architecture,
//! e.g. [21, 29]"): a streaming motion-detect -> per-region-recognize
//! pipeline over synthetic scenes with exact ground truth.

pub mod framegen;
pub mod motion;
pub mod pipeline;

pub use framegen::{frame_tensor, render_frame, scene, ObjectTrack, Scene};
pub use motion::moving_regions;
pub use pipeline::{FrameJob, FrameResult, VideoPipeline};

//! dnc-serve: Divide-and-Conquer inference serving.
//!
//! Reproduction of *"Improving Inference Performance of Machine Learning
//! with the Divide-and-Conquer Principle"* (Kogan, 2023) as a three-layer
//! Rust + JAX + Pallas stack: Pallas kernels (L1) and JAX models (L2) are
//! AOT-lowered to HLO text at build time; this crate (L3) loads and serves
//! them over PJRT with the paper's `prun` parallel-inference engine.

pub mod bar;
pub mod bench;
pub mod config;
pub mod coordinator;
pub mod engine;
pub mod metrics;
pub mod runtime;
pub mod nlp;
pub mod ocr;
pub mod simcpu;
pub mod workload;
pub mod util;
pub mod video;

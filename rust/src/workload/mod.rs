//! Workload generators reproducing the paper's evaluation inputs:
//! the Fig. 3 box-count distribution for OCR and the §4.2/§4.3
//! sequence-length patterns for BERT.

pub mod boxes;
pub mod seqlen;

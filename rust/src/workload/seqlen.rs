//! BERT workload generators: the sequence-length patterns of the paper's
//! §4.2/§4.3 experiments.

use crate::util::prng::Rng;

/// Fig. 6: batch of `x` lengths drawn uniformly from [16, 512].
pub fn random_batch(rng: &mut Rng, x: usize) -> Vec<usize> {
    (0..x).map(|_| rng.usize_in(16, 512)).collect()
}

/// Fig. 7's preset mixes, labeled as in the paper ("16-64-256" etc.).
pub fn preset_mixes() -> Vec<(&'static str, Vec<usize>)> {
    vec![
        ("16-64", vec![16, 64]),
        ("16-256", vec![16, 256]),
        ("16-64-256", vec![16, 64, 256]),
        ("64-128-256", vec![64, 128, 256]),
        ("16-64-256-512", vec![16, 64, 256, 512]),
        ("32-32-256-512", vec![32, 32, 256, 512]),
        ("16-16-16-512", vec![16, 16, 16, 512]),
        ("128-128-128-128-512", vec![128, 128, 128, 128, 512]),
    ]
}

/// Fig. 8: one long sequence (256) plus `x` short ones (16 each).
pub fn long_short(x: usize) -> Vec<usize> {
    let mut lens = vec![256];
    lens.extend(std::iter::repeat(16).take(x));
    lens
}

/// Fig. 9: homogeneous batch of 4 sequences of length `len`.
pub fn homogeneous(len: usize) -> Vec<usize> {
    vec![len; 4]
}

pub const FIG9_LENGTHS: [usize; 4] = [64, 128, 256, 512];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn random_batch_in_range() {
        let mut rng = Rng::new(1);
        for x in 2..=8 {
            let lens = random_batch(&mut rng, x);
            assert_eq!(lens.len(), x);
            assert!(lens.iter().all(|&l| (16..=512).contains(&l)));
        }
    }

    #[test]
    fn random_batch_covers_range() {
        let mut rng = Rng::new(2);
        let all: Vec<usize> = (0..500).flat_map(|_| random_batch(&mut rng, 4)).collect();
        assert!(all.iter().any(|&l| l < 64));
        assert!(all.iter().any(|&l| l > 448));
    }

    #[test]
    fn preset_mix_labels_match_contents() {
        for (label, lens) in preset_mixes() {
            let from_label: Vec<usize> =
                label.split('-').map(|s| s.parse().unwrap()).collect();
            assert_eq!(from_label, lens, "{label}");
        }
    }

    #[test]
    fn long_short_shapes() {
        assert_eq!(long_short(0), vec![256]);
        let l3 = long_short(3);
        assert_eq!(l3.len(), 4);
        assert_eq!(l3[0], 256);
        assert!(l3[1..].iter().all(|&l| l == 16));
    }

    #[test]
    fn homogeneous_is_four_equal() {
        assert_eq!(homogeneous(128), vec![128; 4]);
    }
}

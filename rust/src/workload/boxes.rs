//! OCR workload generator: the paper's evaluation dataset shape.
//!
//! The paper selects 500 OpenImages pictures with >= 2 detected text
//! boxes and reports the detected-box-count distribution as a pie chart
//! (Fig. 3). The exact percentages aren't tabulated; `BOX_COUNT_DIST`
//! encodes a right-skewed distribution consistent with the chart's
//! description (2 most common, a 10+ tail), mean ~4.3 boxes — the value
//! the simulator calibration uses (DESIGN.md §5).

use crate::util::prng::Rng;

/// (box count, probability) — counts of 10+ are drawn from 10..=14.
pub const BOX_COUNT_DIST: [(usize, f64); 9] = [
    (2, 0.30),
    (3, 0.19),
    (4, 0.14),
    (5, 0.10),
    (6, 0.08),
    (7, 0.06),
    (8, 0.05),
    (9, 0.04),
    (10, 0.04), // "10+" bucket
];

/// Sample a detected-box count from the Fig. 3 distribution.
pub fn sample_box_count(rng: &mut Rng) -> usize {
    let weights: Vec<f64> = BOX_COUNT_DIST.iter().map(|&(_, p)| p).collect();
    let idx = rng.weighted_index(&weights);
    let (count, _) = BOX_COUNT_DIST[idx];
    if count >= 10 {
        rng.usize_in(10, 14)
    } else {
        count
    }
}

/// Sample a text length (chars) for one box; widths follow as
/// `(len+1) * glyph_w`. Lengths 3..=20 as in `ocr::imagegen`.
pub fn sample_text_len(rng: &mut Rng) -> usize {
    rng.usize_in(3, 20)
}

/// A dataset entry for the simulator: just the box widths.
pub fn sample_box_widths(rng: &mut Rng, glyph_w: usize) -> Vec<usize> {
    let n = sample_box_count(rng);
    (0..n).map(|_| (sample_text_len(rng) + 1) * glyph_w).collect()
}

/// The paper's 500-image evaluation dataset (as width vectors).
pub fn dataset(seed: u64, n_images: usize, glyph_w: usize) -> Vec<Vec<usize>> {
    let mut rng = Rng::new(seed);
    (0..n_images).map(|_| sample_box_widths(&mut rng, glyph_w)).collect()
}

/// Empirical distribution of box counts in a dataset (for Fig. 3).
pub fn count_histogram(images: &[Vec<usize>]) -> Vec<(usize, usize)> {
    let mut hist = std::collections::BTreeMap::new();
    for img in images {
        *hist.entry(img.len().min(10)).or_insert(0usize) += 1;
    }
    hist.into_iter().collect()
}

/// Mean box count of a dataset.
pub fn mean_count(images: &[Vec<usize>]) -> f64 {
    images.iter().map(Vec::len).sum::<usize>() as f64 / images.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn distribution_sums_to_one() {
        let total: f64 = BOX_COUNT_DIST.iter().map(|&(_, p)| p).sum();
        assert!((total - 1.0).abs() < 1e-9);
    }

    #[test]
    fn counts_at_least_two() {
        // the paper's dataset only keeps images with >= 2 boxes
        let mut rng = Rng::new(1);
        for _ in 0..1000 {
            assert!(sample_box_count(&mut rng) >= 2);
        }
    }

    #[test]
    fn mean_near_calibration_value() {
        let imgs = dataset(42, 5000, 8);
        let mean = mean_count(&imgs);
        assert!((3.8..4.8).contains(&mean), "mean={mean}");
    }

    #[test]
    fn histogram_matches_weights_roughly() {
        let imgs = dataset(7, 10_000, 8);
        let hist = count_histogram(&imgs);
        let two = hist.iter().find(|&&(c, _)| c == 2).unwrap().1 as f64 / 10_000.0;
        assert!((two - 0.30).abs() < 0.03, "P(2 boxes)={two}");
        let tail = hist.iter().find(|&&(c, _)| c == 10).unwrap().1 as f64 / 10_000.0;
        assert!((tail - 0.04).abs() < 0.02, "P(10+)={tail}");
    }

    #[test]
    fn widths_are_glyph_multiples() {
        let mut rng = Rng::new(3);
        for w in sample_box_widths(&mut rng, 8) {
            assert_eq!(w % 8, 0);
            assert!((32..=168).contains(&w));
        }
    }

    #[test]
    fn dataset_deterministic() {
        assert_eq!(dataset(5, 50, 8), dataset(5, 50, 8));
        assert_ne!(dataset(5, 50, 8), dataset(6, 50, 8));
    }
}

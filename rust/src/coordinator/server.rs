//! JSON-lines TCP serving front end.
//!
//! One coordinator thread accepts connections; each connection gets a
//! handler thread (requests within a connection are processed in order,
//! concurrency comes from multiple connections — batching across them
//! happens in the shared `embed` batcher). The whole request path is
//! Rust + PJRT; Python ended at `make artifacts`.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::util::json::Json;

use super::router::{route, ServerState};

pub struct Server {
    state: Arc<ServerState>,
    listener: TcpListener,
    stop: Arc<AtomicBool>,
}

impl Server {
    /// Bind to the configured address (port 0 picks a free port).
    pub fn bind(state: Arc<ServerState>) -> Result<Server> {
        let addr = state.config.addr();
        let listener = TcpListener::bind(&addr).with_context(|| format!("binding {addr}"))?;
        Ok(Server { state, listener, stop: Arc::new(AtomicBool::new(false)) })
    }

    pub fn local_addr(&self) -> std::net::SocketAddr {
        self.listener.local_addr().unwrap()
    }

    /// Handle for stopping a `serve_background` server.
    pub fn stop_handle(&self) -> StopHandle {
        StopHandle { stop: Arc::clone(&self.stop), addr: self.local_addr() }
    }

    /// Serve until the stop handle fires. Blocks.
    pub fn serve(self) -> Result<()> {
        crate::info!("serving on {}", self.local_addr());
        for conn in self.listener.incoming() {
            if self.stop.load(Ordering::SeqCst) {
                break;
            }
            match conn {
                Ok(stream) => {
                    let state = Arc::clone(&self.state);
                    std::thread::Builder::new()
                        .name("dnc-conn".into())
                        .spawn(move || {
                            if let Err(e) = handle_connection(stream, &state) {
                                crate::debug!("connection ended: {e:#}");
                            }
                        })
                        .context("spawning connection handler")?;
                }
                Err(e) => crate::warn!("accept failed: {e}"),
            }
        }
        Ok(())
    }

    /// Serve on a background thread; returns after bind.
    pub fn serve_background(self) -> (StopHandle, std::thread::JoinHandle<()>) {
        let handle = self.stop_handle();
        let join = std::thread::Builder::new()
            .name("dnc-server".into())
            .spawn(move || {
                if let Err(e) = self.serve() {
                    crate::error!("server error: {e:#}");
                }
            })
            .expect("spawn server");
        (handle, join)
    }
}

pub struct StopHandle {
    stop: Arc<AtomicBool>,
    addr: std::net::SocketAddr,
}

impl StopHandle {
    /// Signal the accept loop to exit (pokes it with a connection).
    pub fn stop(&self) {
        self.stop.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(self.addr);
    }
}

fn handle_connection(stream: TcpStream, state: &ServerState) -> Result<()> {
    stream.set_nodelay(true).ok();
    let peer = stream.peer_addr().ok();
    crate::debug!("connection from {peer:?}");
    let reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let resp = match Json::parse(&line) {
            Ok(req) => route(state, &req),
            Err(e) => crate::util::json::obj(vec![(
                "error",
                Json::Str(format!("bad json: {e}")),
            )]),
        };
        writer.write_all(resp.to_string().as_bytes())?;
        writer.write_all(b"\n")?;
    }
    Ok(())
}

/// Minimal client for tests/examples: send one request, read one reply.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
        stream.set_nodelay(true).ok();
        Ok(Client { reader: BufReader::new(stream.try_clone()?), writer: stream })
    }

    pub fn call(&mut self, req: &Json) -> Result<Json> {
        self.writer.write_all(req.to_string().as_bytes())?;
        self.writer.write_all(b"\n")?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        Ok(Json::parse(line.trim())
            .map_err(|e| anyhow::anyhow!("bad response json: {e}: {line}"))?)
    }
}

//! JSON-lines TCP serving front end.
//!
//! One coordinator thread accepts connections; each connection gets a
//! handler thread (requests within a connection are processed in order,
//! concurrency comes from multiple connections — batching across them
//! happens in the shared `embed` batcher). The whole request path is
//! Rust + PJRT; Python ended at `make artifacts`.
//!
//! Shutdown is graceful: connection handlers poll the stop flag through
//! a short socket read timeout, `serve` joins every handler it spawned,
//! and finally drains the scheduler so in-flight tasks complete before
//! `serve` returns. `StopHandle::stop()` therefore quiesces the whole
//! stack, leaking no threads.

use std::io::{BufRead, BufReader, ErrorKind, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::util::json::Json;

use super::router::{route, ServerState};

/// How often an idle connection handler checks the stop flag.
const STOP_POLL: Duration = Duration::from_millis(200);

pub struct Server {
    state: Arc<ServerState>,
    listener: TcpListener,
    stop: Arc<AtomicBool>,
}

impl Server {
    /// Bind to the configured address (port 0 picks a free port).
    pub fn bind(state: Arc<ServerState>) -> Result<Server> {
        let addr = state.config.addr();
        let listener = TcpListener::bind(&addr).with_context(|| format!("binding {addr}"))?;
        Ok(Server { state, listener, stop: Arc::new(AtomicBool::new(false)) })
    }

    pub fn local_addr(&self) -> std::net::SocketAddr {
        self.listener.local_addr().unwrap()
    }

    /// Handle for stopping a `serve_background` server.
    pub fn stop_handle(&self) -> StopHandle {
        StopHandle { stop: Arc::clone(&self.stop), addr: self.local_addr() }
    }

    /// Serve until the stop handle fires, then quiesce: join every
    /// connection handler and drain in-flight scheduler tasks. Blocks.
    pub fn serve(self) -> Result<()> {
        crate::info!("serving on {}", self.local_addr());
        let mut handlers: Vec<std::thread::JoinHandle<()>> = Vec::new();
        for conn in self.listener.incoming() {
            if self.stop.load(Ordering::SeqCst) {
                break;
            }
            // Reap finished handlers so long-lived servers don't
            // accumulate joined-but-unjoined threads.
            handlers.retain(|h| !h.is_finished());
            match conn {
                Ok(stream) => {
                    let state = Arc::clone(&self.state);
                    let stop = Arc::clone(&self.stop);
                    let spawned = std::thread::Builder::new()
                        .name("dnc-conn".into())
                        .spawn(move || {
                            if let Err(e) = handle_connection(stream, &state, &stop) {
                                crate::debug!("connection ended: {e:#}");
                            }
                        });
                    match spawned {
                        Ok(h) => handlers.push(h),
                        // Must not early-return here: the shutdown
                        // contract (join handlers, drain scheduler)
                        // still has to run. Dropping the stream closes
                        // the connection; the server keeps serving.
                        Err(e) => crate::warn!("spawning connection handler failed: {e}"),
                    }
                }
                Err(e) => crate::warn!("accept failed: {e}"),
            }
        }
        crate::info!("stopping: joining {} connection handler(s)", handlers.len());
        for h in handlers {
            let _ = h.join();
        }
        let sched = self.state.bert.session().scheduler();
        let drain_timeout = Duration::from_millis(self.state.config.drain_timeout_ms);
        if !sched.drain(drain_timeout) {
            crate::warn!("scheduler did not drain within {drain_timeout:?}");
        }
        crate::info!("stopped");
        Ok(())
    }

    /// Serve on a background thread; returns after bind.
    pub fn serve_background(self) -> (StopHandle, std::thread::JoinHandle<()>) {
        let handle = self.stop_handle();
        let join = std::thread::Builder::new()
            .name("dnc-server".into())
            .spawn(move || {
                if let Err(e) = self.serve() {
                    crate::error!("server error: {e:#}");
                }
            })
            .expect("spawn server");
        (handle, join)
    }
}

pub struct StopHandle {
    stop: Arc<AtomicBool>,
    addr: std::net::SocketAddr,
}

impl StopHandle {
    /// Signal the accept loop to exit (pokes it with a connection).
    /// `Server::serve` returns only after handlers joined and the
    /// scheduler drained, so joining the serve thread after this call
    /// observes a fully quiesced stack.
    pub fn stop(&self) {
        self.stop.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(self.addr);
    }
}

fn handle_connection(stream: TcpStream, state: &ServerState, stop: &AtomicBool) -> Result<()> {
    stream.set_nodelay(true).ok();
    // Short read timeout: the handler wakes to check the stop flag even
    // when the client is idle, so shutdown can join it.
    stream.set_read_timeout(Some(STOP_POLL)).ok();
    let peer = stream.peer_addr().ok();
    crate::debug!("connection from {peer:?}");
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    let mut line = String::new();
    loop {
        if stop.load(Ordering::SeqCst) {
            break;
        }
        match reader.read_line(&mut line) {
            Ok(0) => break, // EOF
            Ok(_) => {
                let trimmed = line.trim();
                if !trimmed.is_empty() {
                    let resp = match Json::parse(trimmed) {
                        Ok(req) => route(state, &req),
                        Err(e) => crate::util::json::obj(vec![(
                            "error",
                            Json::Str(format!("bad json: {e}")),
                        )]),
                    };
                    writer.write_all(resp.to_string().as_bytes())?;
                    writer.write_all(b"\n")?;
                }
                line.clear();
            }
            // Timeout: any partial line read so far stays in `line` and
            // completes on a later read.
            Err(e) if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut => {
                continue;
            }
            Err(e) => return Err(e.into()),
        }
    }
    Ok(())
}

/// Minimal client for tests/examples: send one request, read one reply.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
        stream.set_nodelay(true).ok();
        Ok(Client { reader: BufReader::new(stream.try_clone()?), writer: stream })
    }

    pub fn call(&mut self, req: &Json) -> Result<Json> {
        self.writer.write_all(req.to_string().as_bytes())?;
        self.writer.write_all(b"\n")?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        Ok(Json::parse(line.trim())
            .map_err(|e| anyhow::anyhow!("bad response json: {e}: {line}"))?)
    }
}

//! Typed scheduler-stats snapshot for the `stats` op.
//!
//! The router used to hand-format every `sched.*` gauge (and the
//! per-shard `sched.shard.<i>.<field>` block) inline, so the wire names
//! dashboards scrape lived as string literals scattered through
//! `stats_json`. This module is now the single authority: a
//! [`SchedSnapshot`] is captured from the live scheduler + profile
//! store, and [`SchedSnapshot::gauges`] serializes it through
//! `util::json` in one place. The golden test at the bottom pins every
//! wire name — renaming a field here without updating a dashboard
//! breaks the test first.

use crate::engine::{ProfileStore, SchedStats, Scheduler};
use crate::util::json::{num, Json};

/// Point-in-time typed view of everything the `stats` op reports about
/// the scheduler: the aggregate gauges, one [`SchedStats`] per shard,
/// and the profile store the adaptive loop feeds from.
pub struct SchedSnapshot {
    pub aggregate: SchedStats,
    pub shards: Vec<SchedStats>,
    /// worst per-model windowed p95 across freshly-profiled models
    pub profile_p95_ms: f64,
    /// models ever observed by the profile store
    pub profile_models: usize,
}

/// The per-shard gauge set (`sched.shard.<i>.<field>`): the field names
/// and the typed accessor live together, so the wire contract cannot
/// drift from the struct. Order is the wire order.
const SHARD_FIELDS: [(&str, fn(&SchedStats) -> f64); 15] = [
    ("capacity", |s| s.capacity as f64),
    ("cores_busy", |s| s.cores_busy as f64),
    ("queue_depth", |s| s.queue_depth as f64),
    ("inflight", |s| s.inflight as f64),
    ("submitted", |s| s.submitted as f64),
    ("completed", |s| s.completed as f64),
    ("failed", |s| s.failed as f64),
    ("cancelled", |s| s.cancelled as f64),
    ("steals", |s| s.steals as f64),
    ("timer_wakeups", |s| s.timer_wakeups as f64),
    // core-class split of the shard's ledger slice (new in 0.5.0,
    // appended after the legacy block so scrapers by-position survive)
    ("capacity_fast", |s| s.capacity_fast as f64),
    ("capacity_slow", |s| s.capacity_slow as f64),
    ("busy_fast", |s| s.busy_fast as f64),
    ("busy_slow", |s| s.busy_slow as f64),
    ("class_degraded", |s| s.class_degraded as f64),
];

impl SchedSnapshot {
    /// Capture the current scheduler + profile state.
    pub fn capture(sched: &Scheduler, profiles: &ProfileStore) -> SchedSnapshot {
        SchedSnapshot {
            aggregate: sched.stats(),
            shards: sched.shard_stats(),
            profile_p95_ms: profiles.global_p95_ms().unwrap_or(0.0),
            profile_models: profiles.len(),
        }
    }

    /// Serialize to the flat gauge list the `stats` op appends to the
    /// metrics snapshot, wire order. These names are the dashboard
    /// contract — see `stats_wire_names_are_pinned` below.
    pub fn gauges(&self) -> Vec<(String, Json)> {
        let st = &self.aggregate;
        let flat: [(&str, f64); 31] = [
            ("sched.shards", st.shards as f64),
            ("sched.steals", st.steals as f64),
            ("sched.timer_wakeups", st.timer_wakeups as f64),
            ("sched.capacity", st.capacity as f64),
            ("sched.cores_busy", st.cores_busy as f64),
            ("sched.cores_idle", st.cores_idle as f64),
            ("sched.queue_depth", st.queue_depth as f64),
            ("sched.queue_depth_high", st.queue_depth_high as f64),
            ("sched.queue_depth_normal", st.queue_depth_normal as f64),
            ("sched.queue_depth_low", st.queue_depth_low as f64),
            ("sched.peak_queue_depth", st.peak_queue_depth as f64),
            ("sched.inflight", st.inflight as f64),
            ("sched.submitted", st.submitted as f64),
            ("sched.completed", st.completed as f64),
            ("sched.failed", st.failed as f64),
            ("sched.backfills", st.backfills as f64),
            ("sched.deadline_rejected", st.deadline_rejected as f64),
            ("sched.budget_expired", st.budget_expired as f64),
            ("sched.budget_infeasible", st.budget_infeasible as f64),
            ("sched.cancelled", st.cancelled as f64),
            ("sched.adaptive_resizes", st.adaptive_resizes as f64),
            ("sched.running_deadline_cancelled", st.running_deadline_cancelled as f64),
            (
                "sched.running_deadline_cancelled_budget",
                st.running_deadline_cancelled_budget as f64,
            ),
            ("sched.aging_effective_ms", st.aging_effective_ms),
            ("profile.p95_ms", self.profile_p95_ms),
            ("profile.models", self.profile_models as f64),
            // core-class gauges (new in 0.5.0): the by-class split of
            // capacity/occupancy plus affinity-degradation launches —
            // appended after the legacy block, never interleaved
            ("sched.capacity_fast", st.capacity_fast as f64),
            ("sched.capacity_slow", st.capacity_slow as f64),
            ("sched.busy_fast", st.busy_fast as f64),
            ("sched.busy_slow", st.busy_slow as f64),
            ("sched.class_degraded", st.class_degraded as f64),
        ];
        let mut out: Vec<(String, Json)> =
            flat.iter().map(|&(k, v)| (k.to_string(), num(v))).collect();
        // Per-shard view: capacity is the shard's ledger slice; the
        // counter set mirrors the aggregate so the per-shard accounting
        // invariant is checkable from the wire.
        for (i, sh) in self.shards.iter().enumerate() {
            for (k, get) in SHARD_FIELDS {
                out.push((format!("sched.shard.{i}.{k}"), num(get(sh))));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snapshot(shards: usize) -> SchedSnapshot {
        SchedSnapshot {
            aggregate: SchedStats::default(),
            shards: vec![SchedStats::default(); shards],
            profile_p95_ms: 0.0,
            profile_models: 0,
        }
    }

    /// GOLDEN: the wire names dashboards scrape. A failure here means a
    /// breaking stats-protocol change — add new gauges to the tail of
    /// the new-in-0.5.0 blocks instead of renaming or reordering these.
    #[test]
    fn stats_wire_names_are_pinned() {
        let names: Vec<String> =
            snapshot(2).gauges().into_iter().map(|(k, _)| k).collect();
        let legacy_flat = [
            "sched.shards",
            "sched.steals",
            "sched.timer_wakeups",
            "sched.capacity",
            "sched.cores_busy",
            "sched.cores_idle",
            "sched.queue_depth",
            "sched.queue_depth_high",
            "sched.queue_depth_normal",
            "sched.queue_depth_low",
            "sched.peak_queue_depth",
            "sched.inflight",
            "sched.submitted",
            "sched.completed",
            "sched.failed",
            "sched.backfills",
            "sched.deadline_rejected",
            "sched.budget_expired",
            "sched.budget_infeasible",
            "sched.cancelled",
            "sched.adaptive_resizes",
            "sched.running_deadline_cancelled",
            "sched.running_deadline_cancelled_budget",
            "sched.aging_effective_ms",
            "profile.p95_ms",
            "profile.models",
        ];
        // every legacy flat gauge survives, in its original order
        let positions: Vec<usize> = legacy_flat
            .iter()
            .map(|want| {
                names
                    .iter()
                    .position(|n| n == want)
                    .unwrap_or_else(|| panic!("gauge '{want}' missing from the wire"))
            })
            .collect();
        assert!(
            positions.windows(2).all(|w| w[0] < w[1]),
            "legacy gauges reordered: {positions:?}"
        );
        // every legacy per-shard gauge survives for every shard
        let legacy_shard = [
            "capacity",
            "cores_busy",
            "queue_depth",
            "inflight",
            "submitted",
            "completed",
            "failed",
            "cancelled",
            "steals",
            "timer_wakeups",
        ];
        for i in 0..2 {
            for f in legacy_shard {
                let want = format!("sched.shard.{i}.{f}");
                assert!(names.contains(&want), "gauge '{want}' missing from the wire");
            }
        }
        // the 0.5.0 class gauges ride alongside, never replacing
        for f in ["sched.capacity_fast", "sched.capacity_slow", "sched.busy_fast", "sched.busy_slow", "sched.class_degraded"] {
            assert!(names.contains(&f.to_string()), "missing class gauge '{f}'");
        }
        assert!(names.contains(&"sched.shard.1.class_degraded".to_string()));
    }

    #[test]
    fn shard_blocks_scale_with_shard_count() {
        let g1 = snapshot(1).gauges().len();
        let g3 = snapshot(3).gauges().len();
        assert_eq!(g3 - g1, 2 * SHARD_FIELDS.len());
    }
}

//! Typed scheduler-stats snapshot for the `stats` op, and the
//! crate-wide metrics wire-name registry.
//!
//! The router used to hand-format every `sched.*` gauge (and the
//! per-shard `sched.shard.<i>.<field>` block) inline, so the wire names
//! dashboards scrape lived as string literals scattered through
//! `stats_json`. This module is now the single authority twice over:
//! a [`SchedSnapshot`] is captured from the live scheduler + profile
//! store and serialized through `util::json` in one place, and *every*
//! metrics wire name in the crate — counters, histograms, gauges —
//! lives as a `pub const` in [`names`]. Emission sites reference the
//! constants; pallas-lint rule PL008 rejects raw string literals at
//! any `.add`/`.set`/`.record` call and any `names::X` path that does
//! not resolve here. The golden test at the bottom pins every
//! constant's wire value — renaming one without updating a dashboard
//! breaks the test first.

use crate::engine::{ProfileStore, SchedStats, Scheduler};
use crate::util::json::{num, Json};

/// Every metrics wire name the crate emits, as constants. This is the
/// registry pallas-lint rule PL008 checks emission sites against: a
/// gauge/counter name that is not declared here cannot be emitted
/// (outside tests) without failing the lint. Grouped by emitter.
pub mod names {
    // --- router request-path counters & histograms (metrics registry)
    /// total requests admitted by the router
    pub const REQUESTS: &str = "requests";
    /// end-to-end request latency histogram
    pub const REQUEST: &str = "request";
    /// requests that hit the router-level timeout
    pub const REQUEST_TIMEOUTS: &str = "request_timeouts";
    /// embed batches flushed by the batcher
    pub const BATCHES: &str = "batches";
    /// requests carried inside those flushed batches
    pub const BATCHED_REQUESTS: &str = "batched_requests";
    /// BERT batch execution latency histogram
    pub const BERT_BATCH: &str = "bert_batch";
    /// embed requests waiting in the batcher queue (gauge)
    pub const EMBED_PENDING: &str = "embed_pending";
    /// embed requests currently executing (gauge)
    pub const EMBED_INFLIGHT: &str = "embed_inflight";
    /// embed requests reaped at flush time because their ctx was
    /// already cancelled
    pub const EMBED_CANCELLED_REAPED: &str = "embed_cancelled_reaped";
    /// embed requests reaped at flush time because their budget was
    /// already spent
    pub const EMBED_BUDGET_EXPIRED: &str = "embed_budget_expired";
    /// OCR images processed
    pub const OCR_IMAGES: &str = "ocr_images";
    /// OCR text boxes produced
    pub const OCR_BOXES: &str = "ocr_boxes";
    /// OCR jobs that ran out of budget
    pub const OCR_TIMEOUTS: &str = "ocr_timeouts";

    // --- aggregate scheduler gauges (stats op, wire order)
    pub const SCHED_SHARDS: &str = "sched.shards";
    pub const SCHED_STEALS: &str = "sched.steals";
    pub const SCHED_TIMER_WAKEUPS: &str = "sched.timer_wakeups";
    pub const SCHED_CAPACITY: &str = "sched.capacity";
    pub const SCHED_CORES_BUSY: &str = "sched.cores_busy";
    pub const SCHED_CORES_IDLE: &str = "sched.cores_idle";
    pub const SCHED_QUEUE_DEPTH: &str = "sched.queue_depth";
    pub const SCHED_QUEUE_DEPTH_HIGH: &str = "sched.queue_depth_high";
    pub const SCHED_QUEUE_DEPTH_NORMAL: &str = "sched.queue_depth_normal";
    pub const SCHED_QUEUE_DEPTH_LOW: &str = "sched.queue_depth_low";
    pub const SCHED_PEAK_QUEUE_DEPTH: &str = "sched.peak_queue_depth";
    pub const SCHED_INFLIGHT: &str = "sched.inflight";
    pub const SCHED_SUBMITTED: &str = "sched.submitted";
    pub const SCHED_COMPLETED: &str = "sched.completed";
    pub const SCHED_FAILED: &str = "sched.failed";
    pub const SCHED_BACKFILLS: &str = "sched.backfills";
    pub const SCHED_DEADLINE_REJECTED: &str = "sched.deadline_rejected";
    pub const SCHED_BUDGET_EXPIRED: &str = "sched.budget_expired";
    pub const SCHED_BUDGET_INFEASIBLE: &str = "sched.budget_infeasible";
    pub const SCHED_CANCELLED: &str = "sched.cancelled";
    pub const SCHED_ADAPTIVE_RESIZES: &str = "sched.adaptive_resizes";
    pub const SCHED_RUNNING_DEADLINE_CANCELLED: &str = "sched.running_deadline_cancelled";
    pub const SCHED_RUNNING_DEADLINE_CANCELLED_BUDGET: &str =
        "sched.running_deadline_cancelled_budget";
    pub const SCHED_AGING_EFFECTIVE_MS: &str = "sched.aging_effective_ms";
    pub const PROFILE_P95_MS: &str = "profile.p95_ms";
    pub const PROFILE_MODELS: &str = "profile.models";
    // core-class gauges (0.5.0): appended after the legacy block
    pub const SCHED_CAPACITY_FAST: &str = "sched.capacity_fast";
    pub const SCHED_CAPACITY_SLOW: &str = "sched.capacity_slow";
    pub const SCHED_BUSY_FAST: &str = "sched.busy_fast";
    pub const SCHED_BUSY_SLOW: &str = "sched.busy_slow";
    pub const SCHED_CLASS_DEGRADED: &str = "sched.class_degraded";

    // --- per-shard gauge block: `sched.shard.<i>.<field>`
    /// prefix of every per-shard gauge; the full name is
    /// `{SHARD_PREFIX}{i}.{field}`
    pub const SHARD_PREFIX: &str = "sched.shard.";
    pub const SHARD_CAPACITY: &str = "capacity";
    pub const SHARD_CORES_BUSY: &str = "cores_busy";
    pub const SHARD_QUEUE_DEPTH: &str = "queue_depth";
    pub const SHARD_INFLIGHT: &str = "inflight";
    pub const SHARD_SUBMITTED: &str = "submitted";
    pub const SHARD_COMPLETED: &str = "completed";
    pub const SHARD_FAILED: &str = "failed";
    pub const SHARD_CANCELLED: &str = "cancelled";
    pub const SHARD_STEALS: &str = "steals";
    pub const SHARD_TIMER_WAKEUPS: &str = "timer_wakeups";
    pub const SHARD_CAPACITY_FAST: &str = "capacity_fast";
    pub const SHARD_CAPACITY_SLOW: &str = "capacity_slow";
    pub const SHARD_BUSY_FAST: &str = "busy_fast";
    pub const SHARD_BUSY_SLOW: &str = "busy_slow";
    pub const SHARD_CLASS_DEGRADED: &str = "class_degraded";
}

/// Point-in-time typed view of everything the `stats` op reports about
/// the scheduler: the aggregate gauges, one [`SchedStats`] per shard,
/// and the profile store the adaptive loop feeds from.
pub struct SchedSnapshot {
    pub aggregate: SchedStats,
    pub shards: Vec<SchedStats>,
    /// worst per-model windowed p95 across freshly-profiled models
    pub profile_p95_ms: f64,
    /// models ever observed by the profile store
    pub profile_models: usize,
}

/// The per-shard gauge set (`sched.shard.<i>.<field>`): the field names
/// and the typed accessor live together, so the wire contract cannot
/// drift from the struct. Order is the wire order.
const SHARD_FIELDS: [(&str, fn(&SchedStats) -> f64); 15] = [
    (names::SHARD_CAPACITY, |s| s.capacity as f64),
    (names::SHARD_CORES_BUSY, |s| s.cores_busy as f64),
    (names::SHARD_QUEUE_DEPTH, |s| s.queue_depth as f64),
    (names::SHARD_INFLIGHT, |s| s.inflight as f64),
    (names::SHARD_SUBMITTED, |s| s.submitted as f64),
    (names::SHARD_COMPLETED, |s| s.completed as f64),
    (names::SHARD_FAILED, |s| s.failed as f64),
    (names::SHARD_CANCELLED, |s| s.cancelled as f64),
    (names::SHARD_STEALS, |s| s.steals as f64),
    (names::SHARD_TIMER_WAKEUPS, |s| s.timer_wakeups as f64),
    // core-class split of the shard's ledger slice (new in 0.5.0,
    // appended after the legacy block so scrapers by-position survive)
    (names::SHARD_CAPACITY_FAST, |s| s.capacity_fast as f64),
    (names::SHARD_CAPACITY_SLOW, |s| s.capacity_slow as f64),
    (names::SHARD_BUSY_FAST, |s| s.busy_fast as f64),
    (names::SHARD_BUSY_SLOW, |s| s.busy_slow as f64),
    (names::SHARD_CLASS_DEGRADED, |s| s.class_degraded as f64),
];

impl SchedSnapshot {
    /// Capture the current scheduler + profile state.
    pub fn capture(sched: &Scheduler, profiles: &ProfileStore) -> SchedSnapshot {
        SchedSnapshot {
            aggregate: sched.stats(),
            shards: sched.shard_stats(),
            profile_p95_ms: profiles.global_p95_ms().unwrap_or(0.0),
            profile_models: profiles.len(),
        }
    }

    /// Serialize to the flat gauge list the `stats` op appends to the
    /// metrics snapshot, wire order. These names are the dashboard
    /// contract — see `stats_wire_names_are_pinned` below.
    pub fn gauges(&self) -> Vec<(String, Json)> {
        let st = &self.aggregate;
        let flat: [(&str, f64); 31] = [
            (names::SCHED_SHARDS, st.shards as f64),
            (names::SCHED_STEALS, st.steals as f64),
            (names::SCHED_TIMER_WAKEUPS, st.timer_wakeups as f64),
            (names::SCHED_CAPACITY, st.capacity as f64),
            (names::SCHED_CORES_BUSY, st.cores_busy as f64),
            (names::SCHED_CORES_IDLE, st.cores_idle as f64),
            (names::SCHED_QUEUE_DEPTH, st.queue_depth as f64),
            (names::SCHED_QUEUE_DEPTH_HIGH, st.queue_depth_high as f64),
            (names::SCHED_QUEUE_DEPTH_NORMAL, st.queue_depth_normal as f64),
            (names::SCHED_QUEUE_DEPTH_LOW, st.queue_depth_low as f64),
            (names::SCHED_PEAK_QUEUE_DEPTH, st.peak_queue_depth as f64),
            (names::SCHED_INFLIGHT, st.inflight as f64),
            (names::SCHED_SUBMITTED, st.submitted as f64),
            (names::SCHED_COMPLETED, st.completed as f64),
            (names::SCHED_FAILED, st.failed as f64),
            (names::SCHED_BACKFILLS, st.backfills as f64),
            (names::SCHED_DEADLINE_REJECTED, st.deadline_rejected as f64),
            (names::SCHED_BUDGET_EXPIRED, st.budget_expired as f64),
            (names::SCHED_BUDGET_INFEASIBLE, st.budget_infeasible as f64),
            (names::SCHED_CANCELLED, st.cancelled as f64),
            (names::SCHED_ADAPTIVE_RESIZES, st.adaptive_resizes as f64),
            (
                names::SCHED_RUNNING_DEADLINE_CANCELLED,
                st.running_deadline_cancelled as f64,
            ),
            (
                names::SCHED_RUNNING_DEADLINE_CANCELLED_BUDGET,
                st.running_deadline_cancelled_budget as f64,
            ),
            (names::SCHED_AGING_EFFECTIVE_MS, st.aging_effective_ms),
            (names::PROFILE_P95_MS, self.profile_p95_ms),
            (names::PROFILE_MODELS, self.profile_models as f64),
            // core-class gauges (new in 0.5.0): the by-class split of
            // capacity/occupancy plus affinity-degradation launches —
            // appended after the legacy block, never interleaved
            (names::SCHED_CAPACITY_FAST, st.capacity_fast as f64),
            (names::SCHED_CAPACITY_SLOW, st.capacity_slow as f64),
            (names::SCHED_BUSY_FAST, st.busy_fast as f64),
            (names::SCHED_BUSY_SLOW, st.busy_slow as f64),
            (names::SCHED_CLASS_DEGRADED, st.class_degraded as f64),
        ];
        let mut out: Vec<(String, Json)> =
            flat.iter().map(|&(k, v)| (k.to_string(), num(v))).collect();
        // Per-shard view: capacity is the shard's ledger slice; the
        // counter set mirrors the aggregate so the per-shard accounting
        // invariant is checkable from the wire.
        for (i, sh) in self.shards.iter().enumerate() {
            for (k, get) in SHARD_FIELDS {
                out.push((format!("{}{i}.{k}", names::SHARD_PREFIX), num(get(sh))));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snapshot(shards: usize) -> SchedSnapshot {
        SchedSnapshot {
            aggregate: SchedStats::default(),
            shards: vec![SchedStats::default(); shards],
            profile_p95_ms: 0.0,
            profile_models: 0,
        }
    }

    /// GOLDEN: the wire names dashboards scrape, pinned as (registry
    /// constant, expected literal) pairs. The emitters consume the
    /// constants (PL008 enforces that), so the constant and the
    /// emission site can never disagree — this test pins the remaining
    /// degree of freedom, the constant's *value*. A failure here means
    /// a breaking stats-protocol change — add new gauges to the tail
    /// of the new-in-0.5.0 blocks instead of renaming or reordering.
    #[test]
    fn stats_wire_names_are_pinned() {
        let gauge_names: Vec<String> =
            snapshot(2).gauges().into_iter().map(|(k, _)| k).collect();
        let legacy_flat: [(&str, &str); 26] = [
            (names::SCHED_SHARDS, "sched.shards"),
            (names::SCHED_STEALS, "sched.steals"),
            (names::SCHED_TIMER_WAKEUPS, "sched.timer_wakeups"),
            (names::SCHED_CAPACITY, "sched.capacity"),
            (names::SCHED_CORES_BUSY, "sched.cores_busy"),
            (names::SCHED_CORES_IDLE, "sched.cores_idle"),
            (names::SCHED_QUEUE_DEPTH, "sched.queue_depth"),
            (names::SCHED_QUEUE_DEPTH_HIGH, "sched.queue_depth_high"),
            (names::SCHED_QUEUE_DEPTH_NORMAL, "sched.queue_depth_normal"),
            (names::SCHED_QUEUE_DEPTH_LOW, "sched.queue_depth_low"),
            (names::SCHED_PEAK_QUEUE_DEPTH, "sched.peak_queue_depth"),
            (names::SCHED_INFLIGHT, "sched.inflight"),
            (names::SCHED_SUBMITTED, "sched.submitted"),
            (names::SCHED_COMPLETED, "sched.completed"),
            (names::SCHED_FAILED, "sched.failed"),
            (names::SCHED_BACKFILLS, "sched.backfills"),
            (names::SCHED_DEADLINE_REJECTED, "sched.deadline_rejected"),
            (names::SCHED_BUDGET_EXPIRED, "sched.budget_expired"),
            (names::SCHED_BUDGET_INFEASIBLE, "sched.budget_infeasible"),
            (names::SCHED_CANCELLED, "sched.cancelled"),
            (names::SCHED_ADAPTIVE_RESIZES, "sched.adaptive_resizes"),
            (
                names::SCHED_RUNNING_DEADLINE_CANCELLED,
                "sched.running_deadline_cancelled",
            ),
            (
                names::SCHED_RUNNING_DEADLINE_CANCELLED_BUDGET,
                "sched.running_deadline_cancelled_budget",
            ),
            (names::SCHED_AGING_EFFECTIVE_MS, "sched.aging_effective_ms"),
            (names::PROFILE_P95_MS, "profile.p95_ms"),
            (names::PROFILE_MODELS, "profile.models"),
        ];
        for (konst, wire) in legacy_flat {
            assert_eq!(konst, wire, "registry constant drifted from the wire value");
        }
        // every legacy flat gauge survives, in its original order
        let positions: Vec<usize> = legacy_flat
            .iter()
            .map(|(want, _)| {
                gauge_names
                    .iter()
                    .position(|n| n == want)
                    .unwrap_or_else(|| panic!("gauge '{want}' missing from the wire"))
            })
            .collect();
        assert!(
            positions.windows(2).all(|w| w[0] < w[1]),
            "legacy gauges reordered: {positions:?}"
        );
        // every legacy per-shard gauge survives for every shard
        let legacy_shard: [(&str, &str); 10] = [
            (names::SHARD_CAPACITY, "capacity"),
            (names::SHARD_CORES_BUSY, "cores_busy"),
            (names::SHARD_QUEUE_DEPTH, "queue_depth"),
            (names::SHARD_INFLIGHT, "inflight"),
            (names::SHARD_SUBMITTED, "submitted"),
            (names::SHARD_COMPLETED, "completed"),
            (names::SHARD_FAILED, "failed"),
            (names::SHARD_CANCELLED, "cancelled"),
            (names::SHARD_STEALS, "steals"),
            (names::SHARD_TIMER_WAKEUPS, "timer_wakeups"),
        ];
        assert_eq!(names::SHARD_PREFIX, "sched.shard.");
        for i in 0..2 {
            for (konst, wire) in legacy_shard {
                assert_eq!(konst, wire, "shard-field constant drifted");
                let want = format!("sched.shard.{i}.{konst}");
                assert!(
                    gauge_names.contains(&want),
                    "gauge '{want}' missing from the wire"
                );
            }
        }
        // the 0.5.0 class gauges ride alongside, never replacing
        let class: [(&str, &str); 5] = [
            (names::SCHED_CAPACITY_FAST, "sched.capacity_fast"),
            (names::SCHED_CAPACITY_SLOW, "sched.capacity_slow"),
            (names::SCHED_BUSY_FAST, "sched.busy_fast"),
            (names::SCHED_BUSY_SLOW, "sched.busy_slow"),
            (names::SCHED_CLASS_DEGRADED, "sched.class_degraded"),
        ];
        for (konst, wire) in class {
            assert_eq!(konst, wire, "class-gauge constant drifted");
            assert!(
                gauge_names.contains(&konst.to_string()),
                "missing class gauge '{konst}'"
            );
        }
        assert!(gauge_names.contains(&"sched.shard.1.class_degraded".to_string()));
    }

    /// GOLDEN: the request-path counter/histogram names the router
    /// emits through the metrics registry (scraped via the `stats`
    /// op's snapshot JSON). Same contract as above: emitters use the
    /// constants, this pins the values.
    #[test]
    fn request_path_wire_names_are_pinned() {
        let pairs: [(&str, &str); 13] = [
            (names::REQUESTS, "requests"),
            (names::REQUEST, "request"),
            (names::REQUEST_TIMEOUTS, "request_timeouts"),
            (names::BATCHES, "batches"),
            (names::BATCHED_REQUESTS, "batched_requests"),
            (names::BERT_BATCH, "bert_batch"),
            (names::EMBED_PENDING, "embed_pending"),
            (names::EMBED_INFLIGHT, "embed_inflight"),
            (names::EMBED_CANCELLED_REAPED, "embed_cancelled_reaped"),
            (names::EMBED_BUDGET_EXPIRED, "embed_budget_expired"),
            (names::OCR_IMAGES, "ocr_images"),
            (names::OCR_BOXES, "ocr_boxes"),
            (names::OCR_TIMEOUTS, "ocr_timeouts"),
        ];
        for (konst, wire) in pairs {
            assert_eq!(konst, wire, "registry constant drifted from the wire value");
        }
        // no two registry names may collide: a shared wire name would
        // silently merge two metrics
        let mut all: Vec<&str> = pairs.iter().map(|(k, _)| *k).collect();
        all.extend(SHARD_FIELDS.iter().map(|(k, _)| *k));
        all.sort_unstable();
        let before = all.len();
        all.dedup();
        assert_eq!(before, all.len(), "duplicate wire name in the registry");
    }

    #[test]
    fn shard_blocks_scale_with_shard_count() {
        let g1 = snapshot(1).gauges().len();
        let g3 = snapshot(3).gauges().len();
        assert_eq!(g3 - g1, 2 * SHARD_FIELDS.len());
    }
}

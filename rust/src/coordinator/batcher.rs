//! Dynamic request batcher.
//!
//! Accumulates requests until `max_batch` are waiting or the oldest has
//! waited `max_wait` (the tunable the paper's §2.5 attributes to serving
//! systems like TensorFlow Serving / TorchServe), then hands the batch to
//! the handler on a dedicated flusher thread. Callers block on a reply
//! channel. The handler returns one result per request, in order.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

struct Pending<T, R> {
    item: T,
    reply: Sender<R>,
    enqueued: Instant,
}

struct Queue<T, R> {
    items: Vec<Pending<T, R>>,
    shutdown: bool,
}

pub struct Batcher<T, R> {
    queue: Arc<(Mutex<Queue<T, R>>, Condvar)>,
    flusher: Option<std::thread::JoinHandle<()>>,
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl<T: Send + 'static, R: Send + 'static> Batcher<T, R> {
    /// Start a batcher with a handler run on the flusher thread.
    pub fn start(
        max_batch: usize,
        max_wait: Duration,
        handler: impl Fn(Vec<T>) -> Vec<R> + Send + 'static,
    ) -> Batcher<T, R> {
        assert!(max_batch >= 1);
        let queue = Arc::new((
            Mutex::new(Queue { items: Vec::new(), shutdown: false }),
            Condvar::new(),
        ));
        let q2 = Arc::clone(&queue);
        let flusher = std::thread::Builder::new()
            .name("dnc-batcher".into())
            .spawn(move || flusher_loop(q2, max_batch, max_wait, handler))
            .expect("spawn batcher");
        Batcher { queue, flusher: Some(flusher), max_batch, max_wait }
    }

    /// Enqueue a request; returns the reply channel.
    pub fn submit(&self, item: T) -> Receiver<R> {
        let (reply, rx) = channel();
        let (lock, cv) = &*self.queue;
        let mut q = lock.lock().unwrap();
        q.items.push(Pending { item, reply, enqueued: Instant::now() });
        cv.notify_all();
        rx
    }

    /// Number of requests currently waiting.
    pub fn pending(&self) -> usize {
        self.queue.0.lock().unwrap().items.len()
    }
}

impl<T, R> Drop for Batcher<T, R> {
    fn drop(&mut self) {
        {
            let (lock, cv) = &*self.queue;
            lock.lock().unwrap().shutdown = true;
            cv.notify_all();
        }
        if let Some(h) = self.flusher.take() {
            let _ = h.join();
        }
    }
}

fn flusher_loop<T, R>(
    queue: Arc<(Mutex<Queue<T, R>>, Condvar)>,
    max_batch: usize,
    max_wait: Duration,
    handler: impl Fn(Vec<T>) -> Vec<R>,
) {
    let (lock, cv) = &*queue;
    loop {
        let batch: Vec<Pending<T, R>> = {
            let mut q = lock.lock().unwrap();
            loop {
                if q.shutdown && q.items.is_empty() {
                    return;
                }
                if q.items.len() >= max_batch || q.shutdown {
                    break;
                }
                if let Some(oldest) = q.items.first() {
                    let waited = oldest.enqueued.elapsed();
                    if waited >= max_wait {
                        break;
                    }
                    let (qq, _timeout) = cv.wait_timeout(q, max_wait - waited).unwrap();
                    q = qq;
                } else {
                    q = cv.wait(q).unwrap();
                }
            }
            let take = q.items.len().min(max_batch);
            q.items.drain(..take).collect()
        };
        if batch.is_empty() {
            continue;
        }
        let (items, replies): (Vec<T>, Vec<Sender<R>>) =
            batch.into_iter().map(|p| (p.item, p.reply)).unzip();
        let results = handler(items);
        assert_eq!(results.len(), replies.len(), "handler must return one result per item");
        for (r, tx) in results.into_iter().zip(replies) {
            let _ = tx.send(r); // caller may have given up
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batches_up_to_max() {
        let b: Batcher<u32, usize> = Batcher::start(4, Duration::from_millis(50), |items| {
            let n = items.len();
            items.iter().map(|_| n).collect()
        });
        let rxs: Vec<_> = (0..4).map(|i| b.submit(i)).collect();
        for rx in rxs {
            assert_eq!(rx.recv().unwrap(), 4, "full batch flushed at once");
        }
    }

    #[test]
    fn flushes_on_timeout() {
        let b: Batcher<u32, usize> = Batcher::start(100, Duration::from_millis(10), |items| {
            let n = items.len();
            items.iter().map(|_| n).collect()
        });
        let rx = b.submit(7);
        let t0 = Instant::now();
        assert_eq!(rx.recv().unwrap(), 1, "lone request flushed by timer");
        assert!(t0.elapsed() >= Duration::from_millis(8));
    }

    #[test]
    fn results_in_request_order() {
        let b: Batcher<u32, u32> = Batcher::start(3, Duration::from_millis(20), |items| {
            items.iter().map(|x| x * 10).collect()
        });
        let rxs: Vec<_> = (0..3).map(|i| b.submit(i)).collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            assert_eq!(rx.recv().unwrap(), i as u32 * 10);
        }
    }

    #[test]
    fn drop_flushes_pending() {
        let rx = {
            let b: Batcher<u32, u32> =
                Batcher::start(100, Duration::from_secs(10), |items| items);
            b.submit(42)
            // drop: shutdown flag flushes the waiting item
        };
        assert_eq!(rx.recv().unwrap(), 42);
    }

    #[test]
    fn concurrent_submitters() {
        let b: Arc<Batcher<u32, u32>> =
            Arc::new(Batcher::start(8, Duration::from_millis(5), |items| items));
        let mut joins = Vec::new();
        for t in 0..4 {
            let b = Arc::clone(&b);
            joins.push(std::thread::spawn(move || {
                for i in 0..10 {
                    let v = t * 100 + i;
                    assert_eq!(b.submit(v).recv().unwrap(), v);
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
    }
}

//! Dynamic request batcher.
//!
//! Accumulates requests until `max_batch` are waiting or the oldest has
//! waited `max_wait` (the tunable the paper's §2.5 attributes to serving
//! systems like TensorFlow Serving / TorchServe), then hands the batch
//! off. Callers block on a reply channel (with or without timeout). The
//! handler returns one result per request, in order.
//!
//! Two execution modes:
//! - [`Batcher::start`]: the handler runs synchronously on the flusher
//!   thread (simple; the flusher is busy while a batch executes).
//! - [`Batcher::start_pipelined`]: the submitter only *enqueues* the
//!   batch (e.g. into `engine::sched` via `InferenceService::submit`)
//!   and returns a resolver closure; a dedicated completion thread waits on
//!   the resolver and distributes replies. The flusher is immediately
//!   free to accumulate the next batch, so batch N+1 forms and submits
//!   while batch N executes — and a stalled batch never blocks
//!   accumulation. Thread count stays fixed (flusher + completer).
//!
//! [`Batcher::start_service`] is the serving-edge constructor:
//! pipelined execution plus flush-time admission control — an
//! *admission* closure inspects every item as its batch is drained and
//! may settle it immediately (e.g. a request whose `RequestCtx` budget
//! died while accumulating gets a structured `deadline_rejected`
//! reply) instead of submitting doomed work — time spent waiting in
//! the batcher is charged against the request, not forgotten.
//!
//! Shutdown: [`Batcher::shutdown`] (also run by `Drop`) stops intake.
//! A `submit` after shutdown — or after the flusher died (a panicking
//! submitter) — returns an already-disconnected receiver instead of
//! silently enqueuing into a queue nobody will ever flush, so callers
//! see `RecvError`/`Disconnected` immediately rather than blocking out
//! their whole `recv_timeout`.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::util::sync::lock_recover;

/// Deferred completion of one submitted batch: blocks until the batch
/// finishes and yields one result per item, in order.
pub type Resolver<R> = Box<dyn FnOnce() -> Vec<R> + Send>;

struct Pending<T, R> {
    item: T,
    reply: Sender<R>,
    enqueued: Instant,
}

struct Queue<T, R> {
    items: Vec<Pending<T, R>>,
    shutdown: bool,
}

pub struct Batcher<T, R> {
    queue: Arc<(Mutex<Queue<T, R>>, Condvar)>,
    /// requests flushed out of the queue but not yet delivered — the
    /// batches currently executing (or queued behind the completer)
    inflight: Arc<AtomicUsize>,
    flusher: Option<std::thread::JoinHandle<()>>,
    completer: Option<std::thread::JoinHandle<()>>,
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl<T: Send + 'static, R: Send + 'static> Batcher<T, R> {
    /// Start a batcher whose handler runs on the flusher thread.
    pub fn start(
        max_batch: usize,
        max_wait: Duration,
        handler: impl Fn(Vec<T>) -> Vec<R> + Send + 'static,
    ) -> Batcher<T, R> {
        let queue = new_queue(max_batch);
        let q2 = Arc::clone(&queue);
        let inflight = Arc::new(AtomicUsize::new(0));
        let inf2 = Arc::clone(&inflight);
        let flusher = std::thread::Builder::new()
            .name("dnc-batcher".into())
            .spawn(move || {
                let _drain = DrainOnExit(Arc::clone(&q2));
                flusher_loop(q2, max_batch, max_wait, |_| None, move |items, replies| {
                    let n = items.len();
                    inf2.fetch_add(n, Ordering::Relaxed);
                    deliver(handler(items), replies);
                    inf2.fetch_sub(n, Ordering::Relaxed);
                })
            })
            .expect("spawn batcher");
        Batcher {
            queue,
            inflight,
            flusher: Some(flusher),
            completer: None,
            max_batch,
            max_wait,
        }
    }

    /// Start a pipelined batcher: `submitter` enqueues the batch and
    /// returns a [`Resolver`]; a dedicated completion thread resolves
    /// batches in submission order and distributes replies.
    pub fn start_pipelined(
        max_batch: usize,
        max_wait: Duration,
        submitter: impl Fn(Vec<T>) -> Resolver<R> + Send + 'static,
    ) -> Batcher<T, R> {
        Batcher::start_service(max_batch, max_wait, |_| None, submitter)
    }

    /// The serving-edge constructor: [`start_pipelined`]
    /// (`Self::start_pipelined`) plus flush-time admission control. As
    /// each batch is drained, `admission` inspects every item and may
    /// settle it on the spot by returning its reply (the item is then
    /// never submitted and never counted in flight). The serving edge
    /// uses this to drop requests whose `RequestCtx` says the client is
    /// gone — cancelled, or out of budget — before they become doomed
    /// scheduler work. A batch reaped empty skips the submitter
    /// entirely.
    pub fn start_service(
        max_batch: usize,
        max_wait: Duration,
        admission: impl Fn(&T) -> Option<R> + Send + 'static,
        submitter: impl Fn(Vec<T>) -> Resolver<R> + Send + 'static,
    ) -> Batcher<T, R> {
        Batcher::start_service_with_cap(max_batch, max_wait, |_| None, admission, submitter)
    }

    /// [`start_service`](Self::start_service) with cost-aware flush
    /// sizing. At every flush, `flush_cap` inspects the *oldest*
    /// batchmate — the one nearest its budget's edge — and may return a
    /// smaller batch bound for this flush: the number of items the
    /// oldest item's remaining budget can afford at the profiled
    /// per-item cost (the serving edge wires this to `ProfileStore`
    /// trusted cost). A larger batch amortizes better but runs longer,
    /// and the oldest batchmate pays that latency from whatever budget
    /// it has left; capping the flush keeps a nearly-expired request
    /// from being scheduled into a batch it provably cannot survive.
    /// `None` means no opinion (full `max_batch`); the cap is clamped to
    /// at least 1 so a flush always makes progress — a request that
    /// cannot even afford a batch of one is the admission closure's
    /// problem, not the sizer's.
    pub fn start_service_with_cap(
        max_batch: usize,
        max_wait: Duration,
        flush_cap: impl Fn(&T) -> Option<usize> + Send + 'static,
        admission: impl Fn(&T) -> Option<R> + Send + 'static,
        submitter: impl Fn(Vec<T>) -> Resolver<R> + Send + 'static,
    ) -> Batcher<T, R> {
        let queue = new_queue(max_batch);
        let q2 = Arc::clone(&queue);
        let inflight = Arc::new(AtomicUsize::new(0));
        let inf_flush = Arc::clone(&inflight);
        let inf_done = Arc::clone(&inflight);
        let (ctx, crx) = channel::<(Resolver<R>, Vec<Sender<R>>)>();
        let flusher = std::thread::Builder::new()
            .name("dnc-batcher".into())
            .spawn(move || {
                // `ctx` lives inside the flusher closure: when the
                // flusher exits (shutdown), the channel disconnects and
                // the completer drains whatever was submitted, then exits.
                let _drain = DrainOnExit(Arc::clone(&q2));
                flusher_loop(q2, max_batch, max_wait, flush_cap, move |items, replies| {
                    let mut kept_items = Vec::with_capacity(items.len());
                    let mut kept_replies = Vec::with_capacity(replies.len());
                    for (item, reply) in items.into_iter().zip(replies) {
                        match admission(&item) {
                            // settled at flush time: never submitted,
                            // never in flight
                            Some(r) => {
                                let _ = reply.send(r);
                            }
                            None => {
                                kept_items.push(item);
                                kept_replies.push(reply);
                            }
                        }
                    }
                    if kept_items.is_empty() {
                        return;
                    }
                    inf_flush.fetch_add(kept_items.len(), Ordering::Relaxed);
                    let resolver = submitter(kept_items);
                    let _ = ctx.send((resolver, kept_replies));
                })
            })
            .expect("spawn batcher");
        let completer = std::thread::Builder::new()
            .name("dnc-batcher-done".into())
            .spawn(move || {
                while let Ok((resolver, replies)) = crx.recv() {
                    let n = replies.len();
                    deliver(resolver(), replies);
                    inf_done.fetch_sub(n, Ordering::Relaxed);
                }
            })
            .expect("spawn batcher completer");
        Batcher {
            queue,
            inflight,
            flusher: Some(flusher),
            completer: Some(completer),
            max_batch,
            max_wait,
        }
    }

    /// Enqueue a request; returns the reply channel.
    ///
    /// After [`shutdown`](Self::shutdown) — or if the flusher thread
    /// died (a panicking submitter) — the returned receiver is already
    /// disconnected: the item can never be flushed, and enqueuing it
    /// would strand the caller until its full `recv_timeout` on a queue
    /// nobody drains. An immediate `Disconnected` is the structured
    /// "shutting down" signal callers (e.g. the router) translate.
    pub fn submit(&self, item: T) -> Receiver<R> {
        let (reply, rx) = channel();
        let (lock, cv) = &*self.queue;
        let mut q = lock_recover(lock);
        let flusher_dead = match &self.flusher {
            Some(h) => h.is_finished(),
            None => true,
        };
        if q.shutdown || flusher_dead {
            let _ = item; // dropping `reply` disconnects `rx` immediately
            return rx;
        }
        q.items.push(Pending { item, reply, enqueued: Instant::now() });
        cv.notify_all();
        rx
    }

    /// Number of requests accumulated but not yet flushed to a batch.
    /// Requests in a flushed-but-unresolved batch are **not** counted
    /// here — see [`in_flight`](Self::in_flight); a queue-depth gauge
    /// that ignored them under-reported sustained load.
    pub fn pending(&self) -> usize {
        lock_recover(&self.queue.0).items.len()
    }

    /// Number of requests in flushed batches that have not yet been
    /// delivered (executing, or waiting on the completer).
    pub fn in_flight(&self) -> usize {
        self.inflight.load(Ordering::Relaxed)
    }
}

impl<T, R> Batcher<T, R> {
    /// Stop accepting new work and wake the flusher to drain what is
    /// already queued. Idempotent; [`Drop`] runs it before joining the
    /// worker threads. Subsequent [`submit`](Self::submit)s return an
    /// already-disconnected receiver.
    pub fn shutdown(&self) {
        let (lock, cv) = &*self.queue;
        lock_recover(lock).shutdown = true;
        cv.notify_all();
    }
}

impl<T, R> Drop for Batcher<T, R> {
    fn drop(&mut self) {
        self.shutdown();
        if let Some(h) = self.flusher.take() {
            let _ = h.join();
        }
        // The flusher's exit dropped the completion sender; the completer
        // drains submitted batches and stops.
        if let Some(h) = self.completer.take() {
            let _ = h.join();
        }
    }
}

fn new_queue<T, R>(max_batch: usize) -> Arc<(Mutex<Queue<T, R>>, Condvar)> {
    assert!(max_batch >= 1);
    Arc::new((Mutex::new(Queue { items: Vec::new(), shutdown: false }), Condvar::new()))
}

/// Runs on the flusher thread's way out — normal return *or* a panic
/// unwinding out of a handler/submitter closure: marks the queue shut
/// down and drops any still-enqueued reply senders, so a `submit` that
/// raced past the liveness check disconnects immediately instead of
/// sitting in a queue nobody will ever flush (recovers the mutex from
/// poison; the queue is a plain Vec, always consistent).
struct DrainOnExit<T, R>(Arc<(Mutex<Queue<T, R>>, Condvar)>);

impl<T, R> Drop for DrainOnExit<T, R> {
    fn drop(&mut self) {
        let (lock, cv) = &*self.0;
        let mut q = lock_recover(lock);
        q.shutdown = true;
        q.items.clear();
        cv.notify_all();
    }
}

fn deliver<R>(results: Vec<R>, replies: Vec<Sender<R>>) {
    assert_eq!(results.len(), replies.len(), "handler must return one result per item");
    for (r, tx) in results.into_iter().zip(replies) {
        let _ = tx.send(r); // caller may have given up
    }
}

fn flusher_loop<T, R>(
    queue: Arc<(Mutex<Queue<T, R>>, Condvar)>,
    max_batch: usize,
    max_wait: Duration,
    flush_cap: impl Fn(&T) -> Option<usize>,
    mut sink: impl FnMut(Vec<T>, Vec<Sender<R>>),
) {
    let (lock, cv) = &*queue;
    loop {
        let batch: Vec<Pending<T, R>> = {
            let mut q = lock_recover(lock);
            loop {
                if q.shutdown && q.items.is_empty() {
                    return;
                }
                if q.items.len() >= max_batch || q.shutdown {
                    break;
                }
                if let Some(oldest) = q.items.first() {
                    let waited = oldest.enqueued.elapsed();
                    if waited >= max_wait {
                        break;
                    }
                    let (qq, _timeout) = cv.wait_timeout(q, max_wait - waited).unwrap();
                    q = qq;
                } else {
                    q = cv.wait(q).unwrap();
                }
            }
            // Cost-aware sizing: the oldest batchmate (nearest its
            // budget's edge) may cap this flush below max_batch — see
            // `start_service_with_cap`. Clamped to 1: always progress.
            let mut take = q.items.len().min(max_batch);
            if let Some(cap) = q.items.first().and_then(|p| flush_cap(&p.item)) {
                take = take.min(cap.max(1));
            }
            q.items.drain(..take).collect()
        };
        if batch.is_empty() {
            continue;
        }
        let (items, replies): (Vec<T>, Vec<Sender<R>>) =
            batch.into_iter().map(|p| (p.item, p.reply)).unzip();
        sink(items, replies);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batches_up_to_max() {
        let b: Batcher<u32, usize> = Batcher::start(4, Duration::from_millis(50), |items| {
            let n = items.len();
            items.iter().map(|_| n).collect()
        });
        let rxs: Vec<_> = (0..4).map(|i| b.submit(i)).collect();
        for rx in rxs {
            assert_eq!(rx.recv().unwrap(), 4, "full batch flushed at once");
        }
    }

    #[test]
    fn flushes_on_timeout() {
        let b: Batcher<u32, usize> = Batcher::start(100, Duration::from_millis(10), |items| {
            let n = items.len();
            items.iter().map(|_| n).collect()
        });
        let rx = b.submit(7);
        let t0 = Instant::now();
        assert_eq!(rx.recv().unwrap(), 1, "lone request flushed by timer");
        assert!(t0.elapsed() >= Duration::from_millis(8));
    }

    #[test]
    fn results_in_request_order() {
        let b: Batcher<u32, u32> = Batcher::start(3, Duration::from_millis(20), |items| {
            items.iter().map(|x| x * 10).collect()
        });
        let rxs: Vec<_> = (0..3).map(|i| b.submit(i)).collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            assert_eq!(rx.recv().unwrap(), i as u32 * 10);
        }
    }

    #[test]
    fn drop_flushes_pending() {
        let rx = {
            let b: Batcher<u32, u32> =
                Batcher::start(100, Duration::from_secs(10), |items| items);
            b.submit(42)
            // drop: shutdown flag flushes the waiting item
        };
        assert_eq!(rx.recv().unwrap(), 42);
    }

    #[test]
    fn concurrent_submitters() {
        let b: Arc<Batcher<u32, u32>> =
            Arc::new(Batcher::start(8, Duration::from_millis(5), |items| items));
        let mut joins = Vec::new();
        for t in 0..4 {
            let b = Arc::clone(&b);
            joins.push(std::thread::spawn(move || {
                for i in 0..10 {
                    let v = t * 100 + i;
                    assert_eq!(b.submit(v).recv().unwrap(), v);
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
    }

    #[test]
    fn pipelined_resolves_in_order() {
        let b: Batcher<u32, u32> =
            Batcher::start_pipelined(2, Duration::from_millis(5), |items| {
                Box::new(move || items.iter().map(|x| x + 100).collect())
            });
        let rxs: Vec<_> = (0..4).map(|i| b.submit(i)).collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            assert_eq!(rx.recv().unwrap(), i as u32 + 100);
        }
    }

    #[test]
    fn pipelined_overlaps_batches() {
        // The first batch blocks in its resolver until the second batch
        // has been *submitted* — only possible if accumulation continues
        // while a batch executes.
        let submitted = Arc::new((Mutex::new(0usize), Condvar::new()));
        let s2 = Arc::clone(&submitted);
        let b: Batcher<u32, u32> =
            Batcher::start_pipelined(1, Duration::from_millis(1), move |items| {
                let (lock, cv) = &*s2;
                let mut n = lock.lock().unwrap();
                *n += 1;
                cv.notify_all();
                let s3 = Arc::clone(&s2);
                Box::new(move || {
                    let (lock, cv) = &*s3;
                    let mut n = lock.lock().unwrap();
                    // wait until 2 batches have been submitted
                    while *n < 2 {
                        let (nn, timeout) =
                            cv.wait_timeout(n, Duration::from_secs(2)).unwrap();
                        n = nn;
                        if timeout.timed_out() {
                            break;
                        }
                    }
                    assert!(*n >= 2, "second batch never submitted while first ran");
                    items
                })
            });
        let r1 = b.submit(1);
        let r2 = b.submit(2);
        assert_eq!(r1.recv().unwrap(), 1);
        assert_eq!(r2.recv().unwrap(), 2);
    }

    #[test]
    fn in_flight_counts_flushed_unresolved_batches() {
        // A flushed batch leaves `pending` but must show in `in_flight`
        // until its resolver delivers — otherwise requests "vanish" from
        // the gauges while they execute.
        let gate = Arc::new((Mutex::new(false), Condvar::new()));
        let g2 = Arc::clone(&gate);
        let b: Batcher<u32, u32> =
            Batcher::start_pipelined(1, Duration::from_millis(1), move |items| {
                let g3 = Arc::clone(&g2);
                Box::new(move || {
                    let (lock, cv) = &*g3;
                    let mut open = lock.lock().unwrap();
                    while !*open {
                        let (o, timeout) =
                            cv.wait_timeout(open, Duration::from_secs(5)).unwrap();
                        open = o;
                        if timeout.timed_out() {
                            break;
                        }
                    }
                    items
                })
            });
        let rx = b.submit(5);
        // wait for the flush: request moves pending -> in_flight
        let t0 = Instant::now();
        while b.in_flight() != 1 && t0.elapsed() < Duration::from_secs(5) {
            std::thread::sleep(Duration::from_millis(1));
        }
        assert_eq!(b.in_flight(), 1, "flushed batch must be counted in flight");
        assert_eq!(b.pending(), 0, "flushed batch must leave the pending gauge");
        {
            let (lock, cv) = &*gate;
            *lock.lock().unwrap() = true;
            cv.notify_all();
        }
        assert_eq!(rx.recv().unwrap(), 5);
        let t0 = Instant::now();
        while b.in_flight() != 0 && t0.elapsed() < Duration::from_secs(5) {
            std::thread::sleep(Duration::from_millis(1));
        }
        assert_eq!(b.in_flight(), 0, "delivered batch must clear the gauge");
    }

    #[test]
    fn pipelined_drop_flushes_pending() {
        let rx = {
            let b: Batcher<u32, u32> =
                Batcher::start_pipelined(100, Duration::from_secs(10), |items| {
                    Box::new(move || items)
                });
            b.submit(9)
        };
        assert_eq!(rx.recv().unwrap(), 9);
    }

    #[test]
    fn admission_settles_expired_items_at_flush() {
        // Items > 100 are "expired": admission replies u32::MAX for
        // them at flush time; survivors go through the submitter.
        let b: Batcher<u32, u32> = Batcher::start_service(
            4,
            Duration::from_millis(5),
            |&x| (x > 100).then_some(u32::MAX),
            |items| Box::new(move || items),
        );
        let keep = b.submit(7);
        let dead = b.submit(200);
        assert_eq!(dead.recv_timeout(Duration::from_secs(2)).unwrap(), u32::MAX);
        assert_eq!(keep.recv_timeout(Duration::from_secs(2)).unwrap(), 7);
        // reaped items never count in flight
        assert_eq!(b.in_flight(), 0);
    }

    #[test]
    fn fully_reaped_batch_skips_the_submitter() {
        let submitted = Arc::new(AtomicUsize::new(0));
        let s2 = Arc::clone(&submitted);
        let b: Batcher<u32, u32> = Batcher::start_service(
            4,
            Duration::from_millis(5),
            |_| Some(0),
            move |items| {
                s2.fetch_add(items.len(), Ordering::SeqCst);
                Box::new(move || items)
            },
        );
        let rxs: Vec<_> = (0..3).map(|i| b.submit(i)).collect();
        for rx in rxs {
            assert_eq!(rx.recv_timeout(Duration::from_secs(2)).unwrap(), 0);
        }
        assert_eq!(
            submitted.load(Ordering::SeqCst),
            0,
            "an all-reaped batch must never reach the submitter"
        );
    }

    #[test]
    fn flush_cap_limits_batch_size() {
        // Each item carries "how many batchmates my budget affords".
        // Four items are queued before the flusher can flush (10ms
        // wait); the oldest affords only 2, so the flush must split
        // into batches of at most 2 instead of one batch of 4.
        let sizes = Arc::new(Mutex::new(Vec::new()));
        let s2 = Arc::clone(&sizes);
        let b: Batcher<usize, usize> = Batcher::start_service_with_cap(
            8,
            Duration::from_millis(10),
            |&afford| Some(afford),
            |_| None,
            move |items| {
                s2.lock().unwrap().push(items.len());
                Box::new(move || items)
            },
        );
        let rxs: Vec<_> = (0..4).map(|_| b.submit(2)).collect();
        for rx in rxs {
            rx.recv_timeout(Duration::from_secs(2)).unwrap();
        }
        let sizes = sizes.lock().unwrap();
        assert!(!sizes.is_empty());
        assert!(
            sizes.iter().all(|&n| n <= 2),
            "flush exceeded the oldest batchmate's affordable size: {sizes:?}"
        );
    }

    #[test]
    fn flush_cap_zero_still_makes_progress() {
        // A cap of 0 (the oldest cannot afford even itself) clamps to
        // 1: the flusher must not spin on an undrainable queue — the
        // doomed item flushes alone and the admission layer settles it.
        let b: Batcher<u32, u32> = Batcher::start_service_with_cap(
            8,
            Duration::from_millis(5),
            |_| Some(0),
            |_| None,
            |items| Box::new(move || items),
        );
        let rx = b.submit(11);
        assert_eq!(rx.recv_timeout(Duration::from_secs(2)).unwrap(), 11);
    }

    #[test]
    fn no_cap_keeps_full_batches() {
        let sizes = Arc::new(Mutex::new(Vec::new()));
        let s2 = Arc::clone(&sizes);
        let b: Batcher<u32, u32> = Batcher::start_service_with_cap(
            4,
            Duration::from_millis(20),
            |_| None,
            |_| None,
            move |items| {
                s2.lock().unwrap().push(items.len());
                Box::new(move || items)
            },
        );
        let rxs: Vec<_> = (0..4).map(|i| b.submit(i)).collect();
        for rx in rxs {
            rx.recv_timeout(Duration::from_secs(2)).unwrap();
        }
        assert_eq!(*sizes.lock().unwrap(), vec![4], "capless flush takes max_batch");
    }

    #[test]
    fn submit_after_shutdown_disconnects_immediately() {
        // Before the fix, a post-shutdown submit enqueued into a queue
        // nobody drains and the caller blocked for its whole timeout.
        let b: Batcher<u32, u32> = Batcher::start(4, Duration::from_secs(10), |items| items);
        b.shutdown();
        let t0 = Instant::now();
        let rx = b.submit(1);
        assert!(
            matches!(
                rx.recv_timeout(Duration::from_secs(2)),
                Err(std::sync::mpsc::RecvTimeoutError::Disconnected)
            ),
            "post-shutdown submit must disconnect, not deliver or hang"
        );
        assert!(
            t0.elapsed() < Duration::from_millis(500),
            "disconnect must be immediate, not a timeout: {:?}",
            t0.elapsed()
        );
    }

    #[test]
    fn submit_after_flusher_panic_disconnects() {
        // A panicking submitter kills the flusher thread; later submits
        // must fail fast instead of stranding callers.
        let b: Batcher<u32, u32> =
            Batcher::start_pipelined(1, Duration::from_millis(1), |_items| {
                panic!("submitter blew up")
            });
        let r1 = b.submit(1);
        // the panic drops r1's reply sender: observe the flusher's death
        assert!(r1.recv_timeout(Duration::from_secs(5)).is_err());
        // the thread may take a moment to fully finish unwinding
        let t0 = Instant::now();
        loop {
            let rx = b.submit(2);
            match rx.recv_timeout(Duration::from_millis(50)) {
                Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => break,
                _ if t0.elapsed() > Duration::from_secs(5) => {
                    panic!("submit kept enqueuing into a dead batcher")
                }
                _ => continue,
            }
        }
    }
}

//! Request router: JSON ops -> handlers over the shared serving state.
//!
//! Protocol (JSON-lines over TCP, one object per line):
//!
//! | op             | request fields                        | response fields |
//! |----------------|---------------------------------------|-----------------|
//! | `ping`         | –                                     | `ok`            |
//! | `embed`        | `text`                                | `embedding`     |
//! | `embed_tokens` | `tokens` (array of ids)               | `embedding`     |
//! | `ocr`          | `seed`, `boxes`, opt `variant`        | `texts`, timing |
//! | `stats`        | –                                     | metrics snapshot|
//!
//! Every request may carry an `id`, echoed back. Errors come back as
//! `{"id":..,"error":"..."}`.

use std::sync::Arc;
use std::time::Instant;

use crate::config::Config;
use crate::coordinator::batcher::Batcher;
use crate::metrics::Metrics;
use crate::nlp::{BertServer, Strategy};
use crate::ocr::{generate, GenOptions, OcrPipeline};
use crate::simcpu::ocr::OcrVariant;
use crate::util::json::{arr, num, obj, s, Json};
use crate::util::prng::Rng;

pub struct ServerState {
    pub bert: BertServer,
    pub ocr: OcrPipeline,
    pub metrics: Arc<Metrics>,
    pub config: Config,
    /// cross-connection dynamic batcher for embed requests
    pub embed_batcher: Batcher<Vec<i32>, Result<Vec<f32>, String>>,
}

impl ServerState {
    pub fn new(bert: BertServer, ocr: OcrPipeline, config: Config) -> Arc<ServerState> {
        let metrics = Metrics::new();
        let session = Arc::clone(bert.session());
        let policy = config.policy;
        let m2 = Arc::clone(&metrics);
        let embed_batcher = Batcher::start(
            config.max_batch,
            std::time::Duration::from_millis(config.max_wait_ms),
            move |requests: Vec<Vec<i32>>| {
                let t0 = Instant::now();
                let server = BertServer::new(Arc::clone(&session));
                let n = requests.len();
                m2.add("batches", 1);
                m2.add("batched_requests", n as u64);
                match server.serve(&requests, Strategy::Prun(policy)) {
                    Ok(res) => {
                        m2.record("bert_batch", t0.elapsed());
                        res.outputs.into_iter().map(Ok).collect()
                    }
                    Err(e) => (0..n).map(|_| Err(format!("{e:#}"))).collect(),
                }
            },
        );
        Arc::new(ServerState { bert, ocr, metrics, config, embed_batcher })
    }
}

/// Handle one request object, producing the response object.
pub fn route(state: &ServerState, req: &Json) -> Json {
    let id = req.get("id").cloned().unwrap_or(Json::Null);
    let t0 = Instant::now();
    let mut resp = match req.get("op").and_then(|v| v.as_str()) {
        Some("ping") => obj(vec![("ok", Json::Bool(true))]),
        Some("embed") => handle_embed(state, req),
        Some("embed_tokens") => handle_embed_tokens(state, req),
        Some("ocr") => handle_ocr(state, req),
        Some("stats") => state.metrics.snapshot_json(),
        Some(other) => err(format!("unknown op '{other}'")),
        None => err("missing 'op'".to_string()),
    };
    state.metrics.add("requests", 1);
    state.metrics.record("request", t0.elapsed());
    if let Json::Obj(pairs) = &mut resp {
        pairs.insert(0, ("id".to_string(), id));
    }
    resp
}

fn err(msg: String) -> Json {
    obj(vec![("error", Json::Str(msg))])
}

fn embedding_json(vec: &[f32]) -> Json {
    arr(vec.iter().map(|&x| num(x as f64)))
}

fn handle_embed(state: &ServerState, req: &Json) -> Json {
    let Some(text) = req.get("text").and_then(|v| v.as_str()) else {
        return err("embed needs 'text'".into());
    };
    let tok = state.bert.tokenizer();
    let max_seq = state.bert.session().manifest().bert.max_seq;
    let ids = tok.encode(text, max_seq);
    embed_ids(state, ids)
}

fn handle_embed_tokens(state: &ServerState, req: &Json) -> Json {
    let Some(tokens) = req.get("tokens").and_then(|v| v.as_arr()) else {
        return err("embed_tokens needs 'tokens'".into());
    };
    let ids: Vec<i32> = tokens
        .iter()
        .filter_map(|v| v.as_i64().map(|x| x as i32))
        .collect();
    if ids.len() != tokens.len() || ids.len() < 2 {
        return err("tokens must be >=2 integers".into());
    }
    embed_ids(state, ids)
}

fn embed_ids(state: &ServerState, ids: Vec<i32>) -> Json {
    match state.embed_batcher.submit(ids).recv() {
        Ok(Ok(embedding)) => obj(vec![("embedding", embedding_json(&embedding))]),
        Ok(Err(e)) => err(e),
        Err(_) => err("server shutting down".into()),
    }
}

fn handle_ocr(state: &ServerState, req: &Json) -> Json {
    let seed = req.get("seed").and_then(|v| v.as_i64()).unwrap_or(0) as u64;
    let boxes = req.get("boxes").and_then(|v| v.as_usize()).unwrap_or(3);
    let variant = match req.get("variant").and_then(|v| v.as_str()) {
        None => OcrVariant::Prun(state.config.policy),
        Some(name) => match crate::ocr::variant_from_name(name) {
            Some(v) => v,
            None => return err(format!("unknown variant '{name}'")),
        },
    };
    let mut rng = Rng::new(seed);
    let img = generate(state.ocr.meta(), &mut rng, boxes, &GenOptions::default());
    match state.ocr.process(&img, variant) {
        Ok(res) => {
            state.metrics.add("ocr_images", 1);
            state.metrics.add("ocr_boxes", res.boxes.len() as u64);
            let texts = arr(res.texts.iter().map(|t| match t {
                Some(t) => s(t),
                None => Json::Null,
            }));
            let truth = arr(img.boxes.iter().map(|b| s(&b.text)));
            obj(vec![
                ("texts", texts),
                ("ground_truth", truth),
                ("variant", s(variant.name())),
                ("det_ms", num(res.timing.det.as_secs_f64() * 1e3)),
                ("cls_ms", num(res.timing.cls.as_secs_f64() * 1e3)),
                ("rec_ms", num(res.timing.rec.as_secs_f64() * 1e3)),
            ])
        }
        Err(e) => err(format!("{e:#}")),
    }
}

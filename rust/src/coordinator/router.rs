//! Request router: JSON ops -> handlers over the shared serving state.
//!
//! Protocol (JSON-lines over TCP, one object per line):
//!
//! | op             | request fields                        | response fields |
//! |----------------|---------------------------------------|-----------------|
//! | `ping`         | –                                     | `ok`            |
//! | `embed`        | `text`                                | `embedding`     |
//! | `embed_tokens` | `tokens` (array of ids)               | `embedding`     |
//! | `ocr`          | `seed`, `boxes`, opt `variant`        | `texts`, timing |
//! | `stats`        | –                                     | metrics snapshot + `sched.*` |
//!
//! Every request may carry an `id`, echoed back. Errors come back as
//! `{"id":..,"error":"..."}`.
//!
//! The router is the **ingress**: it mints one [`RequestCtx`] per
//! arriving request — token, end-to-end [`Budget`]
//! (`--request-timeout-ms` for embed, `--ocr-timeout-ms` for OCR),
//! priority — and every layer below consumes that one context:
//!
//! - the embed batcher's flush-time admission reads `ctx.is_cancelled()`
//!   / `ctx.expired()` and settles doomed requests with typed
//!   [`SubmitError`]s (`embed_cancelled_reaped`, `embed_budget_expired`)
//!   before they become scheduler work;
//! - the batch submitter packs each request's ctx into an
//!   [`EmbedBatch`] and goes through `BertServer`'s
//!   [`InferenceService::submit`] — one timed-out batchmate yields its
//!   own typed error without clobbering its siblings;
//! - the scheduler rejects still-queued parts of an out-of-time request
//!   (`sched.budget_expired`), rejects up front a request whose
//!   remaining budget cannot cover the profiled cost
//!   (`sched.budget_infeasible`), and kills a part still running when
//!   the request's clock ends (`sched.running_deadline_cancelled_budget`);
//! - the OCR op submits an [`OcrJob`] through the pipeline's
//!   [`InferenceService::submit`] (a worker thread runs the phases) and
//!   bounded-waits the ticket; on expiry the ticket cancels the ctx
//!   (`ocr_timeouts`), so the pipeline's scheduler tasks release their
//!   cores instead of running unbounded for a client that gave up.

use std::sync::Arc;
use std::time::{Duration, Instant};

use super::stats::names;
use crate::config::Config;
use crate::coordinator::batcher::Batcher;
use crate::engine::{Budget, InferenceService, RequestCtx, SubmitError};
use crate::metrics::Metrics;
use crate::nlp::{BertServer, EmbedBatch};
use crate::ocr::{generate, GenOptions, OcrJob, OcrPipeline};
use crate::simcpu::ocr::OcrVariant;
use crate::util::json::{arr, num, obj, s, Json};
use crate::util::prng::Rng;

/// One embed request travelling through the batcher: the token ids plus
/// the request's [`RequestCtx`] — minted at arrival, so batcher
/// accumulation time is charged against the same account every other
/// layer reads.
pub struct EmbedRequest {
    pub ids: Vec<i32>,
    pub ctx: RequestCtx,
}

pub struct ServerState {
    pub bert: BertServer,
    pub ocr: Arc<OcrPipeline>,
    pub metrics: Arc<Metrics>,
    pub config: Config,
    /// cross-connection dynamic batcher for embed requests
    pub embed_batcher: Batcher<EmbedRequest, Result<Vec<f32>, SubmitError>>,
}

impl ServerState {
    pub fn new(bert: BertServer, ocr: OcrPipeline, config: Config) -> Arc<ServerState> {
        let metrics = Metrics::new();
        let session = Arc::clone(bert.session());
        let policy = config.policy;
        let m2 = Arc::clone(&metrics);
        // The submitter runs on the batcher's flusher thread and only
        // *enqueues* the batch into the scheduler; the returned resolver
        // is waited on by the batcher's completion thread. Batch N+1
        // accumulates and submits while batch N executes.
        let batch_server = BertServer::new(session);
        let m_reap = Arc::clone(&metrics);
        let cap_session = Arc::clone(bert.session());
        let embed_batcher: Batcher<EmbedRequest, Result<Vec<f32>, SubmitError>> =
            Batcher::start_service_with_cap(
                config.max_batch,
                Duration::from_millis(config.max_wait_ms),
                // Cost-aware flush sizing: cap each flush at the number
                // of sequences the *oldest* batchmate's remaining budget
                // can afford at the profile store's trusted per-sequence
                // cost for its bucket. Until a model has a trusted
                // profile (or when the request carries no budget) the
                // sizer has no opinion and the flush takes max_batch.
                move |r: &EmbedRequest| {
                    let remaining = r.ctx.remaining()?;
                    let m = cap_session.manifest();
                    let seq = m.seq_bucket(r.ids.len()).ok()?;
                    let cost =
                        cap_session.profiles().trusted_cost(&m.bert_model_name(1, seq))?;
                    Some((remaining.as_micros() / cost.as_micros().max(1)) as usize)
                },
                // Flush-time admission control: a request whose budget
                // died (or whose client already gave up) while it was
                // accumulating gets a typed reply now instead of
                // becoming doomed scheduler work.
                move |r: &EmbedRequest| {
                    // Cancellation first: the router mints the budget
                    // from the same duration it waits out, so by the
                    // time a timed-out client's token is observed here
                    // its budget has expired too — checking budget
                    // first would misfile every abandoned request as a
                    // deadline symptom.
                    if r.ctx.is_cancelled() {
                        m_reap.add(names::EMBED_CANCELLED_REAPED, 1);
                        Some(Err(SubmitError::Cancelled))
                    } else if r.ctx.expired() {
                        m_reap.add(names::EMBED_BUDGET_EXPIRED, 1);
                        Some(Err(SubmitError::BudgetExpired))
                    } else {
                        None
                    }
                },
                move |requests: Vec<EmbedRequest>| {
                    let t0 = Instant::now();
                    let n = requests.len();
                    m2.add(names::BATCHES, 1);
                    m2.add(names::BATCHED_REQUESTS, n as u64);
                    let mut batch = EmbedBatch::new(policy);
                    for r in requests {
                        batch.push_with(r.ids, r.ctx);
                    }
                    // The batch-level ctx is a fresh umbrella; every
                    // sequence rides its own request's ctx.
                    let ticket = batch_server.submit(batch, RequestCtx::new());
                    let m3 = Arc::clone(&m2);
                    // Per-request settlement: one timed-out (cancelled)
                    // request yields its own typed error without
                    // clobbering its batchmates.
                    Box::new(move || {
                        let results = ticket.wait_each();
                        m3.record(names::BERT_BATCH, t0.elapsed());
                        results
                    })
                },
            );
        Arc::new(ServerState { bert, ocr: Arc::new(ocr), metrics, config, embed_batcher })
    }
}

/// Handle one request object, producing the response object.
pub fn route(state: &ServerState, req: &Json) -> Json {
    let id = req.get("id").cloned().unwrap_or(Json::Null);
    let t0 = Instant::now();
    let mut resp = match req.get("op").and_then(|v| v.as_str()) {
        Some("ping") => obj(vec![("ok", Json::Bool(true))]),
        Some("embed") => handle_embed(state, req),
        Some("embed_tokens") => handle_embed_tokens(state, req),
        Some("ocr") => handle_ocr(state, req),
        Some("stats") => stats_json(state),
        Some(other) => err(format!("unknown op '{other}'")),
        None => err("missing 'op'".to_string()),
    };
    state.metrics.add(names::REQUESTS, 1);
    state.metrics.record(names::REQUEST, t0.elapsed());
    if let Json::Obj(pairs) = &mut resp {
        pairs.insert(0, ("id".to_string(), id));
    }
    resp
}

/// Metrics snapshot plus live scheduler observability (`sched.*`):
/// everything the typed [`SchedSnapshot`](super::stats::SchedSnapshot)
/// carries — queue depth (total and per priority), core occupancy per
/// class, backfill, deadline/budget/cancellation counts, the adaptive
/// feedback loop, the sharded dispatcher (plus a `sched.shard.<i>.*`
/// block per shard) and the profile store it feeds from. The wire names
/// are pinned by the golden test in `coordinator::stats`.
fn stats_json(state: &ServerState) -> Json {
    // gauges: embed requests accumulated but not yet flushed to the
    // scheduler (the batcher's own queue, upstream of sched.queue_depth)
    // and requests in flushed-but-unresolved batches — both are needed,
    // or requests "vanish" from stats while their batch executes
    state.metrics.set(names::EMBED_PENDING, state.embed_batcher.pending() as u64);
    state.metrics.set(names::EMBED_INFLIGHT, state.embed_batcher.in_flight() as u64);
    let mut snap = state.metrics.snapshot_json();
    let session = state.bert.session();
    let sched =
        super::stats::SchedSnapshot::capture(session.scheduler(), session.profiles());
    if let Json::Obj(pairs) = &mut snap {
        pairs.extend(sched.gauges());
    }
    snap
}

fn err(msg: String) -> Json {
    obj(vec![("error", Json::Str(msg))])
}

fn embedding_json(vec: &[f32]) -> Json {
    arr(vec.iter().map(|&x| num(x as f64)))
}

fn handle_embed(state: &ServerState, req: &Json) -> Json {
    let Some(text) = req.get("text").and_then(|v| v.as_str()) else {
        return err("embed needs 'text'".into());
    };
    let tok = state.bert.tokenizer();
    let max_seq = state.bert.session().manifest().bert.max_seq;
    let ids = tok.encode(text, max_seq);
    embed_ids(state, ids)
}

fn handle_embed_tokens(state: &ServerState, req: &Json) -> Json {
    let Some(tokens) = req.get("tokens").and_then(|v| v.as_arr()) else {
        return err("embed_tokens needs 'tokens'".into());
    };
    let ids: Vec<i32> = tokens
        .iter()
        .filter_map(|v| v.as_i64().map(|x| x as i32))
        .collect();
    if ids.len() != tokens.len() || ids.len() < 2 {
        return err("tokens must be >=2 integers".into());
    }
    embed_ids(state, ids)
}

fn embed_ids(state: &ServerState, ids: Vec<i32>) -> Json {
    let timeout = Duration::from_millis(state.config.request_timeout_ms);
    embed_with_timeout(&state.embed_batcher, &state.metrics, ids, timeout)
}

/// Routed embed with a bounded wait. The request's [`RequestCtx`] is
/// minted here — budget = the full `timeout`, starting now — so every
/// layer below charges against the clock this function is actually
/// waiting out. On expiry the ctx is cancelled before returning the
/// structured timeout error, so the request's scheduler task is
/// rejected from the queue (cores never taken) or stopped at the
/// executor's next poll instead of running on for a client that
/// already gave up.
///
/// Public so the timeout path is testable against a mock scheduler
/// without PJRT artifacts (see `tests/integration_timeout.rs`).
pub fn embed_with_timeout(
    batcher: &Batcher<EmbedRequest, Result<Vec<f32>, SubmitError>>,
    metrics: &Metrics,
    ids: Vec<i32>,
    timeout: Duration,
) -> Json {
    use std::sync::mpsc::RecvTimeoutError;
    let ctx = RequestCtx::new().with_budget(Budget::new(timeout));
    let rx = batcher.submit(EmbedRequest { ids, ctx: ctx.clone() });
    match rx.recv_timeout(timeout) {
        Ok(Ok(embedding)) => obj(vec![("embedding", embedding_json(&embedding))]),
        Ok(Err(e)) => err(e.to_string()),
        Err(RecvTimeoutError::Timeout) => {
            ctx.cancel();
            metrics.add(names::REQUEST_TIMEOUTS, 1);
            err("request timed out".into())
        }
        // A dead batcher abandons this request just as surely as a
        // timeout does — cancel so an already-submitted task doesn't
        // keep burning cores (and stall the shutdown drain) with no
        // one left to read it.
        Err(RecvTimeoutError::Disconnected) => {
            ctx.cancel();
            err("server shutting down".into())
        }
    }
}

fn handle_ocr(state: &ServerState, req: &Json) -> Json {
    // A negative seed used to wrap silently through `as u64` (and a
    // fractional one truncated), serving a page the client could never
    // reproduce from the seed it sent; reject anything that is not an
    // exactly-representable non-negative integer.
    let seed = match req.get("seed") {
        None => 0u64,
        Some(v) => match v.as_f64() {
            // strict bound: `u64::MAX as f64` rounds up to 2^64, which
            // would pass `<=` and then saturate to a different seed
            Some(f) if f >= 0.0 && f.fract() == 0.0 && f < u64::MAX as f64 => f as u64,
            _ => return err("'seed' must be a non-negative integer".into()),
        },
    };
    // Bound the synthetic page size structurally: `generate` cost
    // scales with the box count and runs before any cancellation
    // point, so an unbounded client value would let a single request
    // burn the connection thread past any timeout.
    const MAX_BOXES: usize = 64;
    let boxes = req.get("boxes").and_then(|v| v.as_usize()).unwrap_or(3);
    if boxes > MAX_BOXES {
        return err(format!("'boxes' must be <= {MAX_BOXES}"));
    }
    let variant = match req.get("variant").and_then(|v| v.as_str()) {
        None => OcrVariant::Prun(state.config.policy),
        Some(name) => match crate::ocr::variant_from_name(name) {
            Some(v) => v,
            None => return err(format!("unknown variant '{name}'")),
        },
    };
    // The ctx is minted *before* the (bounded) page synthesis, so
    // generation time is charged against the request's budget too.
    let timeout = Duration::from_millis(state.config.ocr_timeout_ms);
    let ctx = RequestCtx::new().with_budget(Budget::new(timeout));
    let mut rng = Rng::new(seed);
    let img = generate(state.ocr.meta(), &mut rng, boxes, &GenOptions::default());
    // ground truth echoes back with the result; the image itself moves
    // into the job
    let truth: Vec<String> = img.boxes.iter().map(|b| b.text.clone()).collect();
    // Bounded wait, same contract as embed: the pipeline runs on a
    // worker thread under the request's ctx, while this connection
    // thread waits out at most what remains of the OCR budget. On
    // expiry the ticket cancels the ctx, so the pipeline's queued
    // parts are rejected without taking cores and a running part stops
    // at the executor's next poll — the worker thread then unwinds
    // through its error path and exits.
    let ticket = state.ocr.submit(OcrJob { image: img, variant }, ctx.clone());
    let wait = ctx.remaining().unwrap_or(timeout);
    match ticket.wait_each_timeout(wait) {
        Some(mut results) => match results.pop() {
            Some(Ok(res)) => {
                state.metrics.add(names::OCR_IMAGES, 1);
                state.metrics.add(names::OCR_BOXES, res.boxes.len() as u64);
                let texts = arr(res.texts.iter().map(|t| match t {
                    Some(t) => s(t),
                    None => Json::Null,
                }));
                let truth = arr(truth.iter().map(|t| s(t)));
                obj(vec![
                    ("texts", texts),
                    ("ground_truth", truth),
                    ("variant", s(variant.name())),
                    ("det_ms", num(res.timing.det.as_secs_f64() * 1e3)),
                    ("cls_ms", num(res.timing.cls.as_secs_f64() * 1e3)),
                    ("rec_ms", num(res.timing.rec.as_secs_f64() * 1e3)),
                ])
            }
            Some(Err(e)) => err(e.to_string()),
            None => err("ocr worker returned nothing".into()),
        },
        None => {
            // wait_each_timeout already cancelled the ctx
            state.metrics.add(names::OCR_TIMEOUTS, 1);
            err("request timed out".into())
        }
    }
}

//! Serving coordinator: the request-path layer above the prun engine —
//! dynamic batcher, request router, JSON-lines TCP server.

pub mod batcher;
pub mod router;
pub mod server;
pub mod stats;

pub use batcher::{Batcher, Resolver};
pub use router::{embed_with_timeout, route, EmbedRequest, ServerState};
pub use stats::SchedSnapshot;
pub use server::{Client, Server, StopHandle};

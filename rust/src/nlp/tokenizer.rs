//! Tokenizer for the BERT serving path.
//!
//! Deterministic hash-based wordpiece-lite: lowercase, split on
//! non-alphanumerics, greedy-chunk long words, FNV-hash each piece into
//! the model's vocab range (reserving the special ids). Untrained BERT
//! weights mean token *identity* only has to be stable, not meaningful —
//! what the serving experiments exercise is sequence length.

pub const PAD_ID: i32 = 0;
pub const CLS_ID: i32 = 1;
pub const SEP_ID: i32 = 2;
pub const UNK_ID: i32 = 3;
pub const FIRST_WORD_ID: i32 = 4;

pub struct Tokenizer {
    vocab: usize,
    max_piece: usize,
}

impl Tokenizer {
    pub fn new(vocab: usize) -> Tokenizer {
        assert!(vocab > FIRST_WORD_ID as usize);
        Tokenizer { vocab, max_piece: 8 }
    }

    /// Encode text into ids: [CLS] pieces... [SEP], truncated to max_len.
    pub fn encode(&self, text: &str, max_len: usize) -> Vec<i32> {
        assert!(max_len >= 2, "need room for CLS and SEP");
        let mut ids = vec![CLS_ID];
        'outer: for word in text
            .to_lowercase()
            .split(|c: char| !c.is_alphanumeric())
            .filter(|w| !w.is_empty())
        {
            let bytes = word.as_bytes();
            let mut i = 0;
            while i < bytes.len() {
                let end = (i + self.max_piece).min(bytes.len());
                ids.push(self.piece_id(&bytes[i..end], i > 0));
                i = end;
                if ids.len() == max_len - 1 {
                    break 'outer;
                }
            }
        }
        ids.push(SEP_ID);
        ids
    }

    fn piece_id(&self, piece: &[u8], continuation: bool) -> i32 {
        // FNV-1a, salted with the continuation flag (## prefix analogue)
        let mut h: u64 = 0xcbf29ce484222325 ^ (continuation as u64);
        for &b in piece {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        let range = self.vocab as u64 - FIRST_WORD_ID as u64;
        (FIRST_WORD_ID as u64 + h % range) as i32
    }

    /// Pad ids to `len` with PAD (the pad-batch baseline's padding).
    pub fn pad(ids: &[i32], len: usize) -> Vec<i32> {
        assert!(ids.len() <= len);
        let mut out = ids.to_vec();
        out.resize(len, PAD_ID);
        out
    }

    /// Synthetic sequence of exactly `len` tokens (for workload gen).
    pub fn synthetic(&self, len: usize, seed: u64) -> Vec<i32> {
        assert!(len >= 2);
        let mut rng = crate::util::prng::Rng::new(seed);
        let mut ids = vec![CLS_ID];
        let range = self.vocab as u64 - FIRST_WORD_ID as u64;
        for _ in 0..len - 2 {
            ids.push((FIRST_WORD_ID as u64 + rng.next_u64() % range) as i32);
        }
        ids.push(SEP_ID);
        ids
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encode_has_cls_sep_and_is_deterministic() {
        let t = Tokenizer::new(8192);
        let a = t.encode("Hello, world!", 64);
        let b = t.encode("Hello, world!", 64);
        assert_eq!(a, b);
        assert_eq!(a[0], CLS_ID);
        assert_eq!(*a.last().unwrap(), SEP_ID);
        assert_eq!(a.len(), 4); // CLS hello world SEP
    }

    #[test]
    fn case_and_punct_insensitive_splitting() {
        let t = Tokenizer::new(8192);
        assert_eq!(t.encode("HELLO world", 64), t.encode("hello, WORLD", 64));
    }

    #[test]
    fn long_words_chunked() {
        let t = Tokenizer::new(8192);
        let ids = t.encode("abcdefghijklmnop", 64); // 16 chars -> 2 pieces
        assert_eq!(ids.len(), 4);
        // continuation piece differs from the same bytes at word start
        let a = t.encode("abcdefgh", 64)[1];
        assert_ne!(ids[2], a, "continuation salt distinguishes pieces");
    }

    #[test]
    fn truncation_respects_max_len() {
        let t = Tokenizer::new(8192);
        let long_text = "word ".repeat(100);
        let ids = t.encode(&long_text, 16);
        assert_eq!(ids.len(), 16);
        assert_eq!(*ids.last().unwrap(), SEP_ID);
    }

    #[test]
    fn ids_in_vocab_range() {
        let t = Tokenizer::new(8192);
        for id in t.encode("The quick brown fox jumps over the lazy dog 1234567890", 64) {
            assert!((0..8192).contains(&id));
        }
    }

    #[test]
    fn pad_fills_with_pad_id() {
        let padded = Tokenizer::pad(&[CLS_ID, 42, SEP_ID], 6);
        assert_eq!(padded, vec![CLS_ID, 42, SEP_ID, PAD_ID, PAD_ID, PAD_ID]);
    }

    #[test]
    fn synthetic_exact_length_and_seeded() {
        let t = Tokenizer::new(8192);
        let a = t.synthetic(37, 5);
        assert_eq!(a.len(), 37);
        assert_eq!(a, t.synthetic(37, 5));
        assert_ne!(a, t.synthetic(37, 6));
    }
}

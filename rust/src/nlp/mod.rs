//! BERT serving substrate (paper §4.2/§4.3): tokenizer, length
//! bucketing, and the three batch-serving strategies (pad-batch /
//! no-batch / prun).

pub mod serving;
pub mod tokenizer;

pub use serving::{BatchResult, BertServer, EmbedBatch, Strategy};
pub use tokenizer::Tokenizer;

//! BERT batch-serving strategies over the real engine (paper §4.2/§4.3).
//!
//! Mirrors `simcpu::bert` in real execution: `pad-batch` pads the whole
//! batch to one (bucketed) shape and runs it once; `no-batch` runs each
//! sequence alone; `prun` gives each sequence its own part at its own
//! length bucket. Shape bucketing (DESIGN.md §4) stands in for the
//! paper's exact-length runs: a sequence of length L runs in the smallest
//! artifact bucket >= L, padded with PAD only to the bucket edge.
//!
//! Submission goes through the unified API: `BertServer` implements
//! [`InferenceService`] over an [`EmbedBatch`] — each sequence may carry
//! its *own* [`RequestCtx`] (the coordinator's dynamic batcher packs
//! sequences from different clients into one scheduler job), and
//! sequences without one inherit the batch-level ctx.

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::engine::{
    AllocPolicy, InferenceService, JobPart, PrunRequest, RequestCtx, Session, SubmitError,
    SubmitTicket,
};
use crate::runtime::Tensor;

use super::tokenizer::Tokenizer;

/// Serving strategy for a batch of variable-length requests.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Strategy {
    PadBatch,
    NoBatch,
    Prun(AllocPolicy),
}

impl Strategy {
    pub fn name(&self) -> &'static str {
        match self {
            Strategy::PadBatch => "pad-batch",
            Strategy::NoBatch => "no-batch",
            Strategy::Prun(p) => p.name(),
        }
    }

    pub fn parse(s: &str) -> Option<Strategy> {
        match s {
            "pad-batch" | "batch" => Some(Strategy::PadBatch),
            "no-batch" => Some(Strategy::NoBatch),
            other => AllocPolicy::parse(other).map(Strategy::Prun),
        }
    }
}

/// Result of serving one batch.
#[derive(Debug)]
pub struct BatchResult {
    /// pooled embedding per request, request order
    pub outputs: Vec<Vec<f32>>,
    pub wall: Duration,
    /// model invocations performed (1 for pad-batch, k otherwise)
    pub invocations: usize,
}

/// A batch of token-id sequences for [`BertServer`]'s
/// [`InferenceService`] impl. Each sequence may ride with the
/// [`RequestCtx`] of the client request it answers (the coordinator's
/// batcher packs many clients into one scheduler job); sequences
/// without one inherit the batch-level ctx passed to `submit`.
#[derive(Debug, Clone, Default)]
pub struct EmbedBatch {
    sequences: Vec<(Vec<i32>, Option<RequestCtx>)>,
    policy: AllocPolicy,
}

impl EmbedBatch {
    pub fn new(policy: AllocPolicy) -> EmbedBatch {
        EmbedBatch { sequences: Vec::new(), policy }
    }

    /// All sequences share the batch-level ctx given to `submit`.
    pub fn from_requests(requests: &[Vec<i32>], policy: AllocPolicy) -> EmbedBatch {
        EmbedBatch {
            sequences: requests.iter().map(|r| (r.clone(), None)).collect(),
            policy,
        }
    }

    /// Append a sequence inheriting the batch-level ctx.
    pub fn push(&mut self, ids: Vec<i32>) {
        self.sequences.push((ids, None));
    }

    /// Append a sequence answering its own request: `ctx` (token,
    /// budget, priority) travels into exactly this sequence's part.
    pub fn push_with(&mut self, ids: Vec<i32>, ctx: RequestCtx) {
        self.sequences.push((ids, Some(ctx)));
    }

    pub fn len(&self) -> usize {
        self.sequences.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sequences.is_empty()
    }
}

pub struct BertServer {
    session: Arc<Session>,
}

impl BertServer {
    pub fn new(session: Arc<Session>) -> BertServer {
        BertServer { session }
    }

    pub fn session(&self) -> &Arc<Session> {
        &self.session
    }

    pub fn tokenizer(&self) -> Tokenizer {
        Tokenizer::new(self.session.manifest().bert.vocab)
    }

    /// Serve a batch of token-id sequences (unpadded, variable length)
    /// on behalf of `ctx` — blocking convenience over
    /// [`InferenceService::submit`].
    pub fn serve(
        &self,
        requests: &[Vec<i32>],
        strategy: Strategy,
        ctx: &RequestCtx,
    ) -> Result<BatchResult> {
        if requests.is_empty() {
            bail!("empty batch");
        }
        let m = self.session.manifest();
        let t0 = Instant::now();
        match strategy {
            Strategy::PadBatch => {
                let max_len = requests.iter().map(Vec::len).max().unwrap();
                let seq = m.seq_bucket(max_len)?;
                let batch = m.batch_bucket(requests.len())?;
                let mut data = Vec::with_capacity(batch * seq);
                for r in requests {
                    data.extend(Tokenizer::pad(r, seq));
                }
                // dummy rows fill the batch bucket
                data.resize(batch * seq, super::tokenizer::PAD_ID);
                let model = m.bert_model_name(batch, seq);
                let out = self.session.run_with(
                    &model,
                    vec![Tensor::i32(vec![batch, seq], data)],
                    ctx,
                )?;
                let pooled = out[0].as_f32()?;
                let hidden = out[0].shape[1];
                let outputs = requests
                    .iter()
                    .enumerate()
                    .map(|(i, _)| pooled[i * hidden..(i + 1) * hidden].to_vec())
                    .collect();
                Ok(BatchResult { outputs, wall: t0.elapsed(), invocations: 1 })
            }
            Strategy::NoBatch => {
                let mut outputs = Vec::with_capacity(requests.len());
                for r in requests {
                    let (model, tensor) = self.single_part(r)?;
                    let out = self.session.run_with(&model, vec![tensor], ctx)?;
                    outputs.push(out[0].as_f32()?.to_vec());
                }
                Ok(BatchResult { outputs, wall: t0.elapsed(), invocations: requests.len() })
            }
            Strategy::Prun(policy) => {
                let n = requests.len();
                let outputs = self
                    .submit(EmbedBatch::from_requests(requests, policy), ctx.clone())
                    .wait()
                    .map_err(anyhow::Error::new)?;
                Ok(BatchResult { outputs, wall: t0.elapsed(), invocations: n })
            }
        }
    }

    /// (model name, [1, bucket] tensor) for a single request.
    fn single_part(&self, ids: &[i32]) -> Result<(String, Tensor)> {
        let m = self.session.manifest();
        let seq = m.seq_bucket(ids.len())?;
        let data = Tokenizer::pad(ids, seq);
        Ok((m.bert_model_name(1, seq), Tensor::i32(vec![1, seq], data)))
    }
}

impl InferenceService for BertServer {
    type Request = EmbedBatch;
    type Response = Vec<f32>;

    /// Submit an embed batch: one scheduler part per sequence, each
    /// carrying its own [`RequestCtx`] (or inheriting `ctx`); the
    /// ticket settles one pooled embedding per sequence, input order,
    /// with typed [`SubmitError`]s — a cancelled or out-of-budget
    /// batchmate never clobbers its siblings.
    fn submit(&self, req: EmbedBatch, ctx: RequestCtx) -> SubmitTicket<Vec<f32>> {
        let EmbedBatch { sequences, policy } = req;
        let n = sequences.len();
        if n == 0 {
            return SubmitTicket::rejected(ctx, 0, SubmitError::Failed("empty batch".into()));
        }
        let mut parts = Vec::with_capacity(n);
        for (ids, seq_ctx) in sequences {
            let (model, tensor) = match self.single_part(&ids) {
                Ok(p) => p,
                // A malformed sequence (e.g. longer than every bucket)
                // rejects the whole batch, the legacy contract.
                Err(e) => {
                    return SubmitTicket::rejected(
                        ctx,
                        n,
                        SubmitError::Failed(format!("{e:#}")),
                    )
                }
            };
            let mut part = JobPart::new(model, vec![tensor]);
            if let Some(c) = seq_ctx {
                part = part.with_ctx(c);
            }
            parts.push(part);
        }
        self.session
            .submit(PrunRequest::new(parts).with_policy(policy), ctx)
            .map(|done| match done.outputs.first() {
                Some(t) => t
                    .as_f32()
                    .map(|v| v.to_vec())
                    .map_err(|e| SubmitError::Failed(format!("{e:#}"))),
                None => Err(SubmitError::Failed("part returned no outputs".to_string())),
            })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strategy_names_parse() {
        assert_eq!(Strategy::parse("pad-batch"), Some(Strategy::PadBatch));
        assert_eq!(Strategy::parse("no-batch"), Some(Strategy::NoBatch));
        assert_eq!(
            Strategy::parse("prun-def"),
            Some(Strategy::Prun(AllocPolicy::PrunDef))
        );
        assert_eq!(Strategy::parse("bogus"), None);
        assert_eq!(Strategy::Prun(AllocPolicy::PrunEq).name(), "prun-eq");
    }

    #[test]
    fn embed_batch_builders() {
        let mut b = EmbedBatch::new(AllocPolicy::PrunDef);
        assert!(b.is_empty());
        b.push(vec![1, 2]);
        b.push_with(vec![3, 4], RequestCtx::new());
        assert_eq!(b.len(), 2);
        let from = EmbedBatch::from_requests(&[vec![1], vec![2]], AllocPolicy::PrunEq);
        assert_eq!(from.len(), 2);
    }
}

//! BERT batch-serving strategies over the real engine (paper §4.2/§4.3).
//!
//! Mirrors `simcpu::bert` in real execution: `pad-batch` pads the whole
//! batch to one (bucketed) shape and runs it once; `no-batch` runs each
//! sequence alone; `prun` gives each sequence its own part at its own
//! length bucket. Shape bucketing (DESIGN.md §4) stands in for the
//! paper's exact-length runs: a sequence of length L runs in the smallest
//! artifact bucket >= L, padded with PAD only to the bucket edge.

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::engine::{
    AllocPolicy, Budget, CancelToken, JobPart, PrunHandle, PrunOptions, Session,
};
use crate::runtime::Tensor;

use super::tokenizer::Tokenizer;

/// Serving strategy for a batch of variable-length requests.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Strategy {
    PadBatch,
    NoBatch,
    Prun(AllocPolicy),
}

impl Strategy {
    pub fn name(&self) -> &'static str {
        match self {
            Strategy::PadBatch => "pad-batch",
            Strategy::NoBatch => "no-batch",
            Strategy::Prun(p) => p.name(),
        }
    }

    pub fn parse(s: &str) -> Option<Strategy> {
        match s {
            "pad-batch" | "batch" => Some(Strategy::PadBatch),
            "no-batch" => Some(Strategy::NoBatch),
            other => AllocPolicy::parse(other).map(Strategy::Prun),
        }
    }
}

/// Result of serving one batch.
#[derive(Debug)]
pub struct BatchResult {
    /// pooled embedding per request, request order
    pub outputs: Vec<Vec<f32>>,
    pub wall: Duration,
    /// model invocations performed (1 for pad-batch, k otherwise)
    pub invocations: usize,
}

/// A batch submitted to the scheduler but not yet waited on: the
/// non-blocking half of [`BertServer::serve`] for the prun strategy,
/// used by the coordinator's pipelined batcher.
pub struct BatchSubmit {
    handle: PrunHandle,
    t0: Instant,
    n: usize,
}

impl BatchSubmit {
    /// Block until every sequence's part completes.
    pub fn wait(self) -> Result<BatchResult> {
        let outcome = self.handle.wait()?;
        let outputs = outcome
            .outputs
            .iter()
            .map(|out| Ok(out[0].as_f32()?.to_vec()))
            .collect::<Result<Vec<_>>>()?;
        Ok(BatchResult { outputs, wall: self.t0.elapsed(), invocations: self.n })
    }

    /// Block until every part settles and return one result per request,
    /// input order. A cancelled or failed request carries its own error
    /// without discarding its batchmates' embeddings — the per-request
    /// isolation the coordinator's batcher needs once requests can time
    /// out (and be cancelled) individually.
    pub fn wait_each(self) -> Vec<Result<Vec<f32>, String>> {
        self.handle
            .wait_each()
            .into_iter()
            .map(|r| match r {
                Ok(done) => match done.outputs.first() {
                    Some(t) => t.as_f32().map(|v| v.to_vec()).map_err(|e| format!("{e:#}")),
                    None => Err("part returned no outputs".to_string()),
                },
                Err(e) => Err(format!("{e:#}")),
            })
            .collect()
    }

    /// Cancel every request of this batch still outstanding.
    pub fn cancel(&self) {
        self.handle.cancel();
    }
}

pub struct BertServer {
    session: Arc<Session>,
}

impl BertServer {
    pub fn new(session: Arc<Session>) -> BertServer {
        BertServer { session }
    }

    pub fn session(&self) -> &Arc<Session> {
        &self.session
    }

    pub fn tokenizer(&self) -> Tokenizer {
        Tokenizer::new(self.session.manifest().bert.vocab)
    }

    /// Serve a batch of token-id sequences (unpadded, variable length).
    pub fn serve(&self, requests: &[Vec<i32>], strategy: Strategy) -> Result<BatchResult> {
        if requests.is_empty() {
            bail!("empty batch");
        }
        let m = self.session.manifest();
        let t0 = Instant::now();
        match strategy {
            Strategy::PadBatch => {
                let max_len = requests.iter().map(Vec::len).max().unwrap();
                let seq = m.seq_bucket(max_len)?;
                let batch = m.batch_bucket(requests.len())?;
                let mut data = Vec::with_capacity(batch * seq);
                for r in requests {
                    data.extend(Tokenizer::pad(r, seq));
                }
                // dummy rows fill the batch bucket
                data.resize(batch * seq, super::tokenizer::PAD_ID);
                let model = m.bert_model_name(batch, seq);
                let out = self.session.run(&model, vec![Tensor::i32(vec![batch, seq], data)])?;
                let pooled = out[0].as_f32()?;
                let hidden = out[0].shape[1];
                let outputs = requests
                    .iter()
                    .enumerate()
                    .map(|(i, _)| pooled[i * hidden..(i + 1) * hidden].to_vec())
                    .collect();
                Ok(BatchResult { outputs, wall: t0.elapsed(), invocations: 1 })
            }
            Strategy::NoBatch => {
                let mut outputs = Vec::with_capacity(requests.len());
                for r in requests {
                    let (model, tensor) = self.single_part(r)?;
                    let out = self.session.run(&model, vec![tensor])?;
                    outputs.push(out[0].as_f32()?.to_vec());
                }
                Ok(BatchResult { outputs, wall: t0.elapsed(), invocations: requests.len() })
            }
            Strategy::Prun(policy) => self.serve_submit(requests, policy)?.wait(),
        }
    }

    /// Submit a batch under the prun strategy without blocking: one job
    /// part per sequence, handed to `engine::sched` via
    /// [`Session::prun_submit`]. Returns immediately with a completion
    /// handle.
    pub fn serve_submit(
        &self,
        requests: &[Vec<i32>],
        policy: AllocPolicy,
    ) -> Result<BatchSubmit> {
        self.submit_parts(requests.iter().map(|r| (r.as_slice(), None, None)), policy)
    }

    /// [`serve_submit`](Self::serve_submit) with one [`CancelToken`] per
    /// request: each sequence's job part carries its requester's token,
    /// so a single timed-out request cancels exactly its own part — the
    /// rest of the batch is untouched.
    pub fn serve_submit_cancellable(
        &self,
        requests: &[(Vec<i32>, CancelToken)],
        policy: AllocPolicy,
    ) -> Result<BatchSubmit> {
        self.submit_parts(
            requests.iter().map(|(r, token)| (r.as_slice(), Some(token.clone()), None)),
            policy,
        )
    }

    /// [`serve_submit_cancellable`](Self::serve_submit_cancellable) plus
    /// one request [`Budget`] per sequence: each part carries its *own*
    /// request's remaining deadline account (finer than deriving one
    /// running deadline from the batch minimum — batchmates with
    /// different arrival times get different remainders), so the
    /// scheduler rejects a part whose request is already out of time and
    /// kills a part still running when its request's clock ends.
    pub fn serve_submit_budgeted(
        &self,
        requests: &[(Vec<i32>, CancelToken, Budget)],
        policy: AllocPolicy,
    ) -> Result<BatchSubmit> {
        self.submit_parts(
            requests
                .iter()
                .map(|(r, token, budget)| (r.as_slice(), Some(token.clone()), Some(*budget))),
            policy,
        )
    }

    /// Shared submit pipeline: one job part per sequence (carrying its
    /// request's token and budget, when there are any), handed to the
    /// scheduler via [`Session::prun_submit`].
    fn submit_parts<'a>(
        &self,
        requests: impl ExactSizeIterator<Item = (&'a [i32], Option<CancelToken>, Option<Budget>)>,
        policy: AllocPolicy,
    ) -> Result<BatchSubmit> {
        let n = requests.len();
        if n == 0 {
            bail!("empty batch");
        }
        let t0 = Instant::now();
        let parts = requests
            .map(|(r, token, budget)| {
                let (model, tensor) = self.single_part(r)?;
                let mut part = JobPart::new(model, vec![tensor]);
                if let Some(t) = token {
                    part = part.with_cancel(t);
                }
                if let Some(b) = budget {
                    part = part.with_budget(b);
                }
                Ok(part)
            })
            .collect::<Result<Vec<_>>>()?;
        let handle =
            self.session.prun_submit(parts, PrunOptions { policy, ..Default::default() });
        Ok(BatchSubmit { handle, t0, n })
    }

    /// (model name, [1, bucket] tensor) for a single request.
    fn single_part(&self, ids: &[i32]) -> Result<(String, Tensor)> {
        let m = self.session.manifest();
        let seq = m.seq_bucket(ids.len())?;
        let data = Tokenizer::pad(ids, seq);
        Ok((m.bert_model_name(1, seq), Tensor::i32(vec![1, seq], data)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strategy_names_parse() {
        assert_eq!(Strategy::parse("pad-batch"), Some(Strategy::PadBatch));
        assert_eq!(Strategy::parse("no-batch"), Some(Strategy::NoBatch));
        assert_eq!(
            Strategy::parse("prun-def"),
            Some(Strategy::Prun(AllocPolicy::PrunDef))
        );
        assert_eq!(Strategy::parse("bogus"), None);
        assert_eq!(Strategy::Prun(AllocPolicy::PrunEq).name(), "prun-eq");
    }
}

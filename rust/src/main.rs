//! `dnc-serve` — Divide-and-Conquer inference serving CLI.
//!
//! ```text
//! dnc-serve serve   [--port P] [--cores C] [--policy prun-def] ...
//! dnc-serve ocr     [--images N] [--variant base|prun-def|...] [--seed S]
//! dnc-serve bert    [--batch X] [--strategy pad-batch|no-batch|prun-def] [--reps N]
//! dnc-serve figures [--only fig2,...] [--reps N]
//! dnc-serve info
//! ```

use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use dnc_serve::bench::figures;
use dnc_serve::config::Config;
use dnc_serve::coordinator::{Server, ServerState};
use dnc_serve::engine::{RequestCtx, Session};
use dnc_serve::nlp::{BertServer, Strategy, Tokenizer};
use dnc_serve::ocr::{exact_match, generate, GenOptions, OcrMeta, OcrPipeline};
use dnc_serve::runtime::Manifest;
use dnc_serve::util::args::Args;
use dnc_serve::util::prng::Rng;
use dnc_serve::util::stats::mean;
use dnc_serve::workload::seqlen;
use dnc_serve::{info, simcpu};

fn main() {
    dnc_serve::util::logging::init_from_env();
    let args = Args::parse_env();
    let result = match args.subcommand.as_deref() {
        Some("serve") => cmd_serve(&args),
        Some("ocr") => cmd_ocr(&args),
        Some("bert") => cmd_bert(&args),
        Some("figures") => cmd_figures(&args),
        Some("info") => cmd_info(&args),
        _ => {
            eprintln!("{}", HELP);
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

const HELP: &str = "dnc-serve — Divide-and-Conquer inference serving

USAGE:
  dnc-serve serve   [--port P] [--cores SPEC] [--workers W] [--policy POLICY]
                    [--max-batch N] [--max-wait-ms T] [--aging-ms T]
                    [--adaptive] [--deadline-running-ms T]
                    [--request-timeout-ms T] [--ocr-timeout-ms T]
                    [--drain-timeout-ms T] [--config FILE]
  dnc-serve ocr     [--images N] [--variant base|prun-def|prun-1|prun-eq]
                    [--seed S] [--boxes N] [--cores C]
  dnc-serve bert    [--batch X] [--strategy pad-batch|no-batch|prun-def]
                    [--reps N] [--seed S] [--cores C]
  dnc-serve figures [--only LIST] [--reps N]   regenerate the paper's figures
  dnc-serve info                               artifact + machine + sched summary

CORES SPEC:
  --cores 16                   homogeneous core budget (the default)
  --cores fast=4,slow=12       heterogeneous classes; slow runs at 0.5x
  --cores fast=4,slow=12@0.3   ...with an explicit relative speed per class
";

fn load_stack(cfg: &Config) -> Result<(Arc<Session>, OcrMeta)> {
    let manifest = Arc::new(
        Manifest::load(&cfg.artifacts)
            .context("loading artifacts (run `make artifacts` first)")?,
    );
    let session = if cfg.adaptive {
        // Policy tuning (aging factor, clamps, recalibration period) is
        // deliberately not a CLI surface yet: the defaults are derived
        // from measurement, not workload-specific (engine::adaptive).
        let acfg = dnc_serve::engine::AdaptiveConfig::default();
        Session::with_adaptive(manifest, cfg.sched(), cfg.workers, acfg)?
    } else {
        Session::with_config(manifest, cfg.sched(), cfg.workers)?
    };
    let session = Arc::new(session);
    let meta = OcrMeta::load(&cfg.artifacts)?;
    Ok((session, meta))
}

fn cmd_serve(args: &Args) -> Result<()> {
    let mut cfg = Config::default();
    cfg.apply_args(args)?;
    args.finish()?;
    let (session, meta) = load_stack(&cfg)?;
    let bert = BertServer::new(Arc::clone(&session));
    let ocr = OcrPipeline::new(session, meta);
    info!("warming up executors...");
    ocr.warmup()?;
    let state = ServerState::new(bert, ocr, cfg);
    let server = Server::bind(state)?;
    info!("ready on {} (JSON-lines; ops: ping/embed/embed_tokens/ocr/stats)", server.local_addr());
    server.serve()
}

fn cmd_ocr(args: &Args) -> Result<()> {
    let mut cfg = Config::default();
    cfg.apply_args(args)?;
    let n_images = args.usize_or("images", 10);
    let n_boxes = args.usize_or("boxes", 0); // 0 = sample from Fig. 3 dist
    let seed = args.u64_or("seed", 42);
    let variant_name = args.get_or("variant", "prun-def").to_string();
    args.finish()?;
    let variant = dnc_serve::ocr::variant_from_name(&variant_name)
        .with_context(|| format!("unknown variant '{variant_name}'"))?;

    let (session, meta) = load_stack(&cfg)?;
    let pipeline = OcrPipeline::new(session, meta);
    pipeline.warmup()?;

    let mut rng = Rng::new(seed);
    let mut totals = Vec::new();
    let (mut hits, mut boxes_total) = (0usize, 0usize);
    let t0 = Instant::now();
    for i in 0..n_images {
        let count = if n_boxes > 0 {
            n_boxes
        } else {
            dnc_serve::workload::boxes::sample_box_count(&mut rng)
        };
        let img = generate(pipeline.meta(), &mut rng, count, &GenOptions::default());
        // one request context per page — the CLI is this path's ingress
        let res = pipeline.process(&img, variant, &RequestCtx::new())?;
        let (h, n) = exact_match(&res, &img);
        hits += h;
        boxes_total += n;
        totals.push(res.timing.total().as_secs_f64() * 1e3);
        println!(
            "image {i:3}: {} boxes, {}/{} exact, det {:.1}ms cls {:.1}ms rec {:.1}ms",
            res.boxes.len(),
            h,
            n,
            res.timing.det.as_secs_f64() * 1e3,
            res.timing.cls.as_secs_f64() * 1e3,
            res.timing.rec.as_secs_f64() * 1e3,
        );
    }
    println!(
        "\n{} images in {:.2}s | variant {} | mean latency {:.1} ms | exact-match {}/{} ({:.1}%)",
        n_images,
        t0.elapsed().as_secs_f64(),
        variant_name,
        mean(&totals),
        hits,
        boxes_total,
        100.0 * hits as f64 / boxes_total.max(1) as f64
    );
    Ok(())
}

fn cmd_bert(args: &Args) -> Result<()> {
    let mut cfg = Config::default();
    cfg.apply_args(args)?;
    let x = args.usize_or("batch", 4);
    let reps = args.usize_or("reps", 10);
    let seed = args.u64_or("seed", 7);
    let strategy_name = args.get_or("strategy", "prun-def").to_string();
    args.finish()?;
    let strategy = Strategy::parse(&strategy_name)
        .with_context(|| format!("unknown strategy '{strategy_name}'"))?;

    let (session, _) = load_stack(&cfg)?;
    let server = BertServer::new(session);
    let tok = Tokenizer::new(server.session().manifest().bert.vocab);

    let mut rng = Rng::new(seed);
    let mut lat = Vec::new();
    let t0 = Instant::now();
    let mut served = 0usize;
    for rep in 0..reps {
        let lens = seqlen::random_batch(&mut rng, x);
        let reqs: Vec<Vec<i32>> = lens
            .iter()
            .enumerate()
            .map(|(i, &l)| tok.synthetic(l, seed + (rep * 64 + i) as u64))
            .collect();
        // one request context per batch — the CLI is this path's ingress
        let res = server.serve(&reqs, strategy, &RequestCtx::new())?;
        lat.push(res.wall.as_secs_f64() * 1e3);
        served += x;
    }
    let total = t0.elapsed().as_secs_f64();
    println!(
        "strategy {strategy_name} | batch {x} | {reps} reps | mean batch latency {:.1} ms | throughput {:.1} seq/s",
        mean(&lat),
        served as f64 / total
    );
    Ok(())
}

fn cmd_figures(args: &Args) -> Result<()> {
    let only = args.get("only").map(|s| s.to_string());
    let reps = args.usize_or("reps", 1000);
    args.finish()?;
    let want = |name: &str| only.as_deref().map(|o| o.split(',').any(|x| x == name)).unwrap_or(true);
    let threads = [1usize, 2, 4, 8, 16];
    if want("fig2") {
        figures::fig2(&threads).print();
    }
    if want("fig3") {
        figures::fig3().print();
    }
    if want("fig4") {
        figures::fig4("cls").print();
        figures::fig4("rec").print();
        figures::fig4("total").print();
    }
    if want("fig5") {
        figures::fig5(&threads).print();
    }
    if want("fig6") {
        figures::fig6(reps).print();
    }
    if want("fig7") {
        figures::fig7().print();
    }
    if want("fig8") {
        figures::fig8().print();
    }
    if want("fig9") {
        figures::fig9().print();
    }
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let mut cfg = Config::default();
    cfg.apply_args(args)?;
    args.finish()?;
    let manifest = Manifest::load(&cfg.artifacts)?;
    println!("artifacts dir : {}", cfg.artifacts.display());
    println!("executables   : {}", manifest.models.len());
    let mut families: Vec<(&str, usize)> = Vec::new();
    for fam in ["bert", "ocr_det", "ocr_cls", "ocr_rec"] {
        let n = manifest.models.values().filter(|m| m.family == fam).count();
        families.push((fam, n));
    }
    for (fam, n) in families {
        println!("  {fam:8}    : {n}");
    }
    println!(
        "bert          : {} layers, hidden {}, vocab {}, seq buckets {:?}, batch buckets {:?}",
        manifest.bert.layers,
        manifest.bert.hidden,
        manifest.bert.vocab,
        manifest.bert.seq_buckets,
        manifest.bert.batch_buckets
    );
    println!("weights       : {} tensors in {}", manifest.bert_weights.tensors.len(), manifest.bert_weights.file);
    println!(
        "machine       : {} cores available; paper testbed {} cores (simulated)",
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
        simcpu::calib::PAPER_CORES
    );
    let sched = cfg.sched();
    println!("sched         : core budget {}, aging {} ms, backfill {}, policy {}, {} executor worker(s)",
        sched.cores,
        sched.aging.as_millis(),
        if sched.backfill { "on" } else { "off" },
        cfg.policy.name(),
        cfg.workers
    );
    println!(
        "adaptive      : {}, running deadline {}",
        if cfg.adaptive { "on (profiled core sizing + aging recalibration)" } else { "off" },
        match sched.deadline_running {
            Some(d) => format!("{} ms", d.as_millis()),
            None => "none".to_string(),
        }
    );
    println!(
        "budgets       : embed {} ms, ocr {} ms (end-to-end request budgets; \
         parts inherit the remainder)",
        cfg.request_timeout_ms, cfg.ocr_timeout_ms
    );
    if !manifest.models.is_empty() {
        bail_if_missing(&manifest, &cfg)?;
    }
    Ok(())
}

fn bail_if_missing(manifest: &Manifest, cfg: &Config) -> Result<()> {
    for entry in manifest.models.values() {
        let p = cfg.artifacts.join(&entry.hlo);
        if !p.exists() {
            bail!("manifest references missing HLO file {}", p.display());
        }
    }
    println!("all HLO files present ✓");
    Ok(())
}

//! Shared bench-harness pieces: the scaling-aware mock runner
//! ([`SimRunner`]) and the *legacy* JSON gate format.
//!
//! The scenarios themselves no longer live here. They are data —
//! `rust/bench/scenarios/*.toml` — loaded and executed by the
//! [`crate::bar`] barometer (`bench-bar` binary), which subsumed the
//! old hand-coded `bench-gate` suite. What remains in this module:
//!
//! - [`SimRunner`] / [`sim_model`] / [`sim_base_ms`]: the simulated
//!   executor every scenario runs on. Latencies are deadline-based
//!   sleeps, not CPU work, so results are stable across machines and
//!   the scenarios exercise the real dispatcher (ledger,
//!   backfill/aging, adaptive recalibration) without PJRT artifacts.
//! - [`ScenarioResult`] / [`results_to_json`] / [`compare`]: the
//!   `BENCH_pr.json` record shape and comparator. `bench-bar` still
//!   emits this JSON for one release so downstream trajectory tooling
//!   keeps parsing PR runs; the CSV records under `rust/bench/record/`
//!   are the format of record now (see `rust/bench/FORMAT.md`).

use std::time::{Duration, Instant};

use crate::engine::{CoreGrant, TaskRunner};
use crate::runtime::{CancelToken, ExecResult, ReplyFn, TaskCancelled, Tensor};
use crate::simcpu::ScalProfile;
use crate::util::json::{num, obj, Json};

/// Scalability profile of the simulated models: a small serial fraction
/// and a mild per-thread coordination cost — the BERT-like shape whose
/// optimum sits near the full budget (simcpu::calib documents the
/// extended-Amdahl model).
pub const SIM_PROFILE: ScalProfile = ScalProfile::new(0.05, 0.2);

/// Virtual core budget the classic scenarios schedule against
/// (paper: 16). Scenario TOMLs without a `[machine]` section default
/// to this many homogeneous cores.
pub const SIM_CORES: usize = 16;

/// Scaling-aware mock runner: a model named `"sim:<base_ms>"` executes
/// for `SIM_PROFILE.time_ms_at(base_ms, threads, speed)` wall-clock
/// milliseconds — the granted core class's relative speed stretches the
/// whole cost, so slow cores are visibly slow — as a deadline-based
/// sleep (slice jitter does not accumulate), polling its cancel token
/// about once per millisecond.
///
/// A model name that is not a well-formed `sim:` spec fails the task:
/// in a bench context a typo'd model must poison the measurement, not
/// quietly simulate some default latency.
pub struct SimRunner {
    pub workers: usize,
}

/// `"sim:<base_ms>"` model name for [`SimRunner`].
pub fn sim_model(base_ms: f64) -> String {
    format!("sim:{base_ms}")
}

/// Parse a [`SimRunner`] model name back to its base latency.
///
/// Malformed names are a hard error. This used to fall back to
/// `1.0`, which made a typo'd scenario silently benchmark a 1ms
/// no-op — quietly-wrong numbers are worse than no numbers.
pub fn sim_base_ms(model: &str) -> Result<f64, String> {
    model
        .strip_prefix("sim:")
        .and_then(|s| s.parse::<f64>().ok())
        .filter(|b| b.is_finite() && *b >= 0.0)
        .ok_or_else(|| {
            format!(
                "malformed sim model name `{model}` — expected `sim:<base_ms>` \
                 with a finite non-negative base"
            )
        })
}

impl TaskRunner for SimRunner {
    fn workers(&self) -> usize {
        self.workers
    }

    fn run_on(
        &self,
        worker: usize,
        model: &str,
        _inputs: Vec<Tensor>,
        grant: CoreGrant,
        cancel: CancelToken,
        reply: ReplyFn,
    ) {
        let base = match sim_base_ms(model) {
            Ok(b) => b,
            Err(e) => {
                reply(Err(anyhow::anyhow!(e)));
                return;
            }
        };
        let ms = SIM_PROFILE.time_ms_at(base, grant.threads.max(1), grant.speed).max(0.0);
        std::thread::spawn(move || {
            let deadline = Instant::now() + Duration::from_secs_f64(ms / 1e3);
            loop {
                if cancel.is_cancelled() {
                    reply(Err(anyhow::Error::new(TaskCancelled)));
                    return;
                }
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                std::thread::sleep((deadline - now).min(Duration::from_millis(1)));
            }
            reply(Ok(ExecResult {
                outputs: Vec::new(),
                exec_time: Duration::from_secs_f64(ms / 1e3),
                worker,
            }));
        });
    }
}

/// One scenario's measured outcome in the legacy `BENCH_pr.json`
/// shape. The barometer's richer records ([`crate::bar::Measurement`])
/// project down to this for the one-release compatibility window.
#[derive(Debug, Clone)]
pub struct ScenarioResult {
    pub name: String,
    pub jobs: usize,
    pub throughput_jobs_s: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
}

// ---------------------------------------------------------------- JSON

/// `{"scenarios": {"<name>": {"jobs": .., "throughput_jobs_s": ..,
/// "p50_ms": .., "p95_ms": ..}}}`
pub fn results_to_json(results: &[ScenarioResult]) -> Json {
    let entries: Vec<(String, Json)> = results
        .iter()
        .map(|r| {
            (
                r.name.clone(),
                obj(vec![
                    ("jobs", num(r.jobs as f64)),
                    ("throughput_jobs_s", num(r.throughput_jobs_s)),
                    ("p50_ms", num(r.p50_ms)),
                    ("p95_ms", num(r.p95_ms)),
                ]),
            )
        })
        .collect();
    Json::Obj(vec![("scenarios".to_string(), Json::Obj(entries))])
}

/// Compare a PR run against a recorded baseline in the legacy JSON
/// shape. `tolerance_pct` is the default allowed drift; a baseline
/// scenario may override it with its own `"tolerance_pct"` field
/// (noisier concurrent scenarios carry a wider one). Returns one
/// human-readable line per regression; empty means the gate passes.
/// Scenarios present in the baseline but missing from the PR run (or
/// vice versa) are regressions too — a silently dropped benchmark must
/// not pass the gate.
///
/// Retained for downstream consumers of `BENCH_pr.json`; the CI gate
/// itself now runs `bench-bar diff` over the CSV records.
pub fn compare(pr: &Json, baseline: &Json, tolerance_pct: f64) -> Vec<String> {
    let mut failures = Vec::new();
    let empty = Json::Obj(Vec::new());
    let base_scen = baseline.get("scenarios").unwrap_or(&empty);
    let pr_scen = pr.get("scenarios").unwrap_or(&empty);
    let (Json::Obj(base_pairs), Json::Obj(pr_pairs)) = (base_scen, pr_scen) else {
        return vec!["malformed bench JSON: missing 'scenarios' object".to_string()];
    };
    for (name, base) in base_pairs {
        let Some(pr_entry) = pr_scen.get(name) else {
            failures.push(format!("scenario '{name}' missing from PR run"));
            continue;
        };
        let tol = base
            .get("tolerance_pct")
            .and_then(|v| v.as_f64())
            .unwrap_or(tolerance_pct)
            / 100.0;
        let metric = |j: &Json, key: &str| j.get(key).and_then(|v| v.as_f64());
        // quick and full runs are not comparable (different job counts
        // shift the percentiles and steady-state throughput): a jobs
        // mismatch means the baseline was recorded in the other mode.
        if let (Some(b), Some(p)) = (metric(base, "jobs"), metric(pr_entry, "jobs")) {
            if b != p {
                failures.push(format!(
                    "{name}: job count mismatch (baseline {b}, PR {p}) — was the \
                     baseline recorded without --quick (or vice versa)?"
                ));
                continue;
            }
        }
        // throughput: lower is worse
        if let (Some(b), Some(p)) =
            (metric(base, "throughput_jobs_s"), metric(pr_entry, "throughput_jobs_s"))
        {
            if p < b * (1.0 - tol) {
                failures.push(format!(
                    "{name}: throughput regressed {p:.1} < {b:.1} jobs/s (-{:.0}% tolerance)",
                    tol * 100.0
                ));
            }
        }
        // p95 latency: higher is worse
        if let (Some(b), Some(p)) = (metric(base, "p95_ms"), metric(pr_entry, "p95_ms")) {
            if p > b * (1.0 + tol) {
                failures.push(format!(
                    "{name}: p95 regressed {p:.1} > {b:.1} ms (+{:.0}% tolerance)",
                    tol * 100.0
                ));
            }
        }
    }
    for (name, _) in pr_pairs {
        if base_scen.get(name).is_none() {
            failures.push(format!(
                "scenario '{name}' has no baseline — record one with --record"
            ));
        }
    }
    failures
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{CoreMap, PartTask, SchedConfig, Scheduler};
    use std::sync::Arc;

    fn result(name: &str, thr: f64, p95: f64) -> ScenarioResult {
        ScenarioResult {
            name: name.to_string(),
            jobs: 10,
            throughput_jobs_s: thr,
            p50_ms: p95 / 2.0,
            p95_ms: p95,
        }
    }

    #[test]
    fn json_round_trip() {
        let rs = vec![result("a", 100.0, 8.0), result("b", 50.0, 20.0)];
        let j = results_to_json(&rs);
        let back = Json::parse(&j.to_string()).unwrap();
        let a = back.get("scenarios").unwrap().get("a").unwrap();
        assert_eq!(a.get("jobs").unwrap().as_usize().unwrap(), 10);
        assert!((a.get("p95_ms").unwrap().as_f64().unwrap() - 8.0).abs() < 1e-9);
    }

    #[test]
    fn compare_passes_within_tolerance() {
        let base = results_to_json(&[result("a", 100.0, 10.0)]);
        let pr = results_to_json(&[result("a", 90.0, 11.0)]);
        assert!(compare(&pr, &base, 15.0).is_empty());
    }

    #[test]
    fn compare_fails_on_regression() {
        let base = results_to_json(&[result("a", 100.0, 10.0)]);
        let slow = results_to_json(&[result("a", 100.0, 12.0)]);
        let fails = compare(&slow, &base, 15.0);
        assert_eq!(fails.len(), 1, "{fails:?}");
        assert!(fails[0].contains("p95"), "{fails:?}");
        let starved = results_to_json(&[result("a", 80.0, 10.0)]);
        let fails = compare(&starved, &base, 15.0);
        assert_eq!(fails.len(), 1, "{fails:?}");
        assert!(fails[0].contains("throughput"), "{fails:?}");
    }

    #[test]
    fn compare_fails_on_missing_scenarios() {
        let base = results_to_json(&[result("a", 100.0, 10.0)]);
        let pr = results_to_json(&[result("b", 100.0, 10.0)]);
        let fails = compare(&pr, &base, 15.0);
        assert_eq!(fails.len(), 2, "{fails:?}");
    }

    #[test]
    fn per_scenario_tolerance_overrides_default() {
        // baseline carries tolerance_pct = 50 for a noisy scenario
        let mut base = results_to_json(&[result("noisy", 100.0, 10.0)]);
        if let Json::Obj(pairs) = &mut base {
            if let Json::Obj(scen) = &mut pairs[0].1 {
                if let Json::Obj(entry) = &mut scen[0].1 {
                    entry.push(("tolerance_pct".to_string(), num(50.0)));
                }
            }
        }
        let pr = results_to_json(&[result("noisy", 60.0, 14.0)]);
        assert!(compare(&pr, &base, 15.0).is_empty());
        let pr = results_to_json(&[result("noisy", 40.0, 14.0)]);
        assert_eq!(compare(&pr, &base, 15.0).len(), 1);
    }

    #[test]
    fn sim_runner_models_scaling() {
        // more threads -> shorter simulated time, up to the overhead
        let t1 = SIM_PROFILE.time_ms(40.0, 1);
        let t12 = SIM_PROFILE.time_ms(40.0, 12);
        assert!((t1 - 40.0).abs() < 1e-9);
        assert!(t12 < 10.0, "{t12}");
    }

    #[test]
    fn sim_base_ms_parses_well_formed_names() {
        assert_eq!(sim_base_ms("sim:8").unwrap(), 8.0);
        assert_eq!(sim_base_ms(&sim_model(2.5)).unwrap(), 2.5);
        assert_eq!(sim_base_ms("sim:0").unwrap(), 0.0);
    }

    #[test]
    fn sim_base_ms_rejects_malformed_names() {
        // Regression: these used to fall back to 1.0 and quietly
        // benchmark a no-op.
        for bad in ["sim:banana", "bert-base", "sim:", "sim", "sim:-4", "sim:inf", "sim:NaN"] {
            let err = sim_base_ms(bad).unwrap_err();
            assert!(err.contains("malformed sim model name"), "{bad}: {err}");
        }
    }

    #[test]
    fn malformed_sim_model_fails_the_task_end_to_end() {
        // The runner must reply with the parse error, not simulate a
        // default latency: the submit handle sees a hard failure.
        let sched = Scheduler::start(
            SchedConfig { cores: CoreMap::homogeneous(4), ..SchedConfig::default() },
            Arc::new(SimRunner { workers: 1 }),
        );
        let err = sched
            .submit(PartTask::new("sim:banana".to_string(), Vec::new(), 1))
            .wait()
            .expect_err("malformed sim model must fail the task");
        assert!(err.to_string().contains("malformed sim model"), "{err}");
    }
}

//! CI bench-gate scenarios: small, artifact-free benchmarks of the
//! scheduler + adaptive policy, with machine-readable results.
//!
//! Modelled on rebar's recorded-baseline discipline: every scenario
//! emits `(throughput, p50, p95)`; the `bench-gate` binary
//! (`rust/scripts/bench_gate.rs`) writes them to `BENCH_pr.json`,
//! compares against the checked-in `BENCH_baseline.json`, and fails CI
//! on a regression beyond the tolerance. The scenarios run on a
//! *scaling-aware mock runner* ([`SimRunner`]) so they exercise the
//! real dispatcher (ledger, backfill/aging, adaptive recalibration)
//! without PJRT artifacts — they run on any box, including CI.
//!
//! Scenario latencies are simulated sleeps, not CPU work, so results
//! are stable across machines; per-scenario tolerances in the baseline
//! absorb the residual timer jitter.

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::engine::{
    allocate, AdaptiveConfig, AdaptivePolicy, AllocPolicy, Budget, CoreGrant, CoreMap,
    PartTask, PartWeights, Priority, ProfileStore, RequestCtx, SchedConfig, Scheduler,
    TaskRunner,
};
use crate::runtime::{CancelToken, ExecResult, ReplyFn, TaskCancelled, Tensor};
use crate::simcpu::ScalProfile;
use crate::util::json::{num, obj, Json};
use crate::util::stats::percentiles;

/// Scalability profile of the simulated models: a small serial fraction
/// and a mild per-thread coordination cost — the BERT-like shape whose
/// optimum sits near the full budget (simcpu::calib documents the
/// extended-Amdahl model).
pub const SIM_PROFILE: ScalProfile = ScalProfile::new(0.05, 0.2);

/// Virtual core budget every scenario schedules against (paper: 16).
pub const SIM_CORES: usize = 16;

/// Scaling-aware mock runner: a model named `"sim:<base_ms>"` executes
/// for `SIM_PROFILE.time_ms_at(base_ms, threads, speed)` wall-clock
/// milliseconds — the granted core class's relative speed stretches the
/// whole cost, so slow cores are visibly slow — as a deadline-based
/// sleep (slice jitter does not accumulate), polling its cancel token
/// about once per millisecond.
pub struct SimRunner {
    pub workers: usize,
}

/// `"sim:<base_ms>"` model name for [`SimRunner`].
pub fn sim_model(base_ms: f64) -> String {
    format!("sim:{base_ms}")
}

fn sim_base_ms(model: &str) -> f64 {
    model
        .strip_prefix("sim:")
        .and_then(|s| s.parse().ok())
        .unwrap_or(1.0)
}

impl TaskRunner for SimRunner {
    fn workers(&self) -> usize {
        self.workers
    }

    fn run_on(
        &self,
        worker: usize,
        model: &str,
        _inputs: Vec<Tensor>,
        grant: CoreGrant,
        cancel: CancelToken,
        reply: ReplyFn,
    ) {
        let ms = SIM_PROFILE
            .time_ms_at(sim_base_ms(model), grant.threads.max(1), grant.speed)
            .max(0.0);
        std::thread::spawn(move || {
            let deadline = Instant::now() + Duration::from_secs_f64(ms / 1e3);
            loop {
                if cancel.is_cancelled() {
                    reply(Err(anyhow::Error::new(TaskCancelled)));
                    return;
                }
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                std::thread::sleep((deadline - now).min(Duration::from_millis(1)));
            }
            reply(Ok(ExecResult {
                outputs: Vec::new(),
                exec_time: Duration::from_secs_f64(ms / 1e3),
                worker,
            }));
        });
    }
}

/// One scenario's measured outcome.
#[derive(Debug, Clone)]
pub struct ScenarioResult {
    pub name: String,
    pub jobs: usize,
    pub throughput_jobs_s: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
}

impl ScenarioResult {
    fn from_walls(name: &str, walls_ms: &[f64], total_s: f64) -> ScenarioResult {
        let ps = percentiles(walls_ms, &[50.0, 95.0]);
        ScenarioResult {
            name: name.to_string(),
            jobs: walls_ms.len(),
            throughput_jobs_s: walls_ms.len() as f64 / total_s.max(1e-9),
            p50_ms: ps[0],
            p95_ms: ps[1],
        }
    }
}

/// One job part of a scenario workload: a simulated model plus the
/// *declared* input size the static (size-proportional) split sees.
#[derive(Debug, Clone, Copy)]
struct SimPart {
    base_ms: f64,
    size: usize,
}

/// The fig-8 long/short mixed job with **misleading sizes** — the §6
/// motivation for profiled weights: the costly part *declares* a small
/// input, so the size-proportional split starves it.
/// 1 heavy part (40ms single-thread, size 16) + 3 light parts (5ms,
/// size 256 each).
const LONGSHORT: [SimPart; 4] = [
    SimPart { base_ms: 40.0, size: 16 },
    SimPart { base_ms: 5.0, size: 256 },
    SimPart { base_ms: 5.0, size: 256 },
    SimPart { base_ms: 5.0, size: 256 },
];

/// The fig-8 long/short mixed job with *honest* sizes (cost tracks
/// size): 1 long (24ms, size 256) + 3 short (6ms, size 16).
const HONEST_MIX: [SimPart; 4] = [
    SimPart { base_ms: 24.0, size: 256 },
    SimPart { base_ms: 6.0, size: 16 },
    SimPart { base_ms: 6.0, size: 16 },
    SimPart { base_ms: 6.0, size: 16 },
];

fn start_sched(deadline_running: Option<Duration>) -> Arc<Scheduler> {
    start_sched_sharded(0, deadline_running)
}

/// Like [`start_sched`] but with an explicit shard count. `0` = auto,
/// which at [`SIM_CORES`] = 16 derives a single shard, so every legacy
/// scenario keeps measuring the one-dispatcher configuration.
fn start_sched_sharded(shards: usize, deadline_running: Option<Duration>) -> Arc<Scheduler> {
    Scheduler::start(
        SchedConfig {
            cores: CoreMap::homogeneous(SIM_CORES),
            shards,
            aging: Duration::from_millis(50),
            backfill: true,
            deadline_running,
            ..SchedConfig::default()
        },
        Arc::new(SimRunner { workers: 4 }),
    )
}

/// Core map for the heterogeneity scenarios: 4 full-speed cores plus 12
/// half-speed ones — the big.LITTLE-style machine where class-blind
/// placement leaves latency-sensitive work on slow silicon.
pub const HETERO_SPEC: &str = "fast=4,slow=12@0.5";

fn start_sched_hetero() -> Arc<Scheduler> {
    Scheduler::start(
        SchedConfig {
            cores: CoreMap::parse(HETERO_SPEC).expect("valid hetero spec"),
            shards: 1,
            aging: Duration::from_millis(50),
            backfill: true,
            deadline_running: None,
            ..SchedConfig::default()
        },
        Arc::new(SimRunner { workers: 4 }),
    )
}

/// Submit one job (all parts with the given allocation) and block until
/// every part finishes; returns the job wall time in ms.
fn run_job(sched: &Scheduler, parts: &[SimPart], alloc: &[usize]) -> f64 {
    let t0 = Instant::now();
    let handles: Vec<_> = parts
        .iter()
        .zip(alloc.iter())
        .map(|(p, &threads)| {
            sched.submit(PartTask::new(sim_model(p.base_ms), Vec::new(), threads))
        })
        .collect();
    for h in handles {
        h.wait().expect("gate scenario part must complete");
    }
    t0.elapsed().as_secs_f64() * 1e3
}

/// The adaptive-vs-static comparison (acceptance criterion: profiled
/// sizing beats the size-proportional split by >= 10% p95 on this
/// workload). `adaptive = false` sizes parts by declared size;
/// `adaptive = true` first runs the paper's §3.1 profiling phase (each
/// model at one thread, enough samples to trust the window) and then
/// sizes parts by measured cost via [`AdaptivePolicy::part_weights`].
pub fn longshort_scenario(adaptive: bool, jobs: usize) -> ScenarioResult {
    let sched = start_sched(None);
    let parts = LONGSHORT;
    let sizes: Vec<usize> = parts.iter().map(|p| p.size).collect();
    let models: Vec<String> = parts.iter().map(|p| sim_model(p.base_ms)).collect();

    let alloc = if adaptive {
        let profiles = Arc::new(ProfileStore::new());
        let policy =
            AdaptivePolicy::new(Arc::clone(&profiles), AdaptiveConfig::default());
        // Profiling phase: run every part once per round at 1 thread
        // (prun-1), observing single-thread cost — repeated until the
        // distribution window is trusted over the EWMA.
        // (profiling time is excluded from the measurement window)
        for _ in 0..crate::engine::profile::MIN_DISTRIBUTION_SAMPLES {
            let handles: Vec<_> = parts
                .iter()
                .map(|p| sched.submit(PartTask::new(sim_model(p.base_ms), Vec::new(), 1)))
                .collect();
            for (h, m) in handles.into_iter().zip(models.iter()) {
                let done = h.wait().expect("profiling part must complete");
                profiles.observe(m, done.exec);
            }
        }
        let keyed: Vec<(&str, usize)> = models
            .iter()
            .zip(sizes.iter())
            .map(|(m, &s)| (m.as_str(), s))
            .collect();
        allocate(
            PartWeights::Measured(&policy.part_weights(&keyed)),
            &CoreMap::homogeneous(SIM_CORES),
            AllocPolicy::PrunDef,
        )
        .into_threads()
    } else {
        allocate(
            PartWeights::Sizes(&sizes),
            &CoreMap::homogeneous(SIM_CORES),
            AllocPolicy::PrunDef,
        )
        .into_threads()
    };

    let t0 = Instant::now();
    let walls: Vec<f64> = (0..jobs).map(|_| run_job(&sched, &parts, &alloc)).collect();
    let total_s = t0.elapsed().as_secs_f64();
    let name = if adaptive { "longshort_adaptive" } else { "longshort_static" };
    ScenarioResult::from_walls(name, &walls, total_s)
}

/// Serving-style smoke: concurrent submitters pushing honest-size mixed
/// jobs through the dispatcher (ledger contention, backfill, queueing).
pub fn sched_smoke_scenario(jobs_per_submitter: usize) -> ScenarioResult {
    const SUBMITTERS: usize = 2;
    let sched = start_sched(None);
    let parts = HONEST_MIX;
    let sizes: Vec<usize> = parts.iter().map(|p| p.size).collect();
    let alloc = allocate(
        PartWeights::Sizes(&sizes),
        &CoreMap::homogeneous(SIM_CORES),
        AllocPolicy::PrunDef,
    )
    .into_threads();

    let t0 = Instant::now();
    let mut joins = Vec::new();
    for _ in 0..SUBMITTERS {
        let sched = Arc::clone(&sched);
        let alloc = alloc.clone();
        joins.push(std::thread::spawn(move || {
            (0..jobs_per_submitter)
                .map(|_| run_job(&sched, &parts, &alloc))
                .collect::<Vec<f64>>()
        }));
    }
    let mut walls = Vec::new();
    for j in joins {
        walls.extend(j.join().expect("submitter thread"));
    }
    let total_s = t0.elapsed().as_secs_f64();
    ScenarioResult::from_walls("sched_smoke", &walls, total_s)
}

/// The ROADMAP's "cancellation storm" (the serving edge giving up en
/// masse): every job is one survivor part racing three doomed full-size
/// hogs whose requesters cancel almost immediately. The survivor needs
/// 8 of the 16 cores but the hogs hold 12, so it *must* wait for the
/// cancellation machinery to reclaim cores. If cancellation stops being
/// prompt — a queued sweep regression, a token poll that stopped
/// interrupting, a ledger leak — the survivor queues behind ~1s of
/// abandoned work per hog and p95 explodes past any tolerance. The
/// survivor carries a generous request budget (never fires) so the
/// dispatcher's armed-deadline sweep stays on the measured path.
pub fn cancel_storm_scenario(jobs: usize) -> ScenarioResult {
    let sched = start_sched(None);
    let t0 = Instant::now();
    let mut walls = Vec::with_capacity(jobs);
    for _ in 0..jobs {
        let tj = Instant::now();
        let doomed: Vec<_> = (0..3)
            .map(|_| sched.submit(PartTask::new(sim_model(1000.0), Vec::new(), 4)))
            .collect();
        let survivor = sched.submit(
            PartTask::new(sim_model(8.0), Vec::new(), 8)
                .with_budget(Budget::new(Duration::from_secs(5))),
        );
        std::thread::sleep(Duration::from_millis(2));
        for h in &doomed {
            h.cancel();
        }
        survivor.wait().expect("storm survivor must complete");
        for h in doomed {
            h.wait().expect_err("doomed storm parts must be cancelled");
        }
        walls.push(tj.elapsed().as_secs_f64() * 1e3);
    }
    ScenarioResult::from_walls("cancel_storm", &walls, t0.elapsed().as_secs_f64())
}

/// The ROADMAP's priority-inversion scenario, exercising
/// `RequestCtx::priority` end to end: eight Low-priority hog jobs are
/// submitted at once — the first four saturate the 16-core ledger, the
/// second four queue behind them — and then a High-priority
/// latency-sensitive job arrives *last*. Its ctx priority must jump it
/// ahead of the queued Low wave, so its wall time is one hog
/// generation (~30ms) plus its own execution, not two. If priority
/// admission regresses (ordering bug, a ctx priority dropped on the
/// floor between layers), the high job waits out the entire second
/// wave and p95 roughly doubles — past any tolerance.
pub fn priority_inversion_scenario(jobs: usize) -> ScenarioResult {
    let sched = start_sched(None);
    let t0 = Instant::now();
    let mut walls = Vec::with_capacity(jobs);
    for _ in 0..jobs {
        let low = RequestCtx::new().with_priority(Priority::Low);
        let high = RequestCtx::new().with_priority(Priority::High);
        let tj = Instant::now();
        let hogs: Vec<_> = (0..8)
            .map(|_| {
                sched.submit(PartTask::new(sim_model(100.0), Vec::new(), 4).with_ctx(&low))
            })
            .collect();
        // submitted last, admitted first among the queued work
        let urgent =
            sched.submit(PartTask::new(sim_model(10.0), Vec::new(), 4).with_ctx(&high));
        urgent.wait().expect("high-priority job must complete");
        walls.push(tj.elapsed().as_secs_f64() * 1e3);
        // drain the hogs so iterations don't bleed into each other
        for h in hogs {
            h.wait().expect("hog job must complete");
        }
    }
    ScenarioResult::from_walls("priority_inversion", &walls, t0.elapsed().as_secs_f64())
}

/// The heterogeneity-inversion scenario (fig-style demo of the core
/// ledger's classes): on the [`HETERO_SPEC`] machine — 4 fast cores, 12
/// half-speed slow ones — three 4-thread hog jobs and then one
/// 4-thread latency-sensitive job arrive back to back.
///
/// `class_aware = false` submits everything with a plain
/// [`RequestCtx`], so every task's affinity is `Any` and placement is
/// class-blind: the first hog grabs the fast quartet and the latency
/// job lands on slow silicon, where its whole cost stretches by the
/// class's 0.5 relative speed — *heterogeneity inversion*, the
/// throughput-optimal-but-latency-hostile outcome.
///
/// `class_aware = true` expresses the deployment intent through the
/// same ctx plumbing the serving edge uses: hogs are
/// [`Priority::Low`] (derived affinity `Prefer(Slow)`), the latency job
/// [`Priority::High`] (derived `Prefer(Fast)`). The hogs soak the slow
/// pool, the fast quartet stays free for the job that feels every
/// millisecond, and its p95 roughly halves. The gate's self-relative
/// bar ([`hetero_bar`]) pins that gap at >= 10%.
pub fn hetero_inversion_scenario(class_aware: bool, jobs: usize) -> ScenarioResult {
    let sched = start_sched_hetero();
    let (hog_ctx, latency_ctx) = if class_aware {
        (
            RequestCtx::new().with_priority(Priority::Low),
            RequestCtx::new().with_priority(Priority::High),
        )
    } else {
        (RequestCtx::new(), RequestCtx::new())
    };
    let t0 = Instant::now();
    let mut walls = Vec::with_capacity(jobs);
    for _ in 0..jobs {
        let tj = Instant::now();
        let hogs: Vec<_> = (0..3)
            .map(|_| {
                sched.submit(
                    PartTask::new(sim_model(60.0), Vec::new(), 4).with_ctx(&hog_ctx),
                )
            })
            .collect();
        let latency = sched
            .submit(PartTask::new(sim_model(10.0), Vec::new(), 4).with_ctx(&latency_ctx));
        latency.wait().expect("latency-sensitive job must complete");
        walls.push(tj.elapsed().as_secs_f64() * 1e3);
        // drain the hogs so iterations don't bleed into each other
        for h in hogs {
            h.wait().expect("hog job must complete");
        }
    }
    let name = if class_aware { "hetero_inversion" } else { "hetero_inversion_blind" };
    ScenarioResult::from_walls(name, &walls, t0.elapsed().as_secs_f64())
}

/// Self-relative acceptance bar for the heterogeneity demo: class-aware
/// placement must beat class-blind placement by >= 10% p95 on the same
/// workload and the same machine. Returns the failure line, or `None`
/// when the bar holds.
pub fn hetero_bar(aware: &ScenarioResult, blind: &ScenarioResult) -> Option<String> {
    if aware.p95_ms > 0.9 * blind.p95_ms {
        Some(format!(
            "hetero_inversion: class-aware p95 {:.2} ms not >=10% better than \
             class-blind {:.2} ms",
            aware.p95_ms, blind.p95_ms
        ))
    } else {
        None
    }
}

/// The sharded-dispatcher scenario: a many-producer *open-loop* submit
/// flood. Four producer threads each push `per_producer` one-core 1ms
/// jobs into the scheduler as fast as `submit` returns — no pacing, no
/// waiting on completions — so the measured phase is pure submission
/// cost under 4-way producer contention: id assignment, shard routing,
/// the shard-side counter bump, and the event-channel send (with the
/// dispatcher draining that same channel concurrently).
///
/// `throughput_jobs_s` is therefore *submit ops/sec* — the figure
/// sharding is meant to lift, since with one shard every producer and
/// the lone dispatcher contend on a single channel — while p50/p95 are
/// per-task completion walls (submit -> done) from the drain that
/// follows, keeping the usual latency regression net. Tasks carry
/// consecutive request ids so the flood spreads round-robin across all
/// shards. `shards <= 1` records the single-shard reference point
/// (`submit_storm_single`) that the gate's self-relative sharding bar
/// compares against.
pub fn submit_storm_scenario(shards: usize, per_producer: usize) -> ScenarioResult {
    const PRODUCERS: usize = 4;
    let sched = start_sched_sharded(shards, None);
    let barrier = Arc::new(std::sync::Barrier::new(PRODUCERS + 1));
    let mut joins = Vec::new();
    for p in 0..PRODUCERS {
        let sched = Arc::clone(&sched);
        let barrier = Arc::clone(&barrier);
        joins.push(std::thread::spawn(move || {
            barrier.wait();
            let mut pending = Vec::with_capacity(per_producer);
            for i in 0..per_producer {
                let rid = (p * per_producer + i) as u64;
                let h = sched.submit(
                    PartTask::new(sim_model(1.0), Vec::new(), 1).with_request_id(rid),
                );
                pending.push((Instant::now(), h));
            }
            let submits_done = Instant::now();
            let walls: Vec<f64> = pending
                .into_iter()
                .map(|(t, h)| {
                    h.wait().expect("storm part must complete");
                    t.elapsed().as_secs_f64() * 1e3
                })
                .collect();
            (submits_done, walls)
        }));
    }
    let t0 = Instant::now();
    barrier.wait();
    let mut walls = Vec::new();
    let mut submit_phase = Duration::ZERO;
    for j in joins {
        let (done, w) = j.join().expect("producer thread");
        submit_phase = submit_phase.max(done.duration_since(t0));
        walls.extend(w);
    }
    let name = if shards <= 1 { "submit_storm_single" } else { "submit_storm" };
    ScenarioResult::from_walls(name, &walls, submit_phase.as_secs_f64())
}

/// Run the gate's full scenario list. `quick` shrinks job counts for
/// the per-PR smoke run; the recorded baseline uses the same counts, so
/// quick and full runs are not comparable to each other.
pub fn run_all(quick: bool) -> Vec<ScenarioResult> {
    let jobs = if quick { 20 } else { 60 };
    vec![
        sched_smoke_scenario(jobs / 2),
        longshort_scenario(false, jobs),
        longshort_scenario(true, jobs),
        cancel_storm_scenario(jobs),
        priority_inversion_scenario(jobs),
        hetero_inversion_scenario(true, jobs),
        hetero_inversion_scenario(false, jobs),
        // 4 producers x (jobs * 5) tasks: 400 submits quick, 1200 full.
        submit_storm_scenario(2, jobs * 5),
        submit_storm_scenario(1, jobs * 5),
    ]
}

// ---------------------------------------------------------------- JSON

/// `{"scenarios": {"<name>": {"jobs": .., "throughput_jobs_s": ..,
/// "p50_ms": .., "p95_ms": ..}}}`
pub fn results_to_json(results: &[ScenarioResult]) -> Json {
    let entries: Vec<(String, Json)> = results
        .iter()
        .map(|r| {
            (
                r.name.clone(),
                obj(vec![
                    ("jobs", num(r.jobs as f64)),
                    ("throughput_jobs_s", num(r.throughput_jobs_s)),
                    ("p50_ms", num(r.p50_ms)),
                    ("p95_ms", num(r.p95_ms)),
                ]),
            )
        })
        .collect();
    Json::Obj(vec![("scenarios".to_string(), Json::Obj(entries))])
}

/// Compare a PR run against the recorded baseline. `tolerance_pct` is
/// the default allowed drift; a baseline scenario may override it with
/// its own `"tolerance_pct"` field (noisier concurrent scenarios carry
/// a wider one). Returns one human-readable line per regression; empty
/// means the gate passes. Scenarios present in the baseline but missing
/// from the PR run (or vice versa) are regressions too — a silently
/// dropped benchmark must not pass the gate.
pub fn compare(pr: &Json, baseline: &Json, tolerance_pct: f64) -> Vec<String> {
    let mut failures = Vec::new();
    let empty = Json::Obj(Vec::new());
    let base_scen = baseline.get("scenarios").unwrap_or(&empty);
    let pr_scen = pr.get("scenarios").unwrap_or(&empty);
    let (Json::Obj(base_pairs), Json::Obj(pr_pairs)) = (base_scen, pr_scen) else {
        return vec!["malformed bench JSON: missing 'scenarios' object".to_string()];
    };
    for (name, base) in base_pairs {
        let Some(pr_entry) = pr_scen.get(name) else {
            failures.push(format!("scenario '{name}' missing from PR run"));
            continue;
        };
        let tol = base
            .get("tolerance_pct")
            .and_then(|v| v.as_f64())
            .unwrap_or(tolerance_pct)
            / 100.0;
        let metric = |j: &Json, key: &str| j.get(key).and_then(|v| v.as_f64());
        // quick and full runs are not comparable (different job counts
        // shift the percentiles and steady-state throughput): a jobs
        // mismatch means the baseline was recorded in the other mode.
        if let (Some(b), Some(p)) = (metric(base, "jobs"), metric(pr_entry, "jobs")) {
            if b != p {
                failures.push(format!(
                    "{name}: job count mismatch (baseline {b}, PR {p}) — was the \
                     baseline recorded without --quick (or vice versa)?"
                ));
                continue;
            }
        }
        // throughput: lower is worse
        if let (Some(b), Some(p)) =
            (metric(base, "throughput_jobs_s"), metric(pr_entry, "throughput_jobs_s"))
        {
            if p < b * (1.0 - tol) {
                failures.push(format!(
                    "{name}: throughput regressed {p:.1} < {b:.1} jobs/s (-{:.0}% tolerance)",
                    tol * 100.0
                ));
            }
        }
        // p95 latency: higher is worse
        if let (Some(b), Some(p)) = (metric(base, "p95_ms"), metric(pr_entry, "p95_ms")) {
            if p > b * (1.0 + tol) {
                failures.push(format!(
                    "{name}: p95 regressed {p:.1} > {b:.1} ms (+{:.0}% tolerance)",
                    tol * 100.0
                ));
            }
        }
    }
    for (name, _) in pr_pairs {
        if base_scen.get(name).is_none() {
            failures.push(format!(
                "scenario '{name}' has no baseline — record one with --record"
            ));
        }
    }
    failures
}

#[cfg(test)]
mod tests {
    use super::*;

    fn result(name: &str, thr: f64, p95: f64) -> ScenarioResult {
        ScenarioResult {
            name: name.to_string(),
            jobs: 10,
            throughput_jobs_s: thr,
            p50_ms: p95 / 2.0,
            p95_ms: p95,
        }
    }

    #[test]
    fn json_round_trip() {
        let rs = vec![result("a", 100.0, 8.0), result("b", 50.0, 20.0)];
        let j = results_to_json(&rs);
        let back = Json::parse(&j.to_string()).unwrap();
        let a = back.get("scenarios").unwrap().get("a").unwrap();
        assert_eq!(a.get("jobs").unwrap().as_usize().unwrap(), 10);
        assert!((a.get("p95_ms").unwrap().as_f64().unwrap() - 8.0).abs() < 1e-9);
    }

    #[test]
    fn compare_passes_within_tolerance() {
        let base = results_to_json(&[result("a", 100.0, 10.0)]);
        let pr = results_to_json(&[result("a", 90.0, 11.0)]);
        assert!(compare(&pr, &base, 15.0).is_empty());
    }

    #[test]
    fn compare_fails_on_regression() {
        let base = results_to_json(&[result("a", 100.0, 10.0)]);
        let slow = results_to_json(&[result("a", 100.0, 12.0)]);
        let fails = compare(&slow, &base, 15.0);
        assert_eq!(fails.len(), 1, "{fails:?}");
        assert!(fails[0].contains("p95"), "{fails:?}");
        let starved = results_to_json(&[result("a", 80.0, 10.0)]);
        let fails = compare(&starved, &base, 15.0);
        assert_eq!(fails.len(), 1, "{fails:?}");
        assert!(fails[0].contains("throughput"), "{fails:?}");
    }

    #[test]
    fn compare_fails_on_missing_scenarios() {
        let base = results_to_json(&[result("a", 100.0, 10.0)]);
        let pr = results_to_json(&[result("b", 100.0, 10.0)]);
        let fails = compare(&pr, &base, 15.0);
        assert_eq!(fails.len(), 2, "{fails:?}");
    }

    #[test]
    fn per_scenario_tolerance_overrides_default() {
        // baseline carries tolerance_pct = 50 for a noisy scenario
        let mut base = results_to_json(&[result("noisy", 100.0, 10.0)]);
        if let Json::Obj(pairs) = &mut base {
            if let Json::Obj(scen) = &mut pairs[0].1 {
                if let Json::Obj(entry) = &mut scen[0].1 {
                    entry.push(("tolerance_pct".to_string(), num(50.0)));
                }
            }
        }
        let pr = results_to_json(&[result("noisy", 60.0, 14.0)]);
        assert!(compare(&pr, &base, 15.0).is_empty());
        let pr = results_to_json(&[result("noisy", 40.0, 14.0)]);
        assert_eq!(compare(&pr, &base, 15.0).len(), 1);
    }

    #[test]
    fn sim_runner_models_scaling() {
        // more threads -> shorter simulated time, up to the overhead
        let t1 = SIM_PROFILE.time_ms(40.0, 1);
        let t12 = SIM_PROFILE.time_ms(40.0, 12);
        assert!((t1 - 40.0).abs() < 1e-9);
        assert!(t12 < 10.0, "{t12}");
    }

    #[test]
    fn cancel_storm_reclaims_cores_promptly() {
        // Three 1000ms hogs are cancelled ~2ms in; the 8-core survivor
        // must then run, so each job's wall stays in the tens of
        // milliseconds — three orders below the hogs' nominal runtime.
        let r = cancel_storm_scenario(3);
        assert_eq!(r.jobs, 3);
        assert!(
            r.p95_ms < 500.0,
            "survivor waited on abandoned work: p95 {:.1}ms",
            r.p95_ms
        );
    }

    #[test]
    fn priority_inversion_high_job_jumps_the_queued_wave() {
        // One hog generation is ~30ms simulated; the high-priority job
        // must finish well before the second Low wave would have let
        // it run (~60ms+). Generous bound for slow CI boxes.
        let r = priority_inversion_scenario(3);
        assert_eq!(r.jobs, 3);
        assert!(
            r.p95_ms < 55.0,
            "high-priority job waited out the low wave: p95 {:.1}ms",
            r.p95_ms
        );
    }

    #[test]
    fn submit_storm_floods_and_drains() {
        // 2 shards over the 16 sim cores: 4 producers x 10 one-core
        // tasks flood in, everything must drain, and the recorded
        // throughput is the (positive) submit-phase rate.
        let r = submit_storm_scenario(2, 10);
        assert_eq!(r.name, "submit_storm");
        assert_eq!(r.jobs, 40);
        assert!(r.throughput_jobs_s > 0.0);
        assert!(r.p95_ms < 2_000.0, "storm drain stalled: p95 {:.1}ms", r.p95_ms);
        let r = submit_storm_scenario(1, 5);
        assert_eq!(r.name, "submit_storm_single");
        assert_eq!(r.jobs, 20);
    }

    #[test]
    fn longshort_static_starves_the_heavy_part() {
        // the declared sizes hand the heavy part a single core
        let sizes: Vec<usize> = LONGSHORT.iter().map(|p| p.size).collect();
        let alloc = allocate(
            PartWeights::Sizes(&sizes),
            &CoreMap::homogeneous(SIM_CORES),
            AllocPolicy::PrunDef,
        )
        .into_threads();
        assert_eq!(alloc[0], 1, "{alloc:?}");
        assert_eq!(alloc.iter().sum::<usize>(), SIM_CORES);
    }

    #[test]
    fn hetero_class_awareness_beats_blind_placement() {
        // Class-blind: a hog grabs the fast quartet, the latency job
        // runs on half-speed cores (~7ms). Class-aware: hogs soak the
        // slow pool, the latency job keeps the fast cores (~3.5ms).
        let aware = hetero_inversion_scenario(true, 4);
        let blind = hetero_inversion_scenario(false, 4);
        assert_eq!(aware.name, "hetero_inversion");
        assert_eq!(blind.name, "hetero_inversion_blind");
        assert!(
            hetero_bar(&aware, &blind).is_none(),
            "inversion not demonstrated: aware p95 {:.2}ms vs blind p95 {:.2}ms",
            aware.p95_ms,
            blind.p95_ms
        );
    }

    #[test]
    fn hetero_bar_flags_a_closed_gap() {
        let aware = result("hetero_inversion", 30.0, 7.5);
        let blind = result("hetero_inversion_blind", 30.0, 8.0);
        let fail = hetero_bar(&aware, &blind).expect("bar must flag a <10% gap");
        assert!(fail.contains("p95"), "{fail}");
        let aware = result("hetero_inversion", 30.0, 4.5);
        assert!(hetero_bar(&aware, &blind).is_none());
    }
}

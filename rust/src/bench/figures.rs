//! Regenerators for every table/figure in the paper's evaluation (§4).
//!
//! Each `figN` builds the paper's workload with `crate::workload`, runs it
//! through the calibrated simulator at the paper's 16 cores (DESIGN.md §4
//! explains why virtual time), and returns a table shaped like the
//! figure. `cargo bench` prints them; EXPERIMENTS.md records the
//! paper-vs-ours comparison.

use crate::engine::allocator::AllocPolicy;
use crate::simcpu::bert::{seqs_per_sec, sim_no_batch, sim_pad_batch, sim_prun, sim_prun_report};
use crate::simcpu::calib::PAPER_CORES;
use crate::simcpu::ocr::{sim_dataset, sim_image, OcrVariant};
use crate::util::prng::Rng;
use crate::util::stats::{mean, stddev};
use crate::workload::{boxes, seqlen};

use super::table::{ms, tput, Table};

pub const DATASET_SEED: u64 = 0xf16;
pub const DATASET_IMAGES: usize = 500;
pub const GLYPH_W: usize = 8;

fn dataset() -> Vec<Vec<usize>> {
    boxes::dataset(DATASET_SEED, DATASET_IMAGES, GLYPH_W)
}

/// Fig. 2: PaddleOCR base latency vs threads, stacked by phase.
pub fn fig2(threads: &[usize]) -> Table {
    let imgs = dataset();
    let mut t = Table::new(
        "Figure 2 — PaddleOCR inference latency vs threads (base), per phase (ms)",
        &["threads", "det", "cls", "rec", "total"],
    );
    for &c in threads {
        let b = sim_dataset(&imgs, OcrVariant::Base, c);
        t.row(vec![
            c.to_string(),
            ms(b.det_ms),
            ms(b.cls_ms),
            ms(b.rec_ms),
            ms(b.total_ms()),
        ]);
    }
    t.note("paper anchors: total 554 @1t, 364 @4t, 435 @16t; cls 27 @1t -> 38 @16t");
    t
}

/// Fig. 3: distribution of detected box counts in the dataset.
pub fn fig3() -> Table {
    let imgs = dataset();
    let hist = boxes::count_histogram(&imgs);
    let mut t = Table::new(
        "Figure 3 — distribution of detected text boxes (500 images)",
        &["boxes", "images", "share"],
    );
    for (count, n) in &hist {
        let label = if *count >= 10 { "10+".to_string() } else { count.to_string() };
        t.row(vec![
            label,
            n.to_string(),
            format!("{:.1}%", 100.0 * *n as f64 / imgs.len() as f64),
        ]);
    }
    t.note(&format!("mean boxes/image = {:.2} (calibration uses 4.3)", boxes::mean_count(&imgs)));
    t
}

/// Fig. 4: per-variant latency grouped by detected box count @16 cores.
/// part: "cls" | "rec" | "total".
pub fn fig4(part: &str) -> Table {
    let imgs = dataset();
    let mut t = Table::new(
        &format!("Figure 4({}) — {} latency by box count @16 cores (ms)",
            match part { "cls" => "a", "rec" => "b", _ => "c" }, part),
        &["boxes", "base", "prun-def", "prun-1", "prun-eq", "def/base"],
    );
    for count in 2..=10usize {
        let group: Vec<&Vec<usize>> = imgs
            .iter()
            .filter(|im| if count == 10 { im.len() >= 10 } else { im.len() == count })
            .collect();
        if group.is_empty() {
            continue;
        }
        let mean_of = |v: OcrVariant| -> f64 {
            let vals: Vec<f64> = group
                .iter()
                .map(|w| {
                    let b = sim_image(w, v, PAPER_CORES);
                    match part {
                        "cls" => b.cls_ms,
                        "rec" => b.rec_ms,
                        _ => b.total_ms(),
                    }
                })
                .collect();
            mean(&vals)
        };
        let base = mean_of(OcrVariant::Base);
        let pdef = mean_of(OcrVariant::Prun(AllocPolicy::PrunDef));
        let p1 = mean_of(OcrVariant::Prun(AllocPolicy::PrunOne));
        let peq = mean_of(OcrVariant::Prun(AllocPolicy::PrunEq));
        let label = if count == 10 { "10+".to_string() } else { count.to_string() };
        t.row(vec![
            label,
            ms(base),
            ms(pdef),
            ms(p1),
            ms(peq),
            format!("{:.2}x", base / pdef),
        ]);
    }
    t.note("paper: prun-def gains grow with box count (2.33x at 9 boxes end-to-end); prun-1 wins cls at small counts");
    t
}

/// Fig. 5: end-to-end + cls/rec latency vs threads, base vs prun-def.
pub fn fig5(threads: &[usize]) -> Table {
    let imgs = dataset();
    let mut t = Table::new(
        "Figure 5 — PaddleOCR latency vs threads, base vs prun (ms)",
        &["threads", "base total", "prun total", "base cls", "prun cls", "base rec", "prun rec", "speedup"],
    );
    for &c in threads {
        let b = sim_dataset(&imgs, OcrVariant::Base, c);
        let p = sim_dataset(&imgs, OcrVariant::Prun(AllocPolicy::PrunDef), c);
        t.row(vec![
            c.to_string(),
            ms(b.total_ms()),
            ms(p.total_ms()),
            ms(b.cls_ms),
            ms(p.cls_ms),
            ms(b.rec_ms),
            ms(p.rec_ms),
            format!("{:.2}x", b.total_ms() / p.total_ms()),
        ]);
    }
    t.note("paper @16t: rec speedup >2.4x, end-to-end 1.5x (detection phase shared)");
    t
}

/// Fig. 6: BERT throughput on random-length batches (1000 reps, ±std).
pub fn fig6(reps: usize) -> Table {
    let mut rng = Rng::new(0xbe27);
    let mut t = Table::new(
        "Figure 6 — BERT throughput, batches of U[16,512] lengths (seq/s)",
        &["batch", "pad-batch", "±std", "prun", "±std", "prun/pad"],
    );
    for x in 2..=8usize {
        let mut pad = Vec::with_capacity(reps);
        let mut prun = Vec::with_capacity(reps);
        for _ in 0..reps {
            let lens = seqlen::random_batch(&mut rng, x);
            pad.push(seqs_per_sec(x, sim_pad_batch(&lens, PAPER_CORES)));
            prun.push(seqs_per_sec(x, sim_prun(&lens, PAPER_CORES, AllocPolicy::PrunDef)));
        }
        t.row(vec![
            x.to_string(),
            tput(mean(&pad)),
            tput(stddev(&pad)),
            tput(mean(&prun)),
            tput(stddev(&prun)),
            format!("{:.2}x", mean(&prun) / mean(&pad)),
        ]);
    }
    t.note("paper: prun outperforms pad-batch at every batch size; variance is inherently high");
    t
}

/// Fig. 7: preset length mixes.
pub fn fig7() -> Table {
    let mut t = Table::new(
        "Figure 7 — BERT throughput on preset batches (seq/s)",
        &["batch", "pad-batch", "prun", "prun/pad"],
    );
    for (label, lens) in seqlen::preset_mixes() {
        let pad = seqs_per_sec(lens.len(), sim_pad_batch(&lens, PAPER_CORES));
        let prun = seqs_per_sec(lens.len(), sim_prun(&lens, PAPER_CORES, AllocPolicy::PrunDef));
        t.row(vec![
            label.to_string(),
            tput(pad),
            tput(prun),
            format!("{:.2}x", prun / pad),
        ]);
    }
    t.note("paper: prun wins grow with batch heterogeneity (padding waste eliminated)");
    t
}

/// Fig. 8: 1 long (256) + X short (16) sequences; threads for the long one.
pub fn fig8() -> Table {
    let mut t = Table::new(
        "Figure 8 — 1x256-token + Xx16-token batch (seq/s; threads of long seq)",
        &["X", "pad-batch", "prun", "long-seq threads", "prun/pad"],
    );
    for x in 0..=15usize {
        let lens = seqlen::long_short(x);
        let pad = seqs_per_sec(lens.len(), sim_pad_batch(&lens, PAPER_CORES));
        let (report, alloc) = sim_prun_report(&lens, PAPER_CORES, AllocPolicy::PrunDef);
        let prun = seqs_per_sec(lens.len(), report.makespan_ms);
        t.row(vec![
            x.to_string(),
            tput(pad),
            tput(prun),
            alloc[0].to_string(),
            format!("{:.2}x", prun / pad),
        ]);
    }
    t.note("paper: X=0 overhead negligible; steep growth to X~3; long seq sheds threads as shorts join");
    t
}

/// Fig. 9: homogeneous batches of 4.
pub fn fig9() -> Table {
    let mut t = Table::new(
        "Figure 9 — BERT throughput, 4 equal-length sequences (seq/s)",
        &["len", "no-batch", "batch", "prun", "prun/batch"],
    );
    for &len in &seqlen::FIG9_LENGTHS {
        let lens = seqlen::homogeneous(len);
        let nb = seqs_per_sec(4, sim_no_batch(&lens, PAPER_CORES));
        let b = seqs_per_sec(4, sim_pad_batch(&lens, PAPER_CORES));
        let p = seqs_per_sec(4, sim_prun(&lens, PAPER_CORES, AllocPolicy::PrunDef));
        t.row(vec![
            len.to_string(),
            tput(nb),
            tput(b),
            tput(p),
            format!("{:.2}x", p / b),
        ]);
    }
    t.note("paper: batch > no-batch (batching pays); prun > batch modestly (no padding waste to recover)");
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig2_shape() {
        let t = fig2(&[1, 4, 16]);
        assert_eq!(t.rows.len(), 3);
        // dip-then-rise in the totals column
        let total = |i: usize| t.rows[i][4].parse::<f64>().unwrap();
        assert!(total(1) < total(0));
        assert!(total(1) < total(2));
    }

    #[test]
    fn fig2_dataset_anchors_match_paper() {
        // The quantitative calibration check: base totals over the full
        // 500-image dataset vs the paper's measured 554/364/435 ms, and
        // cls negative scaling 27 -> 38 ms. ±10% tolerance.
        let t = fig2(&[1, 4, 16]);
        let cell = |r: usize, c: usize| t.rows[r][c].parse::<f64>().unwrap();
        let anchors = [(0, 554.0), (1, 364.0), (2, 435.0)];
        for (row, want) in anchors {
            let got = cell(row, 4);
            assert!((got - want).abs() / want < 0.10, "total row {row}: {got} vs {want}");
        }
        let cls1 = cell(0, 2);
        let cls16 = cell(2, 2);
        assert!((cls1 - 27.0).abs() / 27.0 < 0.20, "cls@1 {cls1}");
        assert!((cls16 - 38.0).abs() / 38.0 < 0.20, "cls@16 {cls16}");
        assert!(cls16 > cls1, "cls negative scaling");
    }

    #[test]
    fn fig5_dataset_speedups_match_paper() {
        // paper @16t: rec speedup > 2.4x, end-to-end ~1.5x (1.2..2.3 band)
        let t = fig5(&[16]);
        let row = &t.rows[0];
        let base_total: f64 = row[1].parse().unwrap();
        let prun_total: f64 = row[2].parse().unwrap();
        let base_rec: f64 = row[5].parse().unwrap();
        let prun_rec: f64 = row[6].parse().unwrap();
        assert!(base_rec / prun_rec > 2.0, "rec speedup {}", base_rec / prun_rec);
        let e2e = base_total / prun_total;
        assert!((1.2..2.3).contains(&e2e), "end-to-end speedup {e2e}");
    }

    #[test]
    fn fig3_shares_sum_to_one() {
        let t = fig3();
        let total: usize = t.rows.iter().map(|r| r[1].parse::<usize>().unwrap()).sum();
        assert_eq!(total, DATASET_IMAGES);
    }

    #[test]
    fn fig4_speedup_grows() {
        let t = fig4("total");
        let first: f64 = t.rows.first().unwrap()[5].trim_end_matches('x').parse().unwrap();
        let last: f64 = t.rows.last().unwrap()[5].trim_end_matches('x').parse().unwrap();
        assert!(last > first, "speedup grows with boxes: {first} -> {last}");
    }

    #[test]
    fn fig6_prun_wins_everywhere() {
        let t = fig6(50);
        for row in &t.rows {
            let ratio: f64 = row[5].trim_end_matches('x').parse().unwrap();
            assert!(ratio > 1.0, "batch {}: {ratio}", row[0]);
        }
    }

    #[test]
    fn fig8_thread_column_monotone_nonincreasing() {
        let t = fig8();
        let threads: Vec<usize> = t.rows.iter().map(|r| r[3].parse().unwrap()).collect();
        assert!(threads.windows(2).all(|w| w[0] >= w[1]), "{threads:?}");
        assert_eq!(threads[0], 16);
    }

    #[test]
    fn fig9_ordering() {
        let t = fig9();
        for row in &t.rows {
            let nb: f64 = row[1].parse().unwrap();
            let b: f64 = row[2].parse().unwrap();
            let p: f64 = row[3].parse().unwrap();
            assert!(b > nb, "batching pays at len {}", row[0]);
            assert!(p > b, "prun wins at len {}", row[0]);
        }
    }
}

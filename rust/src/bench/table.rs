//! Plain-text result tables for the figure-regeneration harness.

#[derive(Debug, Clone)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
    /// free-text notes printed under the table (paper-vs-ours commentary)
    pub notes: Vec<String>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            notes: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells);
    }

    pub fn note(&mut self, text: &str) {
        self.notes.push(text.to_string());
    }

    /// Render as github-flavoured markdown.
    pub fn to_markdown(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| {
            let padded: Vec<String> = cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:w$}", c, w = widths[i]))
                .collect();
            format!("| {} |", padded.join(" | "))
        };
        let mut out = format!("### {}\n\n", self.title);
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        let sep: Vec<String> = widths.iter().map(|&w| "-".repeat(w)).collect();
        out.push_str(&fmt_row(&sep));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        for n in &self.notes {
            out.push_str(&format!("\n> {n}\n"));
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.to_markdown());
    }
}

/// Format ms with sensible precision.
pub fn ms(x: f64) -> String {
    if x >= 100.0 {
        format!("{x:.0}")
    } else if x >= 10.0 {
        format!("{x:.1}")
    } else {
        format!("{x:.2}")
    }
}

/// Format a throughput (seq/s).
pub fn tput(x: f64) -> String {
    format!("{x:.1}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_markdown() {
        let mut t = Table::new("Demo", &["a", "bb"]);
        t.row(vec!["1".into(), "2".into()]);
        t.note("shape matches");
        let md = t.to_markdown();
        assert!(md.contains("### Demo"));
        assert!(md.contains("| a | bb |"));
        assert!(md.contains("| 1 | 2  |"));
        assert!(md.contains("> shape matches"));
    }

    #[test]
    #[should_panic]
    fn row_width_checked() {
        let mut t = Table::new("x", &["a"]);
        t.row(vec!["1".into(), "2".into()]);
    }

    #[test]
    fn number_formats() {
        assert_eq!(ms(554.3), "554");
        assert_eq!(ms(27.12), "27.1");
        assert_eq!(ms(6.234), "6.23");
        assert_eq!(tput(12.34), "12.3");
    }
}

//! Benchmark/figure-regeneration harness (one regenerator per paper
//! table/figure; see DESIGN.md §6 for the experiment index).

pub mod figures;
pub mod table;

pub use table::Table;

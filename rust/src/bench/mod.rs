//! Benchmark/figure-regeneration harness (one regenerator per paper
//! table/figure; see DESIGN.md §6 for the experiment index) plus the
//! CI bench-gate scenarios ([`gate`]).

pub mod figures;
pub mod gate;
pub mod table;

pub use table::Table;

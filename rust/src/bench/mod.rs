//! Benchmark/figure-regeneration harness (one regenerator per paper
//! table/figure; see DESIGN.md §6 for the experiment index) plus the
//! simulated-runner substrate the barometer measures on ([`gate`];
//! the scenarios themselves are data — see `crate::bar` and
//! `rust/bench/FORMAT.md`).

pub mod figures;
pub mod gate;
pub mod table;

pub use table::Table;

//! Log-bucketed latency histogram (HdrHistogram-lite).
//!
//! Buckets are powers of sqrt(2) over microseconds, giving <= ~6% relative
//! quantile error across 1 us .. 70 s with 64 buckets — plenty for serving
//! latency reporting, and allocation-free on the record path.

use std::sync::atomic::{AtomicU64, Ordering};

const BUCKETS: usize = 64;
const SQRT2: f64 = std::f64::consts::SQRT_2;

pub struct Histogram {
    counts: [AtomicU64; BUCKETS],
    total: AtomicU64,
    sum_us: AtomicU64,
    max_us: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            counts: std::array::from_fn(|_| AtomicU64::new(0)),
            total: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
            max_us: AtomicU64::new(0),
        }
    }

    fn bucket_of(us: u64) -> usize {
        if us <= 1 {
            return 0;
        }
        // log_sqrt2(us) = 2*log2(us)
        let b = (2.0 * (us as f64).log2()).floor() as usize;
        b.min(BUCKETS - 1)
    }

    /// Lower edge (us) of bucket i.
    fn bucket_floor(i: usize) -> f64 {
        SQRT2.powi(i as i32)
    }

    pub fn record(&self, duration: std::time::Duration) {
        self.record_us(duration.as_micros() as u64);
    }

    pub fn record_us(&self, us: u64) {
        self.counts[Self::bucket_of(us)].fetch_add(1, Ordering::Relaxed);
        self.total.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.max_us.fetch_max(us, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.total.load(Ordering::Relaxed)
    }

    pub fn mean_us(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum_us.load(Ordering::Relaxed) as f64 / n as f64
        }
    }

    pub fn max_us(&self) -> u64 {
        self.max_us.load(Ordering::Relaxed)
    }

    /// Approximate quantile (0..=1) in microseconds.
    pub fn quantile_us(&self, q: f64) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * n as f64).ceil().max(1.0) as u64;
        let mut acc = 0u64;
        for i in 0..BUCKETS {
            acc += self.counts[i].load(Ordering::Relaxed);
            if acc >= target {
                // midpoint of the bucket in log space
                return Self::bucket_floor(i) * SQRT2.sqrt();
            }
        }
        self.max_us() as f64
    }

    pub fn snapshot(&self) -> Snapshot {
        Snapshot {
            count: self.count(),
            mean_us: self.mean_us(),
            p50_us: self.quantile_us(0.50),
            p95_us: self.quantile_us(0.95),
            p99_us: self.quantile_us(0.99),
            max_us: self.max_us(),
        }
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct Snapshot {
    pub count: u64,
    pub mean_us: f64,
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
    pub max_us: u64,
}

impl Snapshot {
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::{num, obj};
        obj(vec![
            ("count", num(self.count as f64)),
            ("mean_us", num(self.mean_us)),
            ("p50_us", num(self.p50_us)),
            ("p95_us", num(self.p95_us)),
            ("p99_us", num(self.p99_us)),
            ("max_us", num(self.max_us as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_histogram() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.mean_us(), 0.0);
        assert_eq!(h.quantile_us(0.5), 0.0);
    }

    #[test]
    fn mean_and_max_exact() {
        let h = Histogram::new();
        for us in [100u64, 200, 300] {
            h.record_us(us);
        }
        assert_eq!(h.count(), 3);
        assert!((h.mean_us() - 200.0).abs() < 1e-9);
        assert_eq!(h.max_us(), 300);
    }

    #[test]
    fn quantiles_within_bucket_error() {
        let h = Histogram::new();
        for us in 1..=10_000u64 {
            h.record_us(us);
        }
        let p50 = h.quantile_us(0.50);
        let p99 = h.quantile_us(0.99);
        assert!((p50 / 5000.0 - 1.0).abs() < 0.45, "p50={p50}");
        assert!((p99 / 9900.0 - 1.0).abs() < 0.45, "p99={p99}");
        assert!(p99 > p50);
    }

    #[test]
    fn concurrent_recording() {
        let h = std::sync::Arc::new(Histogram::new());
        let mut handles = Vec::new();
        for t in 0..4 {
            let h = h.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..1000u64 {
                    h.record_us(t * 1000 + i);
                }
            }));
        }
        for j in handles {
            j.join().unwrap();
        }
        assert_eq!(h.count(), 4000);
    }

    #[test]
    fn snapshot_json_shape() {
        let h = Histogram::new();
        h.record_us(500);
        let j = h.snapshot().to_json();
        assert_eq!(j.get("count").unwrap().as_usize().unwrap(), 1);
        assert!(j.get("p99_us").is_some());
    }
}

//! Serving metrics: counters + latency histograms with JSON snapshots.

pub mod histogram;

pub use histogram::{Histogram, Snapshot};

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::util::json::{num, Json};
use crate::util::sync::lock_recover;

/// Named counters and histograms shared across the serving stack.
#[derive(Default)]
pub struct Metrics {
    counters: Mutex<HashMap<String, Arc<AtomicU64>>>,
    histograms: Mutex<HashMap<String, Arc<Histogram>>>,
}

impl Metrics {
    pub fn new() -> Arc<Metrics> {
        Arc::new(Metrics::default())
    }

    pub fn counter(&self, name: &str) -> Arc<AtomicU64> {
        let mut map = lock_recover(&self.counters);
        Arc::clone(map.entry(name.to_string()).or_default())
    }

    pub fn add(&self, name: &str, delta: u64) {
        self.counter(name).fetch_add(delta, Ordering::Relaxed);
    }

    /// Gauge-style overwrite (e.g. queue depth, core occupancy): the
    /// snapshot reports the latest value instead of an accumulation.
    pub fn set(&self, name: &str, value: u64) {
        self.counter(name).store(value, Ordering::Relaxed);
    }

    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        let mut map = lock_recover(&self.histograms);
        Arc::clone(
            map.entry(name.to_string())
                .or_insert_with(|| Arc::new(Histogram::new())),
        )
    }

    pub fn record(&self, name: &str, d: std::time::Duration) {
        self.histogram(name).record(d);
    }

    /// JSON snapshot of everything (served by the `stats` op).
    pub fn snapshot_json(&self) -> Json {
        let mut pairs: Vec<(String, Json)> = Vec::new();
        {
            let counters = lock_recover(&self.counters);
            let mut names: Vec<_> = counters.keys().cloned().collect();
            names.sort();
            for name in names {
                pairs.push((
                    format!("counter.{name}"),
                    num(counters[&name].load(Ordering::Relaxed) as f64),
                ));
            }
        }
        {
            let hists = lock_recover(&self.histograms);
            let mut names: Vec<_> = hists.keys().cloned().collect();
            names.sort();
            for name in names {
                pairs.push((format!("latency.{name}"), hists[&name].snapshot().to_json()));
            }
        }
        Json::Obj(pairs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.add("requests", 2);
        m.add("requests", 3);
        assert_eq!(m.counter("requests").load(Ordering::Relaxed), 5);
    }

    #[test]
    fn gauges_overwrite() {
        let m = Metrics::new();
        m.set("queue_depth", 7);
        m.set("queue_depth", 3);
        assert_eq!(m.counter("queue_depth").load(Ordering::Relaxed), 3);
    }

    #[test]
    fn histograms_shared_by_name() {
        let m = Metrics::new();
        m.record("serve", std::time::Duration::from_micros(100));
        m.record("serve", std::time::Duration::from_micros(300));
        assert_eq!(m.histogram("serve").count(), 2);
    }

    #[test]
    fn snapshot_contains_both_kinds() {
        let m = Metrics::new();
        m.add("reqs", 1);
        m.record("lat", std::time::Duration::from_micros(50));
        let j = m.snapshot_json();
        assert!(j.get("counter.reqs").is_some());
        assert!(j.get("latency.lat").is_some());
    }
}

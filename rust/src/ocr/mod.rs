//! PaddleOCR-equivalent substrate (paper §4.1): synthetic page generator,
//! detection post-processing, orientation rectification, CTC-style
//! decoding, and the 3-phase pipeline with base/prun execution paths.

pub mod decode;
pub mod detect;
pub mod imagegen;
pub mod meta;
pub mod pipeline;

pub use detect::DetBox;
pub use imagegen::{generate, GenOptions, GtBox, Image};
pub use meta::OcrMeta;
pub use pipeline::{
    exact_match, variant_from_name, OcrJob, OcrPipeline, OcrResult, PhaseTiming,
};

//! Typed view of `artifacts/ocr_meta.json` — glyph codebook and geometry
//! shared between the Python models and the Rust generator/decoder.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct OcrMeta {
    pub charset: Vec<char>,
    pub glyph_w: usize,
    pub box_h: usize,
    pub marker_slot: Vec<u8>,
    pub img_h: usize,
    pub img_w: usize,
    pub pool: usize,
    pub stride: usize,
    pub det_thresh: f64,
    pub box_ink: f32,
    pub rec_width_buckets: Vec<usize>,
    pub n_classes: usize,
    pub blank_id: usize,
    pub marker_id: usize,
    /// [n_classes][glyph_w] binary codes
    pub codebook: Vec<Vec<f32>>,
}

impl OcrMeta {
    pub fn load(artifacts_dir: &Path) -> Result<OcrMeta> {
        let v = Json::parse_file(&artifacts_dir.join("ocr_meta.json"))?;
        let charset: Vec<char> = v.req("charset")?.as_str().context("charset")?.chars().collect();
        let codebook = v
            .req("codebook")?
            .as_arr()
            .context("codebook")?
            .iter()
            .map(|row| row.f32_arr())
            .collect::<Result<Vec<_>>>()?;
        let meta = OcrMeta {
            glyph_w: v.req("glyph_w")?.as_usize().context("glyph_w")?,
            box_h: v.req("box_h")?.as_usize().context("box_h")?,
            marker_slot: v
                .req("marker_slot")?
                .usize_arr()?
                .into_iter()
                .map(|b| b as u8)
                .collect(),
            img_h: v.req("img_h")?.as_usize().context("img_h")?,
            img_w: v.req("img_w")?.as_usize().context("img_w")?,
            pool: v.req("pool")?.as_usize().context("pool")?,
            stride: v.req("stride")?.as_usize().context("stride")?,
            det_thresh: v.req("det_thresh")?.as_f64().context("det_thresh")?,
            box_ink: v.req("box_ink")?.as_f64().context("box_ink")? as f32,
            rec_width_buckets: v.req("rec_width_buckets")?.usize_arr()?,
            n_classes: v.req("n_classes")?.as_usize().context("n_classes")?,
            blank_id: v.req("blank_id")?.as_usize().context("blank_id")?,
            marker_id: v.req("marker_id")?.as_usize().context("marker_id")?,
            charset,
            codebook,
        };
        if meta.codebook.len() != meta.n_classes {
            bail!("codebook rows {} != n_classes {}", meta.codebook.len(), meta.n_classes);
        }
        Ok(meta)
    }

    pub fn char_index(&self, c: char) -> Option<usize> {
        self.charset.iter().position(|&x| x == c)
    }

    /// 8-column glyph code for a charset index (from the codebook).
    pub fn glyph_code(&self, idx: usize) -> &[f32] {
        &self.codebook[idx]
    }

    /// Smallest recognizer width bucket that fits a box of `width` px.
    pub fn width_bucket(&self, width: usize) -> Result<usize> {
        self.rec_width_buckets
            .iter()
            .copied()
            .find(|&b| b >= width)
            .with_context(|| format!("box width {width} exceeds largest bucket"))
    }

    /// Pixel width of a rendered text of `n` chars (marker + glyphs).
    pub fn text_width(&self, n_chars: usize) -> usize {
        (n_chars + 1) * self.glyph_w
    }

    /// Longest text that still fits the largest width bucket.
    pub fn max_text_len(&self) -> usize {
        self.rec_width_buckets.last().unwrap() / self.glyph_w - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifacts_dir;

    fn meta() -> Option<OcrMeta> {
        let dir = artifacts_dir();
        if !dir.join("ocr_meta.json").exists() {
            return None;
        }
        Some(OcrMeta::load(&dir).unwrap())
    }

    #[test]
    fn loads_and_is_consistent() {
        let Some(m) = meta() else { return };
        assert_eq!(m.charset.len(), 64);
        assert_eq!(m.n_classes, 66);
        assert_eq!(m.glyph_w, 8);
        assert_eq!(m.codebook.len(), 66);
        assert!(m.codebook.iter().all(|r| r.len() == m.glyph_w));
        // blank row is all zero, marker row matches marker_slot
        assert!(m.codebook[m.blank_id].iter().all(|&x| x == 0.0));
        for (a, &b) in m.codebook[m.marker_id].iter().zip(m.marker_slot.iter()) {
            assert_eq!(*a, b as f32);
        }
    }

    #[test]
    fn char_roundtrip() {
        let Some(m) = meta() else { return };
        for (i, &c) in m.charset.iter().enumerate() {
            assert_eq!(m.char_index(c), Some(i));
        }
        assert_eq!(m.char_index('!'), None);
    }

    #[test]
    fn width_buckets() {
        let Some(m) = meta() else { return };
        assert_eq!(m.width_bucket(1).unwrap(), 64);
        assert_eq!(m.width_bucket(64).unwrap(), 64);
        assert_eq!(m.width_bucket(65).unwrap(), 128);
        assert!(m.width_bucket(10_000).is_err());
        assert_eq!(m.text_width(7), 64);
        assert!(m.max_text_len() >= 20);
    }
}

//! Detection post-processing: score map -> refined text boxes.
//!
//! The detector model outputs a [H/stride, W/stride] probability map.
//! We threshold it, extract 4-connected components, take their bounding
//! rectangles, scale back to pixel space, and *refine* each rectangle
//! against the original image with brightness projections (the standard
//! binarize-and-project trick real OCR detectors use) so crops align to
//! the glyph grid exactly.

use super::imagegen::Image;
use super::meta::OcrMeta;

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DetBox {
    pub x: usize,
    pub y: usize,
    pub width: usize,
    pub height: usize,
}

/// Threshold for the score map.
pub const SCORE_THRESH: f32 = 0.5;
/// Per-pixel brightness threshold separating ink from page during refine
/// (ink >= box_ink - noise; background <= noise).
pub const REFINE_THRESH: f32 = 0.125;

/// Extract connected components of `score > SCORE_THRESH` and return
/// their bounding boxes in score-map coordinates.
pub fn components(score: &[f32], h: usize, w: usize) -> Vec<DetBox> {
    assert_eq!(score.len(), h * w);
    let mut visited = vec![false; h * w];
    let mut out = Vec::new();
    let mut stack = Vec::new();
    for start in 0..h * w {
        if visited[start] || score[start] <= SCORE_THRESH {
            continue;
        }
        let (mut min_r, mut max_r) = (start / w, start / w);
        let (mut min_c, mut max_c) = (start % w, start % w);
        stack.push(start);
        visited[start] = true;
        while let Some(p) = stack.pop() {
            let (r, c) = (p / w, p % w);
            min_r = min_r.min(r);
            max_r = max_r.max(r);
            min_c = min_c.min(c);
            max_c = max_c.max(c);
            let mut push = |q: usize| {
                if !visited[q] && score[q] > SCORE_THRESH {
                    visited[q] = true;
                    stack.push(q);
                }
            };
            if r > 0 {
                push(p - w);
            }
            if r + 1 < h {
                push(p + w);
            }
            if c > 0 {
                push(p - 1);
            }
            if c + 1 < w {
                push(p + 1);
            }
        }
        out.push(DetBox {
            x: min_c,
            y: min_r,
            width: max_c - min_c + 1,
            height: max_r - min_r + 1,
        });
    }
    // deterministic order: top-to-bottom, left-to-right
    out.sort_by_key(|b| (b.y, b.x));
    out
}

/// Refine a rough (score-map-scaled) box against the original image:
/// expand by one pool window, then shrink to the exact ink rectangle via
/// row/column brightness projections. Returns None if nothing bright is
/// found (spurious component).
pub fn refine(img: &Image, meta: &OcrMeta, rough: &DetBox) -> Option<DetBox> {
    let plane = meta.img_h * meta.img_w;
    let s = meta.stride;
    let pad = meta.pool;
    let x0 = rough.x.saturating_mul(s).saturating_sub(pad);
    let y0 = rough.y.saturating_mul(s).saturating_sub(pad);
    let x1 = ((rough.x + rough.width) * s + pad).min(meta.img_w);
    let y1 = ((rough.y + rough.height) * s + pad).min(meta.img_h);

    // channel-sum compare (avoids a divide per pixel — §Perf: refine is
    // the detect-postprocess hot loop, ~2 passes over each box region)
    let thresh3 = 3.0 * REFINE_THRESH;
    let bright = |r: usize, c: usize| -> bool {
        let idx = r * meta.img_w + c;
        img.pixels[idx] + img.pixels[plane + idx] + img.pixels[2 * plane + idx] > thresh3
    };

    // row projection
    let mut rows: Vec<usize> = Vec::new();
    for r in y0..y1 {
        let count = (x0..x1).filter(|&c| bright(r, c)).count();
        if count * 4 > (x1 - x0) {
            rows.push(r);
        }
    }
    let (ry0, ry1) = (*rows.first()?, *rows.last()? + 1);
    // column projection within the found rows
    let mut cols: Vec<usize> = Vec::new();
    for c in x0..x1 {
        let count = (ry0..ry1).filter(|&r| bright(r, c)).count();
        if count * 4 > (ry1 - ry0) {
            cols.push(c);
        }
    }
    let (cx0, cx1) = (*cols.first()?, *cols.last()? + 1);

    // Snap width to the glyph grid. Rendered boxes end with a dark gap
    // column (glyph c7) or a dark marker tail when flipped; the bright
    // projection can lose up to glyph_w-1 trailing dark columns — round
    // the width up to the next multiple of glyph_w.
    let raw_w = cx1 - cx0;
    let width = raw_w.div_ceil(meta.glyph_w) * meta.glyph_w;
    let width = width.min(meta.img_w - cx0);
    if width == 0 || ry1 - ry0 < meta.box_h / 2 {
        return None;
    }
    Some(DetBox { x: cx0, y: ry0, width, height: ry1 - ry0 })
}

/// Full detection post-processing: score-map tensor -> refined boxes.
pub fn extract_boxes(img: &Image, meta: &OcrMeta, score: &[f32]) -> Vec<DetBox> {
    let h = meta.img_h.div_ceil(meta.stride);
    let w = meta.img_w.div_ceil(meta.stride);
    components(score, h, w)
        .iter()
        .filter_map(|rough| refine(img, meta, rough))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ocr::imagegen::{generate, GenOptions};
    use crate::runtime::artifacts_dir;
    use crate::util::prng::Rng;

    fn meta() -> Option<OcrMeta> {
        let dir = artifacts_dir();
        if !dir.join("ocr_meta.json").exists() {
            return None;
        }
        Some(OcrMeta::load(&dir).unwrap())
    }

    #[test]
    fn components_empty_map() {
        assert!(components(&vec![0.0; 48 * 64], 48, 64).is_empty());
    }

    #[test]
    fn components_two_blobs() {
        let (h, w) = (8, 8);
        let mut score = vec![0.0f32; h * w];
        for r in 1..3 {
            for c in 1..3 {
                score[r * w + c] = 0.9;
            }
        }
        for r in 5..7 {
            for c in 5..8 {
                score[r * w + c] = 0.9;
            }
        }
        let boxes = components(&score, h, w);
        assert_eq!(boxes.len(), 2);
        assert_eq!(boxes[0], DetBox { x: 1, y: 1, width: 2, height: 2 });
        assert_eq!(boxes[1], DetBox { x: 5, y: 5, width: 3, height: 2 });
    }

    #[test]
    fn components_diagonal_not_connected() {
        let (h, w) = (4, 4);
        let mut score = vec![0.0f32; h * w];
        score[0] = 0.9; // (0,0)
        score[w + 1] = 0.9; // (1,1) — diagonal neighbour only
        assert_eq!(components(&score, h, w).len(), 2);
    }

    #[test]
    fn refine_recovers_exact_box_from_synthetic_map() {
        // Build the score map analytically (mean-pool + threshold mimic)
        // to test refine without the model in the loop.
        let Some(m) = meta() else { return };
        let opts = GenOptions { noise: 0.0, flip_prob: 0.0, ..Default::default() };
        let img = generate(&m, &mut Rng::new(21), 3, &opts);
        for gt in &img.boxes {
            let rough = DetBox {
                x: gt.x / m.stride,
                y: gt.y / m.stride,
                width: gt.width.div_ceil(m.stride),
                height: m.box_h.div_ceil(m.stride),
            };
            let refined = refine(&img, &m, &rough).expect("box found");
            assert_eq!(refined.x, gt.x, "x for '{}'", gt.text);
            assert_eq!(refined.y, gt.y);
            assert_eq!(refined.width, gt.width, "width for '{}'", gt.text);
            assert_eq!(refined.height, m.box_h);
        }
    }

    #[test]
    fn refine_with_noise_still_exact() {
        let Some(m) = meta() else { return };
        let opts = GenOptions { noise: 0.04, flip_prob: 0.5, ..Default::default() };
        let img = generate(&m, &mut Rng::new(23), 4, &opts);
        for gt in &img.boxes {
            let rough = DetBox {
                x: gt.x / m.stride,
                y: gt.y / m.stride,
                width: gt.width.div_ceil(m.stride),
                height: m.box_h.div_ceil(m.stride),
            };
            let refined = refine(&img, &m, &rough).expect("box found");
            assert_eq!((refined.x, refined.width), (gt.x, gt.width), "'{}'", gt.text);
        }
    }

    #[test]
    fn refine_rejects_empty_region() {
        let Some(m) = meta() else { return };
        let img = Image { pixels: vec![0.0; 3 * m.img_h * m.img_w], boxes: vec![] };
        let rough = DetBox { x: 5, y: 5, width: 4, height: 8 };
        assert!(refine(&img, &m, &rough).is_none());
    }
}

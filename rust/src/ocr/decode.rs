//! Recognizer-output decoding: per-slot log-probs -> text.
//!
//! The recognizer emits [slots, n_classes] log-probabilities. Decoding is
//! CTC-style argmax: take the best class per slot, drop the marker slot,
//! stop at the first blank (the generator leaves no embedded blanks), and
//! map the rest through the charset.

use anyhow::{bail, Result};

use super::meta::OcrMeta;

/// Argmax per row of a [rows, n_classes] flat matrix.
pub fn argmax_rows(logp: &[f32], n_classes: usize) -> Vec<usize> {
    logp.chunks(n_classes)
        .map(|row| {
            row.iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i)
                .unwrap()
        })
        .collect()
}

/// Decode per-slot class ids into text.
pub fn decode_ids(ids: &[usize], meta: &OcrMeta) -> Result<String> {
    let mut out = String::new();
    let mut seen_blank = false;
    for (slot, &id) in ids.iter().enumerate() {
        if id == meta.marker_id {
            if slot != 0 {
                bail!("marker class in interior slot {slot}");
            }
            continue;
        }
        if id == meta.blank_id {
            seen_blank = true;
            continue;
        }
        if seen_blank {
            bail!("character after blank at slot {slot} — misaligned crop?");
        }
        if id >= meta.charset.len() {
            bail!("class id {id} out of charset range");
        }
        out.push(meta.charset[id]);
    }
    Ok(out)
}

/// Full decode from the recognizer output tensor data.
pub fn decode(logp: &[f32], n_classes: usize, meta: &OcrMeta) -> Result<String> {
    decode_ids(&argmax_rows(logp, n_classes), meta)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifacts_dir;

    fn meta() -> Option<OcrMeta> {
        let dir = artifacts_dir();
        if !dir.join("ocr_meta.json").exists() {
            return None;
        }
        Some(OcrMeta::load(&dir).unwrap())
    }

    #[test]
    fn argmax_rows_basic() {
        let logp = [0.1f32, 0.9, 0.0, 0.7, 0.2, 0.1];
        assert_eq!(argmax_rows(&logp, 3), vec![1, 0]);
    }

    #[test]
    fn decode_marker_chars_blanks() {
        let Some(m) = meta() else { return };
        // marker, 'a'(0), 'b'(1), blank, blank
        let ids = vec![m.marker_id, 0, 1, m.blank_id, m.blank_id];
        assert_eq!(decode_ids(&ids, &m).unwrap(), "ab");
    }

    #[test]
    fn decode_rejects_char_after_blank() {
        let Some(m) = meta() else { return };
        let ids = vec![m.marker_id, 0, m.blank_id, 1];
        assert!(decode_ids(&ids, &m).is_err());
    }

    #[test]
    fn decode_rejects_interior_marker() {
        let Some(m) = meta() else { return };
        let ids = vec![m.marker_id, 0, m.marker_id];
        assert!(decode_ids(&ids, &m).is_err());
    }

    #[test]
    fn decode_empty_text() {
        let Some(m) = meta() else { return };
        let ids = vec![m.marker_id, m.blank_id, m.blank_id];
        assert_eq!(decode_ids(&ids, &m).unwrap(), "");
    }
}

//! The 3-phase OCR pipeline (paper Fig. 1) over the real PJRT engine.
//!
//! detection -> per-box orientation classification -> rectification ->
//! per-box recognition -> decode. The classification and recognition
//! phases run either `base` (loop over boxes, each `run` with the whole
//! core budget — the unmodified pipeline) or via `prun` (all boxes
//! submitted at once, threads allocated by size — the paper's Listings
//! 2 -> 3 change).

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::engine::{AllocPolicy, JobPart, PrunOptions, Session};
use crate::runtime::Tensor;
use crate::simcpu::ocr::OcrVariant;

use super::decode;
use super::detect::{self, DetBox};
use super::imagegen::{crop_tensor, Image};
use super::meta::OcrMeta;

/// Per-phase wall-clock timing of one image.
#[derive(Debug, Clone, Copy, Default)]
pub struct PhaseTiming {
    pub det: Duration,
    pub cls: Duration,
    pub rec: Duration,
}

impl PhaseTiming {
    pub fn total(&self) -> Duration {
        self.det + self.cls + self.rec
    }
}

/// Result for one image.
#[derive(Debug)]
pub struct OcrResult {
    pub boxes: Vec<DetBox>,
    /// decoded text per box (post-rectification); None if decode failed
    pub texts: Vec<Option<String>>,
    pub flipped: Vec<bool>,
    pub timing: PhaseTiming,
}

pub struct OcrPipeline {
    session: Arc<Session>,
    meta: OcrMeta,
}

impl OcrPipeline {
    pub fn new(session: Arc<Session>, meta: OcrMeta) -> OcrPipeline {
        OcrPipeline { session, meta }
    }

    pub fn meta(&self) -> &OcrMeta {
        &self.meta
    }

    /// Pre-compile all OCR models.
    pub fn warmup(&self) -> Result<()> {
        let mut models = vec!["ocr_det".to_string()];
        for &w in &self.meta.rec_width_buckets {
            models.push(format!("ocr_cls_w{w}"));
            models.push(format!("ocr_rec_w{w}"));
        }
        let refs: Vec<&str> = models.iter().map(String::as_str).collect();
        self.session.warmup(&refs)
    }

    /// Run the full pipeline on one image.
    pub fn process(&self, img: &Image, variant: OcrVariant) -> Result<OcrResult> {
        // ---- Phase 1: detection (identical in all variants) ----
        let t0 = Instant::now();
        let score = self
            .session
            .run("ocr_det", vec![img.to_tensor(&self.meta)])
            .context("detection")?;
        let boxes = detect::extract_boxes(img, &self.meta, score[0].as_f32()?);
        let det = t0.elapsed();

        if boxes.is_empty() {
            return Ok(OcrResult { boxes, texts: vec![], flipped: vec![], timing: PhaseTiming { det, ..Default::default() } });
        }

        // ---- Phase 2: orientation classification ----
        let t1 = Instant::now();
        let upright_crops: Vec<(Tensor, usize)> = boxes
            .iter()
            .map(|b| {
                let bucket = self.meta.width_bucket(b.width)?;
                Ok((crop_tensor(img, &self.meta, b.x, b.y, b.width, bucket, false), bucket))
            })
            .collect::<Result<_>>()?;
        let cls_logits = self.run_phase(
            upright_crops.iter().map(|(t, bucket)| (format!("ocr_cls_w{bucket}"), t.clone())),
            variant,
        )?;
        let flipped: Vec<bool> = cls_logits
            .iter()
            .map(|out| {
                let l = out[0].as_f32().unwrap();
                l[1] > l[0]
            })
            .collect();
        let cls = t1.elapsed();

        // ---- Phase 3: rectify + recognition ----
        let t2 = Instant::now();
        let rec_inputs: Vec<(String, Tensor)> = boxes
            .iter()
            .zip(flipped.iter())
            .map(|(b, &fl)| {
                let bucket = self.meta.width_bucket(b.width)?;
                let crop = crop_tensor(img, &self.meta, b.x, b.y, b.width, bucket, fl);
                Ok((format!("ocr_rec_w{bucket}"), crop))
            })
            .collect::<Result<_>>()?;
        let rec_out = self.run_phase(rec_inputs.into_iter(), variant)?;
        let texts: Vec<Option<String>> = rec_out
            .iter()
            .map(|out| {
                let logp = out[0].as_f32().ok()?;
                let n_classes = out[0].shape[1];
                decode::decode(logp, n_classes, &self.meta).ok()
            })
            .collect();
        let rec = t2.elapsed();

        Ok(OcrResult { boxes, texts, flipped, timing: PhaseTiming { det, cls, rec } })
    }

    /// Run one per-box phase under the chosen variant.
    fn run_phase(
        &self,
        inputs: impl Iterator<Item = (String, Tensor)>,
        variant: OcrVariant,
    ) -> Result<Vec<Vec<Tensor>>> {
        let parts: Vec<JobPart> =
            inputs.map(|(model, t)| JobPart::new(model, vec![t])).collect();
        match variant {
            OcrVariant::Base => {
                // unmodified pipeline: iterate, each run owns all cores
                parts
                    .into_iter()
                    .map(|p| self.session.run(&p.model, p.inputs))
                    .collect()
            }
            OcrVariant::Prun(policy) => {
                Ok(self.session.prun(parts, PrunOptions { policy, ..Default::default() })?.outputs)
            }
        }
    }
}

/// Exact-match accuracy of a result against ground truth.
pub fn exact_match(result: &OcrResult, img: &Image) -> (usize, usize) {
    let mut hits = 0;
    let total = img.boxes.len();
    for gt in &img.boxes {
        // match by position (results are sorted top-left first)
        if let Some(i) = result.boxes.iter().position(|b| b.x == gt.x && b.y == gt.y) {
            if result.texts[i].as_deref() == Some(gt.text.as_str()) {
                hits += 1;
            }
        }
    }
    (hits, total)
}

/// Convenience: which policy to use for a CLI variant name.
pub fn variant_from_name(name: &str) -> Option<OcrVariant> {
    match name {
        "base" => Some(OcrVariant::Base),
        other => AllocPolicy::parse(other).map(OcrVariant::Prun),
    }
}

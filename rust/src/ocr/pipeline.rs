//! The 3-phase OCR pipeline (paper Fig. 1) over the real PJRT engine.
//!
//! detection -> per-box orientation classification -> rectification ->
//! per-box recognition -> decode. The classification and recognition
//! phases run either `base` (loop over boxes, each `run` with the whole
//! core budget — the unmodified pipeline) or via `prun` (all boxes
//! submitted at once, threads allocated by size — the paper's Listings
//! 2 -> 3 change).
//!
//! [`OcrPipeline::process_budgeted`] threads one serving request's
//! [`CancelToken`] and [`Budget`] through every model invocation of all
//! three phases: a cancelled or out-of-time request stops at the next
//! phase boundary (CPU side) or at the scheduler/executor (model side)
//! instead of running the remaining phases for a client that gave up.

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::engine::{
    AllocPolicy, Budget, CancelToken, JobPart, PrunOptions, SchedError, Session,
    TaskCancelled,
};
use crate::runtime::Tensor;
use crate::simcpu::ocr::OcrVariant;

use super::decode;
use super::detect::{self, DetBox};
use super::imagegen::{crop_tensor, Image};
use super::meta::OcrMeta;

/// Per-phase wall-clock timing of one image.
#[derive(Debug, Clone, Copy, Default)]
pub struct PhaseTiming {
    pub det: Duration,
    pub cls: Duration,
    pub rec: Duration,
}

impl PhaseTiming {
    pub fn total(&self) -> Duration {
        self.det + self.cls + self.rec
    }
}

/// Result for one image.
#[derive(Debug)]
pub struct OcrResult {
    pub boxes: Vec<DetBox>,
    /// decoded text per box (post-rectification); None if decode failed
    pub texts: Vec<Option<String>>,
    pub flipped: Vec<bool>,
    pub timing: PhaseTiming,
}

pub struct OcrPipeline {
    session: Arc<Session>,
    meta: OcrMeta,
}

impl OcrPipeline {
    pub fn new(session: Arc<Session>, meta: OcrMeta) -> OcrPipeline {
        OcrPipeline { session, meta }
    }

    pub fn meta(&self) -> &OcrMeta {
        &self.meta
    }

    /// Pre-compile all OCR models.
    pub fn warmup(&self) -> Result<()> {
        let mut models = vec!["ocr_det".to_string()];
        for &w in &self.meta.rec_width_buckets {
            models.push(format!("ocr_cls_w{w}"));
            models.push(format!("ocr_rec_w{w}"));
        }
        let refs: Vec<&str> = models.iter().map(String::as_str).collect();
        self.session.warmup(&refs)
    }

    /// Run the full pipeline on one image.
    pub fn process(&self, img: &Image, variant: OcrVariant) -> Result<OcrResult> {
        self.process_budgeted(img, variant, &CancelToken::new(), None)
    }

    /// [`process`](Self::process) under a serving request's control: the
    /// request's `cancel` token and remaining `budget` travel into every
    /// model invocation (detection, classification, recognition), so the
    /// scheduler rejects still-queued parts of an out-of-time request
    /// and kills a running part when the request's clock ends. The
    /// CPU-side phase boundaries check both too — a request that died
    /// during classification never pays for recognition crops.
    pub fn process_budgeted(
        &self,
        img: &Image,
        variant: OcrVariant,
        cancel: &CancelToken,
        budget: Option<Budget>,
    ) -> Result<OcrResult> {
        // ---- Phase 1: detection (identical in all variants) ----
        let t0 = Instant::now();
        let score = self
            .session
            .run_cancellable("ocr_det", vec![img.to_tensor(&self.meta)], cancel.clone(), budget)
            .context("detection")?;
        let boxes = detect::extract_boxes(img, &self.meta, score[0].as_f32()?);
        let det = t0.elapsed();

        if boxes.is_empty() {
            return Ok(OcrResult { boxes, texts: vec![], flipped: vec![], timing: PhaseTiming { det, ..Default::default() } });
        }

        // ---- Phase 2: orientation classification ----
        check_request(cancel, budget).context("before classification")?;
        let t1 = Instant::now();
        let upright_crops: Vec<(Tensor, usize)> = boxes
            .iter()
            .map(|b| {
                let bucket = self.meta.width_bucket(b.width)?;
                Ok((crop_tensor(img, &self.meta, b.x, b.y, b.width, bucket, false), bucket))
            })
            .collect::<Result<_>>()?;
        let cls_logits = self.run_phase(
            upright_crops.iter().map(|(t, bucket)| (format!("ocr_cls_w{bucket}"), t.clone())),
            variant,
            cancel,
            budget,
        )?;
        let flipped: Vec<bool> = cls_logits
            .iter()
            .map(|out| {
                let l = out[0].as_f32().unwrap();
                l[1] > l[0]
            })
            .collect();
        let cls = t1.elapsed();

        // ---- Phase 3: rectify + recognition ----
        check_request(cancel, budget).context("before recognition")?;
        let t2 = Instant::now();
        let rec_inputs: Vec<(String, Tensor)> = boxes
            .iter()
            .zip(flipped.iter())
            .map(|(b, &fl)| {
                let bucket = self.meta.width_bucket(b.width)?;
                let crop = crop_tensor(img, &self.meta, b.x, b.y, b.width, bucket, fl);
                Ok((format!("ocr_rec_w{bucket}"), crop))
            })
            .collect::<Result<_>>()?;
        let rec_out = self.run_phase(rec_inputs.into_iter(), variant, cancel, budget)?;
        let texts: Vec<Option<String>> = rec_out
            .iter()
            .map(|out| {
                let logp = out[0].as_f32().ok()?;
                let n_classes = out[0].shape[1];
                decode::decode(logp, n_classes, &self.meta).ok()
            })
            .collect();
        let rec = t2.elapsed();

        Ok(OcrResult { boxes, texts, flipped, timing: PhaseTiming { det, cls, rec } })
    }

    /// Run one per-box phase under the chosen variant, threading the
    /// request's token and budget into every scheduler submission.
    fn run_phase(
        &self,
        inputs: impl Iterator<Item = (String, Tensor)>,
        variant: OcrVariant,
        cancel: &CancelToken,
        budget: Option<Budget>,
    ) -> Result<Vec<Vec<Tensor>>> {
        let parts: Vec<JobPart> = inputs
            .map(|(model, t)| JobPart::new(model, vec![t]).with_cancel(cancel.clone()))
            .collect();
        match variant {
            OcrVariant::Base => {
                // unmodified pipeline: iterate, each run owns all cores —
                // and a request that dies mid-loop stops at the next box
                parts
                    .into_iter()
                    .map(|p| {
                        check_request(cancel, budget)?;
                        self.session.run_cancellable(&p.model, p.inputs, cancel.clone(), budget)
                    })
                    .collect()
            }
            OcrVariant::Prun(policy) => Ok(self
                .session
                .prun(parts, PrunOptions { policy, budget, ..Default::default() })?
                .outputs),
        }
    }
}

/// CPU-side phase guard: fail fast with the same typed errors the
/// scheduler uses, so a request cancelled or out of time between model
/// invocations never pays for the next phase's crop/tensor work.
fn check_request(cancel: &CancelToken, budget: Option<Budget>) -> Result<()> {
    if cancel.is_cancelled() {
        return Err(anyhow::Error::new(TaskCancelled));
    }
    if budget.is_some_and(|b| b.expired()) {
        return Err(anyhow::Error::new(SchedError::BudgetExpired));
    }
    Ok(())
}

/// Exact-match accuracy of a result against ground truth.
pub fn exact_match(result: &OcrResult, img: &Image) -> (usize, usize) {
    let mut hits = 0;
    let total = img.boxes.len();
    for gt in &img.boxes {
        // match by position (results are sorted top-left first)
        if let Some(i) = result.boxes.iter().position(|b| b.x == gt.x && b.y == gt.y) {
            if result.texts[i].as_deref() == Some(gt.text.as_str()) {
                hits += 1;
            }
        }
    }
    (hits, total)
}

/// Convenience: which policy to use for a CLI variant name.
pub fn variant_from_name(name: &str) -> Option<OcrVariant> {
    match name {
        "base" => Some(OcrVariant::Base),
        other => AllocPolicy::parse(other).map(OcrVariant::Prun),
    }
}

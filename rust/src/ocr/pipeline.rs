//! The 3-phase OCR pipeline (paper Fig. 1) over the real PJRT engine.
//!
//! detection -> per-box orientation classification -> rectification ->
//! per-box recognition -> decode. The classification and recognition
//! phases run either `base` (loop over boxes, each invocation with the
//! whole core budget — the unmodified pipeline) or via `prun` (all
//! boxes submitted at once, threads allocated by size — the paper's
//! Listings 2 -> 3 change).
//!
//! One [`RequestCtx`] threads through every model invocation of all
//! three phases: a cancelled or out-of-time request stops at the next
//! phase boundary (CPU side) or at the scheduler/executor (model side)
//! instead of running the remaining phases for a client that gave up.
//! [`OcrPipeline`] also implements [`InferenceService`] over an
//! [`OcrJob`]: `submit` runs the pipeline on a named worker thread and
//! returns a [`SubmitTicket`] — which is how the router serves the
//! `ocr` op with a bounded wait instead of pinning its connection
//! thread.

use std::sync::mpsc::{channel, RecvTimeoutError};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::engine::{
    AllocPolicy, Allocation, InferenceService, JobPart, PrunRequest, RequestCtx, SchedError,
    Session, SubmitError, SubmitTicket, TaskCancelled,
};
use crate::runtime::Tensor;
use crate::simcpu::ocr::OcrVariant;

use super::decode;
use super::detect::{self, DetBox};
use super::imagegen::{crop_tensor, Image};
use super::meta::OcrMeta;

/// Per-phase wall-clock timing of one image.
#[derive(Debug, Clone, Copy, Default)]
pub struct PhaseTiming {
    pub det: Duration,
    pub cls: Duration,
    pub rec: Duration,
}

impl PhaseTiming {
    pub fn total(&self) -> Duration {
        self.det + self.cls + self.rec
    }
}

/// Result for one image.
#[derive(Debug)]
pub struct OcrResult {
    pub boxes: Vec<DetBox>,
    /// decoded text per box (post-rectification); None if decode failed
    pub texts: Vec<Option<String>>,
    pub flipped: Vec<bool>,
    pub timing: PhaseTiming,
}

/// One OCR request for [`OcrPipeline`]'s [`InferenceService`] impl: a
/// page plus the execution variant.
#[derive(Debug)]
pub struct OcrJob {
    pub image: Image,
    pub variant: OcrVariant,
}

pub struct OcrPipeline {
    session: Arc<Session>,
    meta: Arc<OcrMeta>,
}

impl OcrPipeline {
    pub fn new(session: Arc<Session>, meta: OcrMeta) -> OcrPipeline {
        OcrPipeline { session, meta: Arc::new(meta) }
    }

    pub fn meta(&self) -> &OcrMeta {
        &self.meta
    }

    /// Pre-compile all OCR models.
    pub fn warmup(&self) -> Result<()> {
        let mut models = vec!["ocr_det".to_string()];
        for &w in &self.meta.rec_width_buckets {
            models.push(format!("ocr_cls_w{w}"));
            models.push(format!("ocr_rec_w{w}"));
        }
        let refs: Vec<&str> = models.iter().map(String::as_str).collect();
        self.session.warmup(&refs)
    }

    /// Run the full pipeline on one image, synchronously, on behalf of
    /// `ctx`: the request's token and budget travel into every model
    /// invocation (detection, classification, recognition), so the
    /// scheduler rejects still-queued parts of an out-of-time request
    /// and kills a running part when the request's clock ends. The
    /// CPU-side phase boundaries check both too — a request that died
    /// during classification never pays for recognition crops.
    pub fn process(&self, img: &Image, variant: OcrVariant, ctx: &RequestCtx) -> Result<OcrResult> {
        run_pipeline(&self.session, &self.meta, img, variant, ctx)
    }

}

impl InferenceService for OcrPipeline {
    type Request = OcrJob;
    type Response = OcrResult;

    /// Run the pipeline on a named worker thread under `ctx`; the
    /// single-item ticket settles the page's [`OcrResult`]. The serving
    /// edge pairs this with [`SubmitTicket::wait_each_timeout`]: on
    /// expiry the request is cancelled, so the pipeline's scheduler
    /// tasks release their cores and the worker thread unwinds through
    /// its error path instead of running unbounded for a client that
    /// gave up.
    fn submit(&self, job: OcrJob, ctx: RequestCtx) -> SubmitTicket<OcrResult> {
        let session = Arc::clone(&self.session);
        let meta = Arc::clone(&self.meta);
        let worker_ctx = ctx.clone();
        let (tx, rx) = channel();
        let spawned = std::thread::Builder::new().name("dnc-ocr".into()).spawn(move || {
            let res = run_pipeline(&session, &meta, &job.image, job.variant, &worker_ctx)
                .map_err(|e| SubmitError::classify(&e));
            let _ = tx.send(vec![res]); // the waiter may have given up
        });
        if let Err(e) = spawned {
            return SubmitTicket::rejected(
                ctx,
                1,
                SubmitError::Failed(format!("spawning ocr worker failed: {e}")),
            );
        }
        let token = ctx.token();
        SubmitTicket::pending(
            ctx,
            Allocation::default(), // phases size themselves as they go
            vec![token],
            1,
            Box::new(move |deadline| {
                let res = match deadline {
                    None => rx.recv().map_err(|_| RecvTimeoutError::Disconnected),
                    Some(d) => rx.recv_timeout(d.saturating_duration_since(Instant::now())),
                };
                match res {
                    Ok(results) => Some(results),
                    Err(RecvTimeoutError::Timeout) => None,
                    Err(RecvTimeoutError::Disconnected) => Some(vec![Err(
                        SubmitError::Failed("ocr worker died".to_string()),
                    )]),
                }
            }),
        )
    }
}

/// The 3-phase pipeline body, free of `&self` so the worker thread of
/// [`OcrPipeline::submit`] can own its captures.
fn run_pipeline(
    session: &Session,
    meta: &OcrMeta,
    img: &Image,
    variant: OcrVariant,
    ctx: &RequestCtx,
) -> Result<OcrResult> {
    // ---- Phase 1: detection (identical in all variants) ----
    let t0 = Instant::now();
    let score = session
        .run_with("ocr_det", vec![img.to_tensor(meta)], ctx)
        .context("detection")?;
    let boxes = detect::extract_boxes(img, meta, score[0].as_f32()?);
    let det = t0.elapsed();

    if boxes.is_empty() {
        return Ok(OcrResult {
            boxes,
            texts: vec![],
            flipped: vec![],
            timing: PhaseTiming { det, ..Default::default() },
        });
    }

    // ---- Phase 2: orientation classification ----
    check_request(ctx).context("before classification")?;
    let t1 = Instant::now();
    let upright_crops: Vec<(Tensor, usize)> = boxes
        .iter()
        .map(|b| {
            let bucket = meta.width_bucket(b.width)?;
            Ok((crop_tensor(img, meta, b.x, b.y, b.width, bucket, false), bucket))
        })
        .collect::<Result<_>>()?;
    let cls_logits = run_phase(
        session,
        upright_crops.iter().map(|(t, bucket)| (format!("ocr_cls_w{bucket}"), t.clone())),
        variant,
        ctx,
    )?;
    let flipped: Vec<bool> = cls_logits
        .iter()
        .map(|out| {
            let l = out[0].as_f32().unwrap();
            l[1] > l[0]
        })
        .collect();
    let cls = t1.elapsed();

    // ---- Phase 3: rectify + recognition ----
    check_request(ctx).context("before recognition")?;
    let t2 = Instant::now();
    let rec_inputs: Vec<(String, Tensor)> = boxes
        .iter()
        .zip(flipped.iter())
        .map(|(b, &fl)| {
            let bucket = meta.width_bucket(b.width)?;
            let crop = crop_tensor(img, meta, b.x, b.y, b.width, bucket, fl);
            Ok((format!("ocr_rec_w{bucket}"), crop))
        })
        .collect::<Result<_>>()?;
    let rec_out = run_phase(session, rec_inputs.into_iter(), variant, ctx)?;
    let texts: Vec<Option<String>> = rec_out
        .iter()
        .map(|out| {
            let logp = out[0].as_f32().ok()?;
            let n_classes = out[0].shape[1];
            decode::decode(logp, n_classes, meta).ok()
        })
        .collect();
    let rec = t2.elapsed();

    Ok(OcrResult { boxes, texts, flipped, timing: PhaseTiming { det, cls, rec } })
}

/// Run one per-box phase under the chosen variant; every scheduler
/// submission inherits the request's ctx.
fn run_phase(
    session: &Session,
    inputs: impl Iterator<Item = (String, Tensor)>,
    variant: OcrVariant,
    ctx: &RequestCtx,
) -> Result<Vec<Vec<Tensor>>> {
    let parts: Vec<JobPart> = inputs.map(|(model, t)| JobPart::new(model, vec![t])).collect();
    match variant {
        OcrVariant::Base => {
            // unmodified pipeline: iterate, each run owns all cores —
            // and a request that dies mid-loop stops at the next box
            parts
                .into_iter()
                .map(|p| {
                    check_request(ctx)?;
                    session.run_with(&p.model, p.inputs, ctx)
                })
                .collect()
        }
        OcrVariant::Prun(policy) => Ok(session
            .prun(PrunRequest::new(parts).with_policy(policy), ctx)?
            .outputs),
    }
}

/// CPU-side phase guard: fail fast with the same typed errors the
/// scheduler uses, so a request cancelled or out of time between model
/// invocations never pays for the next phase's crop/tensor work.
fn check_request(ctx: &RequestCtx) -> Result<()> {
    if ctx.is_cancelled() {
        return Err(anyhow::Error::new(TaskCancelled));
    }
    if ctx.expired() {
        return Err(anyhow::Error::new(SchedError::BudgetExpired));
    }
    Ok(())
}

/// Exact-match accuracy of a result against ground truth.
pub fn exact_match(result: &OcrResult, img: &Image) -> (usize, usize) {
    let mut hits = 0;
    let total = img.boxes.len();
    for gt in &img.boxes {
        // match by position (results are sorted top-left first)
        if let Some(i) = result.boxes.iter().position(|b| b.x == gt.x && b.y == gt.y) {
            if result.texts[i].as_deref() == Some(gt.text.as_str()) {
                hits += 1;
            }
        }
    }
    (hits, total)
}

/// Convenience: which policy to use for a CLI variant name.
pub fn variant_from_name(name: &str) -> Option<OcrVariant> {
    match name {
        "base" => Some(OcrVariant::Base),
        other => AllocPolicy::parse(other).map(OcrVariant::Prun),
    }
}

//! Synthetic document-image generator — the OpenImages substitute
//! (DESIGN.md §4): pages with glyph-coded text boxes at known positions,
//! so the pipeline's output can be checked exactly against ground truth.
//!
//! Layout contract (shared with `python/compile/model.py`):
//! - page background ~0 brightness (plus optional noise);
//! - a text box is `box_h` tall: column-constant pattern of bright (1.0)
//!   and ink (box_ink) columns — marker slot then one 8-column glyph per
//!   character;
//! - a "rotated" box is the 180° rotation of its upright rendering;
//! - boxes are separated by >= 16 px so the detector's 8x8/stride-4
//!   pooling keeps them as distinct components.

use crate::runtime::Tensor;
use crate::util::prng::Rng;

use super::meta::OcrMeta;

/// Ground-truth box.
#[derive(Debug, Clone, PartialEq)]
pub struct GtBox {
    pub x: usize,
    pub y: usize,
    pub width: usize,
    pub text: String,
    pub flipped: bool,
}

/// A generated page with ground truth.
#[derive(Debug, Clone)]
pub struct Image {
    /// channel-major pixels, [3, img_h, img_w] flattened
    pub pixels: Vec<f32>,
    pub boxes: Vec<GtBox>,
}

impl Image {
    /// As the detector's input tensor [1, 3, H, W].
    pub fn to_tensor(&self, meta: &OcrMeta) -> Tensor {
        Tensor::f32(vec![1, 3, meta.img_h, meta.img_w], self.pixels.clone())
    }

    pub fn texts(&self) -> Vec<&str> {
        self.boxes.iter().map(|b| b.text.as_str()).collect()
    }
}

/// Generator options.
#[derive(Debug, Clone)]
pub struct GenOptions {
    /// uniform noise amplitude added per pixel (clamped to [0,1])
    pub noise: f32,
    /// probability a box is rendered rotated by 180°
    pub flip_prob: f64,
    /// text length range (chars)
    pub min_len: usize,
    pub max_len: usize,
}

impl Default for GenOptions {
    fn default() -> Self {
        GenOptions { noise: 0.03, flip_prob: 0.3, min_len: 3, max_len: 20 }
    }
}

const H_GAP: usize = 16;
const V_GAP: usize = 16;
const MARGIN: usize = 8;

/// Generate a page with (up to) `n_boxes` text boxes. Fewer boxes are
/// placed if the page runs out of room (caller can check `boxes.len()`).
pub fn generate(meta: &OcrMeta, rng: &mut Rng, n_boxes: usize, opts: &GenOptions) -> Image {
    let mut pixels = vec![0.0f32; 3 * meta.img_h * meta.img_w];
    let mut boxes = Vec::new();

    // Row-major greedy placement.
    let rows = (meta.img_h - 2 * MARGIN + V_GAP) / (meta.box_h + V_GAP);
    let mut cursor_y = MARGIN;
    let mut cursor_x = MARGIN;
    let mut row = 0;

    while boxes.len() < n_boxes && row < rows {
        let len = rng.usize_in(opts.min_len, opts.max_len.min(meta.max_text_len()));
        let width = meta.text_width(len);
        if cursor_x + width + MARGIN > meta.img_w {
            // next row
            row += 1;
            cursor_y += meta.box_h + V_GAP;
            cursor_x = MARGIN;
            continue;
        }
        let text: String = (0..len)
            .map(|_| meta.charset[rng.usize_in(0, meta.charset.len() - 1)])
            .collect();
        let flipped = rng.bool(opts.flip_prob);
        draw_box(&mut pixels, meta, cursor_x, cursor_y, &text, flipped);
        boxes.push(GtBox { x: cursor_x, y: cursor_y, width, text, flipped });
        cursor_x += width + H_GAP;
    }

    if opts.noise > 0.0 {
        // One RNG draw per pixel location, shared across the three
        // channels (§Perf: per-channel draws tripled generation cost; the
        // models consume channel means, so the distinction is immaterial).
        let plane = meta.img_h * meta.img_w;
        for i in 0..plane {
            let delta = (rng.f32() * 2.0 - 1.0) * opts.noise;
            for ch in 0..3 {
                let p = &mut pixels[ch * plane + i];
                *p = (*p + delta).clamp(0.0, 1.0);
            }
        }
    }
    Image { pixels, boxes }
}

/// Column pattern of a rendered text: marker slot then per-char glyphs.
pub fn column_pattern(meta: &OcrMeta, text: &str) -> Vec<f32> {
    let mut cols = Vec::with_capacity(meta.text_width(text.chars().count()));
    for &bit in &meta.marker_slot {
        cols.push(if bit == 1 { 1.0 } else { meta.box_ink });
    }
    for c in text.chars() {
        let idx = meta
            .char_index(c)
            .unwrap_or_else(|| panic!("char '{c}' not in charset"));
        for &bit in meta.glyph_code(idx) {
            cols.push(if bit == 1.0 { 1.0 } else { meta.box_ink });
        }
    }
    cols
}

fn draw_box(pixels: &mut [f32], meta: &OcrMeta, x: usize, y: usize, text: &str, flipped: bool) {
    let mut cols = column_pattern(meta, text);
    if flipped {
        cols.reverse(); // column-constant pattern: 180° rotation == reverse
    }
    let plane = meta.img_h * meta.img_w;
    for (j, &v) in cols.iter().enumerate() {
        for r in 0..meta.box_h {
            let base = (y + r) * meta.img_w + x + j;
            for ch in 0..3 {
                pixels[ch * plane + base] = v;
            }
        }
    }
}

/// Crop a box region out of an image, padded to `bucket_w`, as the
/// classifier/recognizer input tensor [1, 3, box_h, bucket_w].
pub fn crop_tensor(
    img: &Image,
    meta: &OcrMeta,
    x: usize,
    y: usize,
    width: usize,
    bucket_w: usize,
    rotate180: bool,
) -> Tensor {
    assert!(width <= bucket_w);
    let plane = meta.img_h * meta.img_w;
    let mut out = vec![0.0f32; 3 * meta.box_h * bucket_w];
    for ch in 0..3 {
        for r in 0..meta.box_h {
            for c in 0..width {
                let (sr, sc) = if rotate180 {
                    (meta.box_h - 1 - r, width - 1 - c)
                } else {
                    (r, c)
                };
                out[ch * meta.box_h * bucket_w + r * bucket_w + c] =
                    img.pixels[ch * plane + (y + sr) * meta.img_w + x + sc];
            }
        }
    }
    Tensor::f32(vec![1, 3, meta.box_h, bucket_w], out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifacts_dir;

    fn meta() -> Option<OcrMeta> {
        let dir = artifacts_dir();
        if !dir.join("ocr_meta.json").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        Some(OcrMeta::load(&dir).unwrap())
    }

    #[test]
    fn generates_requested_boxes() {
        let Some(m) = meta() else { return };
        let mut rng = Rng::new(1);
        let img = generate(&m, &mut rng, 4, &GenOptions::default());
        assert_eq!(img.boxes.len(), 4);
        assert_eq!(img.pixels.len(), 3 * m.img_h * m.img_w);
        // boxes inside the page and non-overlapping rows/cols
        for b in &img.boxes {
            assert!(b.x + b.width <= m.img_w);
            assert!(b.y + m.box_h <= m.img_h);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let Some(m) = meta() else { return };
        let a = generate(&m, &mut Rng::new(7), 3, &GenOptions::default());
        let b = generate(&m, &mut Rng::new(7), 3, &GenOptions::default());
        assert_eq!(a.pixels, b.pixels);
        assert_eq!(a.boxes, b.boxes);
    }

    #[test]
    fn box_pixels_bright_background_dark() {
        let Some(m) = meta() else { return };
        let opts = GenOptions { noise: 0.0, flip_prob: 0.0, ..Default::default() };
        let img = generate(&m, &mut Rng::new(3), 2, &opts);
        let b = &img.boxes[0];
        // marker column 0 is bright
        let v = img.pixels[b.y * m.img_w + b.x];
        assert_eq!(v, 1.0);
        // background corner dark
        assert_eq!(img.pixels[0], 0.0);
        // inside-box ink columns >= box_ink
        let v2 = img.pixels[b.y * m.img_w + b.x + 4]; // marker cols 4..8 are ink
        assert_eq!(v2, m.box_ink);
    }

    #[test]
    fn flipped_box_is_reversed_pattern() {
        let Some(m) = meta() else { return };
        let opts = GenOptions { noise: 0.0, flip_prob: 1.0, ..Default::default() };
        let img = generate(&m, &mut Rng::new(9), 1, &opts);
        let b = &img.boxes[0];
        assert!(b.flipped);
        // last column of a flipped box = first column of upright = bright
        let v = img.pixels[b.y * m.img_w + b.x + b.width - 1];
        assert_eq!(v, 1.0);
    }

    #[test]
    fn crop_recovers_column_pattern() {
        let Some(m) = meta() else { return };
        let opts = GenOptions { noise: 0.0, flip_prob: 0.0, ..Default::default() };
        let img = generate(&m, &mut Rng::new(11), 1, &opts);
        let b = &img.boxes[0];
        let bucket = m.width_bucket(b.width).unwrap();
        let crop = crop_tensor(&img, &m, b.x, b.y, b.width, bucket, false);
        let data = crop.as_f32().unwrap();
        let pattern = column_pattern(&m, &b.text);
        for (j, &want) in pattern.iter().enumerate() {
            assert_eq!(data[j], want, "col {j}");
        }
        // padding is zero
        assert_eq!(data[bucket - 1], 0.0);
    }

    #[test]
    fn crop_rotate180_unflips() {
        let Some(m) = meta() else { return };
        let opts = GenOptions { noise: 0.0, flip_prob: 1.0, ..Default::default() };
        let img = generate(&m, &mut Rng::new(13), 1, &opts);
        let b = &img.boxes[0];
        let bucket = m.width_bucket(b.width).unwrap();
        let crop = crop_tensor(&img, &m, b.x, b.y, b.width, bucket, true);
        let data = crop.as_f32().unwrap();
        let pattern = column_pattern(&m, &b.text);
        for (j, &want) in pattern.iter().enumerate() {
            assert_eq!(data[j], want, "col {j}");
        }
    }

    #[test]
    fn too_many_boxes_truncated_not_overlapping() {
        let Some(m) = meta() else { return };
        let mut rng = Rng::new(5);
        let img = generate(&m, &mut rng, 50, &GenOptions::default());
        assert!(img.boxes.len() < 50);
        // pairwise disjoint (rows are disjoint by construction; check x in same row)
        for a in &img.boxes {
            for b in &img.boxes {
                if a != b && a.y == b.y {
                    assert!(a.x + a.width <= b.x || b.x + b.width <= a.x);
                }
            }
        }
    }
}

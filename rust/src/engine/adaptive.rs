//! `engine::adaptive` — the profile→scheduler feedback loop.
//!
//! The paper's §6 names "more dynamic thread allocation strategies" as
//! its first future-work item; §3.1 sketches profiling-derived part
//! weights. [`ProfileStore`](super::profile::ProfileStore) measures
//! per-model latency distributions — this module is the policy layer
//! that *consumes* them:
//!
//! - **Profiled core sizing.** [`AdaptivePolicy::part_weights`] weighs
//!   each job part by its measured cost (windowed p95 once enough fresh
//!   samples exist) instead of raw input size, so the Listing-1 split
//!   gives "cores according to expected computational cost" even when
//!   cost does not correlate with size. `Session`'s submit path consults
//!   it whenever the session runs in adaptive mode.
//! - **Adaptive aging bound.** [`AdaptivePolicy::aging_bound`] derives
//!   the backfill aging bound from the observed worst per-model p95
//!   part latency (`aging = aging_factor * p95`, clamped to
//!   `[min_aging, max_aging]`) instead of the static `--aging-ms`: on a
//!   fast workload the queue head waits less; on a slow one backfill
//!   keeps the cores busy longer before draining. The dispatcher
//!   recalibrates on a periodic tick (`recalibrate_every`).
//! - **Running-task deadlines.** The scheduler's dispatcher enforces
//!   `deadline_running` (`--deadline-running-ms`) over the in-flight
//!   table as a thin loop over each task's `CancelToken`: a running
//!   part past its deadline is cancelled cooperatively and its cores
//!   reclaimed through the normal completion path — the cancellation
//!   machinery turned from reactive (caller cancels) to proactive
//!   (scheduler enforces). See `engine::sched::DispatchState`.
//!
//! The policy is deliberately stateless beyond its profile store: every
//! decision is recomputed from the live distribution, so a workload
//! shift (or staleness decay) feeds back within one recalibration tick.

use std::sync::Arc;
use std::time::Duration;

use super::profile::ProfileStore;

/// Tuning for the adaptive policy layer. All durations are wall-clock.
#[derive(Debug, Clone, Copy)]
pub struct AdaptiveConfig {
    /// how often the dispatcher re-derives the aging bound from profiles
    pub recalibrate_every: Duration,
    /// aging bound = `aging_factor` * observed global p95 part latency
    pub aging_factor: f64,
    /// clamp floor for the derived aging bound
    pub min_aging: Duration,
    /// clamp ceiling for the derived aging bound
    pub max_aging: Duration,
}

impl Default for AdaptiveConfig {
    fn default() -> Self {
        AdaptiveConfig {
            recalibrate_every: Duration::from_millis(100),
            // One bypassed queue head may wait roughly two typical part
            // executions: one draining, one backfilled — the same "aging
            // + drain of running work" budget the static default models,
            // now sized from measurement instead of a constant.
            aging_factor: 2.0,
            min_aging: Duration::from_millis(5),
            max_aging: Duration::from_millis(1000),
        }
    }
}

/// Profile-driven scheduling policy shared by the session (core sizing)
/// and the scheduler's dispatcher (aging recalibration).
pub struct AdaptivePolicy {
    profiles: Arc<ProfileStore>,
    cfg: AdaptiveConfig,
}

impl AdaptivePolicy {
    pub fn new(profiles: Arc<ProfileStore>, cfg: AdaptiveConfig) -> AdaptivePolicy {
        assert!(cfg.aging_factor > 0.0, "aging_factor must be positive");
        assert!(cfg.min_aging <= cfg.max_aging, "aging clamp inverted");
        AdaptivePolicy { profiles, cfg }
    }

    pub fn config(&self) -> &AdaptiveConfig {
        &self.cfg
    }

    pub fn profiles(&self) -> &Arc<ProfileStore> {
        &self.profiles
    }

    /// Measured-cost relative weights for `(model, size)` parts: the
    /// profiled latency distribution where known (p95 once the window
    /// has enough fresh samples), size-proportional fallback otherwise.
    /// Feed the result to `allocate` via `PartWeights::Measured` — the
    /// Listing-1 budget
    /// invariants (every part >= 1 core, total == C when k <= C) hold
    /// for any weight vector, so adaptive sizing can never oversubscribe.
    pub fn part_weights(&self, parts: &[(&str, usize)]) -> Vec<f64> {
        self.profiles.weights(parts)
    }

    /// Backfill aging bound derived from the observed worst per-model
    /// p95 part latency; `fallback` (the static `--aging-ms`) until
    /// anything has been profiled.
    pub fn aging_bound(&self, fallback: Duration) -> Duration {
        match self.profiles.global_p95_ms() {
            None => fallback,
            Some(p95_ms) => {
                let derived = Duration::from_secs_f64(
                    (self.cfg.aging_factor * p95_ms / 1e3).max(0.0),
                );
                derived.clamp(self.cfg.min_aging, self.cfg.max_aging)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy(cfg: AdaptiveConfig) -> AdaptivePolicy {
        AdaptivePolicy::new(Arc::new(ProfileStore::new()), cfg)
    }

    #[test]
    fn aging_bound_falls_back_until_profiled() {
        let p = policy(AdaptiveConfig::default());
        let fallback = Duration::from_millis(50);
        assert_eq!(p.aging_bound(fallback), fallback);
    }

    #[test]
    fn aging_bound_scales_with_p95_and_clamps() {
        let cfg = AdaptiveConfig {
            aging_factor: 2.0,
            min_aging: Duration::from_millis(10),
            max_aging: Duration::from_millis(100),
            ..AdaptiveConfig::default()
        };
        let p = policy(cfg);
        let fallback = Duration::from_millis(50);
        // p95 ~ 20ms -> bound 40ms, inside the clamp
        for _ in 0..10 {
            p.profiles().observe("m", Duration::from_millis(20));
        }
        let b = p.aging_bound(fallback);
        assert!(
            (b.as_secs_f64() - 0.040).abs() < 0.005,
            "want ~40ms, got {b:?}"
        );
        // p95 ~ 400ms -> derived 800ms, clamped to 100ms
        for _ in 0..20 {
            p.profiles().observe("m", Duration::from_millis(400));
        }
        assert_eq!(p.aging_bound(fallback), Duration::from_millis(100));
    }

    #[test]
    fn aging_bound_clamps_from_below() {
        let cfg = AdaptiveConfig {
            aging_factor: 1.0,
            min_aging: Duration::from_millis(10),
            max_aging: Duration::from_millis(100),
            ..AdaptiveConfig::default()
        };
        let p = policy(cfg);
        for _ in 0..10 {
            p.profiles().observe("m", Duration::from_micros(100));
        }
        assert_eq!(p.aging_bound(Duration::from_millis(50)), Duration::from_millis(10));
    }

    #[test]
    fn part_weights_follow_measured_cost() {
        let p = policy(AdaptiveConfig::default());
        for _ in 0..10 {
            p.profiles().observe("heavy", Duration::from_millis(40));
            p.profiles().observe("light", Duration::from_millis(4));
        }
        // sizes say light is 16x bigger; measurement says heavy is 10x
        // costlier — the policy must side with the measurement
        let w = p.part_weights(&[("heavy", 16), ("light", 256)]);
        assert!((w[0] / w[1] - 10.0).abs() < 0.5, "{w:?}");
    }
}

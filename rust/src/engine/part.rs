//! Job parts: the unit `prun` divides work into.

use crate::runtime::Tensor;

use super::ctx::RequestCtx;

/// One independent piece of an inference job (paper §3.1's `j_i`): a
/// model to run and its inputs. The part's *size* — the total element
/// count of its input tensors — is what prun-def weighs by.
#[derive(Debug, Clone)]
pub struct JobPart {
    pub model: String,
    pub inputs: Vec<Tensor>,
    /// optional per-part request context: when this part answers its
    /// *own* serving request (one sequence of a dynamic batch), its
    /// request's ctx rides here and wins over the job-wide ctx passed
    /// to `submit` — batchmates with different arrival times get
    /// different budgets, tokens and priorities
    pub ctx: Option<RequestCtx>,
}

impl JobPart {
    pub fn new(model: impl Into<String>, inputs: Vec<Tensor>) -> JobPart {
        JobPart { model: model.into(), inputs, ctx: None }
    }

    /// Attach the [`RequestCtx`] of the request this part serves: the
    /// scheduler derives the part's token, budget, priority and cost
    /// hint from it, overriding the job-wide ctx.
    pub fn with_ctx(mut self, ctx: RequestCtx) -> JobPart {
        self.ctx = Some(ctx);
        self
    }

    /// Input-tensor size, the paper's default weight proxy (§3.1: weight
    /// set "proportionally to the size of input tensors").
    pub fn size(&self) -> usize {
        self.inputs.iter().map(|t| t.size()).sum()
    }
}

/// Extract the sizes vector for the allocator.
pub fn part_sizes(parts: &[JobPart]) -> Vec<usize> {
    parts.iter().map(|p| p.size()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn size_sums_inputs() {
        let p = JobPart::new(
            "m",
            vec![Tensor::zeros_f32(vec![2, 3]), Tensor::i32(vec![4], vec![0; 4])],
        );
        assert_eq!(p.size(), 10);
    }

    #[test]
    fn sizes_vector() {
        let parts = vec![
            JobPart::new("a", vec![Tensor::zeros_f32(vec![1, 16])]),
            JobPart::new("b", vec![Tensor::zeros_f32(vec![1, 64])]),
        ];
        assert_eq!(part_sizes(&parts), vec![16, 64]);
    }

    #[test]
    fn with_ctx_rides_on_the_part() {
        let ctx = RequestCtx::new();
        let p = JobPart::new("m", Vec::new()).with_ctx(ctx.clone());
        assert!(p.ctx.unwrap().token().same_flag(&ctx.token()));
    }
}

//! Job parts: the unit `prun` divides work into.

use crate::runtime::{CancelToken, Tensor};

use super::budget::Budget;

/// One independent piece of an inference job (paper §3.1's `j_i`): a
/// model to run and its inputs. The part's *size* — the total element
/// count of its input tensors — is what prun-def weighs by.
#[derive(Debug, Clone)]
pub struct JobPart {
    pub model: String,
    pub inputs: Vec<Tensor>,
    /// optional per-part cancellation token (e.g. the serving request
    /// this part answers); parts without one share the job's fate
    pub cancel: Option<CancelToken>,
    /// optional per-part request budget (the serving request's end-to-end
    /// deadline account); parts without one inherit the job's
    /// `PrunOptions::budget`, if any
    pub budget: Option<Budget>,
}

impl JobPart {
    pub fn new(model: impl Into<String>, inputs: Vec<Tensor>) -> JobPart {
        JobPart { model: model.into(), inputs, cancel: None, budget: None }
    }

    /// Attach the cancellation token of the request this part serves.
    pub fn with_cancel(mut self, token: CancelToken) -> JobPart {
        self.cancel = Some(token);
        self
    }

    /// Attach the request budget of the request this part serves: the
    /// scheduler derives both the part's admission rejection and its
    /// running kill clock from what remains of it.
    pub fn with_budget(mut self, budget: Budget) -> JobPart {
        self.budget = Some(budget);
        self
    }

    /// Input-tensor size, the paper's default weight proxy (§3.1: weight
    /// set "proportionally to the size of input tensors").
    pub fn size(&self) -> usize {
        self.inputs.iter().map(|t| t.size()).sum()
    }
}

/// Extract the sizes vector for the allocator.
pub fn part_sizes(parts: &[JobPart]) -> Vec<usize> {
    parts.iter().map(|p| p.size()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn size_sums_inputs() {
        let p = JobPart::new(
            "m",
            vec![Tensor::zeros_f32(vec![2, 3]), Tensor::i32(vec![4], vec![0; 4])],
        );
        assert_eq!(p.size(), 10);
    }

    #[test]
    fn sizes_vector() {
        let parts = vec![
            JobPart::new("a", vec![Tensor::zeros_f32(vec![1, 16])]),
            JobPart::new("b", vec![Tensor::zeros_f32(vec![1, 64])]),
        ];
        assert_eq!(part_sizes(&parts), vec![16, 64]);
    }
}

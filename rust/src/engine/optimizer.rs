//! Makespan-aware thread allocation — the "dynamic strategy" the paper
//! leaves as future work (§4.1: "the results in Figure 4 call for a
//! dynamic mechanism, which would choose the best thread allocation
//! strategy based on the given workload and available resources").
//!
//! Given each part's single-thread cost and scalability profile, greedily
//! assign cores by marginal benefit: every part starts at 1 thread; the
//! next core goes to the part whose completion time drops the most, and
//! never to a part already past its profile's optimum (where extra
//! threads *hurt* — the paper's negative-scaling phases). This subsumes
//! prun-1 (optimum=1 everywhere) and approaches prun-def when scaling is
//! uniform. Ablated against the paper's policies in
//! `benches/ablation_policies.rs`.

use crate::simcpu::profile::ScalProfile;

/// A part as seen by the optimizer.
#[derive(Debug, Clone, Copy)]
pub struct OptPart {
    pub t1_ms: f64,
    pub profile: ScalProfile,
}

/// Greedy marginal-benefit allocation of `cores` over `parts`.
/// Returns thread counts (>=1 each). Cores that no part can profit from
/// are left unassigned — unlike Listing 1, which always spends them.
pub fn allocate_optimal(parts: &[OptPart], cores: usize) -> Vec<usize> {
    assert!(cores >= 1);
    let k = parts.len();
    if k == 0 {
        return Vec::new();
    }
    let mut alloc = vec![1usize; k];
    if k >= cores {
        return alloc;
    }
    let mut budget = cores - k;
    let time = |i: usize, c: usize| parts[i].profile.time_ms(parts[i].t1_ms, c);
    while budget > 0 {
        // best (gain, index) for one more thread
        let mut best: Option<(f64, usize)> = None;
        for i in 0..k {
            let gain = time(i, alloc[i]) - time(i, alloc[i] + 1);
            if gain > 1e-12 && best.map(|(g, _)| gain > g).unwrap_or(true) {
                best = Some((gain, i));
            }
        }
        match best {
            Some((_, i)) => {
                alloc[i] += 1;
                budget -= 1;
            }
            None => break, // every part is at (or past) its optimum
        }
    }
    alloc
}

/// Expected makespan if all parts run concurrently (lower bound used by
/// the ablation; the DES gives the exact queued value).
pub fn expected_makespan_ms(parts: &[OptPart], alloc: &[usize]) -> f64 {
    parts
        .iter()
        .zip(alloc.iter())
        .map(|(p, &c)| p.profile.time_ms(p.t1_ms, c))
        .fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::allocator::{allocate, AllocPolicy, PartWeights};
    use crate::engine::ledger::CoreMap;
    use crate::simcpu::des::{simulate, SimPart};

    fn part(t1: f64, serial: f64, ovh: f64) -> OptPart {
        OptPart { t1_ms: t1, profile: ScalProfile::new(serial, ovh) }
    }

    #[test]
    fn single_scalable_part_gets_cores_up_to_optimum() {
        let p = part(160.0, 0.0, 0.0); // perfectly scalable
        assert_eq!(allocate_optimal(&[p], 16), vec![16]);
    }

    #[test]
    fn negative_scaling_part_stays_at_one() {
        // optimum at 1 thread: extra threads only hurt
        let p = part(5.0, 0.9, 2.0);
        assert_eq!(allocate_optimal(&[p], 16), vec![1]);
    }

    #[test]
    fn equal_parts_split_evenly() {
        let p = part(100.0, 0.1, 0.1);
        let alloc = allocate_optimal(&[p, p], 16);
        assert_eq!(alloc[0] + alloc[1], 16);
        assert!((alloc[0] as i64 - alloc[1] as i64).abs() <= 1);
    }

    #[test]
    fn bigger_part_gets_more() {
        let small = part(20.0, 0.1, 0.1);
        let big = part(200.0, 0.1, 0.1);
        let alloc = allocate_optimal(&[small, big], 16);
        assert!(alloc[1] > alloc[0], "{alloc:?}");
    }

    #[test]
    fn more_parts_than_cores_one_each() {
        let p = part(50.0, 0.0, 0.0);
        let alloc = allocate_optimal(&vec![p; 20], 16);
        assert!(alloc.iter().all(|&c| c == 1));
    }

    #[test]
    fn never_beyond_individual_optimum() {
        // a part whose optimum is ~4 threads must not get more even with
        // the whole machine free
        let p = part(80.0, 0.25, 2.5);
        let best = p.profile.optimal_threads(p.t1_ms, 16);
        let alloc = allocate_optimal(&[p], 16);
        assert_eq!(alloc[0], best);
    }

    #[test]
    fn optimal_never_worse_than_prun_def_in_sim() {
        // On the paper's negative-scaling rec phase, the dynamic policy
        // should dominate Listing 1 (which spends all cores blindly).
        let prof = ScalProfile::new(0.35, 6.5);
        for k in [2usize, 3, 5, 8] {
            let t1s: Vec<f64> = (0..k).map(|i| 30.0 + 12.0 * i as f64).collect();
            let parts: Vec<SimPart> = t1s.iter().map(|&t| SimPart::new(t, prof)).collect();
            let opt_parts: Vec<OptPart> =
                t1s.iter().map(|&t| OptPart { t1_ms: t, profile: prof }).collect();

            let sizes: Vec<usize> = t1s.iter().map(|&t| t as usize).collect();
            let def =
                allocate(PartWeights::Sizes(&sizes), &CoreMap::homogeneous(16), AllocPolicy::PrunDef)
                    .into_threads();
            let opt = allocate_optimal(&opt_parts, 16);

            let m_def = simulate(&parts, &def, 16).makespan_ms;
            let m_opt = simulate(&parts, &opt, 16).makespan_ms;
            assert!(
                m_opt <= m_def * 1.001,
                "k={k}: optimal {m_opt} worse than prun-def {m_def} ({opt:?} vs {def:?})"
            );
        }
    }

    #[test]
    fn expected_makespan_is_max() {
        let a = part(100.0, 0.0, 0.0);
        let b = part(50.0, 0.0, 0.0);
        let m = expected_makespan_ms(&[a, b], &[2, 2]);
        assert!((m - 50.0).abs() < 1e-9);
    }
}

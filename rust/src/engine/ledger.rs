//! `engine::ledger` — core classes for a heterogeneous machine.
//!
//! The paper's Listing-1 allocator and the sharded scheduler both used
//! to treat the virtual core budget C as C *identical* cores. Real
//! serving fleets are not identical: big/little mobile parts, mixed
//! instance generations, SMT siblings. Class-blind placement on such a
//! machine *inverts* latency — a small latency-critical part lands on
//! whatever core is free, which under load is a slow one, while batch
//! hogs squat on the fast ones (the mobile-processors measurement in
//! PAPERS.md, arxiv 2405.01851).
//!
//! This module is the vocabulary the rest of the engine schedules with:
//!
//! - [`CoreClass`] — the class of a core (`Fast` / `Slow`).
//! - [`CoreMap`] — how many cores of each class the machine has and
//!   their relative speed (`--cores fast=4,slow=12` on the CLI;
//!   [`CoreMap::homogeneous`] reproduces the old all-identical ledger
//!   and is the default everywhere, so existing baselines are
//!   unchanged).
//! - [`ClassAffinity`] — where a task *wants* to run. `Any` is
//!   deliberately class-blind (classes are tried in declaration order,
//!   fast first — exactly the inversion-prone behavior the bench gate's
//!   `hetero_inversion` scenario measures); `Prefer` tries its class
//!   first and *degrades* to the other instead of queueing forever —
//!   affinity is a preference, never a feasibility constraint.
//! - [`CoreGrant`] — what the scheduler actually hands a
//!   [`TaskRunner`](super::sched::TaskRunner): the thread count plus
//!   the class (and speed factor) those threads live on, so
//!   scaling-aware runners (simcpu, the bench mocks) can model the
//!   slowdown of a degraded placement.

use std::fmt;

use super::sched::Priority;

/// The class of a ledger core. Declaration order is the class-blind
/// placement order: [`ClassAffinity::Any`] fills `Fast` first.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CoreClass {
    Fast,
    Slow,
}

impl CoreClass {
    /// Number of core classes (array dimension for per-class state).
    pub const COUNT: usize = 2;

    /// Every class, in declaration (= class-blind placement) order.
    pub const ALL: [CoreClass; CoreClass::COUNT] = [CoreClass::Fast, CoreClass::Slow];

    /// Index into per-class arrays (`[usize; CoreClass::COUNT]`).
    pub fn index(self) -> usize {
        match self {
            CoreClass::Fast => 0,
            CoreClass::Slow => 1,
        }
    }

    /// The other class — the degradation target of a `Prefer`.
    pub fn other(self) -> CoreClass {
        match self {
            CoreClass::Fast => CoreClass::Slow,
            CoreClass::Slow => CoreClass::Fast,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            CoreClass::Fast => "fast",
            CoreClass::Slow => "slow",
        }
    }
}

impl fmt::Display for CoreClass {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Relative speed a `slow` core defaults to when the spec gives none
/// (`--cores fast=4,slow=12` means the 12 run at half speed).
const DEFAULT_SLOW_SPEED: f64 = 0.5;

/// The machine description the ledger schedules against: how many
/// cores of each class, and each class's relative speed (1.0 = the
/// fast reference; a 0.5-speed core takes twice the wall-clock for the
/// same work — `simcpu::ScalProfile::time_ms_at` models exactly that).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CoreMap {
    counts: [usize; CoreClass::COUNT],
    speeds: [f64; CoreClass::COUNT],
}

impl CoreMap {
    /// The classic all-identical ledger: `n` Fast cores at speed 1.0.
    /// This is the default everywhere, so a plain `--cores 16` keeps
    /// today's behavior and baselines bit-for-bit.
    pub fn homogeneous(n: usize) -> CoreMap {
        CoreMap { counts: [n, 0], speeds: [1.0, DEFAULT_SLOW_SPEED] }
    }

    /// A mixed machine: `fast` cores at speed 1.0 plus `slow` cores at
    /// the default half speed (override with [`with_speed`](Self::with_speed)).
    pub fn heterogeneous(fast: usize, slow: usize) -> CoreMap {
        CoreMap { counts: [fast, slow], speeds: [1.0, DEFAULT_SLOW_SPEED] }
    }

    /// Override one class's relative speed (must be > 0).
    pub fn with_speed(mut self, class: CoreClass, speed: f64) -> CoreMap {
        assert!(speed > 0.0, "class speed must be positive");
        self.speeds[class.index()] = speed;
        self
    }

    /// Parse the CLI/config syntax:
    ///
    /// - `"16"` — homogeneous, 16 fast cores (the old `--cores C`);
    /// - `"fast=4,slow=12"` — 4 fast + 12 half-speed slow cores;
    /// - `"fast=4,slow=12@0.25"` — an explicit relative speed after `@`.
    pub fn parse(s: &str) -> Result<CoreMap, String> {
        let s = s.trim();
        if let Ok(n) = s.parse::<usize>() {
            if n == 0 {
                return Err("core budget must be >= 1".to_string());
            }
            return Ok(CoreMap::homogeneous(n));
        }
        let mut map = CoreMap { counts: [0, 0], speeds: [1.0, DEFAULT_SLOW_SPEED] };
        for entry in s.split(',') {
            let entry = entry.trim();
            let (name, rest) = entry
                .split_once('=')
                .ok_or_else(|| format!("bad core-class entry '{entry}' (want class=count)"))?;
            let class = match name.trim() {
                "fast" => CoreClass::Fast,
                "slow" => CoreClass::Slow,
                other => return Err(format!("unknown core class '{other}'")),
            };
            let (count_s, speed_s) = match rest.split_once('@') {
                Some((c, sp)) => (c, Some(sp)),
                None => (rest, None),
            };
            let count: usize = count_s
                .trim()
                .parse()
                .map_err(|_| format!("bad core count '{count_s}' for class '{name}'"))?;
            map.counts[class.index()] = count;
            if let Some(sp) = speed_s {
                let speed: f64 = sp
                    .trim()
                    .parse()
                    .map_err(|_| format!("bad speed '{sp}' for class '{name}'"))?;
                if speed <= 0.0 {
                    return Err(format!("speed for class '{name}' must be > 0"));
                }
                map.speeds[class.index()] = speed;
            }
        }
        if map.total() == 0 {
            return Err("core map has zero cores".to_string());
        }
        Ok(map)
    }

    /// Total ledger cores across every class (the budget C the
    /// Listing-1 allocator divides).
    pub fn total(&self) -> usize {
        self.counts.iter().sum()
    }

    pub fn count(&self, class: CoreClass) -> usize {
        self.counts[class.index()]
    }

    /// Per-class counts, indexed by [`CoreClass::index`].
    pub fn counts(&self) -> [usize; CoreClass::COUNT] {
        self.counts
    }

    /// Relative speed of `class` (1.0 = fast reference).
    pub fn speed(&self, class: CoreClass) -> f64 {
        self.speeds[class.index()]
    }

    /// True when every core is in one class (the classic ledger; class
    /// affinity is then a no-op and placement is identical to PR 6).
    pub fn is_homogeneous(&self) -> bool {
        self.counts.iter().filter(|&&c| c > 0).count() <= 1
    }
}

impl Default for CoreMap {
    fn default() -> Self {
        CoreMap::homogeneous(16)
    }
}

impl fmt::Display for CoreMap {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.is_homogeneous() && self.count(CoreClass::Slow) == 0 {
            return write!(f, "{}", self.total());
        }
        let mut first = true;
        for class in CoreClass::ALL {
            if self.count(class) == 0 {
                continue;
            }
            if !first {
                write!(f, ",")?;
            }
            first = false;
            write!(f, "{}={}", class.name(), self.count(class))?;
            if (self.speed(class) - 1.0).abs() > f64::EPSILON {
                write!(f, "@{}", self.speed(class))?;
            }
        }
        Ok(())
    }
}

/// Where a task wants to run.
///
/// `Any` is class-*blind*: classes are tried in declaration order
/// (fast first), modelling a scheduler that doesn't know the machine is
/// mixed. `Prefer(c)` tries `c` first and **degrades** to the other
/// class when `c` has no room — a preference, never a hard constraint,
/// so affine work is delayed or slowed but never deadlocked or
/// rejected (property-tested in `tests/prop_sched.rs`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ClassAffinity {
    #[default]
    Any,
    Prefer(CoreClass),
}

impl ClassAffinity {
    /// The default affinity a request's priority implies: High work is
    /// latency-critical (prefer fast cores), Low work is throughput /
    /// backfill (prefer slow cores, keeping fast ones free), Normal
    /// work takes whatever is next — the class-blind order.
    pub fn from_priority(p: Priority) -> ClassAffinity {
        match p {
            Priority::High => ClassAffinity::Prefer(CoreClass::Fast),
            Priority::Low => ClassAffinity::Prefer(CoreClass::Slow),
            Priority::Normal => ClassAffinity::Any,
        }
    }

    /// The class order placement tries, most-preferred first.
    pub fn try_order(self) -> [CoreClass; CoreClass::COUNT] {
        match self {
            ClassAffinity::Any => CoreClass::ALL,
            ClassAffinity::Prefer(c) => [c, c.other()],
        }
    }
}

/// What an admitted task is actually granted: `threads` ledger entries,
/// all of one `class`, running at that class's relative `speed`.
/// Handed to [`TaskRunner::run_on`](super::sched::TaskRunner::run_on);
/// the PJRT executor ignores everything but the worker, while
/// scaling-aware runners divide their simulated execution time by
/// `speed` so a degraded placement is *measurably* slower.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CoreGrant {
    pub threads: usize,
    pub class: CoreClass,
    pub speed: f64,
}

impl CoreGrant {
    /// A grant on the homogeneous reference class (tests, mocks).
    pub fn fast(threads: usize) -> CoreGrant {
        CoreGrant { threads, class: CoreClass::Fast, speed: 1.0 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn homogeneous_map_is_all_fast() {
        let m = CoreMap::homogeneous(16);
        assert_eq!(m.total(), 16);
        assert_eq!(m.count(CoreClass::Fast), 16);
        assert_eq!(m.count(CoreClass::Slow), 0);
        assert!(m.is_homogeneous());
        assert_eq!(m.speed(CoreClass::Fast), 1.0);
        assert_eq!(m.to_string(), "16");
    }

    #[test]
    fn parse_plain_number_is_homogeneous() {
        assert_eq!(CoreMap::parse("16").unwrap(), CoreMap::homogeneous(16));
        assert_eq!(CoreMap::parse(" 4 ").unwrap(), CoreMap::homogeneous(4));
        assert!(CoreMap::parse("0").is_err());
    }

    #[test]
    fn parse_class_syntax() {
        let m = CoreMap::parse("fast=4,slow=12").unwrap();
        assert_eq!(m.count(CoreClass::Fast), 4);
        assert_eq!(m.count(CoreClass::Slow), 12);
        assert_eq!(m.total(), 16);
        assert!(!m.is_homogeneous());
        assert_eq!(m.speed(CoreClass::Slow), 0.5, "slow defaults to half speed");
        let m = CoreMap::parse("fast=2,slow=6@0.25").unwrap();
        assert_eq!(m.speed(CoreClass::Slow), 0.25);
        assert_eq!(m.to_string(), "fast=2,slow=6@0.25");
    }

    #[test]
    fn parse_rejects_nonsense() {
        assert!(CoreMap::parse("medium=4").is_err());
        assert!(CoreMap::parse("fast=x").is_err());
        assert!(CoreMap::parse("fast=0,slow=0").is_err());
        assert!(CoreMap::parse("fast=4,slow=2@-1").is_err());
        assert!(CoreMap::parse("fast4").is_err());
    }

    #[test]
    fn display_round_trips_through_parse() {
        for s in ["16", "fast=4,slow=12", "fast=2,slow=6@0.25"] {
            let m = CoreMap::parse(s).unwrap();
            assert_eq!(CoreMap::parse(&m.to_string()).unwrap(), m, "{s}");
        }
    }

    #[test]
    fn affinity_try_order() {
        assert_eq!(ClassAffinity::Any.try_order(), [CoreClass::Fast, CoreClass::Slow]);
        assert_eq!(
            ClassAffinity::Prefer(CoreClass::Slow).try_order(),
            [CoreClass::Slow, CoreClass::Fast]
        );
    }

    #[test]
    fn affinity_from_priority() {
        assert_eq!(
            ClassAffinity::from_priority(Priority::High),
            ClassAffinity::Prefer(CoreClass::Fast)
        );
        assert_eq!(
            ClassAffinity::from_priority(Priority::Low),
            ClassAffinity::Prefer(CoreClass::Slow)
        );
        assert_eq!(ClassAffinity::from_priority(Priority::Normal), ClassAffinity::Any);
    }

    #[test]
    fn grant_fast_reference() {
        let g = CoreGrant::fast(4);
        assert_eq!(g.threads, 4);
        assert_eq!(g.class, CoreClass::Fast);
        assert_eq!(g.speed, 1.0);
    }
}

//! Online latency profiling — the paper's first future-work item (§6):
//! "more dynamic thread allocation strategies, e.g. ones that can better
//! adjust to the cases where the weight of a work chunk does not
//! correlate linearly with its size".
//!
//! `ProfileStore` keeps, per model, both an EWMA of single-execution
//! latency *and* a bounded window of recent samples, observed from real
//! `ExecResult`s. The window yields a latency **distribution** (p50/p95,
//! sample counts) rather than a single point, which is what the adaptive
//! policy layer (`engine::adaptive`) consumes: tail-aware part weights
//! for the Listing-1 split, and an aging bound derived from observed p95
//! part latency. `PrunRequest::with_weights(WeightSource::Profiled)` weighs
//! job parts by their *measured* cost instead of raw input size (the
//! paper's §3.1 sketches exactly this: "assigning weight can be done
//! with the help of a profiling phase ... which associates job parts of
//! the same (or similar) shape to the relative weight obtained during
//! profiling").
//!
//! Staleness: window samples older than [`STALE_AFTER`] are pruned on
//! every observe/query, so a model whose behaviour shifted (recompiled,
//! different bucket mix) decays back to the EWMA estimate instead of
//! serving quantiles from another era.
//!
//! Locking: the store is shared across executor threads and the serving
//! edge. A panicking executor must not poison the mutex for everyone
//! else — all internal locking recovers from poison (the map is always
//! in a consistent state: every mutation is a single insert/update).

use std::collections::HashMap;
use std::collections::VecDeque;
use std::sync::{Mutex, MutexGuard};
use std::time::{Duration, Instant};

use crate::util::stats::percentile_sorted;
use crate::util::sync::lock_recover;

use super::ledger::{ClassAffinity, CoreClass};

/// EWMA smoothing factor: new = alpha*obs + (1-alpha)*old.
const ALPHA: f64 = 0.3;

/// Bounded per-model sample window for the latency distribution.
pub const WINDOW: usize = 128;

/// Window samples older than this are pruned (staleness decay); the
/// EWMA remains as the long-memory fallback.
pub const STALE_AFTER: Duration = Duration::from_secs(60);

/// Minimum window samples before quantiles are trusted over the EWMA
/// (a 1-sample "p95" is just that sample, and a noisy one at that).
pub const MIN_DISTRIBUTION_SAMPLES: usize = 5;

/// A model must measure at most this fraction of the worst profiled
/// model's p95 before [`ProfileStore::suggest_affinity`] steers it at
/// Fast cores — the gap has to be real, not sampling noise.
pub const FAST_AFFINITY_RATIO: f64 = 0.5;

/// ...and at least this fraction of the worst p95 to be steered at
/// Slow cores (the hogs that would otherwise squat on the Fast class).
pub const SLOW_AFFINITY_RATIO: f64 = 0.9;

/// Per-model profile: long-memory EWMA + recent-sample window.
struct ModelProfile {
    ewma_ms: f64,
    /// (observed-at, latency-ms), oldest first, len <= WINDOW
    window: VecDeque<(Instant, f64)>,
    samples_total: u64,
}

impl ModelProfile {
    fn new(ms: f64, now: Instant) -> ModelProfile {
        let mut window = VecDeque::with_capacity(WINDOW);
        window.push_back((now, ms));
        ModelProfile { ewma_ms: ms, window, samples_total: 1 }
    }

    fn observe(&mut self, ms: f64, now: Instant) {
        self.ewma_ms = ALPHA * ms + (1.0 - ALPHA) * self.ewma_ms;
        if self.window.len() == WINDOW {
            self.window.pop_front();
        }
        self.window.push_back((now, ms));
        self.samples_total += 1;
    }

    fn prune_stale(&mut self, now: Instant) {
        while let Some(&(t, _)) = self.window.front() {
            if now.duration_since(t) > STALE_AFTER {
                self.window.pop_front();
            } else {
                break;
            }
        }
    }

    /// Window samples, sorted ascending (one sort serves every quantile
    /// a caller needs — `stats` reads p50 and p95 from the same buffer).
    fn sorted_window(&self) -> Vec<f64> {
        let mut xs: Vec<f64> = self.window.iter().map(|&(_, ms)| ms).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        xs
    }

    fn stats(&self) -> ModelStats {
        let xs = self.sorted_window();
        let (p50_ms, p95_ms) = if xs.is_empty() {
            (self.ewma_ms, self.ewma_ms)
        } else {
            (percentile_sorted(&xs, 50.0), percentile_sorted(&xs, 95.0))
        };
        ModelStats {
            ewma_ms: self.ewma_ms,
            p50_ms,
            p95_ms,
            samples_window: self.window.len(),
            samples_total: self.samples_total,
        }
    }

    /// The cost estimate the allocator should weigh by: the windowed p95
    /// once the distribution has enough fresh samples (tail latency is
    /// what the Listing-1 split should budget for), the EWMA otherwise.
    fn cost_ms(&self) -> f64 {
        if self.window.len() >= MIN_DISTRIBUTION_SAMPLES {
            percentile_sorted(&self.sorted_window(), 95.0)
        } else {
            self.ewma_ms
        }
    }
}

/// Point-in-time view of one model's latency profile.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ModelStats {
    pub ewma_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    /// fresh (non-stale) samples currently in the window
    pub samples_window: usize,
    /// samples ever observed for this model
    pub samples_total: u64,
}

#[derive(Default)]
pub struct ProfileStore {
    models: Mutex<HashMap<String, ModelProfile>>,
}

impl ProfileStore {
    pub fn new() -> ProfileStore {
        ProfileStore::default()
    }

    /// Lock the model map, recovering from poison: a panicking executor
    /// thread must not take down every unrelated session that profiles
    /// through this store. Each mutation is a single insert/update, so
    /// the map is consistent even if a holder panicked mid-`observe`.
    fn guard(&self) -> MutexGuard<'_, HashMap<String, ModelProfile>> {
        lock_recover(&self.models)
    }

    /// Record an observed execution of `model`.
    pub fn observe(&self, model: &str, elapsed: Duration) {
        let ms = elapsed.as_secs_f64() * 1e3;
        let now = Instant::now();
        let mut map = self.guard();
        match map.get_mut(model) {
            Some(p) => {
                p.prune_stale(now);
                p.observe(ms, now);
            }
            None => {
                map.insert(model.to_string(), ModelProfile::new(ms, now));
            }
        }
    }

    /// Current EWMA latency estimate for `model`, if any.
    pub fn estimate_ms(&self, model: &str) -> Option<f64> {
        self.guard().get(model).map(|p| p.ewma_ms)
    }

    /// Windowed p95 latency for `model` (EWMA fallback while the fresh
    /// window is empty), if the model was ever observed.
    pub fn p95_ms(&self, model: &str) -> Option<f64> {
        let mut map = self.guard();
        let now = Instant::now();
        map.get_mut(model).map(|p| {
            p.prune_stale(now);
            p.stats().p95_ms
        })
    }

    /// Full distribution snapshot for `model`, if ever observed.
    pub fn stats(&self, model: &str) -> Option<ModelStats> {
        let mut map = self.guard();
        let now = Instant::now();
        map.get_mut(model).map(|p| {
            p.prune_stale(now);
            p.stats()
        })
    }

    /// Profiled cost the scheduler may trust for budget-aware
    /// admission: the windowed p95 of `model`, but only once the fresh
    /// window holds [`MIN_DISTRIBUTION_SAMPLES`] — rejecting requests
    /// up front on a 1-sample "p95" (or the cold EWMA) would refuse
    /// serveable traffic on noise. `None` means "no trusted estimate:
    /// admit and let the budget sweep police it".
    pub fn trusted_cost(&self, model: &str) -> Option<Duration> {
        let mut map = self.guard();
        let now = Instant::now();
        let p = map.get_mut(model)?;
        p.prune_stale(now);
        if p.window.len() < MIN_DISTRIBUTION_SAMPLES {
            return None;
        }
        Some(Duration::from_secs_f64(p.stats().p95_ms.max(0.0) / 1e3))
    }

    /// Worst per-model windowed p95 across the models with *fresh*
    /// (non-stale) samples — the "how long can one part plausibly run"
    /// figure the adaptive aging bound is derived from. `None` until
    /// something fresh exists. Deliberately NOT the per-model EWMA
    /// fallback: a slow model that went idle must stop holding the
    /// aging bound up once its window decays (the bound then returns
    /// to the static default until live traffic re-profiles it).
    pub fn global_p95_ms(&self) -> Option<f64> {
        let mut map = self.guard();
        let now = Instant::now();
        map.values_mut()
            .filter_map(|p| {
                p.prune_stale(now);
                if p.window.is_empty() {
                    None
                } else {
                    Some(p.stats().p95_ms)
                }
            })
            .fold(None, |acc, x| Some(acc.map_or(x, |a: f64| a.max(x))))
    }

    /// Where should `model`'s parts run on a heterogeneous machine?
    /// Profile-derived class affinity (the `engine::ledger` counterpart
    /// of the cost weights above): a model measuring well below the
    /// worst profiled p95 is the latency-critical kind that belongs on
    /// Fast cores; one at (or near) the worst p95 is a hog that should
    /// keep off them. Needs a *trusted* distribution for `model` and at
    /// least one other freshly-profiled model to compare against —
    /// anything less is [`ClassAffinity::Any`], never a hard steer.
    pub fn suggest_affinity(&self, model: &str) -> ClassAffinity {
        let Some(cost) = self.trusted_cost(model) else {
            return ClassAffinity::Any;
        };
        let mut map = self.guard();
        let now = Instant::now();
        let fresh: Vec<f64> = map
            .values_mut()
            .filter_map(|p| {
                p.prune_stale(now);
                if p.window.is_empty() { None } else { Some(p.stats().p95_ms) }
            })
            .collect();
        if fresh.len() < 2 {
            // a lone profiled model has nothing to be fast or slow
            // *relative to* — steering on absolutes would misplace
            // every single-model workload
            return ClassAffinity::Any;
        }
        let worst = fresh.iter().fold(0.0f64, |a, &x| a.max(x));
        let ms = cost.as_secs_f64() * 1e3;
        if ms <= FAST_AFFINITY_RATIO * worst {
            ClassAffinity::Prefer(CoreClass::Fast)
        } else if ms >= SLOW_AFFINITY_RATIO * worst {
            ClassAffinity::Prefer(CoreClass::Slow)
        } else {
            ClassAffinity::Any
        }
    }

    pub fn len(&self) -> usize {
        self.guard().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Relative weights for a list of (model, size) parts: measured cost
    /// (windowed p95 once [`MIN_DISTRIBUTION_SAMPLES`] fresh samples
    /// exist, EWMA before that) where known, falling back to input size
    /// for unprofiled models (scaled into the same ballpark via the mean
    /// ms/size ratio of the profiled parts, so mixed batches stay sane).
    pub fn weights(&self, parts: &[(&str, usize)]) -> Vec<f64> {
        let mut map = self.guard();
        // One cost computation per *distinct* model: staleness applies
        // to sizing like every other query path (a model idle past
        // STALE_AFTER must not be weighed by its old-era distribution),
        // and the window sort inside cost_ms runs once per model even
        // when a job repeats the same model across many parts.
        let now = Instant::now();
        let mut cost_cache: HashMap<&str, Option<f64>> =
            HashMap::with_capacity(parts.len());
        let costs: Vec<Option<f64>> = parts
            .iter()
            .map(|(m, _)| {
                *cost_cache.entry(*m).or_insert_with(|| {
                    map.get_mut(*m).map(|p| {
                        p.prune_stale(now);
                        p.cost_ms()
                    })
                })
            })
            .collect();
        // ms per size unit among profiled parts (1.0 if none profiled)
        let known: Vec<(f64, usize)> = parts
            .iter()
            .zip(costs.iter().copied())
            .filter_map(|((_, s), c)| c.map(|ms| (ms, *s)))
            .collect();
        let ratio = if known.is_empty() {
            1.0
        } else {
            let (ms_sum, sz_sum) = known
                .iter()
                .fold((0.0, 0usize), |(a, b), (ms, s)| (a + ms, b + s));
            ms_sum / (sz_sum.max(1) as f64)
        };
        let raw: Vec<f64> = parts
            .iter()
            .zip(costs.iter().copied())
            .map(|((_, s), c)| c.unwrap_or(ratio * *s as f64).max(1e-9))
            .collect();
        let total: f64 = raw.iter().sum();
        raw.into_iter().map(|w| w / total).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn ewma_converges_to_observations() {
        let p = ProfileStore::new();
        for _ in 0..50 {
            p.observe("m", Duration::from_millis(100));
        }
        let est = p.estimate_ms("m").unwrap();
        assert!((est - 100.0).abs() < 1.0, "{est}");
    }

    #[test]
    fn ewma_tracks_shift() {
        let p = ProfileStore::new();
        p.observe("m", Duration::from_millis(10));
        for _ in 0..30 {
            p.observe("m", Duration::from_millis(50));
        }
        let est = p.estimate_ms("m").unwrap();
        assert!((est - 50.0).abs() < 1.0, "{est}");
    }

    #[test]
    fn unknown_model_none() {
        let p = ProfileStore::new();
        assert!(p.estimate_ms("nope").is_none());
        assert!(p.p95_ms("nope").is_none());
        assert!(p.stats("nope").is_none());
        assert!(p.global_p95_ms().is_none());
        assert!(p.is_empty());
    }

    #[test]
    fn window_quantiles_reflect_distribution() {
        let p = ProfileStore::new();
        // 19 fast + 1 slow: p50 stays at the fast mode, p95 sees the tail
        for _ in 0..19 {
            p.observe("m", Duration::from_millis(10));
        }
        p.observe("m", Duration::from_millis(100));
        let st = p.stats("m").unwrap();
        assert_eq!(st.samples_window, 20);
        assert_eq!(st.samples_total, 20);
        assert!(st.p50_ms < 15.0, "{st:?}");
        assert!(st.p95_ms > 50.0, "{st:?}");
        assert!(st.p95_ms <= 100.0, "{st:?}");
    }

    #[test]
    fn window_is_bounded() {
        let p = ProfileStore::new();
        for _ in 0..(WINDOW + 50) {
            p.observe("m", Duration::from_millis(5));
        }
        let st = p.stats("m").unwrap();
        assert_eq!(st.samples_window, WINDOW);
        assert_eq!(st.samples_total, (WINDOW + 50) as u64);
    }

    #[test]
    fn global_p95_is_worst_model() {
        let p = ProfileStore::new();
        for _ in 0..MIN_DISTRIBUTION_SAMPLES {
            p.observe("fast", Duration::from_millis(5));
            p.observe("slow", Duration::from_millis(80));
        }
        let g = p.global_p95_ms().unwrap();
        assert!((g - 80.0).abs() < 1.0, "{g}");
    }

    #[test]
    fn weights_use_profiles_over_sizes() {
        // Two models with equal input sizes but 4x different measured
        // cost: profiled weights must reflect the cost, not the size.
        let p = ProfileStore::new();
        p.observe("cheap", Duration::from_millis(10));
        p.observe("dear", Duration::from_millis(40));
        let w = p.weights(&[("cheap", 100), ("dear", 100)]);
        assert!((w[1] / w[0] - 4.0).abs() < 1e-6, "{w:?}");
        assert!((w.iter().sum::<f64>() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn weights_become_tail_aware_with_enough_samples() {
        // Same median, very different tails: once the window has enough
        // samples the p95-based weights favour the tail-heavy model.
        let p = ProfileStore::new();
        for i in 0..20 {
            p.observe("steady", Duration::from_millis(10));
            // every 4th observation of "spiky" is a 90ms tail
            let ms = if i % 4 == 0 { 90 } else { 10 };
            p.observe("spiky", Duration::from_millis(ms));
        }
        let w = p.weights(&[("steady", 100), ("spiky", 100)]);
        assert!(
            w[1] > 2.0 * w[0],
            "tail-heavy model must out-weigh the steady one: {w:?}"
        );
    }

    #[test]
    fn unprofiled_fallback_scaled_by_ratio() {
        let p = ProfileStore::new();
        p.observe("a", Duration::from_millis(100)); // size 100 -> 1 ms/unit
        let w = p.weights(&[("a", 100), ("unseen", 50)]);
        // unseen gets 50 * 1.0 ms/unit = 50 -> weights 100:50
        assert!((w[0] / w[1] - 2.0).abs() < 1e-6, "{w:?}");
    }

    #[test]
    fn all_unprofiled_degenerates_to_sizes() {
        let p = ProfileStore::new();
        let w = p.weights(&[("x", 30), ("y", 10)]);
        assert!((w[0] / w[1] - 3.0).abs() < 1e-6, "{w:?}");
    }

    #[test]
    fn lock_poison_recovers() {
        // Regression: a panicking thread holding the profile mutex used
        // to poison it permanently — every later observe/estimate from
        // unrelated sessions then panicked on `.unwrap()`. The store
        // must shrug the poison off and keep serving.
        let p = Arc::new(ProfileStore::new());
        p.observe("m", Duration::from_millis(10));
        let p2 = Arc::clone(&p);
        let res = std::thread::spawn(move || {
            let _g = p2.models.lock().unwrap();
            panic!("poison the profile mutex");
        })
        .join();
        assert!(res.is_err(), "the poisoning thread must have panicked");
        p.observe("m", Duration::from_millis(20)); // must not panic
        assert!(p.estimate_ms("m").is_some());
        assert!(p.p95_ms("m").is_some());
        assert_eq!(p.stats("m").unwrap().samples_total, 2);
        let _ = p.weights(&[("m", 10)]);
    }

    #[test]
    fn affinity_suggestion_separates_hogs_from_latency_work() {
        let p = ProfileStore::new();
        assert_eq!(p.suggest_affinity("nope"), ClassAffinity::Any);
        for _ in 0..MIN_DISTRIBUTION_SAMPLES {
            p.observe("tiny", Duration::from_millis(5));
        }
        assert_eq!(
            p.suggest_affinity("tiny"),
            ClassAffinity::Any,
            "a lone profiled model has no relative standing"
        );
        for _ in 0..MIN_DISTRIBUTION_SAMPLES {
            p.observe("hog", Duration::from_millis(80));
            p.observe("mid", Duration::from_millis(50));
        }
        assert_eq!(p.suggest_affinity("tiny"), ClassAffinity::Prefer(CoreClass::Fast));
        assert_eq!(p.suggest_affinity("hog"), ClassAffinity::Prefer(CoreClass::Slow));
        assert_eq!(p.suggest_affinity("mid"), ClassAffinity::Any, "middle of the pack stays class-blind");
    }

    #[test]
    fn trusted_cost_requires_a_full_distribution() {
        let p = ProfileStore::new();
        assert_eq!(p.trusted_cost("m"), None, "unprofiled -> no estimate");
        for _ in 0..MIN_DISTRIBUTION_SAMPLES - 1 {
            p.observe("m", Duration::from_millis(40));
        }
        assert_eq!(
            p.trusted_cost("m"),
            None,
            "a thin window must not drive admission rejections"
        );
        p.observe("m", Duration::from_millis(40));
        let cost = p.trusted_cost("m").expect("full window -> trusted p95");
        assert!(
            (cost.as_secs_f64() * 1e3 - 40.0).abs() < 1.0,
            "p95 of a constant stream is that constant: {cost:?}"
        );
    }
}

//! Online latency profiling — the paper's first future-work item (§6):
//! "more dynamic thread allocation strategies, e.g. ones that can better
//! adjust to the cases where the weight of a work chunk does not
//! correlate linearly with its size".
//!
//! `ProfileStore` keeps an EWMA of per-model single-execution latency,
//! observed from real `ExecResult`s. `PrunOptions::weights =
//! WeightSource::Profiled` then weighs job parts by their *measured*
//! cost instead of raw input size (the paper's §3.1 sketches exactly
//! this: "assigning weight can be done with the help of a profiling
//! phase ... which associates job parts of the same (or similar) shape
//! to the relative weight obtained during profiling").

use std::collections::HashMap;
use std::sync::Mutex;
use std::time::Duration;

/// EWMA smoothing factor: new = alpha*obs + (1-alpha)*old.
const ALPHA: f64 = 0.3;

#[derive(Default)]
pub struct ProfileStore {
    ewma_ms: Mutex<HashMap<String, f64>>,
}

impl ProfileStore {
    pub fn new() -> ProfileStore {
        ProfileStore::default()
    }

    /// Record an observed execution of `model`.
    pub fn observe(&self, model: &str, elapsed: Duration) {
        let ms = elapsed.as_secs_f64() * 1e3;
        let mut map = self.ewma_ms.lock().unwrap();
        map.entry(model.to_string())
            .and_modify(|v| *v = ALPHA * ms + (1.0 - ALPHA) * *v)
            .or_insert(ms);
    }

    /// Current latency estimate for `model`, if any.
    pub fn estimate_ms(&self, model: &str) -> Option<f64> {
        self.ewma_ms.lock().unwrap().get(model).copied()
    }

    pub fn len(&self) -> usize {
        self.ewma_ms.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Relative weights for a list of (model, size) parts: profiled
    /// latency where known, falling back to input size for unprofiled
    /// models (scaled into the same ballpark via the mean ms/size ratio
    /// of the profiled parts, so mixed batches stay sane).
    pub fn weights(&self, parts: &[(&str, usize)]) -> Vec<f64> {
        let map = self.ewma_ms.lock().unwrap();
        let known: Vec<(f64, usize)> = parts
            .iter()
            .filter_map(|(m, s)| map.get(*m).map(|&ms| (ms, *s)))
            .collect();
        // ms per size unit among profiled parts (1.0 if none profiled)
        let ratio = if known.is_empty() {
            1.0
        } else {
            let (ms_sum, sz_sum) = known
                .iter()
                .fold((0.0, 0usize), |(a, b), (ms, s)| (a + ms, b + s));
            ms_sum / (sz_sum.max(1) as f64)
        };
        let raw: Vec<f64> = parts
            .iter()
            .map(|(m, s)| map.get(*m).copied().unwrap_or(ratio * *s as f64).max(1e-9))
            .collect();
        let total: f64 = raw.iter().sum();
        raw.into_iter().map(|w| w / total).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ewma_converges_to_observations() {
        let p = ProfileStore::new();
        for _ in 0..50 {
            p.observe("m", Duration::from_millis(100));
        }
        let est = p.estimate_ms("m").unwrap();
        assert!((est - 100.0).abs() < 1.0, "{est}");
    }

    #[test]
    fn ewma_tracks_shift() {
        let p = ProfileStore::new();
        p.observe("m", Duration::from_millis(10));
        for _ in 0..30 {
            p.observe("m", Duration::from_millis(50));
        }
        let est = p.estimate_ms("m").unwrap();
        assert!((est - 50.0).abs() < 1.0, "{est}");
    }

    #[test]
    fn unknown_model_none() {
        let p = ProfileStore::new();
        assert!(p.estimate_ms("nope").is_none());
        assert!(p.is_empty());
    }

    #[test]
    fn weights_use_profiles_over_sizes() {
        // Two models with equal input sizes but 4x different measured
        // cost: profiled weights must reflect the cost, not the size.
        let p = ProfileStore::new();
        p.observe("cheap", Duration::from_millis(10));
        p.observe("dear", Duration::from_millis(40));
        let w = p.weights(&[("cheap", 100), ("dear", 100)]);
        assert!((w[1] / w[0] - 4.0).abs() < 1e-6, "{w:?}");
        assert!((w.iter().sum::<f64>() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn unprofiled_fallback_scaled_by_ratio() {
        let p = ProfileStore::new();
        p.observe("a", Duration::from_millis(100)); // size 100 -> 1 ms/unit
        let w = p.weights(&[("a", 100), ("unseen", 50)]);
        // unseen gets 50 * 1.0 ms/unit = 50 -> weights 100:50
        assert!((w[0] / w[1] - 2.0).abs() < 1e-6, "{w:?}");
    }

    #[test]
    fn all_unprofiled_degenerates_to_sizes() {
        let p = ProfileStore::new();
        let w = p.weights(&[("x", 30), ("y", 10)]);
        assert!((w[0] / w[1] - 3.0).abs() < 1e-6, "{w:?}");
    }
}

//! `RequestCtx` — the one per-request context every layer consumes.
//!
//! PRs 1–4 grew budgets, cancellation, priorities and profile feedback
//! by adding a *new argument* (and usually a new method variant) at
//! every layer, so the cross-cutting state of one serving request — how
//! many cores it may take, for how long, at what priority, under whose
//! cancellation flag — was smeared across parallel parameter lists:
//! `(Vec<i32>, CancelToken, Budget)` tuples in the batcher,
//! `PrunOptions { priority, budget, .. }` in the engine, bare
//! `(&CancelToken, Option<Budget>)` pairs in the OCR pipeline.
//!
//! A [`RequestCtx`] collapses that into a single value **minted once at
//! the ingress** (router, CLI, bench harness) and threaded *by value*
//! through every layer: the batcher's flush-time admission reads
//! `ctx.expired()` / `ctx.is_cancelled()`, the scheduler consumes the
//! same fields via [`PartTask::with_ctx`](super::sched::PartTask::with_ctx),
//! and the running kill clock arms off the same [`Budget`] the client's
//! connection thread is waiting out. Cloning a ctx shares the token
//! (and copies the budget), so *identity* is preserved across layers —
//! cancelling at any one of them frees the request's cores exactly
//! once, through the scheduler's normal completion accounting.
//!
//! The ctx also carries an optional **cost hint** (the profiled p95 of
//! the work the request is about to do). When present alongside a
//! budget, the scheduler rejects the request at *submit* if the budget
//! cannot cover the hint (`SchedError::BudgetInfeasible`) — admission
//! control before any queueing, the ROADMAP's "budget-aware admission"
//! item. When the ingress has no hint, `Session` fills one per part
//! from its online profile store.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use crate::runtime::CancelToken;

use super::budget::Budget;
use super::ledger::ClassAffinity;
use super::sched::Priority;

/// Monotonic request-id mint, shared by every ingress in the process.
static NEXT_REQUEST_ID: AtomicU64 = AtomicU64::new(1);

/// Per-request context: one identity (`id`, `CancelToken`), one
/// deadline account ([`Budget`]), one queue [`Priority`] and an
/// optional profiled cost hint — minted at the serving edge, consumed
/// by every layer below. Cloning shares the cancellation flag, so all
/// copies describe the *same* request.
///
/// ```
/// use std::time::Duration;
/// use dnc_serve::engine::{Budget, Priority, RequestCtx};
///
/// // The router mints one ctx per arriving request:
/// let ctx = RequestCtx::new()
///     .with_budget(Budget::new(Duration::from_millis(500)))
///     .with_priority(Priority::High);
/// assert!(!ctx.is_cancelled() && !ctx.expired());
///
/// // every layer sees the same token identity
/// let downstream = ctx.clone();
/// ctx.cancel();
/// assert!(downstream.is_cancelled());
/// ```
#[derive(Debug, Clone)]
pub struct RequestCtx {
    id: u64,
    cancel: CancelToken,
    budget: Option<Budget>,
    priority: Priority,
    cost_hint: Option<Duration>,
    /// explicit class affinity; `None` derives from `priority` (see
    /// [`affinity`](Self::affinity))
    affinity: Option<ClassAffinity>,
}

impl RequestCtx {
    /// Mint a fresh context: new id, new cancellation token, no budget,
    /// [`Priority::Normal`]. Call this where a request *enters* the
    /// system — router, CLI, bench harness — not where it happens to be
    /// scheduled, so upstream wall-clock is charged to the right clock.
    pub fn new() -> RequestCtx {
        RequestCtx {
            id: NEXT_REQUEST_ID.fetch_add(1, Ordering::Relaxed),
            cancel: CancelToken::new(),
            budget: None,
            priority: Priority::Normal,
            cost_hint: None,
            affinity: None,
        }
    }

    /// Attach the request's end-to-end deadline account.
    pub fn with_budget(mut self, budget: Budget) -> RequestCtx {
        self.budget = Some(budget);
        self
    }

    /// Mint and attach a budget of `total` starting now — shorthand for
    /// `with_budget(Budget::new(total))` at the ingress.
    pub fn with_timeout(self, total: Duration) -> RequestCtx {
        self.with_budget(Budget::new(total))
    }

    /// Replace the cancellation token (e.g. adopt one owned by an
    /// enclosing request instead of this ctx's fresh one).
    pub fn with_cancel(mut self, token: CancelToken) -> RequestCtx {
        self.cancel = token;
        self
    }

    /// Set the queue priority every part of this request submits at.
    pub fn with_priority(mut self, priority: Priority) -> RequestCtx {
        self.priority = priority;
        self
    }

    /// Attach a profiled cost hint (expected p95 execution time of the
    /// work this request is about to submit). With a budget attached,
    /// the scheduler uses it for budget-aware admission: a request
    /// whose remaining budget cannot cover the hint is rejected at
    /// submit (`SchedError::BudgetInfeasible`) before taking queue
    /// space, let alone cores.
    pub fn with_cost_hint(mut self, hint: Duration) -> RequestCtx {
        self.cost_hint = Some(hint);
        self
    }

    /// Pin this request's work to a core-class preference on a
    /// heterogeneous machine (see `engine::ledger`), overriding the
    /// priority-derived default: latency-critical ingresses ask for
    /// `Prefer(Fast)`, bulk/backfill ones for `Prefer(Slow)`.
    pub fn with_affinity(mut self, affinity: ClassAffinity) -> RequestCtx {
        self.affinity = Some(affinity);
        self
    }

    /// The class affinity this request's parts submit with: the
    /// explicit [`with_affinity`](Self::with_affinity) choice, or the
    /// one the priority implies — High is latency-critical and prefers
    /// Fast cores, Low is throughput work that prefers Slow ones,
    /// Normal is class-blind ([`ClassAffinity::from_priority`]). On a
    /// homogeneous map this is inert either way.
    pub fn affinity(&self) -> ClassAffinity {
        self.affinity.unwrap_or_else(|| ClassAffinity::from_priority(self.priority))
    }

    /// The request id minted at ingress (diagnostics / log correlation).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// A clone of the request's cancellation token (shares the flag).
    pub fn token(&self) -> CancelToken {
        self.cancel.clone()
    }

    pub fn budget(&self) -> Option<Budget> {
        self.budget
    }

    pub fn priority(&self) -> Priority {
        self.priority
    }

    pub fn cost_hint(&self) -> Option<Duration> {
        self.cost_hint
    }

    /// Cancel the request: every layer holding a clone of this ctx (or
    /// its token) observes the flag at its next poll.
    pub fn cancel(&self) {
        self.cancel.cancel();
    }

    pub fn is_cancelled(&self) -> bool {
        self.cancel.is_cancelled()
    }

    /// True once the attached budget has run out (false without one).
    pub fn expired(&self) -> bool {
        self.budget.is_some_and(|b| b.expired())
    }

    /// What remains of the attached budget (`None` = no budget, i.e.
    /// unbounded patience).
    pub fn remaining(&self) -> Option<Duration> {
        self.budget.map(|b| b.remaining())
    }
}

impl Default for RequestCtx {
    fn default() -> Self {
        RequestCtx::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fresh_ctx_has_identity_and_no_budget() {
        let a = RequestCtx::new();
        let b = RequestCtx::new();
        assert_ne!(a.id(), b.id(), "each mint gets its own id");
        assert!(!a.token().same_flag(&b.token()), "each mint gets its own token");
        assert!(a.budget().is_none());
        assert!(!a.expired());
        assert_eq!(a.remaining(), None);
        assert_eq!(a.priority(), Priority::Normal);
    }

    #[test]
    fn clones_share_the_request_identity() {
        let ctx = RequestCtx::new().with_timeout(Duration::from_secs(5));
        let layer_below = ctx.clone();
        assert_eq!(ctx.id(), layer_below.id());
        assert!(ctx.token().same_flag(&layer_below.token()));
        assert_eq!(ctx.budget(), layer_below.budget(), "budget copies share the clock");
        layer_below.cancel();
        assert!(ctx.is_cancelled(), "cancel at any layer is cancel everywhere");
    }

    #[test]
    fn expiry_follows_the_attached_budget() {
        let ctx = RequestCtx::new().with_timeout(Duration::ZERO);
        assert!(ctx.expired());
        assert_eq!(ctx.remaining(), Some(Duration::ZERO));
        let fresh = RequestCtx::new().with_timeout(Duration::from_secs(10));
        assert!(!fresh.expired());
        assert!(fresh.remaining().unwrap() > Duration::from_secs(9));
    }

    #[test]
    fn builders_compose() {
        let token = CancelToken::new();
        let ctx = RequestCtx::new()
            .with_cancel(token.clone())
            .with_priority(Priority::High)
            .with_cost_hint(Duration::from_millis(40))
            .with_timeout(Duration::from_millis(100));
        assert!(ctx.token().same_flag(&token));
        assert_eq!(ctx.priority(), Priority::High);
        assert_eq!(ctx.cost_hint(), Some(Duration::from_millis(40)));
        assert!(ctx.budget().is_some());
    }

    #[test]
    fn affinity_derives_from_priority_until_set_explicitly() {
        use crate::engine::ledger::CoreClass;
        let hi = RequestCtx::new().with_priority(Priority::High);
        assert_eq!(hi.affinity(), ClassAffinity::Prefer(CoreClass::Fast));
        let lo = RequestCtx::new().with_priority(Priority::Low);
        assert_eq!(lo.affinity(), ClassAffinity::Prefer(CoreClass::Slow));
        assert_eq!(RequestCtx::new().affinity(), ClassAffinity::Any);
        // an explicit choice overrides the derivation
        let pinned = RequestCtx::new()
            .with_priority(Priority::High)
            .with_affinity(ClassAffinity::Prefer(CoreClass::Slow));
        assert_eq!(pinned.affinity(), ClassAffinity::Prefer(CoreClass::Slow));
    }
}

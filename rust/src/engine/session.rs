//! The `prun` inference session — the paper's extended API (§3.2).
//!
//! `Session::run` mirrors OnnxRuntime's `InferenceSession.run`;
//! `Session::prun` accepts a *list* of job parts, sizes a private worker
//! allocation for each via [`allocator`](super::allocator), runs them in
//! parallel (one coordinator thread per part, exactly like the paper's
//! implementation creates one worker thread per input), and returns the
//! outputs in input order.
//!
//! Core accounting: a part allocated `c_i` threads holds `c_i` leases
//! from the session's [`CoreLease`] while it executes, so concurrent
//! parts never oversubscribe the machine, and an allocation with
//! `Σc_i > C` degrades to the paper's "run some parts after others".
//!
//! On this testbed the PJRT CPU executable is single-threaded, so `c_i`
//! does not change a *real* part's execution speed — the lease models
//! occupancy only; the calibrated simulator (crate::simcpu) models the
//! intra-op scaling the paper measured on its 16-core VM (DESIGN.md §4).

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::runtime::{ExecutorPool, Manifest, Tensor};

use super::allocator::{allocate_weighted, weights, AllocPolicy};
use super::lease::CoreLease;
use super::part::{part_sizes, JobPart};
use super::profile::ProfileStore;

/// Where part weights come from (paper §3.1: size by default; §6 future
/// work: measured-latency profiles — implemented in engine::profile).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum WeightSource {
    #[default]
    Size,
    Profiled,
}

#[derive(Debug, Clone, Copy, Default)]
pub struct PrunOptions {
    pub policy: AllocPolicy,
    pub weights: WeightSource,
}

impl Default for AllocPolicy {
    fn default() -> Self {
        AllocPolicy::PrunDef
    }
}

/// Per-part timing report.
#[derive(Debug, Clone)]
pub struct PartReport {
    pub threads: usize,
    /// time from prun start until the part acquired its leases
    pub queue: Duration,
    /// pure execute time inside the worker
    pub exec: Duration,
}

/// Result of a `prun` call.
#[derive(Debug)]
pub struct PrunOutcome {
    /// per-part model outputs, input order
    pub outputs: Vec<Vec<Tensor>>,
    pub reports: Vec<PartReport>,
    pub allocation: Vec<usize>,
    pub wall: Duration,
}

pub struct Session {
    pool: Arc<ExecutorPool>,
    lease: CoreLease,
    cores: usize,
    manifest: Arc<Manifest>,
    profiles: ProfileStore,
}

impl Session {
    /// `cores` is the virtual core budget C the allocator divides;
    /// `workers` is the number of real executor threads (usually = the
    /// machine's available parallelism).
    pub fn new(manifest: Arc<Manifest>, cores: usize, workers: usize) -> Result<Session> {
        let pool = Arc::new(ExecutorPool::new(Arc::clone(&manifest), workers)?);
        Ok(Session {
            pool,
            lease: CoreLease::new(cores),
            cores,
            manifest,
            profiles: ProfileStore::new(),
        })
    }

    /// Online latency profiles observed by this session.
    pub fn profiles(&self) -> &ProfileStore {
        &self.profiles
    }

    pub fn cores(&self) -> usize {
        self.cores
    }

    pub fn manifest(&self) -> &Arc<Manifest> {
        &self.manifest
    }

    pub fn pool(&self) -> &Arc<ExecutorPool> {
        &self.pool
    }

    /// Pre-compile models on the executor workers.
    pub fn warmup(&self, models: &[&str]) -> Result<()> {
        self.pool.warmup(models)
    }

    /// Single-job inference using the whole core budget (the baseline the
    /// paper compares against).
    pub fn run(&self, model: &str, inputs: Vec<Tensor>) -> Result<Vec<Tensor>> {
        let _all = self.lease.acquire(self.cores);
        let res = self.pool.run(model, inputs)?;
        self.profiles.observe(model, res.exec_time);
        Ok(res.outputs)
    }

    /// Parallel inference over independent job parts (the paper's `prun`).
    pub fn prun(&self, parts: Vec<JobPart>, opts: PrunOptions) -> Result<PrunOutcome> {
        if parts.is_empty() {
            return Ok(PrunOutcome {
                outputs: Vec::new(),
                reports: Vec::new(),
                allocation: Vec::new(),
                wall: Duration::ZERO,
            });
        }
        let sizes = part_sizes(&parts);
        let w = match opts.weights {
            WeightSource::Size => weights(&sizes),
            WeightSource::Profiled => {
                let keyed: Vec<(&str, usize)> = parts
                    .iter()
                    .zip(sizes.iter())
                    .map(|(p, &s)| (p.model.as_str(), s))
                    .collect();
                self.profiles.weights(&keyed)
            }
        };
        let allocation = allocate_weighted(&w, self.cores, opts.policy);
        let t0 = Instant::now();

        let k = parts.len();
        // Model names survive the move into worker threads (needed for
        // error context and profile observations).
        let models: Vec<String> = parts.iter().map(|p| p.model.clone()).collect();
        let mut outputs: Vec<Option<Vec<Tensor>>> = (0..k).map(|_| None).collect();
        let mut reports: Vec<Option<PartReport>> = (0..k).map(|_| None).collect();

        std::thread::scope(|scope| -> Result<()> {
            let mut handles = Vec::with_capacity(k);
            // Parts are *moved* into their worker threads — the input
            // tensors are handed to the executor without copying (§Perf:
            // an OCR crop is ~120 KiB; cloning per part dominated the
            // dispatch overhead before this).
            for (part, &threads) in parts.into_iter().zip(allocation.iter()) {
                let pool = Arc::clone(&self.pool);
                let lease = &self.lease;
                handles.push(scope.spawn(move || -> Result<(Vec<Tensor>, PartReport)> {
                    // One worker thread per job part, as in the paper; the
                    // thread leases its allocation before running.
                    let guard = lease.acquire(threads);
                    let queue = t0.elapsed();
                    let model = part.model;
                    let res = pool
                        .run(&model, part.inputs)
                        .with_context(|| format!("part model {model}"))?;
                    drop(guard);
                    Ok((res.outputs, PartReport { threads, queue, exec: res.exec_time }))
                }));
            }
            for (i, h) in handles.into_iter().enumerate() {
                let (out, rep) = h
                    .join()
                    .map_err(|_| anyhow::anyhow!("prun worker {i} panicked"))??;
                self.profiles.observe(&models[i], rep.exec);
                outputs[i] = Some(out);
                reports[i] = Some(rep);
            }
            Ok(())
        })?;

        Ok(PrunOutcome {
            outputs: outputs.into_iter().map(Option::unwrap).collect(),
            reports: reports.into_iter().map(Option::unwrap).collect(),
            allocation,
            wall: t0.elapsed(),
        })
    }
}

//! The `prun` inference session — the paper's extended API (§3.2).
//!
//! `Session::run` mirrors OnnxRuntime's `InferenceSession.run`;
//! `Session::prun` accepts a [`PrunRequest`] — a *list* of job parts
//! plus allocation tuning — sizes a private worker allocation for each
//! part via [`allocator`](super::allocator), and executes them through
//! the central [`scheduler`](super::sched). The session is a thin
//! client: one [`PartTask`] per part, waited through channel handles;
//! no OS threads are spawned per call (the seed's thread-per-part +
//! blocking-lease topology is gone).
//!
//! The non-blocking half is the unified submission API: `Session`
//! implements [`InferenceService`] (`submit(PrunRequest, RequestCtx) ->
//! SubmitTicket<TaskDone>`), and every request-shaped value — budget,
//! cancellation token, priority, profiled cost hint — arrives through
//! the one [`RequestCtx`] minted at the ingress (or a per-part ctx
//! riding on a [`JobPart`], for batches whose parts answer different
//! requests).
//!
//! Core accounting: a part allocated `c_i` threads occupies `c_i` entries
//! of the scheduler's core ledger while it executes, so concurrent parts
//! never oversubscribe the machine, and an allocation with `Σc_i > C`
//! degrades to the paper's "run some parts after others" — now with
//! bounded backfill instead of strict FIFO (see `engine::sched`).
//!
//! On this testbed the PJRT CPU executable is single-threaded, so `c_i`
//! does not change a *real* part's execution speed — the ledger models
//! occupancy only; the calibrated simulator (crate::simcpu) models the
//! intra-op scaling the paper measured on its 16-core VM (DESIGN.md §4).

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::runtime::{CancelToken, ExecutorPool, Manifest, Tensor};

use super::adaptive::{AdaptiveConfig, AdaptivePolicy};
use super::allocator::{allocate, AllocPolicy, Allocation, PartWeights};
use super::api::{InferenceService, PrunRequest, SubmitError, SubmitTicket};
use super::ctx::RequestCtx;
use super::ledger::{ClassAffinity, CoreMap};
use super::part::{part_sizes, JobPart};
use super::profile::ProfileStore;
use super::sched::{PartTask, SchedConfig, Scheduler, SubmitHandle, TaskDone, TaskRunner};

/// Where part weights come from (paper §3.1: size by default; §6 future
/// work: measured-latency profiles — implemented in engine::profile).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum WeightSource {
    #[default]
    Size,
    Profiled,
}

impl Default for AllocPolicy {
    fn default() -> Self {
        AllocPolicy::PrunDef
    }
}

/// Per-part timing report.
#[derive(Debug, Clone)]
pub struct PartReport {
    pub threads: usize,
    /// time from submission until the scheduler admitted the part
    pub queue: Duration,
    /// pure execute time inside the worker
    pub exec: Duration,
    /// executor worker the part ran on
    pub worker: usize,
    /// true if the part was admitted by backfill (bypassed a waiting
    /// larger part that did not fit in the idle cores)
    pub backfilled: bool,
}

/// Result of a `prun` call.
#[derive(Debug)]
pub struct PrunOutcome {
    /// per-part model outputs, input order
    pub outputs: Vec<Vec<Tensor>>,
    pub reports: Vec<PartReport>,
    pub allocation: Allocation,
    pub wall: Duration,
}

/// In-flight `prun` job: one scheduler handle per part. `wait` assembles
/// the classic [`PrunOutcome`]; `wait_each` yields per-part results so
/// one cancelled part does not clobber its siblings. **Dropping the
/// handle cancels every part still outstanding** — abandoned work must
/// not keep burning ledger cores (call `wait`/`wait_each` to consume
/// results, or `cancel` to give up explicitly).
pub struct PrunHandle {
    handles: Vec<SubmitHandle>,
    models: Vec<String>,
    allocation: Allocation,
    t0: Instant,
    profiles: Arc<ProfileStore>,
}

impl PrunHandle {
    /// Listing-1 thread allocation chosen for the parts (typed: per-part
    /// counts in input order plus the per-class footprint).
    pub fn allocation(&self) -> &Allocation {
        &self.allocation
    }

    /// Cancel every part of this job: queued parts are rejected without
    /// taking cores; running parts stop at the executor's next token
    /// poll. `wait`/`wait_each` then observe `SchedError::Cancelled`.
    pub fn cancel(&self) {
        for h in &self.handles {
            h.cancel();
        }
    }

    /// Block until every part completes; outputs come back in input
    /// order. If any part failed, returns the first error — after all
    /// parts have finished, so no work is left dangling.
    pub fn wait(mut self) -> Result<PrunOutcome> {
        let handles = std::mem::take(&mut self.handles);
        let models = std::mem::take(&mut self.models);
        let allocation = std::mem::take(&mut self.allocation);
        let (t0, profiles) = (self.t0, Arc::clone(&self.profiles));
        let k = handles.len();
        let mut outputs: Vec<Vec<Tensor>> = Vec::with_capacity(k);
        let mut reports: Vec<PartReport> = Vec::with_capacity(k);
        let mut first_err: Option<anyhow::Error> = None;
        for (i, h) in handles.into_iter().enumerate() {
            let token = h.cancel_token();
            match h.wait() {
                Ok(done) => {
                    // A part whose token fired must not feed the profile
                    // window even when the executor still replied Ok (a
                    // kill racing completion, or an engine returning
                    // truncated timing after an abort): a storm of kills
                    // would drag the windowed p95 down and make
                    // engine::adaptive oversize the next parts.
                    if !token.is_cancelled() {
                        profiles.observe(&models[i], done.exec);
                    }
                    reports.push(PartReport {
                        threads: done.threads,
                        queue: done.queue,
                        exec: done.exec,
                        worker: done.worker,
                        backfilled: done.backfilled,
                    });
                    outputs.push(done.outputs);
                }
                Err(e) => {
                    if first_err.is_none() {
                        first_err =
                            Some(e.context(format!("part {i} model {}", models[i])));
                    }
                }
            }
        }
        if let Some(e) = first_err {
            return Err(e);
        }
        Ok(PrunOutcome { outputs, reports, allocation, wall: t0.elapsed() })
    }

    /// Block until every part completes and return one result per part,
    /// input order. Unlike [`wait`](Self::wait), a failed or cancelled
    /// part yields its own error without discarding sibling outputs —
    /// what a batch of independent serving requests needs.
    pub fn wait_each(self) -> Vec<Result<TaskDone>> {
        self.wait_each_deadline(None)
            .expect("deadline-free wait cannot time out")
    }

    /// [`wait_each`](Self::wait_each) bounded by an absolute deadline:
    /// `None` means the clock struck first — every part still
    /// outstanding (including the one being waited on) has been
    /// cancelled, so its cores return through the scheduler's normal
    /// completion path. The backing store of `SubmitTicket`'s bounded
    /// wait.
    pub(crate) fn wait_each_deadline(
        mut self,
        deadline: Option<Instant>,
    ) -> Option<Vec<Result<TaskDone>>> {
        let handles = std::mem::take(&mut self.handles);
        let models = std::mem::take(&mut self.models);
        let profiles = Arc::clone(&self.profiles);
        let mut out = Vec::with_capacity(handles.len());
        let mut it = handles.into_iter().enumerate();
        while let Some((i, h)) = it.next() {
            let token = h.cancel_token();
            let res = match deadline {
                None => h.wait(),
                Some(d) => {
                    match h.wait_timeout(d.saturating_duration_since(Instant::now())) {
                        Some(r) => r,
                        None => {
                            // out of time: give up on this part and all
                            // its unfinished siblings
                            h.cancel();
                            for (_, rest) in it.by_ref() {
                                rest.cancel();
                            }
                            return None;
                        }
                    }
                }
            };
            match res {
                Ok(done) => {
                    // killed parts must not feed the profile window
                    // (see `wait` above)
                    if !token.is_cancelled() {
                        profiles.observe(&models[i], done.exec);
                    }
                    out.push(Ok(done));
                }
                Err(e) => {
                    out.push(Err(e.context(format!("part {i} model {}", models[i]))));
                }
            }
        }
        Some(out)
    }

    /// Number of parts in this job.
    pub fn len(&self) -> usize {
        self.handles.len()
    }

    pub fn is_empty(&self) -> bool {
        self.handles.is_empty()
    }

    /// The cancellation token of every part, input order.
    pub(crate) fn tokens(&self) -> Vec<CancelToken> {
        self.handles.iter().map(|h| h.cancel_token()).collect()
    }
}

impl Drop for PrunHandle {
    fn drop(&mut self) {
        // An abandoned job must not leave orphaned parts occupying the
        // ledger. `wait`/`wait_each` take the handles out first, so a
        // consumed PrunHandle cancels nothing.
        for h in &self.handles {
            h.cancel();
        }
    }
}

pub struct Session {
    // Field order matters: the scheduler drops (and joins its dispatcher,
    // draining in-flight completions) before the executor pool goes away.
    sched: Arc<Scheduler>,
    pool: Arc<ExecutorPool>,
    cores: CoreMap,
    manifest: Arc<Manifest>,
    profiles: Arc<ProfileStore>,
    /// adaptive mode: profiled core sizing + aging recalibration
    adaptive: Option<Arc<AdaptivePolicy>>,
}

impl Session {
    /// `cores` is the virtual core budget C the allocator divides
    /// (a homogeneous all-Fast map — use [`with_config`](Self::with_config)
    /// with a [`CoreMap`] for heterogeneous machines); `workers` is the
    /// number of real executor threads (usually = the machine's
    /// available parallelism).
    pub fn new(manifest: Arc<Manifest>, cores: usize, workers: usize) -> Result<Session> {
        Session::with_config(
            manifest,
            SchedConfig { cores: CoreMap::homogeneous(cores), ..SchedConfig::default() },
            workers,
        )
    }

    /// Full control over scheduler tuning (aging bound, backfill,
    /// running deadline); static allocation policy.
    pub fn with_config(
        manifest: Arc<Manifest>,
        cfg: SchedConfig,
        workers: usize,
    ) -> Result<Session> {
        Session::build(manifest, cfg, workers, None)
    }

    /// Adaptive mode (`--adaptive`): the session's latency profiles
    /// feed back into scheduling — parts are sized by measured cost
    /// whenever profiles exist (regardless of `PrunRequest::weights`),
    /// and the dispatcher re-derives the aging bound from observed p95
    /// part latency (see `engine::adaptive`).
    pub fn with_adaptive(
        manifest: Arc<Manifest>,
        cfg: SchedConfig,
        workers: usize,
        acfg: AdaptiveConfig,
    ) -> Result<Session> {
        Session::build(manifest, cfg, workers, Some(acfg))
    }

    fn build(
        manifest: Arc<Manifest>,
        cfg: SchedConfig,
        workers: usize,
        acfg: Option<AdaptiveConfig>,
    ) -> Result<Session> {
        let pool = Arc::new(ExecutorPool::new(Arc::clone(&manifest), workers)?);
        let runner: Arc<dyn TaskRunner> = Arc::clone(&pool) as Arc<dyn TaskRunner>;
        let profiles = Arc::new(ProfileStore::new());
        // An explicitly requested adaptive config wins; otherwise honor
        // a policy the caller pre-wired into the SchedConfig itself.
        let adaptive = match acfg {
            Some(a) => Some(Arc::new(AdaptivePolicy::new(Arc::clone(&profiles), a))),
            None => cfg.adaptive.clone(),
        };
        let cores = cfg.cores;
        let sched =
            Scheduler::start(SchedConfig { adaptive: adaptive.clone(), ..cfg }, runner);
        Ok(Session { sched, pool, cores, manifest, profiles, adaptive })
    }

    /// Online latency profiles observed by this session.
    pub fn profiles(&self) -> &ProfileStore {
        &self.profiles
    }

    /// The adaptive policy, when the session runs in adaptive mode.
    pub fn adaptive(&self) -> Option<&Arc<AdaptivePolicy>> {
        self.adaptive.as_ref()
    }

    /// Total virtual core budget C (all classes).
    pub fn cores(&self) -> usize {
        self.cores.total()
    }

    /// The machine's core-class inventory this session schedules over.
    pub fn core_map(&self) -> CoreMap {
        self.cores
    }

    pub fn manifest(&self) -> &Arc<Manifest> {
        &self.manifest
    }

    pub fn pool(&self) -> &Arc<ExecutorPool> {
        &self.pool
    }

    /// The central core-aware scheduler all execution flows through.
    pub fn scheduler(&self) -> &Arc<Scheduler> {
        &self.sched
    }

    /// Pre-compile models on the executor workers.
    pub fn warmup(&self, models: &[&str]) -> Result<()> {
        self.pool.warmup(models)
    }

    /// Single-job inference using the whole core budget (the baseline the
    /// paper compares against). Routed through the scheduler so it, too,
    /// respects the core ledger against concurrent `prun` jobs.
    pub fn run(&self, model: &str, inputs: Vec<Tensor>) -> Result<Vec<Tensor>> {
        self.run_with(model, inputs, &RequestCtx::new())
    }

    /// [`run`](Self::run) on behalf of a serving request: the ctx's
    /// token, budget, priority and cost hint travel into the model
    /// invocation, so a timed-out or cancelled request stops at the
    /// scheduler instead of running unbounded. (Equivalent to
    /// `submit(PrunRequest::single(..), ctx).wait()` — a lone part is
    /// allocated the whole core budget.)
    pub fn run_with(
        &self,
        model: &str,
        inputs: Vec<Tensor>,
        ctx: &RequestCtx,
    ) -> Result<Vec<Tensor>> {
        let mut outputs = self
            .submit(PrunRequest::single(JobPart::new(model, inputs)), ctx.clone())
            .wait()
            .map_err(anyhow::Error::new)?;
        // single part in, single result out
        Ok(outputs.pop().map(|done| done.outputs).unwrap_or_default())
    }

    /// Parallel inference over independent job parts (the paper's
    /// `prun`). Blocking convenience over [`InferenceService::submit`]:
    /// assembles the classic [`PrunOutcome`] with per-part reports and
    /// the Listing-1 allocation.
    pub fn prun(&self, req: PrunRequest, ctx: &RequestCtx) -> Result<PrunOutcome> {
        self.submit_job(req, ctx).wait()
    }

    /// The one submission path every entry point funnels into: sizes
    /// each part's core allocation (Listing 1, adaptive when profiles
    /// exist), stamps every part's task from its ctx (per-part ctx wins
    /// over the job-wide one), fills budget-admission cost hints from
    /// the profile store, and hands everything to the scheduler.
    fn submit_job(&self, req: PrunRequest, ctx: &RequestCtx) -> PrunHandle {
        let t0 = Instant::now();
        let PrunRequest { parts, policy, weights: wsrc, deadline, running_deadline } = req;
        if parts.is_empty() {
            return PrunHandle {
                handles: Vec::new(),
                models: Vec::new(),
                allocation: Allocation::default(),
                t0,
                profiles: Arc::clone(&self.profiles),
            };
        }
        let sizes = part_sizes(&parts);
        // Adaptive mode sizes parts by measured cost whenever profiles
        // exist — the paper's "cores according to expected computational
        // cost" with the profiling phase done online. Otherwise the
        // caller's weight source decides.
        let profiled = self.adaptive.is_some() || wsrc == WeightSource::Profiled;
        let allocation = if profiled {
            let keyed: Vec<(&str, usize)> = parts
                .iter()
                .zip(sizes.iter())
                .map(|(p, &s)| (p.model.as_str(), s))
                .collect();
            let w = self.profiles.weights(&keyed);
            allocate(PartWeights::Measured(&w), &self.cores, policy)
        } else {
            allocate(PartWeights::Sizes(&sizes), &self.cores, policy)
        };
        // Observability: how many parts the profile feedback actually
        // moved away from the size-proportional split. The shadow
        // allocation is skipped while nothing is profiled yet (the
        // weights are then identical by construction).
        if self.adaptive.is_some() && !self.profiles.is_empty() {
            let size_alloc = allocate(PartWeights::Sizes(&sizes), &self.cores, policy);
            let moved = allocation
                .threads()
                .iter()
                .zip(size_alloc.threads().iter())
                .filter(|(a, b)| a != b)
                .count() as u64;
            self.sched.note_adaptive_resizes(moved);
        }
        let deadline = deadline.map(|d| t0 + d);
        let models: Vec<String> = parts.iter().map(|p| p.model.clone()).collect();
        // Parts are *moved* into their tasks — the input tensors are
        // handed to the executor without copying (§Perf: an OCR crop is
        // ~120 KiB; cloning per part dominated dispatch overhead).
        let handles: Vec<SubmitHandle> = parts
            .into_iter()
            .zip(allocation.threads().to_vec())
            .map(|(part, threads)| {
                let JobPart { model, inputs, ctx: part_ctx } = part;
                // Per-part ctx wins over the job-wide one: each part of
                // a serving batch answers its own request, and its own
                // clock/token/priority is the one the client is
                // watching.
                let mut task = PartTask::new(model, inputs, threads)
                    .with_ctx(part_ctx.as_ref().unwrap_or(ctx));
                task.deadline = deadline;
                task.running_deadline = running_deadline;
                // Class placement: a ctx that stayed class-blind defers
                // to the online profiles — measured hogs keep off the
                // Fast cores, measured latency-critical models get them
                // (inert on a homogeneous CoreMap).
                if task.affinity == ClassAffinity::Any {
                    task.affinity = self.profiles.suggest_affinity(&task.model);
                }
                // Budget-aware admission: when the request is budgeted
                // but its ingress supplied no cost hint, consult the
                // online profiles — a model whose trusted p95 already
                // exceeds the remaining budget is rejected at submit.
                if task.budget.is_some() && task.cost_hint.is_none() {
                    task.cost_hint = self.profiles.trusted_cost(&task.model);
                }
                self.sched.submit(task)
            })
            .collect();
        PrunHandle {
            handles,
            models,
            allocation,
            t0,
            profiles: Arc::clone(&self.profiles),
        }
    }
}

impl InferenceService for Session {
    type Request = PrunRequest;
    type Response = TaskDone;

    /// Submit a `prun` job on behalf of `ctx`; the ticket settles one
    /// [`TaskDone`] per part, input order, with typed [`SubmitError`]s.
    fn submit(&self, req: PrunRequest, ctx: RequestCtx) -> SubmitTicket<TaskDone> {
        let handle = self.submit_job(req, &ctx);
        let allocation = handle.allocation().clone();
        let n = handle.len();
        let mut tokens = handle.tokens();
        tokens.push(ctx.token());
        SubmitTicket::pending(
            ctx,
            allocation,
            tokens,
            n,
            Box::new(move |deadline| {
                handle.wait_each_deadline(deadline).map(|rs| {
                    rs.into_iter()
                        .map(|r| r.map_err(|e| SubmitError::classify(&e)))
                        .collect()
                })
            }),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::ledger::CoreGrant;
    use crate::engine::sched::{SchedConfig, Scheduler, TaskRunner};
    use crate::runtime::{ExecResult, ReplyFn};

    /// A runner that replies `Ok` with a *truncated* exec time when its
    /// token fires mid-run — modelling an engine that aborts but still
    /// reports partial timing. The profile guard must keep such samples
    /// out of the window, where a storm of kills would drag the p95
    /// down and make adaptive sizing oversize the next parts.
    struct TruncatingRunner;

    impl TaskRunner for TruncatingRunner {
        fn workers(&self) -> usize {
            1
        }

        fn run_on(
            &self,
            worker: usize,
            _model: &str,
            _inputs: Vec<Tensor>,
            _grant: CoreGrant,
            cancel: CancelToken,
            reply: ReplyFn,
        ) {
            std::thread::spawn(move || {
                let mut slices = 0u64;
                for _ in 0..200 {
                    if cancel.is_cancelled() {
                        break;
                    }
                    std::thread::sleep(Duration::from_millis(1));
                    slices += 1;
                }
                reply(Ok(ExecResult {
                    outputs: Vec::new(),
                    exec_time: Duration::from_millis(slices),
                    worker,
                }));
            });
        }
    }

    fn handle_over(
        sched: &Scheduler,
        token: CancelToken,
        profiles: &Arc<ProfileStore>,
    ) -> PrunHandle {
        let h = sched.submit(PartTask::new("m", Vec::new(), 1).with_cancel(token));
        PrunHandle {
            handles: vec![h],
            models: vec!["m".to_string()],
            allocation: Allocation::of(vec![1], &CoreMap::homogeneous(2)),
            t0: Instant::now(),
            profiles: Arc::clone(profiles),
        }
    }

    #[test]
    fn killed_parts_do_not_feed_the_profile_window() {
        let sched = Scheduler::start(
            SchedConfig { cores: CoreMap::homogeneous(2), ..Default::default() },
            Arc::new(TruncatingRunner),
        );
        let profiles = Arc::new(ProfileStore::new());
        let token = CancelToken::new();
        let handle = handle_over(&sched, token.clone(), &profiles);
        std::thread::sleep(Duration::from_millis(15)); // admitted, running
        token.cancel(); // the kill lands mid-run
        let results = handle.wait_each();
        assert_eq!(results.len(), 1);
        // this runner replies Ok with truncated timing even when killed
        assert!(results[0].is_ok(), "TruncatingRunner always replies Ok");
        assert!(
            profiles.is_empty(),
            "killed part leaked its truncated latency into the profiles"
        );
    }

    #[test]
    fn surviving_parts_still_observe() {
        let sched = Scheduler::start(
            SchedConfig { cores: CoreMap::homogeneous(2), ..Default::default() },
            Arc::new(TruncatingRunner),
        );
        let profiles = Arc::new(ProfileStore::new());
        let handle = handle_over(&sched, CancelToken::new(), &profiles);
        let outcome = handle.wait().expect("uncancelled part completes");
        assert_eq!(outcome.outputs.len(), 1);
        assert_eq!(profiles.len(), 1, "surviving part must be profiled");
        assert_eq!(profiles.stats("m").unwrap().samples_total, 1);
    }
}

//! The paper's thread-allocation algorithm (Listing 1) and the two
//! baseline policies it is evaluated against (§4.1).
//!
//! Given `k` job parts with sizes `s_i` and `C` cores, `prun-def` assigns
//! relative weight `w_i = s_i / Σs` and `c_i = max(1, floor(w_i * C))`
//! cores, then distributes any cores left by the flooring one-by-one to
//! the parts with the largest unallocated remainder `w_i*C - c_i`
//! (round-robin in descending-remainder order, exactly as the paper's
//! C++ listing does).
//!
//! `prun-1` gives every part one thread; `prun-eq` gives every part an
//! equal share `max(1, floor(C/k))`. (The paper's §4.1 prose writes
//! `⌊k/C⌋` for prun-eq — an obvious transposition; equal *cores per
//! input* is `⌊C/k⌋`, which is what we implement.)

/// Thread-allocation policy for `prun`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum AllocPolicy {
    /// Paper Listing 1: size-proportional with remainder distribution.
    PrunDef,
    /// One worker thread per job part.
    PrunOne,
    /// Equal share per part: `max(1, floor(C/k))`.
    PrunEq,
}

impl AllocPolicy {
    pub fn name(&self) -> &'static str {
        match self {
            AllocPolicy::PrunDef => "prun-def",
            AllocPolicy::PrunOne => "prun-1",
            AllocPolicy::PrunEq => "prun-eq",
        }
    }

    pub fn parse(s: &str) -> Option<AllocPolicy> {
        match s {
            "prun-def" | "def" => Some(AllocPolicy::PrunDef),
            "prun-1" | "one" => Some(AllocPolicy::PrunOne),
            "prun-eq" | "eq" => Some(AllocPolicy::PrunEq),
            _ => None,
        }
    }
}

/// Allocate worker threads to job parts of the given `sizes`.
///
/// Faithful port of the paper's Listing 1 for [`AllocPolicy::PrunDef`].
/// Returns one thread count per part (same order as `sizes`).
///
/// Invariants (property-tested in `tests/prop_allocator.rs`):
/// - every part gets >= 1 thread;
/// - when `k <= C`, prun-def allocates exactly `C` threads in total;
/// - when `k > C`, every part gets exactly 1 thread;
/// - a part never gets fewer threads than a smaller part.
pub fn allocate(sizes: &[usize], num_cores: usize, policy: AllocPolicy) -> Vec<usize> {
    allocate_weighted(&weights(sizes), num_cores, policy)
}

/// Listing-1 allocation from explicit relative weights (must sum to ~1).
/// `allocate` derives weights from input sizes (the paper's default);
/// the profiled strategy (engine::profile, paper §6 future work) feeds
/// measured-latency weights through this same code path.
pub fn allocate_weighted(w: &[f64], num_cores: usize, policy: AllocPolicy) -> Vec<usize> {
    assert!(num_cores >= 1, "need at least one core");
    let k = w.len();
    if k == 0 {
        return Vec::new();
    }
    match policy {
        AllocPolicy::PrunOne => vec![1; k],
        AllocPolicy::PrunEq => vec![std::cmp::max(1, num_cores / k); k],
        AllocPolicy::PrunDef => allocate_listing1(w, num_cores),
    }
}

fn allocate_listing1(w: &[f64], num_cores: usize) -> Vec<usize> {
    let num_inputs = w.len();
    let mut thread_allocation = Vec::with_capacity(num_inputs);
    // (index, unallocated weight) — only populated when k <= C, as in the
    // paper listing.
    let mut unallocated_weight: Vec<(usize, f64)> = Vec::new();
    let mut allocated_cores = 0usize;

    for (index, &w_i) in w.iter().enumerate() {
        let mut num_threads_to_use = 1usize;
        if num_inputs <= num_cores {
            num_threads_to_use = (w_i * num_cores as f64).floor() as usize;
            // this may happen due to flooring
            if num_threads_to_use < 1 {
                num_threads_to_use = 1;
            }
            unallocated_weight
                .push((index, w_i * num_cores as f64 - num_threads_to_use as f64));
        }
        thread_allocation.push(num_threads_to_use);
        allocated_cores += num_threads_to_use;
    }

    if allocated_cores < num_cores && !unallocated_weight.is_empty() {
        // sort in decreasing order of unallocated weight (stable: ties keep
        // input order, matching std::sort-with-comparator determinism needs)
        unallocated_weight.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        let mut next_to_adjust = 0usize;
        while allocated_cores < num_cores {
            let index = unallocated_weight[next_to_adjust % num_inputs].0;
            thread_allocation[index] += 1;
            allocated_cores += 1;
            next_to_adjust += 1;
        }
    }
    thread_allocation
}

/// The relative weights `w_i` used by prun-def (exported for reporting —
/// paper Fig. 8 plots the threads given to the long sequence).
pub fn weights(sizes: &[usize]) -> Vec<f64> {
    let total: usize = sizes.iter().sum();
    if total == 0 {
        return vec![1.0 / sizes.len().max(1) as f64; sizes.len()];
    }
    sizes.iter().map(|&s| s as f64 / total as f64).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_part_gets_all_cores() {
        assert_eq!(allocate(&[100], 16, AllocPolicy::PrunDef), vec![16]);
    }

    #[test]
    fn equal_sizes_split_evenly() {
        assert_eq!(allocate(&[50, 50], 16, AllocPolicy::PrunDef), vec![8, 8]);
        assert_eq!(allocate(&[10, 10, 10, 10], 16, AllocPolicy::PrunDef), vec![4, 4, 4, 4]);
    }

    #[test]
    fn proportional_split() {
        // w = [0.75, 0.25], C=16 -> floor: [12, 4], no remainder
        assert_eq!(allocate(&[300, 100], 16, AllocPolicy::PrunDef), vec![12, 4]);
    }

    #[test]
    fn remainder_goes_to_largest_fraction() {
        // w = [0.5, 0.3, 0.2] * 10 -> floor [5, 3, 2] -> exact
        assert_eq!(allocate(&[5, 3, 2], 10, AllocPolicy::PrunDef), vec![5, 3, 2]);
        // w*16 = [8.533, 4.266, 3.2] -> floor [8, 4, 3] = 15, remainder
        // fractions [0.533, 0.266, 0.2] -> part 0 gets the spare core.
        assert_eq!(allocate(&[8, 4, 3], 16, AllocPolicy::PrunDef), vec![9, 4, 3]);
    }

    #[test]
    fn paper_fig8_long_short_allocations() {
        // 1 long (256 tokens) + X short (16 tokens): the long sequence's
        // thread count decreases as shorts join (paper Fig. 8 curve).
        let c = 16;
        let t0 = allocate(&[256], c, AllocPolicy::PrunDef)[0];
        assert_eq!(t0, 16);
        let t3 = allocate(&[256, 16, 16, 16], c, AllocPolicy::PrunDef)[0];
        let t8 = allocate(&[256, 16, 16, 16, 16, 16, 16, 16, 16], c, AllocPolicy::PrunDef)[0];
        assert!(t0 > t3 && t3 > t8, "{t0} {t3} {t8}");
        // with 3 shorts: w_long = 256/304, floor(0.842*16)=13
        assert_eq!(t3, 13);
    }

    #[test]
    fn more_parts_than_cores_gives_one_each() {
        let sizes: Vec<usize> = (1..=20).collect();
        let alloc = allocate(&sizes, 16, AllocPolicy::PrunDef);
        assert!(alloc.iter().all(|&c| c == 1));
    }

    #[test]
    fn tiny_parts_clamped_to_one() {
        // w*16 < 1 for the small parts
        let alloc = allocate(&[1000, 1, 1, 1], 16, AllocPolicy::PrunDef);
        assert!(alloc[1] >= 1 && alloc[2] >= 1 && alloc[3] >= 1);
        assert!(alloc[0] >= 12);
    }

    #[test]
    fn prun_one_policy() {
        assert_eq!(allocate(&[5, 10, 20], 16, AllocPolicy::PrunOne), vec![1, 1, 1]);
    }

    #[test]
    fn prun_eq_policy() {
        assert_eq!(allocate(&[5, 10, 20], 16, AllocPolicy::PrunEq), vec![5, 5, 5]);
        // k > C: still at least one each
        let alloc = allocate(&[1; 20], 16, AllocPolicy::PrunEq);
        assert!(alloc.iter().all(|&c| c == 1));
    }

    #[test]
    fn zero_sizes_degenerate_to_equal() {
        assert_eq!(allocate(&[0, 0], 8, AllocPolicy::PrunDef), vec![4, 4]);
    }

    #[test]
    fn empty_input() {
        assert!(allocate(&[], 16, AllocPolicy::PrunDef).is_empty());
    }

    #[test]
    fn policy_parse_names() {
        assert_eq!(AllocPolicy::parse("prun-def"), Some(AllocPolicy::PrunDef));
        assert_eq!(AllocPolicy::parse("one"), Some(AllocPolicy::PrunOne));
        assert_eq!(AllocPolicy::parse("prun-eq"), Some(AllocPolicy::PrunEq));
        assert_eq!(AllocPolicy::parse("nope"), None);
        assert_eq!(AllocPolicy::PrunDef.name(), "prun-def");
    }

    #[test]
    fn weights_sum_to_one() {
        let w = weights(&[1, 2, 3]);
        assert!((w.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!((w[2] - 0.5).abs() < 1e-12);
    }

    #[test]
    fn allocate_weighted_matches_size_path() {
        let sizes = [300usize, 100, 50];
        let via_sizes = allocate(&sizes, 16, AllocPolicy::PrunDef);
        let via_weights = allocate_weighted(&weights(&sizes), 16, AllocPolicy::PrunDef);
        assert_eq!(via_sizes, via_weights);
    }

    #[test]
    fn allocate_weighted_profiled_weights() {
        // profiled weights can diverge from sizes: 90/10 split on 16
        // floors [14, 1]; the leftover core goes to the larger remainder
        // (0.6 for part 1 vs 0.4 for part 0) per Listing 1.
        let alloc = allocate_weighted(&[0.9, 0.1], 16, AllocPolicy::PrunDef);
        assert_eq!(alloc, vec![14, 2]);
    }
}

//! The paper's thread-allocation algorithm (Listing 1) and the two
//! baseline policies it is evaluated against (§4.1).
//!
//! Given `k` job parts with sizes `s_i` and a [`CoreMap`] with `C`
//! total cores, `prun-def` assigns relative weight `w_i = s_i / Σs` and
//! `c_i = max(1, floor(w_i * C))` cores, then distributes any cores
//! left by the flooring one-by-one to the parts with the largest
//! unallocated remainder `w_i*C - c_i` (round-robin in
//! descending-remainder order, exactly as the paper's C++ listing
//! does).
//!
//! `prun-1` gives every part one thread; `prun-eq` gives every part an
//! equal share `max(1, floor(C/k))`. (The paper's §4.1 prose writes
//! `⌊k/C⌋` for prun-eq — an obvious transposition; equal *cores per
//! input* is `⌊C/k⌋`, which is what we implement.)
//!
//! The single entry point is [`allocate`], which takes the part
//! demand as [`PartWeights`] (raw sizes, the paper's default, or
//! measured-latency weights from `engine::profile`) and returns a
//! typed [`Allocation`] — per-part thread counts plus the per-class
//! footprint of the plan on the machine's [`CoreMap`].

use super::ledger::{CoreClass, CoreMap};

/// Thread-allocation policy for `prun`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum AllocPolicy {
    /// Paper Listing 1: size-proportional with remainder distribution.
    PrunDef,
    /// One worker thread per job part.
    PrunOne,
    /// Equal share per part: `max(1, floor(C/k))`.
    PrunEq,
}

impl AllocPolicy {
    pub fn name(&self) -> &'static str {
        match self {
            AllocPolicy::PrunDef => "prun-def",
            AllocPolicy::PrunOne => "prun-1",
            AllocPolicy::PrunEq => "prun-eq",
        }
    }

    pub fn parse(s: &str) -> Option<AllocPolicy> {
        match s {
            "prun-def" | "def" => Some(AllocPolicy::PrunDef),
            "prun-1" | "one" => Some(AllocPolicy::PrunOne),
            "prun-eq" | "eq" => Some(AllocPolicy::PrunEq),
            _ => None,
        }
    }
}

/// The per-part demand [`allocate`] divides the core budget by.
///
/// `Sizes` is the paper's default — weights are derived from input
/// sizes (`w_i = s_i / Σs`). `Measured` feeds profiled-latency weights
/// (paper §6 future work, `engine::profile::ProfileStore::weights`)
/// through the identical Listing-1 code path; they must sum to ~1.
#[derive(Debug, Clone, Copy)]
pub enum PartWeights<'a> {
    Sizes(&'a [usize]),
    Measured(&'a [f64]),
}

impl PartWeights<'_> {
    fn resolve(&self) -> Vec<f64> {
        match self {
            PartWeights::Sizes(sizes) => weights(sizes),
            PartWeights::Measured(w) => w.to_vec(),
        }
    }
}

/// A typed thread-allocation plan: one thread count per part, plus the
/// plan's first-wave footprint on each core class of the machine.
///
/// `per_class` summarizes what running the first concurrent wave of
/// this plan costs each class under class-blind fast-first packing: the
/// first `min(total_threads, map.total())` threads are charged to Fast
/// until it is full, then to Slow. It is a *capacity* summary (parts
/// may straddle classes in it), not a placement — actual placement is
/// per-task and whole-class, decided by the scheduler's ledger.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Allocation {
    threads: Vec<usize>,
    per_class: [usize; CoreClass::COUNT],
}

impl Allocation {
    /// Build an allocation from explicit per-part thread counts,
    /// computing the per-class footprint against `map`.
    pub fn of(threads: Vec<usize>, map: &CoreMap) -> Allocation {
        let total: usize = threads.iter().sum();
        let mut remaining = total.min(map.total());
        let mut per_class = [0usize; CoreClass::COUNT];
        for class in CoreClass::ALL {
            let take = remaining.min(map.count(class));
            per_class[class.index()] = take;
            remaining -= take;
        }
        Allocation { threads, per_class }
    }

    /// Per-part thread counts, same order as the input parts.
    pub fn threads(&self) -> &[usize] {
        &self.threads
    }

    /// Consume the plan, keeping only the per-part thread counts.
    pub fn into_threads(self) -> Vec<usize> {
        self.threads
    }

    /// First-wave cores charged to `class` (see type docs).
    pub fn class_count(&self, class: CoreClass) -> usize {
        self.per_class[class.index()]
    }

    /// First-wave footprint per class, indexed by [`CoreClass::index`].
    pub fn per_class(&self) -> [usize; CoreClass::COUNT] {
        self.per_class
    }

    /// Total threads across all parts (may exceed the map's core count;
    /// excess waves queue).
    pub fn total_threads(&self) -> usize {
        self.threads.iter().sum()
    }

    pub fn len(&self) -> usize {
        self.threads.len()
    }

    pub fn is_empty(&self) -> bool {
        self.threads.is_empty()
    }
}

/// Allocate worker threads to job parts.
///
/// Faithful port of the paper's Listing 1 for [`AllocPolicy::PrunDef`],
/// dividing `map.total()` cores across the parts described by `parts`.
///
/// Invariants (property-tested in `tests/prop_allocator.rs`):
/// - every part gets >= 1 thread;
/// - when `k <= C`, prun-def allocates exactly `C` threads in total;
/// - when `k > C`, every part gets exactly 1 thread;
/// - a part never gets fewer threads than a smaller part;
/// - the per-class footprint never exceeds any class's core count and
///   sums to `min(total_threads, C)`.
pub fn allocate(parts: PartWeights<'_>, map: &CoreMap, policy: AllocPolicy) -> Allocation {
    let num_cores = map.total();
    assert!(num_cores >= 1, "need at least one core");
    let w = parts.resolve();
    let k = w.len();
    let threads = if k == 0 {
        Vec::new()
    } else {
        match policy {
            AllocPolicy::PrunOne => vec![1; k],
            AllocPolicy::PrunEq => vec![std::cmp::max(1, num_cores / k); k],
            AllocPolicy::PrunDef => allocate_listing1(&w, num_cores),
        }
    };
    Allocation::of(threads, map)
}

fn allocate_listing1(w: &[f64], num_cores: usize) -> Vec<usize> {
    let num_inputs = w.len();
    let mut thread_allocation = Vec::with_capacity(num_inputs);
    // (index, unallocated weight) — only populated when k <= C, as in the
    // paper listing.
    let mut unallocated_weight: Vec<(usize, f64)> = Vec::new();
    let mut allocated_cores = 0usize;

    for (index, &w_i) in w.iter().enumerate() {
        let mut num_threads_to_use = 1usize;
        if num_inputs <= num_cores {
            num_threads_to_use = (w_i * num_cores as f64).floor() as usize;
            // this may happen due to flooring
            if num_threads_to_use < 1 {
                num_threads_to_use = 1;
            }
            unallocated_weight
                .push((index, w_i * num_cores as f64 - num_threads_to_use as f64));
        }
        thread_allocation.push(num_threads_to_use);
        allocated_cores += num_threads_to_use;
    }

    if allocated_cores < num_cores && !unallocated_weight.is_empty() {
        // sort in decreasing order of unallocated weight (stable: ties keep
        // input order, matching std::sort-with-comparator determinism needs)
        unallocated_weight.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        let mut next_to_adjust = 0usize;
        while allocated_cores < num_cores {
            let index = unallocated_weight[next_to_adjust % num_inputs].0;
            thread_allocation[index] += 1;
            allocated_cores += 1;
            next_to_adjust += 1;
        }
    }
    thread_allocation
}

/// The relative weights `w_i` used by prun-def. Internal: callers pass
/// sizes via [`PartWeights::Sizes`]; reporting paths inside the crate
/// (paper Fig. 8 plots the threads given to the long sequence) may
/// still inspect the raw weights.
pub(crate) fn weights(sizes: &[usize]) -> Vec<f64> {
    let total: usize = sizes.iter().sum();
    if total == 0 {
        return vec![1.0 / sizes.len().max(1) as f64; sizes.len()];
    }
    sizes.iter().map(|&s| s as f64 / total as f64).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Thread counts for `sizes` on a homogeneous `c`-core map — the
    /// pre-0.5 call shape, used by tests that only care about counts.
    fn alloc(sizes: &[usize], c: usize, policy: AllocPolicy) -> Vec<usize> {
        allocate(PartWeights::Sizes(sizes), &CoreMap::homogeneous(c), policy).into_threads()
    }

    #[test]
    fn single_part_gets_all_cores() {
        assert_eq!(alloc(&[100], 16, AllocPolicy::PrunDef), vec![16]);
    }

    #[test]
    fn equal_sizes_split_evenly() {
        assert_eq!(alloc(&[50, 50], 16, AllocPolicy::PrunDef), vec![8, 8]);
        assert_eq!(alloc(&[10, 10, 10, 10], 16, AllocPolicy::PrunDef), vec![4, 4, 4, 4]);
    }

    #[test]
    fn proportional_split() {
        // w = [0.75, 0.25], C=16 -> floor: [12, 4], no remainder
        assert_eq!(alloc(&[300, 100], 16, AllocPolicy::PrunDef), vec![12, 4]);
    }

    #[test]
    fn remainder_goes_to_largest_fraction() {
        // w = [0.5, 0.3, 0.2] * 10 -> floor [5, 3, 2] -> exact
        assert_eq!(alloc(&[5, 3, 2], 10, AllocPolicy::PrunDef), vec![5, 3, 2]);
        // w*16 = [8.533, 4.266, 3.2] -> floor [8, 4, 3] = 15, remainder
        // fractions [0.533, 0.266, 0.2] -> part 0 gets the spare core.
        assert_eq!(alloc(&[8, 4, 3], 16, AllocPolicy::PrunDef), vec![9, 4, 3]);
    }

    #[test]
    fn paper_fig8_long_short_allocations() {
        // 1 long (256 tokens) + X short (16 tokens): the long sequence's
        // thread count decreases as shorts join (paper Fig. 8 curve).
        let c = 16;
        let t0 = alloc(&[256], c, AllocPolicy::PrunDef)[0];
        assert_eq!(t0, 16);
        let t3 = alloc(&[256, 16, 16, 16], c, AllocPolicy::PrunDef)[0];
        let t8 = alloc(&[256, 16, 16, 16, 16, 16, 16, 16, 16], c, AllocPolicy::PrunDef)[0];
        assert!(t0 > t3 && t3 > t8, "{t0} {t3} {t8}");
        // with 3 shorts: w_long = 256/304, floor(0.842*16)=13
        assert_eq!(t3, 13);
    }

    #[test]
    fn more_parts_than_cores_gives_one_each() {
        let sizes: Vec<usize> = (1..=20).collect();
        let alloc = alloc(&sizes, 16, AllocPolicy::PrunDef);
        assert!(alloc.iter().all(|&c| c == 1));
    }

    #[test]
    fn tiny_parts_clamped_to_one() {
        // w*16 < 1 for the small parts
        let alloc = alloc(&[1000, 1, 1, 1], 16, AllocPolicy::PrunDef);
        assert!(alloc[1] >= 1 && alloc[2] >= 1 && alloc[3] >= 1);
        assert!(alloc[0] >= 12);
    }

    #[test]
    fn prun_one_policy() {
        assert_eq!(alloc(&[5, 10, 20], 16, AllocPolicy::PrunOne), vec![1, 1, 1]);
    }

    #[test]
    fn prun_eq_policy() {
        assert_eq!(alloc(&[5, 10, 20], 16, AllocPolicy::PrunEq), vec![5, 5, 5]);
        // k > C: still at least one each
        let alloc = alloc(&[1; 20], 16, AllocPolicy::PrunEq);
        assert!(alloc.iter().all(|&c| c == 1));
    }

    #[test]
    fn zero_sizes_degenerate_to_equal() {
        assert_eq!(alloc(&[0, 0], 8, AllocPolicy::PrunDef), vec![4, 4]);
    }

    #[test]
    fn empty_input() {
        let a = allocate(PartWeights::Sizes(&[]), &CoreMap::homogeneous(16), AllocPolicy::PrunDef);
        assert!(a.is_empty());
        assert_eq!(a.per_class(), [0, 0]);
    }

    #[test]
    fn policy_parse_names() {
        assert_eq!(AllocPolicy::parse("prun-def"), Some(AllocPolicy::PrunDef));
        assert_eq!(AllocPolicy::parse("one"), Some(AllocPolicy::PrunOne));
        assert_eq!(AllocPolicy::parse("prun-eq"), Some(AllocPolicy::PrunEq));
        assert_eq!(AllocPolicy::parse("nope"), None);
        assert_eq!(AllocPolicy::PrunDef.name(), "prun-def");
    }

    #[test]
    fn weights_sum_to_one() {
        let w = weights(&[1, 2, 3]);
        assert!((w.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!((w[2] - 0.5).abs() < 1e-12);
    }

    #[test]
    fn measured_weights_match_size_path() {
        let sizes = [300usize, 100, 50];
        let map = CoreMap::homogeneous(16);
        let w = weights(&sizes);
        let via_sizes = allocate(PartWeights::Sizes(&sizes), &map, AllocPolicy::PrunDef);
        let via_weights = allocate(PartWeights::Measured(&w), &map, AllocPolicy::PrunDef);
        assert_eq!(via_sizes, via_weights);
    }

    #[test]
    fn measured_profiled_weights() {
        // profiled weights can diverge from sizes: 90/10 split on 16
        // floors [14, 1]; the leftover core goes to the larger remainder
        // (0.6 for part 1 vs 0.4 for part 0) per Listing 1.
        let a = allocate(
            PartWeights::Measured(&[0.9, 0.1]),
            &CoreMap::homogeneous(16),
            AllocPolicy::PrunDef,
        );
        assert_eq!(a.threads(), &[14, 2]);
    }

    #[test]
    fn per_class_footprint_fast_first() {
        // 16 threads on fast=4,slow=12: the first wave charges 4 to
        // Fast and 12 to Slow.
        let map = CoreMap::heterogeneous(4, 12);
        let a = allocate(PartWeights::Sizes(&[100]), &map, AllocPolicy::PrunDef);
        assert_eq!(a.threads(), &[16]);
        assert_eq!(a.per_class(), [4, 12]);
        // Homogeneous: everything lands on Fast.
        let h = allocate(PartWeights::Sizes(&[100]), &CoreMap::homogeneous(16), AllocPolicy::PrunDef);
        assert_eq!(h.per_class(), [16, 0]);
    }

    #[test]
    fn per_class_footprint_caps_at_map_total() {
        // 20 parts x 1 thread on an 8-core map: first wave is 8 cores,
        // the rest queue. Footprint sums to min(total_threads, C).
        let map = CoreMap::heterogeneous(2, 6);
        let a = allocate(PartWeights::Sizes(&[1; 20]), &map, AllocPolicy::PrunOne);
        assert_eq!(a.total_threads(), 20);
        assert_eq!(a.per_class(), [2, 6]);
        assert_eq!(a.class_count(CoreClass::Fast), 2);
        assert_eq!(a.class_count(CoreClass::Slow), 6);
    }
}

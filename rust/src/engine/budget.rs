//! Request budgets: one end-to-end deadline for a whole request.
//!
//! The paper's Listing 1 gives a job *cores* proportional to its
//! expected cost — but a deadline is a resource too, and before this
//! module each layer invented its own: the router waited
//! `--request-timeout-ms`, the batcher waited `max_wait`, and the
//! scheduler applied one global `--deadline-running-ms` that ignored how
//! much of the client's clock the request had already burned upstream.
//!
//! A [`Budget`] is minted once, at the serving edge, when the request
//! arrives (`issued_at`) with the client's total patience (`total`). It
//! then travels *by value* with the request — through the batcher's
//! accumulation queue, into every `PartTask` the request becomes — so
//! every layer charges its wall-clock against the same account:
//!
//! - the batcher's flusher drops a request whose budget died while
//!   accumulating (structured `deadline_rejected` reply, no scheduler
//!   work submitted);
//! - the scheduler's queue sweep rejects a task whose budget expires
//!   while queued ([`SchedError::BudgetExpired`](super::SchedError),
//!   counted as `sched.budget_expired`, cores never taken);
//! - the dispatcher's running sweep arms the in-flight kill clock at
//!   [`Budget::deadline`], so a part launched after `w` ms of upstream
//!   waiting gets a running window of at most `total - w` — never the
//!   full global deadline for a client that is already half out of
//!   patience.
//!
//! `Budget` is a small `Copy` value (an `Instant` + a `Duration`), not a
//! shared handle: layers read the clock, nobody mutates it.

use std::time::{Duration, Instant};

/// The end-to-end deadline account of one request: minted at the
/// serving edge, consumed by every layer the request passes through.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Budget {
    issued_at: Instant,
    total: Duration,
}

impl Budget {
    /// Mint a budget starting now — call this where the request enters
    /// the system, not where it happens to be scheduled.
    pub fn new(total: Duration) -> Budget {
        Budget { issued_at: Instant::now(), total }
    }

    /// Mint a budget whose clock started at an explicit instant (e.g. a
    /// request timestamped at the socket before parsing).
    pub fn starting_at(issued_at: Instant, total: Duration) -> Budget {
        Budget { issued_at, total }
    }

    pub fn issued_at(&self) -> Instant {
        self.issued_at
    }

    pub fn total(&self) -> Duration {
        self.total
    }

    /// The absolute instant the budget runs out. Saturates far into the
    /// future for totals too large for the platform's `Instant` — a
    /// budget that huge means "effectively no deadline", not a panic.
    pub fn deadline(&self) -> Instant {
        self.issued_at
            .checked_add(self.total)
            .unwrap_or_else(|| self.issued_at + Duration::from_secs(86_400 * 365))
    }

    /// Wall-clock the request has consumed since it was minted.
    pub fn elapsed(&self) -> Duration {
        self.issued_at.elapsed()
    }

    /// What is left of the client's patience (zero once expired).
    pub fn remaining(&self) -> Duration {
        self.total.saturating_sub(self.elapsed())
    }

    pub fn expired(&self) -> bool {
        self.elapsed() >= self.total
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fresh_budget_has_full_remainder() {
        let b = Budget::new(Duration::from_secs(10));
        assert!(!b.expired());
        assert!(b.remaining() > Duration::from_secs(9));
        assert_eq!(b.total(), Duration::from_secs(10));
        assert!(b.deadline() > Instant::now());
    }

    #[test]
    fn zero_budget_is_born_expired() {
        let b = Budget::new(Duration::ZERO);
        assert!(b.expired());
        assert_eq!(b.remaining(), Duration::ZERO);
    }

    #[test]
    fn remaining_charges_upstream_wait() {
        // A budget minted 30ms ago with 100ms total has at most 70ms
        // left — the "T - w" the per-part running deadline derives from.
        let b = Budget::starting_at(Instant::now(), Duration::from_millis(100));
        std::thread::sleep(Duration::from_millis(30));
        assert!(!b.expired());
        assert!(b.remaining() <= Duration::from_millis(70));
        assert!(b.elapsed() >= Duration::from_millis(30));
    }

    #[test]
    fn expires_after_total() {
        let b = Budget::new(Duration::from_millis(5));
        std::thread::sleep(Duration::from_millis(10));
        assert!(b.expired());
        assert_eq!(b.remaining(), Duration::ZERO);
        assert!(b.deadline() <= Instant::now());
    }

    #[test]
    fn huge_total_saturates_instead_of_panicking() {
        let b = Budget::new(Duration::MAX);
        assert!(!b.expired());
        assert!(b.deadline() > Instant::now() + Duration::from_secs(86_400));
    }

    #[test]
    fn copies_share_the_clock() {
        let a = Budget::new(Duration::from_millis(50));
        let b = a;
        assert_eq!(a.deadline(), b.deadline());
        assert_eq!(a.issued_at(), b.issued_at());
    }
}
